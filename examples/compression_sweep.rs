//! Compression study: CPD-SGDM across the whole δ-contraction operator
//! zoo (sign / top-k / rand-k / QSGD), against full-precision PD-SGDM and
//! the no-momentum compressed baselines (CHOCO-SGD, DeepSqueeze).
//!
//!     cargo run --release --example compression_sweep
//!
//! Reports, per operator: advertised δ, final loss/accuracy, total MB,
//! and the bytes reduction vs full precision — the practical summary of
//! the paper's §4.2 and Figures 2(c,d)/3.

use pdsgdm::algorithms::Hyper;
use pdsgdm::compress::{self, Compressor};
use pdsgdm::config::{ExperimentConfig, WorkloadConfig};
use pdsgdm::coordinator::{Session, SessionSpec};
use pdsgdm::metrics;
use pdsgdm::optim::LrSchedule;

fn main() -> anyhow::Result<()> {
    let base = || {
        let mut c = ExperimentConfig::default();
        c.workers = 8;
        c.steps = 1200;
        c.eval_every = 100;
        c.seed = 21;
        c.workload = WorkloadConfig::Mlp { n: 4000, dim: 32, classes: 10, hidden: 64, batch: 16 };
        c.hyper = Hyper {
            lr: LrSchedule::paper_cifar(0.1, 1200),
            mu: 0.9,
            weight_decay: 1e-4,
            period: 4,
            gamma: 0.4, // paper's CIFAR-10 consensus step size
        };
        c
    };

    let mut traces = Vec::new();
    let mut rows = Vec::new();

    // Full-precision reference (Algorithm 1).
    let mut cfg = base();
    cfg.algorithm = "pd-sgdm".into();
    let mut session = Session::build(SessionSpec::new(cfg))?;
    session.run_to_stop();
    let full = session.into_trace();
    let full_mb = full.total_comm_mb();
    rows.push((
        "pd-sgdm (full precision)".to_string(),
        1.0,
        full.final_loss(),
        full.final_accuracy(),
        full_mb,
        1.0,
    ));
    traces.push(full);

    // Algorithm 2 with each operator.
    let d_hint = 32 * 64 + 64 + 10 * 64 + 10; // MLP param dim for δ display
    for spec in ["sign", "top0.05", "rand0.05", "qsgd4"] {
        let mut cfg = base();
        cfg.algorithm = "cpd-sgdm".into();
        cfg.compressor = Some(spec.into());
        let mut session = Session::build(SessionSpec::new(cfg))?;
        session.run_to_stop();
        let trace = session.into_trace();
        let delta = compress::parse(spec).unwrap().delta(d_hint);
        let ratio = full_mb / trace.total_comm_mb();
        rows.push((
            format!("cpd-sgdm + {spec}"),
            delta,
            trace.final_loss(),
            trace.final_accuracy(),
            trace.total_comm_mb(),
            ratio,
        ));
        traces.push(trace);
    }

    // No-momentum compressed baselines for context.
    for algo in ["choco-sgd", "deepsqueeze"] {
        let mut cfg = base();
        cfg.algorithm = algo.into();
        cfg.compressor = Some("sign".into());
        let mut session = Session::build(SessionSpec::new(cfg))?;
        session.run_to_stop();
        let trace = session.into_trace();
        let ratio = full_mb / trace.total_comm_mb();
        rows.push((
            format!("{algo} + sign"),
            compress::Sign.delta(d_hint),
            trace.final_loss(),
            trace.final_accuracy(),
            trace.total_comm_mb(),
            ratio,
        ));
        traces.push(trace);
    }

    println!(
        "\n{:<28} {:>10} {:>11} {:>9} {:>10} {:>10}",
        "run", "delta", "final_loss", "acc", "MB", "MB_saving"
    );
    for (name, delta, loss, acc, mb, ratio) in &rows {
        println!("{name:<28} {delta:>10.4} {loss:>11.4} {acc:>9.3} {mb:>10.2} {ratio:>9.1}x");
    }
    metrics::write_csv(std::path::Path::new("bench_out/compression_sweep.csv"), &traces)?;
    println!("\ntraces -> bench_out/compression_sweep.csv");
    Ok(())
}
