//! End-to-end driver: the full three-layer stack on a real workload.
//!
//!     make artifacts          # once (python AOT -> HLO text)
//!     cargo run --release --example e2e_transformer [model] [steps]
//!
//! L3 (this binary, pure Rust) runs PD-SGDM over 8 ring workers; each
//! gradient is produced by executing the AOT-compiled L2 transformer
//! (whose MLP matmuls are the L1 Pallas kernel) on the PJRT CPU client;
//! the data is a synthetic Markov corpus whose per-token entropy lower-
//! bounds the achievable loss. The loss curve is logged to
//! `bench_out/e2e_<model>.csv` and summarized in EXPERIMENTS.md.
//!
//! Column semantics: since the Session port, the CSV's `sim_seconds`
//! column is the α–β *simulated* time (the same meaning it has in every
//! other trace this repo writes — it used to hold measured wall-clock
//! here). Real elapsed seconds are printed per eval line (`[12.3s]`)
//! and in the final tokens/s summary.
//!
//! Defaults: model = "e2e" (d = 3.45M), steps = 300. Python is NOT on
//! the training path — delete it after `make artifacts` and this still
//! runs.

use std::time::Instant;

use pdsgdm::algorithms::{Hyper, PdSgdm, StepStats};
use pdsgdm::comm::{CostModel, Network};
use pdsgdm::coordinator::{Observer, Session, StopCondition};
use pdsgdm::data::MarkovCorpus;
use pdsgdm::grad::GradientSource;
use pdsgdm::metrics::{self, TracePoint};
use pdsgdm::optim::LrSchedule;
use pdsgdm::runtime::{Runtime, XlaGradSource};
use pdsgdm::topology::{self, Topology, Weighting};

/// Streams the e2e progress line at every evaluation — the custom-
/// observer version of what this example used to hardcode in its loop.
struct E2eProgress {
    t_start: Instant,
    last_train_loss: f64,
}

impl Observer for E2eProgress {
    fn on_step(&mut self, _t: u64, stats: &StepStats) {
        self.last_train_loss = stats.mean_loss;
    }

    fn on_eval(&mut self, _label: &str, p: &TracePoint) {
        println!(
            "step {:>5}  heldout {:.4}  train {:.4}  comm {:>8.2} MB  consensus {:.3e}  [{:.1}s]",
            p.step,
            p.loss,
            self.last_train_loss,
            p.comm_mb,
            p.consensus,
            self.t_start.elapsed().as_secs_f64()
        );
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("e2e").to_string();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let k = 8;
    let period = 4;

    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let step = rt.train_step(&model)?;
    let m = step.manifest.clone();
    println!(
        "model '{}': d = {} params, batch {} x seq {}, vocab {}",
        m.name, m.d, m.batch, m.seq_len, m.vocab
    );

    let corpus_tokens = (m.seq_len + 1) * 96 * k;
    let entropy = MarkovCorpus { vocab: m.vocab, branching: 4, tokens: 0 }.entropy_nats();
    let mut src = XlaGradSource::new(step, k, corpus_tokens, 42)?;
    println!(
        "corpus: {corpus_tokens} Markov tokens over {k} workers; chain entropy {entropy:.3} nats \
         (loss lower bound), ln(V) = {:.3} (random-init level)",
        (m.vocab as f64).ln()
    );

    let (graph, w, rho) = topology::build(Topology::Ring, k, Weighting::UniformDegree, 0);
    let mut net = Network::new(&graph);
    let hyper = Hyper {
        lr: LrSchedule::Warmup { eta: 0.5, warmup_steps: 20 },
        mu: 0.9,
        weight_decay: 0.0,
        period,
        gamma: 0.4,
    };
    let x0 = src.init(42);
    let mut algo = PdSgdm::new(k, x0, w, hyper);
    println!("PD-SGDM: K={k} ring (rho = {rho:.3}), p={period}, mu=0.9, {steps} steps\n");

    let t_start = Instant::now();
    let eval_every = (steps / 20).max(1);
    // Wrap the caller-owned parts in a step-wise Session: the driver
    // loop, cost accounting, and trace recording come from the
    // coordinator; this example only contributes the Observer above.
    let mut session = Session::from_parts(
        &mut algo,
        &mut src,
        &mut net,
        eval_every,
        CostModel::default(),
    );
    session.observe(Box::new(E2eProgress { t_start, last_train_loss: f64::NAN }));
    session.run_until(StopCondition::Steps(steps));
    let mut trace = session.into_trace();
    trace.label = format!("e2e-{model}-pdsgdm-p{period}");

    let wall = t_start.elapsed().as_secs_f64();
    let tokens_seen = steps as f64 * k as f64 * (m.batch * m.seq_len) as f64;
    println!(
        "\ndone: heldout loss {:.4} -> {:.4} (chain entropy {entropy:.3}), \
         {steps} steps x {k} workers in {wall:.1}s = {:.0} tokens/s, \
         {:.2} MB gossiped over {} rounds",
        trace.points[0].loss,
        trace.final_loss(),
        tokens_seen / wall,
        net.total_megabytes(),
        net.rounds,
    );
    metrics::write_csv(
        std::path::Path::new(&format!("bench_out/e2e_{model}.csv")),
        std::slice::from_ref(&trace),
    )?;
    println!(
        "loss curve -> bench_out/e2e_{model}.csv (sim_seconds column is α–β simulated \
         time; wall-clock was {wall:.1}s)"
    );
    Ok(())
}
