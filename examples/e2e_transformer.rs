//! End-to-end driver: the full three-layer stack on a real workload.
//!
//!     make artifacts          # once (python AOT -> HLO text)
//!     cargo run --release --example e2e_transformer [model] [steps]
//!
//! L3 (this binary, pure Rust) runs PD-SGDM over 8 ring workers; each
//! gradient is produced by executing the AOT-compiled L2 transformer
//! (whose MLP matmuls are the L1 Pallas kernel) on the PJRT CPU client;
//! the data is a synthetic Markov corpus whose per-token entropy lower-
//! bounds the achievable loss. The loss curve is logged to
//! `bench_out/e2e_<model>.csv` and summarized in EXPERIMENTS.md.
//!
//! Defaults: model = "e2e" (d = 3.45M), steps = 300. Python is NOT on
//! the training path — delete it after `make artifacts` and this still
//! runs.

use std::time::Instant;

use pdsgdm::algorithms::{Algorithm, Hyper, PdSgdm};
use pdsgdm::comm::Network;
use pdsgdm::data::MarkovCorpus;
use pdsgdm::grad::GradientSource;
use pdsgdm::metrics::{self, Trace, TracePoint};
use pdsgdm::optim::LrSchedule;
use pdsgdm::runtime::{Runtime, XlaGradSource};
use pdsgdm::topology::{self, Topology, Weighting};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("e2e").to_string();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let k = 8;
    let period = 4;

    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let step = rt.train_step(&model)?;
    let m = step.manifest.clone();
    println!(
        "model '{}': d = {} params, batch {} x seq {}, vocab {}",
        m.name, m.d, m.batch, m.seq_len, m.vocab
    );

    let corpus_tokens = (m.seq_len + 1) * 96 * k;
    let entropy = MarkovCorpus { vocab: m.vocab, branching: 4, tokens: 0 }.entropy_nats();
    let mut src = XlaGradSource::new(step, k, corpus_tokens, 42)?;
    println!(
        "corpus: {corpus_tokens} Markov tokens over {k} workers; chain entropy {entropy:.3} nats \
         (loss lower bound), ln(V) = {:.3} (random-init level)",
        (m.vocab as f64).ln()
    );

    let (graph, w, rho) = topology::build(Topology::Ring, k, Weighting::UniformDegree, 0);
    let mut net = Network::new(&graph);
    let hyper = Hyper {
        lr: LrSchedule::Warmup { eta: 0.5, warmup_steps: 20 },
        mu: 0.9,
        weight_decay: 0.0,
        period,
        gamma: 0.4,
    };
    let x0 = src.init(42);
    let mut algo = PdSgdm::new(k, x0, w, hyper);
    println!("PD-SGDM: K={k} ring (rho = {rho:.3}), p={period}, mu=0.9, {steps} steps\n");

    let mut trace = Trace::new(format!("e2e-{model}-pdsgdm-p{period}"));
    let t_start = Instant::now();
    let eval_every = (steps / 20).max(1);
    let mut push_eval = |t: u64,
                         algo: &PdSgdm,
                         src: &mut XlaGradSource,
                         net: &Network,
                         trace: &mut Trace,
                         mean_step_loss: f64| {
        let eval = src.eval(&algo.avg_params());
        trace.push(TracePoint {
            step: t,
            loss: eval.loss,
            accuracy: 0.0,
            comm_mb: net.total_megabytes(),
            consensus: algo.consensus_error(),
            grad_norm_sq: 0.0,
            sim_seconds: t_start.elapsed().as_secs_f64(),
        });
        println!(
            "step {t:>5}  heldout {:.4}  train {:.4}  comm {:>8.2} MB  consensus {:.3e}  [{:.1}s]",
            eval.loss,
            mean_step_loss,
            net.total_megabytes(),
            algo.consensus_error(),
            t_start.elapsed().as_secs_f64()
        );
    };

    push_eval(0, &algo, &mut src, &net, &mut trace, f64::NAN);
    let mut recent = f64::NAN;
    for t in 0..steps {
        let stats = algo.step(t, &mut src, &mut net);
        recent = stats.mean_loss;
        if (t + 1) % eval_every == 0 || t + 1 == steps {
            push_eval(t + 1, &algo, &mut src, &net, &mut trace, recent);
        }
    }

    let wall = t_start.elapsed().as_secs_f64();
    let tokens_seen = steps as f64 * k as f64 * (m.batch * m.seq_len) as f64;
    println!(
        "\ndone: heldout loss {:.4} -> {:.4} (chain entropy {entropy:.3}), \
         {steps} steps x {k} workers in {wall:.1}s = {:.0} tokens/s, \
         {:.2} MB gossiped over {} rounds",
        trace.points[0].loss,
        trace.final_loss(),
        tokens_seen / wall,
        net.total_megabytes(),
        net.rounds,
    );
    metrics::write_csv(
        std::path::Path::new(&format!("bench_out/e2e_{model}.csv")),
        std::slice::from_ref(&trace),
    )?;
    println!("loss curve -> bench_out/e2e_{model}.csv");
    Ok(())
}
