//! Fault & heterogeneity sweep: how an unreliable fabric and non-IID
//! data bend the convergence curves of Figure 3's algorithm family.
//!
//!     cargo run --release --example fig3_faults
//!
//! Part 1 sweeps the per-edge message drop probability on the quadratic
//! workload: gossip renormalizes mixing weights over the neighbors it
//! actually heard from, so runs stay finite but consensus degrades as
//! the effective spectral gap shrinks.
//!
//! Part 2 sweeps the Dirichlet concentration α on the logistic workload
//! (α = 100 ≈ IID, α = 0.1 = near single-class shards), comparing
//! PD-SGDM against Momentum Tracking — the heterogeneity-robust
//! comparator whose gradient tracker is designed for exactly this skew
//! — and MAC-SGD, the momentum-accelerated-consensus baseline at 1×
//! D-SGD bytes.
//!
//! Part 3 sweeps the drop rate over *lossy compressed links*
//! (`faults.compressed = true`): the CHOCO-family algorithms keep
//! per-receiver x̂ replicas and apply stale corrections at full weight,
//! so runs stay finite up to 50% encoded drops (DESIGN.md §7).

use pdsgdm::config::{ExperimentConfig, WorkloadConfig};
use pdsgdm::coordinator::{Session, SessionSpec};
use pdsgdm::data::Sharding;
use pdsgdm::optim::LrSchedule;
use pdsgdm::topology::Topology;

fn base(algorithm: &str) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.algorithm = algorithm.into();
    c.workers = 8;
    c.topology = Topology::Ring;
    c.steps = 400;
    c.eval_every = 40;
    c.seed = 6;
    c
}

fn run(c: ExperimentConfig) -> anyhow::Result<(f64, f64)> {
    let mut session = Session::build(SessionSpec::new(c))?;
    session.run_to_stop();
    let trace = session.into_trace();
    let peak = trace.points.iter().map(|p| p.consensus).fold(0.0, f64::max);
    Ok((trace.final_loss(), peak))
}

fn main() -> anyhow::Result<()> {
    println!("== drop-rate sweep (quadratic, ring K=8) ==");
    println!(
        "{:<20} {:>10} {:>12} {:>16}",
        "algorithm", "drop_prob", "final_loss", "peak_consensus"
    );
    for algo in ["pd-sgdm", "d-sgd", "momentum-tracking"] {
        for drop in [0.0, 0.1, 0.2, 0.4] {
            let mut c = base(algo);
            c.workload = WorkloadConfig::Quadratic { dim: 64, heterogeneity: 2.0, noise: 0.2 };
            c.hyper.lr = LrSchedule::Constant { eta: 0.02 };
            c.faults.drop_prob = drop;
            c.faults.seed = 17;
            let (loss, peak) = run(c)?;
            println!("{algo:<20} {drop:>10.2} {loss:>12.5} {peak:>16.4e}");
        }
    }

    println!("\n== Dirichlet-α sweep (logistic, ring K=8) ==");
    println!(
        "{:<20} {:>10} {:>12} {:>16}",
        "algorithm", "alpha", "final_loss", "peak_consensus"
    );
    for algo in ["pd-sgdm", "momentum-tracking", "mac-sgd"] {
        for alpha in [100.0, 1.0, 0.3, 0.1] {
            let mut c = base(algo);
            c.workload =
                WorkloadConfig::Logistic { n: 2000, dim: 32, classes: 8, batch: 16, l2: 1e-4 };
            c.hyper.lr = LrSchedule::Constant { eta: 0.05 };
            c.sharding = Sharding::Dirichlet { alpha };
            let (loss, peak) = run(c)?;
            println!("{algo:<20} {alpha:>10.1} {loss:>12.5} {peak:>16.4e}");
        }
    }

    println!("\n== compressed-link drop sweep (quadratic, ring K=8, sign) ==");
    println!(
        "{:<20} {:>10} {:>12} {:>16} {:>10}",
        "algorithm", "drop_prob", "final_loss", "peak_consensus", "enc_drops"
    );
    for algo in ["cpd-sgdm", "choco-sgd", "deepsqueeze"] {
        for drop in [0.0, 0.1, 0.3, 0.5] {
            let mut c = base(algo);
            c.workload = WorkloadConfig::Quadratic { dim: 64, heterogeneity: 2.0, noise: 0.2 };
            c.hyper.lr = LrSchedule::Constant { eta: 0.02 };
            c.compressor = Some("sign".into());
            c.faults.drop_prob = drop;
            c.faults.seed = 17;
            // drop = 0.0 alone would not install a plan; force a
            // (zero-rate) one so the whole row runs the replica path.
            c.faults.enabled = true;
            c.faults.compressed = true;
            let mut session = Session::build(SessionSpec::new(c))?;
            session.run_to_stop();
            let enc = session.fault_counters().map_or(0, |f| f.dropped_encoded);
            let trace = session.into_trace();
            let peak = trace.points.iter().map(|p| p.consensus).fold(0.0, f64::max);
            let loss = trace.final_loss();
            println!("{algo:<20} {drop:>10.2} {loss:>12.5} {peak:>16.4e} {enc:>10}");
        }
    }

    println!(
        "\nDrops renormalize the mixing weights over surviving neighbors, so\n\
         the fabric never deadlocks — but peak consensus error grows with\n\
         drop_prob. Under Dirichlet skew (small α), Momentum Tracking's\n\
         gossiped gradient tracker keeps its momentum aimed at the global\n\
         objective while plain periodic momentum drifts toward local minima;\n\
         MAC-SGD buys its acceleration on the consensus direction at plain\n\
         D-SGD bytes. Over lossy compressed links the per-receiver replicas\n\
         keep CHOCO-style corrections consistent: the drop = 0 rows match\n\
         the faultless runs bit-for-bit, and the final loss stays finite\n\
         through 50% encoded drops."
    );
    Ok(())
}
