//! Quickstart: train an MLP with PD-SGDM on 8 ring-connected workers —
//! the paper's §5.1 setup with the synthetic CIFAR-proxy workload.
//!
//!     cargo run --release --example quickstart
//!
//! Prints a short loss/accuracy table and writes the trace CSV to
//! `bench_out/quickstart.csv`, plus a resumable full-state checkpoint.
//! This is the 30-second tour of the public API:
//! config -> SessionSpec -> Session -> run_until(StopCondition) -> Trace,
//! with an Observer streaming progress instead of hardcoded printing.

use pdsgdm::algorithms::Hyper;
use pdsgdm::config::{ExperimentConfig, WorkloadConfig};
use pdsgdm::coordinator::{Session, SessionSpec, VerboseObserver};
use pdsgdm::data::Sharding;
use pdsgdm::metrics;
use pdsgdm::optim::LrSchedule;
use pdsgdm::topology::Topology;

fn main() -> anyhow::Result<()> {
    // The paper's experimental skeleton: K=8 workers, ring topology,
    // momentum 0.9, step-decay LR, communication every p=4 steps.
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.algorithm = "pd-sgdm".into();
    cfg.workers = 8;
    cfg.topology = Topology::Ring;
    cfg.steps = 1500;
    cfg.eval_every = 100;
    cfg.sharding = Sharding::Iid;
    cfg.workload = WorkloadConfig::Mlp {
        n: 4000,
        dim: 32,
        classes: 10,
        hidden: 64,
        batch: 16, // paper: per-worker minibatch 16
    };
    cfg.hyper = Hyper {
        lr: LrSchedule::paper_cifar(0.1, 1500), // 0.1, x0.1 at 50%/75%
        mu: 0.9,
        weight_decay: 1e-4,
        period: 4,
        gamma: 0.4,
    };

    let mut session = Session::build(SessionSpec::new(cfg))?;
    {
        let cfg = session.config.as_ref().expect("built from a config");
        println!(
            "PD-SGDM quickstart: K={} ring (rho = {:.3}), p={}, mu={}",
            cfg.workers, session.rho, cfg.hyper.period, cfg.hyper.mu
        );
    }
    // Streamed progress is an Observer, not a driver flag — swap in your
    // own implementation for dashboards/early stopping.
    session.observe(Box::new(VerboseObserver::default()));
    session.run_to_stop();

    println!("\n{}", metrics::summary_table(std::slice::from_ref(session.trace())));
    metrics::write_csv(
        std::path::Path::new("bench_out/quickstart.csv"),
        std::slice::from_ref(session.trace()),
    )?;
    println!("trace -> bench_out/quickstart.csv");
    // Full-state checkpoint: `pdsgdm train --resume` (or
    // SessionSpec::resume_from) continues it bit-identically.
    session.save(std::path::Path::new("bench_out/quickstart.ckpt"))?;
    println!("checkpoint -> bench_out/quickstart.ckpt");
    Ok(())
}
