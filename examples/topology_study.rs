//! Topology study: how the spectral gap ρ = 1 − |λ₂(W)| shapes PD-SGDM,
//! empirically grounding the `(1 + 4/ρ²)` consensus term of Theorem 1.
//!
//!     cargo run --release --example topology_study
//!
//! For each topology family at K=16: prints ρ, the theorem's consensus
//! amplification factor, the measured peak consensus error, and the final
//! loss — chain (small ρ) should drift most, complete (ρ=1) least, with
//! ring/torus/hypercube ordered in between.

use pdsgdm::algorithms::Hyper;
use pdsgdm::config::{ExperimentConfig, WorkloadConfig};
use pdsgdm::coordinator::{Session, SessionSpec};
use pdsgdm::optim::LrSchedule;
use pdsgdm::topology::Topology;

fn main() -> anyhow::Result<()> {
    let k = 16;
    let topologies: &[(&str, Topology)] = &[
        ("chain", Topology::Chain),
        ("ring", Topology::Ring),
        ("torus", Topology::Torus2d),
        ("regular-3", Topology::RandomRegular { degree: 3 }),
        ("hypercube", Topology::Hypercube),
        ("star", Topology::Star),
        ("complete", Topology::Complete),
    ];

    println!(
        "{:<12} {:>8} {:>12} {:>16} {:>12} {:>10}",
        "topology", "rho", "1+4/rho^2", "peak_consensus", "final_loss", "comm_MB"
    );
    for (name, topo) in topologies {
        let mut c = ExperimentConfig::default();
        c.workers = k;
        c.topology = *topo;
        // Metropolis handles the irregular degrees of star/random graphs.
        c.weighting = pdsgdm::topology::Weighting::Metropolis;
        c.steps = 600;
        c.eval_every = 20;
        c.seed = 5;
        c.workload = WorkloadConfig::Quadratic { dim: 64, heterogeneity: 2.0, noise: 0.2 };
        c.hyper = Hyper {
            lr: LrSchedule::Constant { eta: 0.02 },
            mu: 0.9,
            weight_decay: 0.0,
            period: 8,
            gamma: 0.4,
        };
        let mut session = Session::build(SessionSpec::new(c))?;
        let rho = session.rho;
        session.run_to_stop();
        let trace = session.into_trace();
        let peak = trace.points.iter().map(|p| p.consensus).fold(0.0, f64::max);
        println!(
            "{name:<12} {rho:>8.4} {:>12.1} {peak:>16.4e} {:>12.4} {:>10.2}",
            1.0 + 4.0 / (rho * rho),
            trace.final_loss(),
            trace.total_comm_mb(),
        );
    }
    println!(
        "\nTheorem 1: consensus error is O(eta^2 p^2 G^2 (1 + 4/rho^2)) — the\n\
         peak_consensus column should shrink as rho grows."
    );
    Ok(())
}
