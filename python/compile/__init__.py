"""Build-time-only package: L2 JAX model + L1 Pallas kernels + AOT export.

Nothing in here is imported at runtime — ``make artifacts`` runs
``compile.aot`` once, and the Rust binary consumes the HLO text files.
"""
