"""AOT export: lower the L2/L1 computations to HLO *text* artifacts.

Run once by ``make artifacts``; the Rust runtime
(rust/src/runtime/) loads these with ``HloModuleProto::from_text_file``
and never touches python again.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which this image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).

Artifacts (per model config <cfg>):
  train_step_<cfg>.hlo.txt  (params f32[d], tokens i32[B,S+1]) -> (loss, grad)
  momentum_<cfg>.hlo.txt    (eta,mu f32[1], x,m,g f32[d])      -> (x', m')
  mix_k<K>_<cfg>.hlo.txt    (w f32[K,K], xs f32[K,d])          -> xs'
plus a manifest ``<cfg>.meta.json`` with shapes the Rust side validates
against its config.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import mix as mix_kernel
from compile.kernels import momentum as momentum_kernel


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via the stablehlo -> XlaComputation hop."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(fn, example_args, path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) // 1024} KiB)")


def emit_config(cfg: model.ModelConfig, ks, out_dir):
    d = model.param_count(cfg)
    print(f"[{cfg.name}] d={d} B={cfg.batch} S={cfg.seq_len} K={ks}")

    params = jax.ShapeDtypeStruct((d,), jnp.float32)
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    emit(
        functools.partial(model.train_step, cfg),
        (params, tokens),
        os.path.join(out_dir, f"train_step_{cfg.name}.hlo.txt"),
    )

    scalar = jax.ShapeDtypeStruct((1,), jnp.float32)
    vec = jax.ShapeDtypeStruct((d,), jnp.float32)
    emit(
        lambda x, m, g, eta, mu: momentum_kernel.momentum_update(x, m, g, eta, mu),
        (vec, vec, vec, scalar, scalar),
        os.path.join(out_dir, f"momentum_{cfg.name}.hlo.txt"),
    )

    for k in ks:
        w = jax.ShapeDtypeStruct((k, k), jnp.float32)
        xs = jax.ShapeDtypeStruct((k, d), jnp.float32)
        emit(
            lambda w, xs: mix_kernel.mix(w, xs),
            (w, xs),
            os.path.join(out_dir, f"mix_k{k}_{cfg.name}.hlo.txt"),
        )

    meta = {
        "name": cfg.name,
        "d": d,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "mix_ks": ks,
        "layout": [
            {"name": n, "offset": o, "shape": list(s)}
            for n, o, s in model.param_layout(cfg)[0]
        ],
    }
    meta_path = os.path.join(out_dir, f"{cfg.name}.meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  wrote {meta_path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,e2e",
                    help="comma-separated names from model.CONFIGS")
    ap.add_argument("--ks", default="4,8",
                    help="worker counts K to emit mix artifacts for")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    ks = [int(k) for k in args.ks.split(",")]
    for name in args.configs.split(","):
        emit_config(model.CONFIGS[name], ks, args.out_dir)
    # A sentinel so `make` can cheaply check freshness.
    open(os.path.join(args.out_dir, ".stamp"), "w").write("ok\n")


if __name__ == "__main__":
    main()
