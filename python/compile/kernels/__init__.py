"""L1 — Pallas kernels for the paper's compute hot-spots.

``matmul``   — tiled MXU-style matmul (transformer MLP)
``momentum`` — fused heavy-ball update, paper Eq. (8)
``mix``      — gossip mixing X' = W @ X, paper Eq. (4)
``ref``      — pure-jnp oracles the pytest suite checks against
"""
