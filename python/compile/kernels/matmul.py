"""Tiled matmul Pallas kernel — the transformer's MLP hot-spot (L1).

Hardware adaptation (the paper trained ResNets on P40/CUDA; see
DESIGN.md §Hardware-Adaptation): instead of CUDA threadblock tiles in
shared memory we tile for the TPU memory hierarchy —

  * a (i, j, k) grid of blocks; the (bm, bk) and (bk, bn) operand tiles
    and the (bm, bn) fp32 output/accumulator tile all live in VMEM,
  * the k-axis is the innermost grid dimension and the output BlockSpec
    does not depend on it, so the output tile stays resident in VMEM
    across the whole reduction (Pallas output revisiting) — the TPU
    analogue of a CUDA shared-memory accumulator,
  * block shapes default to multiples of the 128x128 MXU face.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.  The kernel is
numerically identical either way; correctness is asserted against
``ref.matmul_ref`` by python/tests/test_matmul_kernel.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: o_tile (+)= x_tile @ y_tile in fp32."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def pick_block(dim, preferred):
    """Largest divisor of ``dim`` that is <= ``preferred``.

    Keeps every tile exact (no ragged edges / masking) — the model picks
    128-friendly shapes, the hypothesis tests sweep adversarial ones.
    """
    b = max(1, min(dim, preferred))
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_blocked(x, y, *, bm=128, bn=128, bk=128):
    """``x @ y`` via the tiled Pallas kernel; returns f32 (m, n).

    Raw (non-differentiable) entry point — tests sweep block shapes
    through here.  The model uses :func:`matmul`, which adds the VJP.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {y.shape}"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)

    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


@jax.custom_vjp
def matmul(x, y):
    """Differentiable tiled-Pallas matmul (default 128-blocks).

    ``pallas_call`` has no built-in transpose rule, so the VJP is spelled
    out — and routes through the same kernel, so the backward pass of the
    transformer MLP also runs on the L1 hot-spot:

        dX = dO @ Y^T,   dY = X^T @ dO
    """
    return matmul_blocked(x, y)


def _matmul_fwd(x, y):
    return matmul_blocked(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    return (
        matmul_blocked(g, y.T).astype(x.dtype),
        matmul_blocked(x.T, g).astype(y.dtype),
    )


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(bm=128, bn=128, bk=128, in_dtype_bytes=4):
    """Estimated VMEM footprint of one grid step (operands + accumulator).

    Used by the DESIGN.md/EXPERIMENTS.md §Perf roofline estimate — the
    interpret-mode CPU path has no real VMEM, so this is the number we
    report for the TPU target.
    """
    return (bm * bk + bk * bn) * in_dtype_bytes + bm * bn * 4
