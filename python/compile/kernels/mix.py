"""Gossip-mixing Pallas kernel (L1) — paper Eq. (4) communication step.

Computes X' = W @ X where X is the f32[K, d] matrix of stacked worker
iterates (row k = worker k) and W is the K x K doubly-stochastic mixing
matrix, i.e. row k of the output is  sum_j w_kj x_j  — exactly Line 6 of
Algorithm 1.

This is a tall-skinny matmul: K (<= 64 in all our experiments) is tiny
compared to d (millions), so the kernel tiles only the d axis; each grid
step holds all K rows of a (K, bd) slab plus the full W in VMEM and
issues one (K x K) @ (K x bd) MXU contraction.  One HBM pass over X.

Correctness vs ``ref.mix_ref``: python/tests/test_mix_kernel.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(w_ref, x_ref, o_ref):
    """One d-block: o_slab = W @ x_slab with fp32 accumulation."""
    o_ref[...] = jnp.dot(
        w_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


def pick_block(d, preferred):
    """Largest divisor of ``d`` <= preferred (exact tiles along d)."""
    b = max(1, min(d, preferred))
    while d % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bd",))
def mix(w, xs, *, bd=16384):
    """X' = W @ X via the d-tiled Pallas kernel; returns f32[K, d].

    w: f32[K, K]; xs: f32[K, d].  Default bd=16384 with K=8 gives
    (8*16384 in + 8*16384 out + 64 W) * 4B ~= 1 MiB VMEM per step.
    """
    kk, k2 = w.shape
    k3, d = xs.shape
    assert kk == k2 == k3, f"mix shape mismatch: W {w.shape}, X {xs.shape}"
    blk = pick_block(d, bd)

    return pl.pallas_call(
        _mix_kernel,
        grid=(d // blk,),
        in_specs=[
            pl.BlockSpec((kk, kk), lambda i: (0, 0)),
            pl.BlockSpec((kk, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((kk, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((kk, d), jnp.float32),
        interpret=True,
    )(w, xs)
