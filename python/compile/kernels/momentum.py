"""Fused momentum-SGD update Pallas kernel (L1) — paper Eq. (8).

    m' = mu * m + g
    x' = x  - eta * m'

over the flat parameter vector x in R^d.  This is the per-iteration local
update every worker performs p times between communication rounds, and is
purely memory-bound: the fusion guarantees a single HBM->VMEM streaming
pass over each of (x, m, g) and a single write-back of (x', m') — on GPU
this would be a grid-stride elementwise loop, on TPU it is a 1-D BlockSpec
sweep.  eta and mu arrive as f32[1] tensors (not python constants) so one
compiled artifact serves every learning-rate-schedule step.

Correctness vs ``ref.momentum_ref``: python/tests/test_momentum_kernel.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _momentum_kernel(eta_ref, mu_ref, x_ref, m_ref, g_ref, xo_ref, mo_ref):
    """One 1-D block: fused m' = mu*m + g; x' = x - eta*m'."""
    m_new = mu_ref[0] * m_ref[...] + g_ref[...]
    mo_ref[...] = m_new
    xo_ref[...] = x_ref[...] - eta_ref[0] * m_new


def pick_block(d, preferred):
    """Largest divisor of ``d`` <= preferred (exact 1-D tiles)."""
    b = max(1, min(d, preferred))
    while d % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block",))
def momentum_update(x, m, g, eta, mu, *, block=65536):
    """Fused momentum update; returns (x', m') as f32[d] each.

    x, m, g: f32[d]; eta, mu: f32[1] (runtime scalars).  ``block`` is the
    1-D VMEM tile (default 64K elems = 256 KiB/operand, 5 operands
    -> ~1.25 MiB VMEM, far under the 16 MiB budget).
    """
    (d,) = x.shape
    blk = pick_block(d, block)

    return pl.pallas_call(
        _momentum_kernel,
        grid=(d // blk,),
        in_specs=[
            # eta/mu replicated to every grid step (block index 0).
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ],
        interpret=True,
    )(eta, mu, x, m, g)


def hbm_traffic_bytes(d):
    """Single-pass HBM traffic of one fused update (reads + writes).

    3 reads (x, m, g) + 2 writes (x', m') of f32[d]; the fusion makes
    this the information-theoretic minimum for Eq. (8).  Reported in
    EXPERIMENTS.md §Perf.
    """
    return 5 * 4 * d
