"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package is checked against the corresponding
function here by ``python/tests/`` (exact math, no Pallas, no tiling) —
this file is the single source of truth for what the kernels compute.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Plain matmul with fp32 accumulation: ``x @ y``.

    x: (m, k), y: (k, n) -> (m, n).  Inputs may be f32 or bf16; the
    accumulation (and output) are f32, matching the kernel's MXU-style
    fp32 accumulator.
    """
    return jnp.matmul(
        x.astype(jnp.float32), y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def momentum_ref(x, m, g, eta, mu):
    """Paper Eq. (8): fused heavy-ball momentum update.

        m' = mu * m + g
        x' = x - eta * m'

    x, m, g: flat f32[d]; eta, mu: scalars.  Returns (x', m').
    """
    m_new = mu * m + g
    x_new = x - eta * m_new
    return x_new, m_new


def mix_ref(w, xs):
    """Paper Eq. (4) gossip step over the stacked iterate matrix.

    ``xs`` is f32[K, d] with row k = worker k's parameter vector;
    ``w`` is the K x K doubly-stochastic mixing matrix.  Row k of the
    result is  sum_j w[k, j] * xs[j]  ==  (W @ X) with X = xs.
    """
    return jnp.matmul(
        w.astype(jnp.float32), xs.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
