"""L2 — decoder-only transformer over a *flat* parameter vector.

The paper's problem statement (Eq. 1) treats the model as a single vector
x in R^d per worker; the Rust coordinator (L3) does the same — momentum,
gossip mixing, and compression are all vector ops over f32[d].  So the
model here is parameterized by one flat f32[d] array, and the layout
(offset, shape) of every tensor is a static table derived from the config.

Forward pass: token embedding (tied LM head) -> L pre-LN blocks of
causal multi-head attention + GELU MLP -> final LN -> logits -> mean
next-token cross-entropy.  The MLP matmuls route through the L1 Pallas
``kernels.matmul`` kernel so the paper's compute hot-spot lowers into the
same HLO artifact the Rust runtime executes.

``train_step(cfg, params, tokens)`` returns ``(loss, grad)`` via
``jax.value_and_grad`` — one fused fwd+bwd HLO, no python anywhere near
the L3 request path.
"""

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from compile.kernels import matmul as matmul_kernel


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture hyper-parameters (fixed per AOT artifact)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int  # per-worker micro-batch

    @property
    def head_dim(self):
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Config registry — names referenced by aot.py, the Rust config system,
# and the examples.  ``tiny`` keeps pytest fast; ``e2e`` is the
# end-to-end driver's model (see EXPERIMENTS.md for the CPU-budget
# scaling note vs the paper's ResNet50/ImageNet run).
CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=64, d_model=32, n_layers=2,
                        n_heads=2, d_ff=64, seq_len=16, batch=2),
    "small": ModelConfig("small", vocab=512, d_model=128, n_layers=2,
                         n_heads=4, d_ff=512, seq_len=64, batch=4),
    "e2e": ModelConfig("e2e", vocab=1024, d_model=256, n_layers=4,
                       n_heads=8, d_ff=1024, seq_len=128, batch=4),
}


def param_layout(cfg: ModelConfig):
    """Static (name, offset, shape) table for the flat vector.

    Layout order is stable and documented — the Rust side re-derives
    sizes from the same scheme (rust/src/runtime/artifacts.rs) for
    checkpointing and initialization.
    """
    entries = []
    off = 0

    def add(name, shape):
        nonlocal off
        entries.append((name, off, shape))
        off += math.prod(shape)

    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    add("embed", (V, D))  # token embedding, tied as the LM head
    add("pos", (cfg.seq_len, D))  # learned positions
    for i in range(cfg.n_layers):
        add(f"l{i}.ln1.scale", (D,))
        add(f"l{i}.ln1.bias", (D,))
        add(f"l{i}.attn.wqkv", (D, 3 * D))
        add(f"l{i}.attn.bqkv", (3 * D,))
        add(f"l{i}.attn.wo", (D, D))
        add(f"l{i}.attn.bo", (D,))
        add(f"l{i}.ln2.scale", (D,))
        add(f"l{i}.ln2.bias", (D,))
        add(f"l{i}.mlp.w1", (D, F))
        add(f"l{i}.mlp.b1", (F,))
        add(f"l{i}.mlp.w2", (F, D))
        add(f"l{i}.mlp.b2", (D,))
    add("lnf.scale", (D,))
    add("lnf.bias", (D,))
    return entries, off


def param_count(cfg: ModelConfig) -> int:
    """Total d = dim of the flat parameter vector."""
    return param_layout(cfg)[1]


def unflatten(cfg: ModelConfig, flat):
    """Flat f32[d] -> dict of named tensors (static slices, trace-safe)."""
    entries, total = param_layout(cfg)
    assert flat.shape == (total,), (flat.shape, total)
    out = {}
    for name, off, shape in entries:
        n = math.prod(shape)
        out[name] = jax.lax.slice(flat, (off,), (off + n,)).reshape(shape)
    return out


def init_params(cfg: ModelConfig, key) -> jnp.ndarray:
    """GPT-2-style init, returned as the flat vector."""
    entries, total = param_layout(cfg)
    chunks = []
    for name, _off, shape in entries:
        key, sub = jax.random.split(key)
        if name.endswith((".bias", ".bqkv", ".bo", ".b1", ".b2")):
            val = jnp.zeros(shape, jnp.float32)
        elif name.endswith(".scale"):
            val = jnp.ones(shape, jnp.float32)
        elif name in ("embed", "pos"):
            val = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:  # weight matrices: 1/sqrt(fan_in), residual branches damped
            val = jax.random.normal(sub, shape, jnp.float32) / math.sqrt(shape[0])
            if name.endswith((".wo", ".w2")):
                val = val / math.sqrt(2 * cfg.n_layers)
        chunks.append(val.reshape(-1))
    flat = jnp.concatenate(chunks)
    assert flat.shape == (total,)
    return flat


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _mlp(cfg, x, w1, b1, w2, b2):
    """GELU MLP; the two matmuls are the L1 Pallas kernel."""
    B, S, D = x.shape
    h = matmul_kernel.matmul(x.reshape(B * S, D), w1) + b1
    h = jax.nn.gelu(h)
    o = matmul_kernel.matmul(h, w2) + b2
    return o.reshape(B, S, D)


def _attention(cfg, x, wqkv, bqkv, wo, bo):
    """Causal multi-head self-attention (plain jnp — XLA fuses this fine;
    the paper's hot-spot budget goes to the MLP matmuls)."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    qkv = jnp.einsum("bsd,de->bse", x, wqkv) + bqkv
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((S, S), bool))
    att = jnp.where(mask, att, jnp.float32(-1e30))
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, D)
    return jnp.einsum("bsd,de->bse", o, wo) + bo


def forward(cfg: ModelConfig, flat, tokens):
    """tokens i32[B, S] -> logits f32[B, S, V]."""
    p = unflatten(cfg, flat)
    B, S = tokens.shape
    x = p["embed"][tokens] + p["pos"][:S]
    for i in range(cfg.n_layers):
        h = _layer_norm(x, p[f"l{i}.ln1.scale"], p[f"l{i}.ln1.bias"])
        x = x + _attention(cfg, h, p[f"l{i}.attn.wqkv"], p[f"l{i}.attn.bqkv"],
                           p[f"l{i}.attn.wo"], p[f"l{i}.attn.bo"])
        h = _layer_norm(x, p[f"l{i}.ln2.scale"], p[f"l{i}.ln2.bias"])
        x = x + _mlp(cfg, h, p[f"l{i}.mlp.w1"], p[f"l{i}.mlp.b1"],
                     p[f"l{i}.mlp.w2"], p[f"l{i}.mlp.b2"])
    x = _layer_norm(x, p["lnf.scale"], p["lnf.bias"])
    return jnp.einsum("bsd,vd->bsv", x, p["embed"])  # tied head


def loss_fn(cfg: ModelConfig, flat, tokens):
    """Mean next-token cross-entropy; tokens i32[B, S+1]."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, flat, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, flat, tokens):
    """(loss f32[], grad f32[d]) — the single artifact the Rust L3 runs."""
    loss, grad = jax.value_and_grad(functools.partial(loss_fn, cfg))(flat, tokens)
    return loss, grad
