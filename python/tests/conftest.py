"""Shared pytest fixtures/settings for the kernel + model suite."""

import os
import sys

# Tests run from python/ (``cd python && pytest tests``) or the repo root;
# make ``compile`` importable either way.
_here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _here not in sys.path:
    sys.path.insert(0, _here)

from hypothesis import settings

# Pallas interpret mode is slow; keep example counts modest but real.
settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")
