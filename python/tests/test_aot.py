"""AOT export path: lowering to HLO text succeeds and is parseable-ish.

Full load-and-execute of the text is covered by the Rust integration
tests (rust/tests/runtime_integration.rs); here we assert the python
half: text is produced, mentions the right entry computation, and the
manifest matches the model layout.
"""

import functools
import json
import os

import jax
import jax.numpy as jnp

from compile import aot, model


def test_train_step_lowers_to_hlo_text(tmp_path):
    cfg = model.CONFIGS["tiny"]
    d = model.param_count(cfg)
    params = jax.ShapeDtypeStruct((d,), jnp.float32)
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    lowered = jax.jit(functools.partial(model.train_step, cfg)).lower(params, tokens)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert f"f32[{d}]" in text  # flat param vector appears in the signature
    assert "ENTRY" in text


def test_emit_config_writes_all_artifacts(tmp_path):
    cfg = model.CONFIGS["tiny"]
    aot.emit_config(cfg, ks=[4], out_dir=str(tmp_path))
    for f in ["train_step_tiny.hlo.txt", "momentum_tiny.hlo.txt",
              "mix_k4_tiny.hlo.txt", "tiny.meta.json"]:
        p = tmp_path / f
        assert p.exists() and p.stat().st_size > 0, f

    meta = json.loads((tmp_path / "tiny.meta.json").read_text())
    assert meta["d"] == model.param_count(cfg)
    layout = model.param_layout(cfg)[0]
    assert len(meta["layout"]) == len(layout)
    assert meta["layout"][0]["name"] == "embed"
    assert meta["layout"][-1]["offset"] + 32 == meta["d"]  # lnf.bias, D=32
