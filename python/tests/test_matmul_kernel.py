"""L1 matmul kernel vs the pure-jnp oracle (hypothesis shape sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import matmul as mk
from compile.kernels import ref


def _rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    bm=st.sampled_from([1, 3, 8, 16, 128]),
    bn=st.sampled_from([1, 5, 8, 32, 128]),
    bk=st.sampled_from([1, 7, 8, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_shape_sweep(m, k, n, bm, bn, bk, seed):
    """Adversarial (m,k,n) x block-shape sweep: kernel == oracle."""
    x = _rand((m, k), seed)
    y = _rand((k, n), seed + 1)
    got = mk.matmul_blocked(jnp.array(x), jnp.array(y), bm=bm, bn=bn, bk=bk)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_matmul_dtypes_accumulate_f32(dtype):
    """bf16 inputs still accumulate (and return) f32, like the MXU."""
    x = _rand((64, 64), 0).astype(dtype)
    y = _rand((64, 64), 1).astype(dtype)
    got = mk.matmul_blocked(jnp.array(x), jnp.array(y), bm=32, bn=32, bk=32)
    assert got.dtype == jnp.float32
    want = ref.matmul_ref(jnp.array(x), jnp.array(y))
    tol = 1e-4 if dtype == np.float32 else 0.25
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_matmul_shape_mismatch_raises():
    with pytest.raises(AssertionError):
        mk.matmul_blocked(jnp.zeros((4, 5)), jnp.zeros((6, 4)))


def test_matmul_vjp_matches_jnp_grad():
    """The hand-written VJP equals autodiff through plain jnp.matmul."""
    x = jnp.array(_rand((12, 20), 2))
    y = jnp.array(_rand((20, 8), 3))

    def f_kernel(x, y):
        return jnp.sum(jnp.sin(mk.matmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.sin(ref.matmul_ref(x, y)))

    gx_k, gy_k = jax.grad(f_kernel, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gy_k), np.asarray(gy_r), rtol=1e-4, atol=1e-5)


@given(bm=st.sampled_from([32, 64, 128, 256]),
       bn=st.sampled_from([32, 64, 128, 256]),
       bk=st.sampled_from([32, 64, 128, 256]))
def test_vmem_estimate_under_budget(bm, bn, bk):
    """The §Perf VMEM estimator stays under the 16 MiB TPU budget for
    every block shape the model/aot path can select."""
    assert mk.vmem_bytes(bm, bn, bk) <= 16 * 1024 * 1024


def test_pick_block_exact_divisor():
    for dim in [1, 7, 128, 384, 1000]:
        for pref in [1, 8, 128, 4096]:
            b = mk.pick_block(dim, pref)
            assert 1 <= b <= max(1, min(dim, pref))
            assert dim % b == 0
