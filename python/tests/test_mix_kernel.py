"""L1 gossip-mixing kernel vs the oracle — paper Eq. (4) / Alg. 1 line 6."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels import mix as mx
from compile.kernels import ref


def _ring_w(k):
    """Ring mixing matrix (1/3, 1/3, 1/3), the paper's experimental topology."""
    w = np.zeros((k, k), np.float32)
    for i in range(k):
        w[i, i] = 1 / 3
        w[i, (i - 1) % k] += 1 / 3
        w[i, (i + 1) % k] += 1 / 3
    return w


@given(
    k=st.integers(1, 16),
    d=st.integers(1, 2000),
    bd=st.sampled_from([1, 17, 256, 16384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mix_matches_ref(k, d, bd, seed):
    rng = np.random.default_rng(seed)
    w = rng.random((k, k)).astype(np.float32)
    w = w / w.sum(axis=1, keepdims=True)
    xs = rng.standard_normal((k, d)).astype(np.float32)
    got = mx.mix(jnp.array(w), jnp.array(xs), bd=bd)
    want = ref.mix_ref(w, xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@given(k=st.sampled_from([3, 4, 8, 16]), d=st.integers(1, 512),
       seed=st.integers(0, 2**31 - 1))
def test_mix_preserves_average(k, d, seed):
    """Doubly-stochastic W preserves the worker average — the invariant
    behind Eq. (18)/(45): x̄ evolves as if no communication happened."""
    rng = np.random.default_rng(seed)
    xs = rng.standard_normal((k, d)).astype(np.float32)
    w = _ring_w(k)
    out = np.asarray(mx.mix(jnp.array(w), jnp.array(xs)))
    np.testing.assert_allclose(out.mean(axis=0), xs.mean(axis=0),
                               rtol=1e-4, atol=1e-4)


def test_mix_identity_w_is_noop():
    xs = np.random.default_rng(0).standard_normal((8, 100)).astype(np.float32)
    out = mx.mix(jnp.eye(8, dtype=jnp.float32), jnp.array(xs))
    np.testing.assert_allclose(np.asarray(out), xs, rtol=1e-6)


def test_mix_consensus_contraction():
    """Repeated ring mixing contracts consensus error by (1-rho) per round
    (Lemma 1): ||X W - X̄|| <= (1-rho) ||X - X̄||."""
    k = 8
    w = _ring_w(k)
    evals = np.sort(np.abs(np.linalg.eigvalsh(w)))
    rho = 1 - evals[-2]
    rng = np.random.default_rng(3)
    xs = rng.standard_normal((k, 64)).astype(np.float32)
    dev = xs - xs.mean(0, keepdims=True)
    before = np.linalg.norm(dev)
    mixed = np.asarray(mx.mix(jnp.array(w), jnp.array(xs)))
    after = np.linalg.norm(mixed - mixed.mean(0, keepdims=True))
    assert after <= (1 - rho) * before + 1e-4
