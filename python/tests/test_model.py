"""L2 transformer: shapes, layout, loss sanity, gradient correctness."""

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


CFG = model.CONFIGS["tiny"]


def _params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


def _tokens(seed=1, seq=None):
    seq = CFG.seq_len if seq is None else seq
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (CFG.batch, seq), 0, CFG.vocab)


def test_layout_is_contiguous_and_complete():
    entries, total = model.param_layout(CFG)
    off = 0
    for name, o, shape in entries:
        assert o == off, f"{name} not contiguous"
        off += math.prod(shape)
    assert off == total == model.param_count(CFG)


def test_layout_names_unique():
    entries, _ = model.param_layout(CFG)
    names = [n for n, _, _ in entries]
    assert len(names) == len(set(names))


def test_unflatten_roundtrip():
    flat = _params()
    p = model.unflatten(CFG, flat)
    rebuilt = jnp.concatenate([p[n].reshape(-1)
                               for n, _, _ in model.param_layout(CFG)[0]])
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))


def test_init_statistics():
    flat = _params()
    p = model.unflatten(CFG, flat)
    assert float(jnp.abs(p["l0.attn.bqkv"]).max()) == 0.0
    np.testing.assert_allclose(np.asarray(p["l0.ln1.scale"]), 1.0)
    # embeddings ~ N(0, 0.02)
    assert 0.01 < float(jnp.std(p["embed"])) < 0.03


def test_forward_shape_and_finite():
    logits = model.forward(CFG, _params(), _tokens())
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_log_vocab():
    """Random init => loss ~= ln(V) (uniform next-token distribution)."""
    loss = model.loss_fn(CFG, _params(), _tokens(seq=CFG.seq_len + 1))
    assert abs(float(loss) - math.log(CFG.vocab)) < 0.5


def test_causality():
    """Changing a future token must not change past logits."""
    flat = _params()
    toks = np.asarray(_tokens())
    logits_a = np.asarray(model.forward(CFG, flat, jnp.array(toks)))
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 1) % CFG.vocab
    logits_b = np.asarray(model.forward(CFG, flat, jnp.array(toks2)))
    np.testing.assert_allclose(logits_a[:, :-1], logits_b[:, :-1],
                               rtol=1e-5, atol=1e-5)


def test_train_step_gradient_matches_numerical():
    flat = _params()
    toks = _tokens(seq=CFG.seq_len + 1)
    loss, grad = model.train_step(CFG, flat, toks)
    assert grad.shape == flat.shape
    assert bool(jnp.all(jnp.isfinite(grad)))
    f = functools.partial(model.loss_fn, CFG)
    d = flat.shape[0]
    rng = np.random.default_rng(0)
    eps = 1e-3
    for i in rng.integers(0, d, size=5):
        e = jnp.zeros(d).at[i].set(eps)
        num = (f(flat + e, toks) - f(flat - e, toks)) / (2 * eps)
        assert abs(float(num) - float(grad[i])) < 5e-3, i


def test_gradient_descent_reduces_loss():
    """A few plain-SGD steps on one batch must reduce the loss."""
    flat = _params()
    toks = _tokens(seq=CFG.seq_len + 1)
    loss0, _ = model.train_step(CFG, flat, toks)
    for _ in range(5):
        _, grad = model.train_step(CFG, flat, toks)
        flat = flat - 0.5 * grad
    loss1, _ = model.train_step(CFG, flat, toks)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("name", ["tiny", "small", "e2e"])
def test_all_configs_have_valid_layouts(name):
    cfg = model.CONFIGS[name]
    entries, total = model.param_layout(cfg)
    assert total > 0
    assert cfg.d_model % cfg.n_heads == 0
    # tied head: no separate lm_head entry
    assert not any(n == "lm_head" for n, _, _ in entries)
