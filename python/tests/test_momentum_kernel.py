"""L1 fused momentum kernel vs the oracle — paper Eq. (8)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels import momentum as mo
from compile.kernels import ref


def _vecs(d, seed):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(d).astype(np.float32) for _ in range(3))


@given(
    d=st.integers(1, 5000),
    block=st.sampled_from([1, 64, 1000, 65536]),
    eta=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.999),
    seed=st.integers(0, 2**31 - 1),
)
def test_momentum_matches_ref(d, block, eta, mu, seed):
    x, m, g = _vecs(d, seed)
    xo, mo_ = mo.momentum_update(
        jnp.array(x), jnp.array(m), jnp.array(g),
        jnp.array([eta], np.float32), jnp.array([mu], np.float32),
        block=block,
    )
    xr, mr = ref.momentum_ref(x, m, g, np.float32(eta), np.float32(mu))
    np.testing.assert_allclose(np.asarray(xo), np.asarray(xr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mo_), np.asarray(mr), rtol=1e-5, atol=1e-5)


def test_momentum_zero_mu_is_plain_sgd():
    """mu=0 must reduce Eq. (8) to vanilla SGD: x' = x - eta*g, m' = g."""
    x, m, g = _vecs(257, 7)
    xo, mn = mo.momentum_update(
        jnp.array(x), jnp.array(m), jnp.array(g),
        jnp.array([0.5], np.float32), jnp.array([0.0], np.float32))
    np.testing.assert_allclose(np.asarray(mn), g, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(xo), x - 0.5 * g, rtol=1e-5, atol=1e-6)


def test_momentum_accumulates_geometric_series():
    """t steps with constant g: m_t = g * (1-mu^t)/(1-mu) (Lemma 3 setup)."""
    d, mu, eta, steps = 64, 0.9, 0.01, 20
    g = np.ones(d, np.float32)
    x = np.zeros(d, np.float32)
    m = np.zeros(d, np.float32)
    for _ in range(steps):
        xo, mn = mo.momentum_update(
            jnp.array(x), jnp.array(m), jnp.array(g),
            jnp.array([eta], np.float32), jnp.array([mu], np.float32))
        x, m = np.asarray(xo), np.asarray(mn)
    expect = (1 - mu**steps) / (1 - mu)
    np.testing.assert_allclose(m, expect, rtol=1e-4)
    # and the Lemma 3 bound ||m||^2 <= G^2/(1-mu)^2 with G = ||g||:
    assert np.linalg.norm(m) <= np.linalg.norm(g) / (1 - mu) + 1e-4


def test_hbm_traffic_is_minimal():
    d = 1_000_000
    assert mo.hbm_traffic_bytes(d) == 5 * 4 * d
