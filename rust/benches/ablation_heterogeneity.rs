//! **Heterogeneity ablation** — how data skew across workers affects the
//! paper's algorithms.
//!
//! The paper's assumption set allows arbitrary per-worker distributions
//! D^(k) but its experiments use homogeneous shards. We sweep
//! Dirichlet(α) label skew (α = ∞ ≡ iid, small α = near-disjoint label
//! sets) at K=8 ring and compare:
//!
//!   * PD-SGDM (p=4) — does periodic communication survive skew?
//!   * PD-SGD (no momentum) — does momentum help more under skew?
//!   * D-SGDM (every-step gossip) — upper bound with 4x the rounds
//!   * C-SGDM — the skew-oblivious centralized reference
//!   * D-SGDM+m (Yu et al. [23], gossips x AND m) — 2x payload variant
//!
//! Run with `cargo bench --bench ablation_heterogeneity`.

mod common;

use pdsgdm::data::Sharding;

fn main() {
    let steps = 2000;
    println!("# ablation_heterogeneity: K=8 ring, MLP proxy, Dirichlet(alpha) skew");
    println!("alpha,algorithm,final_loss,final_acc,comm_mb");

    let algos: &[(&str, u64)] = &[
        ("pd-sgdm", 4),
        ("pd-sgd", 4),
        ("d-sgdm", 1),
        ("d-sgdm-pm", 1),
        ("c-sgdm", 1),
    ];
    let mut summary: Vec<(String, f64, f64)> = Vec::new();
    for &alpha in &[f64::INFINITY, 1.0, 0.3, 0.1] {
        for &(algo, p) in algos {
            let mut c = common::paper_config(steps, "mlp");
            c.algorithm = algo.into();
            c.hyper.period = p;
            c.sharding = if alpha.is_infinite() {
                Sharding::Iid
            } else {
                Sharding::Dirichlet { alpha }
            };
            let label = format!("{algo}(p={p})@alpha={alpha}");
            let trace = common::run_labeled(c, &label);
            println!(
                "{alpha},{algo}(p={p}),{:.4},{:.4},{:.2}",
                trace.final_loss(),
                trace.final_accuracy(),
                trace.total_comm_mb()
            );
            summary.push((label, trace.final_accuracy(), trace.total_comm_mb()));
        }
    }

    // Claims worth asserting:
    // 1. PD-SGDM stays within a few accuracy points of C-SGDM even at
    //    alpha=0.1 (gossip handles skew).
    let acc = |needle: &str| {
        summary
            .iter()
            .find(|(l, _, _)| l.starts_with(needle))
            .map(|(_, a, _)| *a)
            .unwrap()
    };
    let pd_01 = acc("pd-sgdm(p=4)@alpha=0.1");
    let c_01 = acc("c-sgdm(p=1)@alpha=0.1");
    println!(
        "\ncheck: PD-SGDM@alpha=0.1 acc {pd_01:.3} within 0.10 of C-SGDM {c_01:.3}: {}",
        if (pd_01 - c_01).abs() <= 0.10 { "OK" } else { "MISMATCH" }
    );
    // 2. The [23]-style momentum-gossip variant costs exactly 2x the
    //    bytes of plain every-step gossip — the overhead the paper's
    //    related-work section criticizes.
    let mb = |needle: &str| {
        summary
            .iter()
            .find(|(l, _, _)| l.starts_with(needle))
            .map(|(_, _, m)| *m)
            .unwrap()
    };
    let ratio = mb("d-sgdm-pm(p=1)@alpha=inf") / mb("d-sgdm(p=1)@alpha=inf");
    println!(
        "check: d-sgdm-pm bytes / d-sgdm bytes = {ratio:.2} (= 2.0): {}",
        if (ratio - 2.0).abs() < 0.01 { "OK" } else { "MISMATCH" }
    );
    // 3. PD-SGDM(p=4) uses 4x less comm than every-step D-SGDM at equal
    //    iteration count.
    let saving = mb("d-sgdm(p=1)@alpha=inf") / mb("pd-sgdm(p=4)@alpha=inf");
    println!(
        "check: every-step gossip / periodic(p=4) bytes = {saving:.2} (= 4.0): {}",
        if (saving - 4.0).abs() < 0.05 { "OK" } else { "MISMATCH" }
    );
}
