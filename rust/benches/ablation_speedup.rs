//! **Corollary 1/2 ablation** — linear speedup in the number of workers.
//!
//! The theorems say: with η = O(√(K/T)) and p = O(T^{1/4}/K^τ), τ > 3/4,
//! the rate is O(1/√(KT)) — K workers are K times as fast. On the
//! noiseless-optimum quadratic (f* = 0) we measure, per K ∈ {1,2,4,8,16}:
//!
//!   * the stationary loss floor at fixed η (Theorem 1's ησ²L/(1-μ)K
//!     terms => floor ∝ 1/K), and
//!   * iterations to reach a fixed loss under the Corollary 1 η(K)
//!     schedule (=> steps ∝ 1/K).
//!
//! Run with `cargo bench --bench ablation_speedup`.

mod common;

use pdsgdm::config::WorkloadConfig;
use pdsgdm::coordinator::{Session, SessionSpec};
use pdsgdm::optim::LrSchedule;

fn main() {
    let ks = [1usize, 2, 4, 8, 16];
    let steps = 3000u64;

    println!("# ablation_speedup: stationary floor vs K (fixed eta)");
    println!("k,floor_loss,floor_x_k,steps_to_0.2,comm_mb");
    let mut floors = Vec::new();
    let mut rows = Vec::new();
    for &k in &ks {
        let mut c = common::paper_config(steps, "quadratic");
        c.algorithm = "pd-sgdm".into();
        c.workers = k;
        c.eval_every = 50;
        c.workload = WorkloadConfig::Quadratic { dim: 64, heterogeneity: 0.0, noise: 2.0 };
        c.hyper.lr = LrSchedule::Constant { eta: 0.02 };
        c.hyper.period = 4;
        let mut session = Session::build(SessionSpec::new(c)).unwrap();
        session.run_to_stop();
        let trace = session.into_trace();
        let tail: Vec<f64> = trace
            .points
            .iter()
            .filter(|p| p.step >= steps / 2)
            .map(|p| p.loss)
            .collect();
        let floor = tail.iter().sum::<f64>() / tail.len() as f64;
        let t_hit = trace
            .steps_to_loss(0.2)
            .map(|t| t.to_string())
            .unwrap_or_else(|| "never".into());
        println!(
            "{k},{floor:.5},{:.4},{t_hit},{:.2}",
            floor * k as f64,
            trace.total_comm_mb()
        );
        rows.push((k, floor));
        floors.push(floor * k as f64);
    }
    // linear speedup check: floor * K should be ~constant
    let fmax = floors.iter().cloned().fold(f64::MIN, f64::max);
    let fmin = floors.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "\ncheck: floor*K constancy ratio max/min = {:.2} (linear speedup <= ~2.0)  {}",
        fmax / fmin,
        if fmax / fmin <= 2.0 { "OK" } else { "MISMATCH" }
    );

    // tau sweep: p = T^{1/4}/K^tau — Remark 1 says tau > 3/4 keeps the
    // linear-speedup term dominant; small tau lets the topology term bite.
    println!("\n# ablation_speedup: Remark 1 tau sweep (K=8)");
    println!("tau,p,final_loss,comm_mb");
    let t_total = 3000u64;
    for tau in [0.25f64, 0.5, 0.75, 1.0] {
        let p = ((t_total as f64).powf(0.25) / (8f64).powf(tau)).round().max(1.0) as u64;
        let mut c = common::paper_config(t_total, "quadratic");
        c.algorithm = "pd-sgdm".into();
        c.workers = 8;
        c.workload = WorkloadConfig::Quadratic { dim: 64, heterogeneity: 1.0, noise: 0.5 };
        c.hyper.lr = LrSchedule::Corollary1 { eta0: 1.0, k: 8, total_steps: t_total };
        c.hyper.period = p;
        let mut session = Session::build(SessionSpec::new(c)).unwrap();
        session.run_to_stop();
        let trace = session.into_trace();
        println!("{tau},{p},{:.5},{:.2}", trace.final_loss(), trace.total_comm_mb());
    }
}
