//! **Theorem 1 ablation** — consensus error vs (p, ρ).
//!
//! Lemma 5 bounds Σ_k ||x_k − x̄||² by 2η²p²G²K(1 + 4/ρ²)/(1−μ)². We
//! sweep the two controllable factors:
//!
//!   * p ∈ {2, 4, 8, 16, 32} at fixed ring topology — peak consensus
//!     should grow ~p²;
//!   * topology ∈ {chain, ring, torus, hypercube, complete} at fixed
//!     p=8 — peak consensus should fall as ρ rises.
//!
//! Run with `cargo bench --bench ablation_topology`.

mod common;

use pdsgdm::config::WorkloadConfig;
use pdsgdm::coordinator::{Session, SessionSpec};
use pdsgdm::optim::LrSchedule;
use pdsgdm::topology::Topology;

fn peak_consensus(topo: Topology, p: u64) -> (f64, f64) {
    let mut c = common::paper_config(400, "quadratic");
    c.algorithm = "pd-sgdm".into();
    c.workers = 16;
    c.topology = topo;
    c.weighting = pdsgdm::topology::Weighting::Metropolis;
    c.eval_every = 5;
    c.workload = WorkloadConfig::Quadratic { dim: 64, heterogeneity: 2.0, noise: 0.2 };
    c.hyper.lr = LrSchedule::Constant { eta: 0.02 };
    c.hyper.period = p;
    let mut session = Session::build(SessionSpec::new(c)).unwrap();
    let rho = session.rho;
    session.run_to_stop();
    let trace = session.into_trace();
    let peak = trace.points.iter().map(|pt| pt.consensus).fold(0.0, f64::max);
    (rho, peak)
}

fn main() {
    println!("# ablation_topology: consensus vs p (ring, K=16)");
    println!("p,peak_consensus,peak_over_p2");
    let mut over_p2 = Vec::new();
    for p in [2u64, 4, 8, 16, 32] {
        let (_, peak) = peak_consensus(Topology::Ring, p);
        println!("{p},{peak:.4e},{:.4e}", peak / (p * p) as f64);
        over_p2.push(peak / (p * p) as f64);
    }
    println!(
        "\ncheck: peak grows superlinearly in p (peak(32) >> peak(2)): {}",
        if over_p2.last().unwrap() * 1024.0 > over_p2[0] * 4.0 * 4.0 { "OK" } else { "MISMATCH" }
    );

    println!("\n# ablation_topology: consensus vs rho (p=8, K=16)");
    println!("topology,rho,amplification_1p4rho2,peak_consensus");
    let topos: &[(&str, Topology)] = &[
        ("chain", Topology::Chain),
        ("ring", Topology::Ring),
        ("torus", Topology::Torus2d),
        ("hypercube", Topology::Hypercube),
        ("complete", Topology::Complete),
    ];
    let mut peaks = Vec::new();
    for (name, topo) in topos {
        let (rho, peak) = peak_consensus(*topo, 8);
        println!("{name},{rho:.4},{:.1},{peak:.4e}", 1.0 + 4.0 / (rho * rho));
        peaks.push((rho, peak));
    }
    let chain = peaks[0].1;
    let complete = peaks.last().unwrap().1;
    println!(
        "\ncheck: complete-graph consensus {complete:.3e} < chain consensus {chain:.3e}: {}",
        if complete < chain { "OK" } else { "MISMATCH" }
    );
}
