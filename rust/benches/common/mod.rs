//! Shared helpers for the figure benches: paper-style configs and the
//! CSV/console reporting contract (every bench prints the series the
//! corresponding paper figure plots, then writes it to bench_out/).

use pdsgdm::algorithms::Hyper;
use pdsgdm::config::{ExperimentConfig, WorkloadConfig};
use pdsgdm::coordinator::{Session, SessionSpec, StopCondition};
use pdsgdm::metrics::{self, Trace};
use pdsgdm::optim::LrSchedule;

/// The paper's §5.1 skeleton scaled to this testbed: K=8 ring, mu=0.9,
/// weight decay 1e-4, step-decay LR (x0.1 at 50%/75%), batch 16 — with
/// the MLP-on-blobs CIFAR-10 proxy ("resnet20 stand-in") or the logistic
/// ("resnet50 stand-in", convex => smoother curves like ImageNet's).
pub fn paper_config(steps: u64, workload: &str) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.workers = 8;
    c.steps = steps;
    c.eval_every = (steps / 30).max(1);
    c.seed = 2020;
    c.workload = match workload {
        "mlp" => WorkloadConfig::Mlp { n: 4000, dim: 32, classes: 10, hidden: 64, batch: 16 },
        "logistic" => WorkloadConfig::Logistic { n: 4000, dim: 64, classes: 10, batch: 16, l2: 1e-4 },
        "quadratic" => WorkloadConfig::Quadratic { dim: 64, heterogeneity: 1.0, noise: 0.5 },
        other => panic!("unknown workload {other}"),
    };
    c.hyper = Hyper {
        lr: LrSchedule::paper_cifar(0.1, steps),
        mu: 0.9,
        weight_decay: 1e-4,
        period: 4,
        gamma: 0.4,
    };
    c
}

/// Run one configured experiment to its config-implied stop condition
/// and relabel its trace.
pub fn run_labeled(cfg: ExperimentConfig, label: &str) -> Trace {
    let stop = None;
    run_until_labeled(cfg, stop, label)
}

/// Run one configured experiment until `stop` (or, when `None`, the
/// config's own stop condition — steps plus any `[stop]` budgets).
/// Budget sweeps hand in `StopCondition::CommBudgetMb` /
/// `SimSecondsBudget` values here instead of guessing step counts.
pub fn run_until_labeled(
    cfg: ExperimentConfig,
    stop: Option<StopCondition>,
    label: &str,
) -> Trace {
    let mut session = match Session::build(SessionSpec::new(cfg)) {
        Ok(s) => s,
        Err(e) => panic!("build {label}: {e}"),
    };
    match stop {
        Some(stop) => {
            session.run_until(stop);
        }
        None => {
            session.run_to_stop();
        }
    }
    let mut trace = session.into_trace();
    trace.label = label.to_string();
    trace
}

/// Print the full series as CSV to stdout (the figure's data), plus the
/// summary table, and persist to bench_out/<name>.csv.
pub fn report(name: &str, traces: &[Trace]) {
    println!("# {name}: series (CSV)");
    println!("{}", Trace::csv_header());
    for t in traces {
        print!("{}", t.to_csv_rows());
    }
    println!("\n# {name}: summary");
    print!("{}", metrics::summary_table(traces));
    let path = format!("bench_out/{name}.csv");
    metrics::write_csv(std::path::Path::new(&path), traces).expect("write csv");
    println!("# -> {path}\n");
}
