//! **Figure 1** — convergence & generalization of PD-SGDM.
//!
//! Paper: training loss vs iterations (a: ResNet20/CIFAR-10,
//! b: ResNet50/ImageNet) and test accuracy vs epochs (c, d), comparing
//! PD-SGDM with p ∈ {4, 8, 16} against centralized momentum SGD
//! (C-SGDM). Expected shape (paper's claim): all four curves converge to
//! ~the same loss and final accuracy — periodic communication is free.
//!
//! Here: (a, c) on the MLP proxy, (b, d) on the logistic proxy
//! (DESIGN.md §2 substitution). Run with `cargo bench --bench
//! fig1_convergence`.

mod common;

fn main() {
    let steps = 2000;
    for (panel, workload) in [("fig1a_c", "mlp"), ("fig1b_d", "logistic")] {
        let mut traces = Vec::new();

        let mut c = common::paper_config(steps, workload);
        c.algorithm = "c-sgdm".into();
        traces.push(common::run_labeled(c, "c-sgdm"));

        for p in [4u64, 8, 16] {
            let mut c = common::paper_config(steps, workload);
            c.algorithm = "pd-sgdm".into();
            c.hyper.period = p;
            traces.push(common::run_labeled(c, &format!("pd-sgdm(p={p})")));
        }
        common::report(panel, &traces);

        // The figure's claim, asserted: every PD-SGDM curve lands within
        // a small band of C-SGDM on both loss and accuracy.
        let base_loss = traces[0].final_loss();
        let base_acc = traces[0].final_accuracy();
        for t in &traces[1..] {
            let dl = (t.final_loss() - base_loss).abs();
            let da = (t.final_accuracy() - base_acc).abs();
            println!(
                "check {panel} {}: |Δloss| = {dl:.4} (≤0.25), |Δacc| = {da:.4} (≤0.08)  {}",
                t.label,
                if dl <= 0.25 && da <= 0.08 { "OK" } else { "MISMATCH" }
            );
        }
        println!();
    }
}
