//! **Figure 2** — accuracy vs communication cost (MB).
//!
//! Paper: (a, b) PD-SGDM with p ∈ {4, 8, 16}: larger p reaches the same
//! accuracy with proportionally less traffic. (c, d) CPD-SGDM (sign, p ∈
//! {4, 8, 16}) vs PD-SGDM(p=16): compression wins by a further ~32x per
//! round, so CPD-SGDM dominates even the cheapest full-precision run.
//!
//! The x-axis here is the byte-exact wire accounting of comm::Network
//! (compressed payloads use each operator's true codec size). Run with
//! `cargo bench --bench fig2_comm_cost`.

mod common;

fn main() {
    let steps = 2000;

    // (a, b): PD-SGDM accuracy-vs-MB for p in {4, 8, 16}.
    for (panel, workload) in [("fig2a", "mlp"), ("fig2b", "logistic")] {
        let mut traces = Vec::new();
        for p in [4u64, 8, 16] {
            let mut c = common::paper_config(steps, workload);
            c.algorithm = "pd-sgdm".into();
            c.hyper.period = p;
            traces.push(common::run_labeled(c, &format!("pd-sgdm(p={p})")));
        }
        common::report(panel, &traces);
        // claim: total MB halves as p doubles, accuracy unchanged
        let mb: Vec<f64> = traces.iter().map(|t| t.total_comm_mb()).collect();
        println!(
            "check {panel}: MB(p=4)/MB(p=8) = {:.2} (≈2), MB(p=8)/MB(p=16) = {:.2} (≈2)\n",
            mb[0] / mb[1],
            mb[1] / mb[2]
        );
    }

    // (c, d): CPD-SGDM(sign) vs the cheapest full-precision PD-SGDM(p=16).
    for (panel, workload) in [("fig2c", "mlp"), ("fig2d", "logistic")] {
        let mut traces = Vec::new();
        let mut c = common::paper_config(steps, workload);
        c.algorithm = "pd-sgdm".into();
        c.hyper.period = 16;
        traces.push(common::run_labeled(c, "pd-sgdm(p=16)"));
        for p in [4u64, 8, 16] {
            let mut c = common::paper_config(steps, workload);
            c.algorithm = "cpd-sgdm".into();
            c.compressor = Some("sign".into());
            c.hyper.period = p;
            traces.push(common::run_labeled(c, &format!("cpd-sgdm(p={p},sign)")));
        }
        common::report(panel, &traces);
        let full = traces[0].total_comm_mb();
        for t in &traces[1..] {
            println!(
                "check {panel} {}: {:.2} MB vs pd-sgdm(p=16) {full:.2} MB -> {:.1}x less, acc Δ = {:+.4}",
                t.label,
                t.total_comm_mb(),
                full / t.total_comm_mb(),
                t.final_accuracy() - traces[0].final_accuracy()
            );
        }
        println!();
    }
}
