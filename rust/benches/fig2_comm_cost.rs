//! **Figure 2** — accuracy vs communication cost (MB).
//!
//! Paper: (a, b) PD-SGDM with p ∈ {4, 8, 16}: larger p reaches the same
//! accuracy with proportionally less traffic. (c, d) CPD-SGDM (sign, p ∈
//! {4, 8, 16}) vs PD-SGDM(p=16): compression wins by a further ~32x per
//! round, so CPD-SGDM dominates even the cheapest full-precision run.
//!
//! The x-axis here is the byte-exact wire accounting of comm::Network
//! (compressed payloads use each operator's true codec size). Run with
//! `cargo bench --bench fig2_comm_cost`.
//!
//! The final section sweeps *communication budgets* instead of step
//! counts (`StopCondition::CommBudgetMb`): every run halts within one
//! comm round of the budget, which is the fair way to compare
//! periodic/compressed schedules against every-step baselines like
//! D-SGD under equal traffic.

mod common;

use pdsgdm::coordinator::StopCondition;

fn main() {
    let steps = 2000;

    // (a, b): PD-SGDM accuracy-vs-MB for p in {4, 8, 16}.
    for (panel, workload) in [("fig2a", "mlp"), ("fig2b", "logistic")] {
        let mut traces = Vec::new();
        for p in [4u64, 8, 16] {
            let mut c = common::paper_config(steps, workload);
            c.algorithm = "pd-sgdm".into();
            c.hyper.period = p;
            traces.push(common::run_labeled(c, &format!("pd-sgdm(p={p})")));
        }
        common::report(panel, &traces);
        // claim: total MB halves as p doubles, accuracy unchanged
        let mb: Vec<f64> = traces.iter().map(|t| t.total_comm_mb()).collect();
        println!(
            "check {panel}: MB(p=4)/MB(p=8) = {:.2} (≈2), MB(p=8)/MB(p=16) = {:.2} (≈2)\n",
            mb[0] / mb[1],
            mb[1] / mb[2]
        );
    }

    // (c, d): CPD-SGDM(sign) vs the cheapest full-precision PD-SGDM(p=16).
    for (panel, workload) in [("fig2c", "mlp"), ("fig2d", "logistic")] {
        let mut traces = Vec::new();
        let mut c = common::paper_config(steps, workload);
        c.algorithm = "pd-sgdm".into();
        c.hyper.period = 16;
        traces.push(common::run_labeled(c, "pd-sgdm(p=16)"));
        for p in [4u64, 8, 16] {
            let mut c = common::paper_config(steps, workload);
            c.algorithm = "cpd-sgdm".into();
            c.compressor = Some("sign".into());
            c.hyper.period = p;
            traces.push(common::run_labeled(c, &format!("cpd-sgdm(p={p},sign)")));
        }
        common::report(panel, &traces);
        let full = traces[0].total_comm_mb();
        for t in &traces[1..] {
            println!(
                "check {panel} {}: {:.2} MB vs pd-sgdm(p=16) {full:.2} MB -> {:.1}x less, acc Δ = {:+.4}",
                t.label,
                t.total_comm_mb(),
                full / t.total_comm_mb(),
                t.final_accuracy() - traces[0].final_accuracy()
            );
        }
        println!();
    }

    // Budget sweep: loss reachable under a fixed traffic allowance. The
    // session stops within one comm round of each budget, so every cell
    // spends (almost exactly) the same bytes — the comparison the
    // wall-clock/deployment papers ask for, impossible with fixed step
    // counts because per-round payloads differ by ~32x across this table.
    println!("# fig2e: loss under equal comm budgets (MB) — budget-stopped runs");
    println!("algorithm,budget_mb,steps_used,comm_mb,loss");
    let mut traces = Vec::new();
    for budget_mb in [0.5f64, 2.0, 8.0] {
        for (algo, compressor, p) in [
            ("d-sgd", None, 1u64),
            ("pd-sgdm", None, 4),
            ("cpd-sgdm", Some("sign"), 4),
        ] {
            let mut c = common::paper_config(200_000, "mlp");
            c.algorithm = algo.into();
            c.compressor = compressor.map(str::to_string);
            c.hyper.period = p;
            c.eval_every = 50;
            let label = format!("{algo}(p={p})@{budget_mb}MB");
            let t = common::run_until_labeled(
                c,
                Some(StopCondition::Any(vec![
                    StopCondition::Steps(200_000),
                    StopCondition::CommBudgetMb(budget_mb),
                ])),
                &label,
            );
            println!(
                "{algo},{budget_mb},{},{:.3},{:.4}",
                t.points.last().map(|p| p.step).unwrap_or(0),
                t.total_comm_mb(),
                t.final_loss()
            );
            traces.push(t);
        }
    }
    common::report("fig2e_budget", &traces);
}
