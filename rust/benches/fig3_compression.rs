//! **Figure 3** — CPD-SGDM convergence under compression.
//!
//! Paper: training loss vs iterations of CPD-SGDM (sign operator,
//! p ∈ {4, 8, 16}) against full-precision PD-SGDM(p=4), on ResNet20 (a)
//! and ResNet50 (b). Claim: compressed communication converges to almost
//! the same loss as full precision.
//!
//! Run with `cargo bench --bench fig3_compression`.

mod common;

fn main() {
    let steps = 2000;
    for (panel, workload) in [("fig3a", "mlp"), ("fig3b", "logistic")] {
        let mut traces = Vec::new();

        let mut c = common::paper_config(steps, workload);
        c.algorithm = "pd-sgdm".into();
        c.hyper.period = 4;
        traces.push(common::run_labeled(c, "pd-sgdm(p=4)"));

        for p in [4u64, 8, 16] {
            let mut c = common::paper_config(steps, workload);
            c.algorithm = "cpd-sgdm".into();
            c.compressor = Some("sign".into());
            c.hyper.period = p;
            traces.push(common::run_labeled(c, &format!("cpd-sgdm(p={p},sign)")));
        }
        common::report(panel, &traces);

        let base = traces[0].final_loss();
        for t in &traces[1..] {
            let dl = (t.final_loss() - base).abs();
            println!(
                "check {panel} {}: |final loss - full precision| = {dl:.4} (≤0.25)  {}",
                t.label,
                if dl <= 0.25 { "OK" } else { "MISMATCH" }
            );
        }
        println!();
    }
}
