//! **Hot-path microbenchmarks** — the L3 kernels EXPERIMENTS.md §Perf
//! tracks: momentum update, gossip mixing, and every compression
//! operator, at the e2e model size (d = 3.45M) and a 16M "GPT-2-small
//! slice" size. Also times one XLA train_step / momentum / mix artifact
//! execution when artifacts are present, so the L3-vs-L2 cost split is
//! visible.
//!
//! Run with `cargo bench --bench hotpath`.

use std::time::Duration;

use pdsgdm::benchlib::{bench, black_box, report};
use pdsgdm::comm::Network;
use pdsgdm::compress::{Compressor, Identity, Qsgd, RandK, Sign, TopK};
use pdsgdm::optim::MomentumState;
use pdsgdm::rng::Xoshiro256;
use pdsgdm::topology::{mixing_matrix, Topology, Weighting};

const BUDGET: Duration = Duration::from_millis(400);

fn bench_momentum(d: usize) {
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut x = rng.normal_vec(d, 1.0);
    let g = rng.normal_vec(d, 1.0);
    let mut st = MomentumState::new(d, 0.9, 1e-4);
    let stats = bench(3, BUDGET, || {
        st.step(&mut x, &g, 0.01);
        black_box(x[0]);
    });
    report(
        &format!("momentum_step d={d}"),
        &stats,
        Some((d as f64, "param")),
    );
}

fn bench_gossip(k: usize, d: usize) {
    let g = Topology::Ring.build(k, 0);
    let w = mixing_matrix(&g, Weighting::UniformDegree);
    let gossip = pdsgdm::algorithms::GossipState::new(w);
    let mut rng = Xoshiro256::seed_from_u64(2);
    let mut xs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
    let mut net = Network::new(&g);
    let stats = bench(2, BUDGET, || {
        black_box(gossip.mix(&mut xs, &mut net));
    });
    report(
        &format!("gossip_mix K={k} d={d}"),
        &stats,
        Some(((k * d) as f64, "param")),
    );
}

fn bench_compressors(d: usize) {
    let mut rng = Xoshiro256::seed_from_u64(3);
    let x = rng.normal_vec(d, 1.0);
    let ops: Vec<(&str, Box<dyn Compressor>)> = vec![
        ("sign", Box::new(Sign)),
        ("top0.01", Box::new(TopK { ratio: 0.01 })),
        ("rand0.01", Box::new(RandK { ratio: 0.01 })),
        ("qsgd4", Box::new(Qsgd { levels: 4 })),
        ("identity", Box::new(Identity)),
    ];
    for (name, op) in ops {
        let mut r = rng.fork(7);
        let stats = bench(2, BUDGET, || {
            black_box(op.compress(&x, &mut r).wire_bytes);
        });
        report(
            &format!("compress/{name} d={d}"),
            &stats,
            Some((d as f64, "elem")),
        );
    }
}

fn bench_xla_artifacts() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("tiny.meta.json").exists() {
        println!("(skipping XLA artifact benches: run `make artifacts`)");
        return;
    }
    let rt = pdsgdm::runtime::Runtime::new(dir).expect("runtime");
    for model in ["tiny", "e2e"] {
        let Ok(step) = rt.train_step(model) else {
            continue;
        };
        let m = step.manifest.clone();
        let params = m.init_params(1);
        let mut rng = Xoshiro256::seed_from_u64(4);
        let tokens: Vec<i32> = (0..m.batch * (m.seq_len + 1))
            .map(|_| rng.below(m.vocab) as i32)
            .collect();
        let stats = bench(1, Duration::from_millis(if m.d > 1_000_000 { 100 } else { 400 }), || {
            black_box(step.run(&params, &tokens).expect("exec").0);
        });
        let flops = 6.0 * m.d as f64 * (m.batch * m.seq_len) as f64;
        report(
            &format!("xla_train_step model={model} d={}", m.d),
            &stats,
            Some((flops, "flop")),
        );

        let mstep = rt.momentum_step(model).expect("momentum");
        let mut r2 = Xoshiro256::seed_from_u64(5);
        let (x, mm, g) = (
            r2.normal_vec(m.d, 1.0),
            r2.normal_vec(m.d, 1.0),
            r2.normal_vec(m.d, 1.0),
        );
        let stats = bench(1, BUDGET, || {
            black_box(mstep.run(&x, &mm, &g, 0.01, 0.9).expect("exec").0[0]);
        });
        report(
            &format!("xla_momentum model={model} d={}", m.d),
            &stats,
            Some((m.d as f64, "param")),
        );
    }
}

fn main() {
    println!("# hotpath microbenchmarks (median over repeated runs)\n");
    for d in [3_454_464usize, 16_000_000] {
        bench_momentum(d);
    }
    for (k, d) in [(8usize, 3_454_464usize), (16, 1_000_000)] {
        bench_gossip(k, d);
    }
    bench_compressors(3_454_464);
    println!();
    bench_xla_artifacts();
}
