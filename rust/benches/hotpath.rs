//! **Hot-path benchmarks** — the numbers EXPERIMENTS.md §Perf tracks,
//! emitted both as console rows and machine-readable records in
//! `BENCH_hotpath.json` at the repo root (the tracked perf trajectory).
//!
//! Sections:
//!
//! 1. `algo_step` — END-TO-END `Algorithm::step` throughput of PD-SGDM on
//!    the MLP oracle at K ∈ {1, 4, 8, 16}, sequential vs the pooled
//!    [`pdsgdm::engine::LocalStepEngine`], including the K-scaling
//!    speedup and a bit-identical-trace determinism check. This is the
//!    paper's "linear speedup in K" claim measured on this machine.
//! 2. `mix_round` / `comm_round` — the communication half of the step
//!    loop at K ∈ {4, 8, 16}: one full-precision gossip round
//!    (`GossipState::mix`) and one compressed exchange round
//!    (`CompressedExchange::round`, Sign codec), each sequential vs
//!    fanned over the persistent [`pdsgdm::engine::WorkerPool`], with a
//!    seq-vs-pool bit-identity assertion before timing (a determinism
//!    break is a hard bench failure, which CI turns into a red build).
//!    Plus `mix_round_largek` / `algo_step_largek`: the same phases on
//!    the exponential graph at K ∈ {64, 256, 1024} (d up to 65536),
//!    with a no-reallocation assert on the flat arena's data pointer.
//! 3. L3 micro-kernels: momentum update, gossip mixing, every
//!    compression operator, and every wire codec (encode+decode
//!    round-trip, asserting the `wire_bytes == encode(..).len()`
//!    invariant) at the e2e model size (d = 3.45M) and a 16M
//!    "GPT-2-small slice".
//! 4. One XLA train_step / momentum execution when artifacts are present
//!    AND the crate was built with `--features pjrt`, so the L3-vs-L2
//!    cost split is visible.
//!
//! Run with `cargo bench --bench hotpath` (append `-- --smoke` for the
//! CI-speed mode: same code paths, shrunken sizes/budgets). Both modes
//! write `BENCH_hotpath.json` at the repo root — CI asserts the file
//! exists after every smoke run — and the document's top-level
//! `"smoke"` flag marks shrunken-size records so they are never
//! cross-compared with full-run trajectory numbers.

use std::time::Duration;

use pdsgdm::algorithms::{Algorithm, CompressedExchange, GossipState, Hyper, PdSgdm};
use pdsgdm::arena::ParamArena;
use pdsgdm::benchlib::{bench, black_box, budget, report, smoke, stats_json, JsonSink};
use pdsgdm::comm::Network;
use pdsgdm::compress::{Compressor, Identity, Qsgd, RandK, Sign, TopK};
use pdsgdm::data::{Blobs, Sharding};
use pdsgdm::engine::WorkerPool;
use pdsgdm::grad::{GradientSource, Mlp, Quadratic};
use pdsgdm::json::Json;
use pdsgdm::optim::{LrSchedule, MomentumState};
use pdsgdm::rng::Xoshiro256;
use pdsgdm::topology::{build_sparse, mixing_matrix, Topology, Weighting};

/// Fill a fresh K×d arena with unit normals (bench inputs).
fn normal_arena(k: usize, d: usize, rng: &mut Xoshiro256) -> ParamArena {
    let mut xs = ParamArena::zeros(k, d);
    for i in 0..k {
        xs.row_mut(i).copy_from_slice(&rng.normal_vec(d, 1.0));
    }
    xs
}

// ---------------------------------------------------------------------------
// Section 1: end-to-end algo.step K-scaling
// ---------------------------------------------------------------------------

/// Fresh (algorithm, oracle, network) triple for the K-scaling bench —
/// identical seeds per call so sequential/parallel runs see identical
/// randomness.
fn algo_setup(k: usize, parallel: bool) -> (PdSgdm, Mlp, Network) {
    let (n, dim, classes, hidden, batch) = if smoke() {
        (512, 16, 4, 32, 16)
    } else {
        (4096, 64, 10, 256, 64)
    };
    let data = Blobs { n, dim, classes, spread: 3.0 }.generate(2020);
    let src = Mlp::new(data, k, Sharding::Iid, hidden, batch, 0.0, 7);
    let graph = Topology::Ring.build(k, 0);
    let w = mixing_matrix(&graph, Weighting::UniformDegree);
    let net = Network::new(&graph);
    let hyper = Hyper {
        lr: LrSchedule::Constant { eta: 0.05 },
        mu: 0.9,
        weight_decay: 1e-4,
        period: 4,
        gamma: 0.4,
    };
    let mut algo = PdSgdm::new(k, src.init(1), w, hyper);
    algo.set_parallel(parallel);
    (algo, src, net)
}

/// Run `steps` fresh iterations; return (per-step mean losses, final
/// per-worker iterates) for the determinism cross-check.
fn algo_trace(k: usize, parallel: bool, steps: u64) -> (Vec<f64>, Vec<Vec<f32>>) {
    let (mut algo, mut src, mut net) = algo_setup(k, parallel);
    let losses = (0..steps)
        .map(|t| algo.step(t, &mut src, &mut net).mean_loss)
        .collect();
    let xs = (0..k).map(|w| algo.params(w).to_vec()).collect();
    (losses, xs)
}

fn bench_algo_step(sink: &mut JsonSink) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("\n## algo_step end-to-end K-scaling (pd-sgdm on MLP oracle, {cores} cores)\n");
    for k in [1usize, 4, 8, 16] {
        // Determinism first: the parallel engine must reproduce the
        // sequential trace bit-for-bit (ISSUE 1 acceptance criterion).
        let (l_seq, x_seq) = algo_trace(k, false, 8);
        let (l_par, x_par) = algo_trace(k, true, 8);
        let bit_identical = l_seq.iter().zip(&l_par).all(|(a, b)| a.to_bits() == b.to_bits())
            && x_seq.iter().zip(&x_par).all(|(a, b)| {
                a.iter().zip(b).all(|(p, q)| p.to_bits() == q.to_bits())
            });
        assert!(bit_identical, "K={k}: parallel trace diverged from sequential");

        let mut median_seq_ns = 0.0f64;
        for parallel in [false, true] {
            let (mut algo, mut src, mut net) = algo_setup(k, parallel);
            let d = src.dim();
            let mut t = 0u64;
            let stats = bench(if smoke() { 1 } else { 2 }, budget(), || {
                black_box(algo.step(t, &mut src, &mut net).mean_loss);
                t += 1;
            });
            let mode = if parallel { "parallel" } else { "sequential" };
            report(
                &format!("algo_step[pd-sgdm] K={k} d={d} {mode}"),
                &stats,
                Some(((k * d) as f64, "worker-param")),
            );
            let median_ns = stats.median.as_nanos() as f64;
            let mut fields = vec![
                ("algo", Json::Str("pd-sgdm".into())),
                ("workload", Json::Str("mlp".into())),
                ("k", Json::Num(k as f64)),
                ("d", Json::Num(d as f64)),
                ("cores", Json::Num(cores as f64)),
                ("mode", Json::Str(mode.into())),
            ];
            fields.extend(stats_json(&stats, Some((k * d) as f64)));
            if parallel {
                let speedup = median_seq_ns / median_ns.max(1.0);
                fields.push(("speedup_vs_seq", Json::Num(speedup)));
                fields.push(("bit_identical", Json::Bool(bit_identical)));
                println!(
                    "  -> K={k}: parallel speedup {speedup:.2}x over sequential \
                     (bit-identical trace: {bit_identical})"
                );
            } else {
                median_seq_ns = median_ns;
            }
            sink.push("algo_step", fields);
        }
    }
}

// ---------------------------------------------------------------------------
// Section 2: comm-round seq-vs-pool (the tentpole's second half)
// ---------------------------------------------------------------------------

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// One full-precision gossip round, sequential vs fanned over a
/// persistent pool, at K ∈ {4, 8, 16} — with a bitwise determinism
/// assert before any timing. Pool wins are expected from d ≈ 4096 up
/// (per-receiver fused weighted-sum ≫ dispatch cost); the records are
/// what EXPERIMENTS.md §Perf's before/after table cites.
fn bench_mix_round(sink: &mut JsonSink) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("\n## mix_round seq-vs-pool (gossip comm phase, {cores} cores)\n");
    let ds: &[usize] = if smoke() { &[4096] } else { &[4096, 1_048_576] };
    for &k in &[4usize, 8, 16] {
        let graph = Topology::Ring.build(k, 0);
        let w = mixing_matrix(&graph, Weighting::UniformDegree);
        let pool = WorkerPool::new(k.min(cores));
        for &d in ds {
            let mut rng = Xoshiro256::seed_from_u64(0x317);
            let xs0 = normal_arena(k, d, &mut rng);
            // Determinism first: pooled mixing must be bit-identical.
            {
                let mut gs_seq = GossipState::new(w.clone());
                let mut gs_pool = GossipState::new(w.clone());
                let mut net_seq = Network::new(&graph);
                let mut net_pool = Network::new(&graph);
                let mut xa = xs0.clone();
                let mut xb = xs0.clone();
                for _ in 0..2 {
                    gs_seq.mix(&mut xa, &mut net_seq, None);
                    gs_pool.mix(&mut xb, &mut net_pool, Some(&pool));
                }
                let ok = bits(xa.as_slice()) == bits(xb.as_slice());
                assert!(ok, "mix_round K={k} d={d}: pooled mix diverged from sequential");
            }
            let mut median_seq_ns = 0.0f64;
            for mode in ["sequential", "pool"] {
                let mut gs = GossipState::new(w.clone());
                let mut net = Network::new(&graph);
                let mut xs = xs0.clone();
                let pool_opt = if mode == "pool" { Some(&pool) } else { None };
                let stats = bench(2, budget(), || {
                    black_box(gs.mix(&mut xs, &mut net, pool_opt));
                });
                report(
                    &format!("mix_round K={k} d={d} {mode}"),
                    &stats,
                    Some(((k * d) as f64, "param")),
                );
                let median_ns = stats.median.as_nanos() as f64;
                let mut fields = vec![
                    ("k", Json::Num(k as f64)),
                    ("d", Json::Num(d as f64)),
                    ("cores", Json::Num(cores as f64)),
                    ("mode", Json::Str(mode.into())),
                ];
                fields.extend(stats_json(&stats, Some((k * d) as f64)));
                if mode == "pool" {
                    let speedup = median_seq_ns / median_ns.max(1.0);
                    fields.push(("speedup_vs_seq", Json::Num(speedup)));
                    println!("  -> K={k} d={d}: pool speedup {speedup:.2}x over sequential");
                } else {
                    median_seq_ns = median_ns;
                }
                sink.push("mix_round", fields);
            }
        }
    }
}

/// One compressed exchange round (Sign codec: compress + encode + ship +
/// decode), sequential vs pooled, at K ∈ {4, 8, 16} — again with the
/// bitwise determinism assert up front.
fn bench_comm_round(sink: &mut JsonSink) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("\n## comm_round seq-vs-pool (compressed exchange, sign codec, {cores} cores)\n");
    let ds: &[usize] = if smoke() { &[4096] } else { &[4096, 1_048_576] };
    for &k in &[4usize, 8, 16] {
        let graph = Topology::Ring.build(k, 0);
        let pool = WorkerPool::new(k.min(cores));
        for &d in ds {
            let mut rng = Xoshiro256::seed_from_u64(0xC0);
            let inputs = normal_arena(k, d, &mut rng);
            // Determinism first (Sign is deterministic; the forked
            // per-worker streams make this hold for stochastic codecs
            // too — property-tested in the crate's unit tests).
            {
                let mut ex_seq = CompressedExchange::new(k, 9);
                let mut ex_pool = CompressedExchange::new(k, 9);
                let mut net_seq = Network::new(&graph);
                let mut net_pool = Network::new(&graph);
                for _ in 0..2 {
                    let a = ex_seq
                        .round(&Sign, &mut net_seq, &inputs, None, |_, _| {})
                        .clone();
                    let b = ex_pool.round(&Sign, &mut net_pool, &inputs, Some(&pool), |_, _| {});
                    let ok = bits(a.as_slice()) == bits(b.as_slice());
                    assert!(ok, "comm_round K={k} d={d}: pooled exchange diverged");
                }
            }
            let mut median_seq_ns = 0.0f64;
            for mode in ["sequential", "pool"] {
                let mut ex = CompressedExchange::new(k, 11);
                let mut net = Network::new(&graph);
                let pool_opt = if mode == "pool" { Some(&pool) } else { None };
                let stats = bench(2, budget(), || {
                    black_box(ex.round(&Sign, &mut net, &inputs, pool_opt, |_, _| {}).k());
                });
                report(
                    &format!("comm_round[sign] K={k} d={d} {mode}"),
                    &stats,
                    Some(((k * d) as f64, "param")),
                );
                let median_ns = stats.median.as_nanos() as f64;
                let mut fields = vec![
                    ("operator", Json::Str("sign".into())),
                    ("k", Json::Num(k as f64)),
                    ("d", Json::Num(d as f64)),
                    ("cores", Json::Num(cores as f64)),
                    ("mode", Json::Str(mode.into())),
                ];
                fields.extend(stats_json(&stats, Some((k * d) as f64)));
                if mode == "pool" {
                    let speedup = median_seq_ns / median_ns.max(1.0);
                    fields.push(("speedup_vs_seq", Json::Num(speedup)));
                    println!("  -> K={k} d={d}: pool speedup {speedup:.2}x over sequential");
                } else {
                    median_seq_ns = median_ns;
                }
                sink.push("comm_round", fields);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Section 2b: large-K fleet scaling (ISSUE 7 — flat arenas + sparse CSR
// weights). Exponential graph at K ∈ {64, 256, 1024}: one gossip round
// and one end-to-end algorithm step, with bit-identity asserts at the
// sizes where a second fleet copy is cheap and a no-reallocation assert
// at every K (the arena data pointer must ping-pong between exactly two
// stable allocations once the scratch arena is materialized).
// ---------------------------------------------------------------------------

fn bench_largek(sink: &mut JsonSink) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("\n## large-K fleet (expgraph, sparse CSR weights, flat arenas, {cores} cores)\n");
    for &k in &[64usize, 256, 1024] {
        // K=1024 mixes at d=65536 (full mode) — the ISSUE 7 acceptance
        // size; the oracle-driven step uses a smaller d so the quadratic
        // problem data (two more K×d tables) stays within bench memory.
        let (d_mix, d_step) = match (smoke(), k) {
            (true, 1024) => (1024, 512),
            (true, _) => (512, 256),
            (false, 1024) => (65_536, 16_384),
            (false, _) => (16_384, 4_096),
        };
        let (graph, mw, rho) = build_sparse(Topology::ExpGraph, k, Weighting::UniformDegree, 0);
        println!("  K={k} expgraph: rho={rho:.4} edges={}", graph.edge_count());

        // -- mix_round_largek --
        let mut rng = Xoshiro256::seed_from_u64(0x517 + k as u64);
        let mut xs = normal_arena(k, d_mix, &mut rng);
        if k <= 256 {
            // Pooled mixing must reproduce the sequential round
            // bit-for-bit (a second fleet copy is cheap at these sizes).
            let pool = WorkerPool::new(k.min(cores));
            let mut gs_seq = GossipState::new(mw.clone());
            let mut gs_pool = GossipState::new(mw.clone());
            let mut net_seq = Network::new(&graph);
            let mut net_pool = Network::new(&graph);
            let mut xa = xs.clone();
            let mut xb = xs.clone();
            for _ in 0..2 {
                gs_seq.mix(&mut xa, &mut net_seq, None);
                gs_pool.mix(&mut xb, &mut net_pool, Some(&pool));
            }
            assert!(
                bits(xa.as_slice()) == bits(xb.as_slice()),
                "largek K={k}: pooled mix diverged from sequential"
            );
        }
        let mut gs = GossipState::new(mw.clone());
        let mut net = Network::new(&graph);
        let p0 = xs.data_ptr();
        gs.mix(&mut xs, &mut net, None); // materializes scratch + staging
        let p1 = xs.data_ptr();
        for _ in 0..2 {
            gs.mix(&mut xs, &mut net, None);
            let p = xs.data_ptr();
            assert!(p == p0 || p == p1, "largek K={k}: mix reallocated the arena");
        }
        let stats = bench(1, budget(), || {
            black_box(gs.mix(&mut xs, &mut net, None));
        });
        report(
            &format!("mix_round_largek K={k} d={d_mix} expgraph"),
            &stats,
            Some(((k * d_mix) as f64, "param")),
        );
        let mut fields = vec![
            ("topology", Json::Str("expgraph".into())),
            ("k", Json::Num(k as f64)),
            ("d", Json::Num(d_mix as f64)),
            ("cores", Json::Num(cores as f64)),
            ("rho", Json::Num(rho)),
        ];
        fields.extend(stats_json(&stats, Some((k * d_mix) as f64)));
        sink.push("mix_round_largek", fields);
        drop(gs);
        drop(xs);

        // -- algo_step_largek --
        let hyper = Hyper {
            lr: LrSchedule::Constant { eta: 0.01 },
            mu: 0.9,
            weight_decay: 0.0,
            period: 4,
            gamma: 0.4,
        };
        if k == 64 {
            // End-to-end determinism at the smallest fleet: the pooled
            // engine + arena-backed gossip must retrace the sequential
            // run bit-for-bit.
            let run = |parallel: bool| -> Vec<u32> {
                let mut src = Quadratic::new(k, d_step, 1.0, 0.1, 11);
                let mut algo = PdSgdm::new(k, src.init(1), mw.clone(), hyper.clone());
                algo.set_parallel(parallel);
                let mut net = Network::new(&graph);
                for t in 0..6 {
                    algo.step(t, &mut src, &mut net);
                }
                (0..k)
                    .flat_map(|i| algo.params(i).iter().map(|x| x.to_bits()))
                    .collect()
            };
            assert!(
                run(false) == run(true),
                "largek K={k}: parallel algo trace diverged from sequential"
            );
        }
        let mut src = Quadratic::new(k, d_step, 1.0, 0.1, 13);
        let mut algo = PdSgdm::new(k, src.init(2), mw.clone(), hyper);
        algo.set_parallel(true);
        let mut net = Network::new(&graph);
        let mut t = 0u64;
        let stats = bench(1, budget(), || {
            black_box(algo.step(t, &mut src, &mut net).mean_loss);
            t += 1;
        });
        report(
            &format!("algo_step_largek[pd-sgdm] K={k} d={d_step} expgraph"),
            &stats,
            Some(((k * d_step) as f64, "worker-param")),
        );
        let mut fields = vec![
            ("algo", Json::Str("pd-sgdm".into())),
            ("topology", Json::Str("expgraph".into())),
            ("k", Json::Num(k as f64)),
            ("d", Json::Num(d_step as f64)),
            ("cores", Json::Num(cores as f64)),
        ];
        fields.extend(stats_json(&stats, Some((k * d_step) as f64)));
        sink.push("algo_step_largek", fields);
    }
}

// ---------------------------------------------------------------------------
// Section 3: L3 micro-kernels
// ---------------------------------------------------------------------------

fn bench_momentum(d: usize, sink: &mut JsonSink) {
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut x = rng.normal_vec(d, 1.0);
    let g = rng.normal_vec(d, 1.0);
    let mut st = MomentumState::new(d, 0.9, 1e-4);
    let stats = bench(3, budget(), || {
        st.step(&mut x, &g, 0.01);
        black_box(x[0]);
    });
    report(&format!("momentum_step d={d}"), &stats, Some((d as f64, "param")));
    let mut fields = vec![("d", Json::Num(d as f64))];
    fields.extend(stats_json(&stats, Some(d as f64)));
    sink.push("momentum_step", fields);
}

fn bench_gossip(k: usize, d: usize, sink: &mut JsonSink) {
    let g = Topology::Ring.build(k, 0);
    let w = mixing_matrix(&g, Weighting::UniformDegree);
    let mut gossip = GossipState::new(w);
    let mut rng = Xoshiro256::seed_from_u64(2);
    let mut xs = normal_arena(k, d, &mut rng);
    let mut net = Network::new(&g);
    let stats = bench(2, budget(), || {
        black_box(gossip.mix(&mut xs, &mut net, None));
    });
    report(&format!("gossip_mix K={k} d={d}"), &stats, Some(((k * d) as f64, "param")));
    let mut fields = vec![("k", Json::Num(k as f64)), ("d", Json::Num(d as f64))];
    fields.extend(stats_json(&stats, Some((k * d) as f64)));
    sink.push("gossip_mix", fields);
}

fn bench_compressors(d: usize, sink: &mut JsonSink) {
    let mut rng = Xoshiro256::seed_from_u64(3);
    let x = rng.normal_vec(d, 1.0);
    let ops: Vec<(&str, Box<dyn Compressor>)> = vec![
        ("sign", Box::new(Sign)),
        ("top0.01", Box::new(TopK { ratio: 0.01 })),
        ("rand0.01", Box::new(RandK { ratio: 0.01 })),
        ("qsgd4", Box::new(Qsgd { levels: 4 })),
        ("identity", Box::new(Identity)),
    ];
    for (name, op) in ops {
        let mut r = rng.fork(7);
        let stats = bench(2, budget(), || {
            black_box(op.compress(&x, &mut r).wire_bytes);
        });
        report(&format!("compress/{name} d={d}"), &stats, Some((d as f64, "elem")));
        let mut fields = vec![
            ("operator", Json::Str(name.into())),
            ("d", Json::Num(d as f64)),
        ];
        fields.extend(stats_json(&stats, Some(d as f64)));
        sink.push("compress", fields);
    }
}

fn bench_wire_codecs(d: usize, sink: &mut JsonSink) {
    let mut rng = Xoshiro256::seed_from_u64(9);
    let x = rng.normal_vec(d, 1.0);
    let ops: Vec<(&str, Box<dyn Compressor>)> = vec![
        ("sign", Box::new(Sign)),
        ("top0.01", Box::new(TopK { ratio: 0.01 })),
        ("rand0.01", Box::new(RandK { ratio: 0.01 })),
        ("qsgd4", Box::new(Qsgd { levels: 4 })),
        ("identity", Box::new(Identity)),
    ];
    for (name, op) in ops {
        let mut r = rng.fork(11);
        let q = op.compress(&x, &mut r);
        let wire = op.encode(&q);
        assert_eq!(wire.len(), q.wire_bytes, "{name}: wire-size invariant broken");
        let stats = bench(2, budget(), || {
            let enc = op.encode(&q);
            black_box(op.decode(&enc, d).len());
        });
        report(
            &format!("wire_codec/{name} d={d}"),
            &stats,
            Some((q.wire_bytes as f64, "wire-byte")),
        );
        let mut fields = vec![
            ("operator", Json::Str(name.into())),
            ("d", Json::Num(d as f64)),
            ("wire_bytes", Json::Num(q.wire_bytes as f64)),
        ];
        fields.extend(stats_json(&stats, Some(q.wire_bytes as f64)));
        sink.push("wire_codec", fields);
    }
}

// ---------------------------------------------------------------------------
// Section 4: XLA artifacts (pjrt builds only)
// ---------------------------------------------------------------------------

fn bench_xla_artifacts(sink: &mut JsonSink) {
    if !pdsgdm::runtime::HAS_PJRT {
        println!("(skipping XLA artifact benches: built without the pjrt feature)");
        return;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("tiny.meta.json").exists() {
        println!("(skipping XLA artifact benches: run `make artifacts`)");
        return;
    }
    let rt = pdsgdm::runtime::Runtime::new(dir).expect("runtime");
    for model in ["tiny", "e2e"] {
        let Ok(step) = rt.train_step(model) else {
            continue;
        };
        let m = step.manifest.clone();
        let params = m.init_params(1);
        let mut rng = Xoshiro256::seed_from_u64(4);
        let tokens: Vec<i32> = (0..m.batch * (m.seq_len + 1))
            .map(|_| rng.below(m.vocab) as i32)
            .collect();
        let stats = bench(1, Duration::from_millis(if m.d > 1_000_000 { 100 } else { 400 }), || {
            black_box(step.run(&params, &tokens).expect("exec").0);
        });
        let flops = 6.0 * m.d as f64 * (m.batch * m.seq_len) as f64;
        report(
            &format!("xla_train_step model={model} d={}", m.d),
            &stats,
            Some((flops, "flop")),
        );
        let mut fields = vec![
            ("model", Json::Str(model.into())),
            ("d", Json::Num(m.d as f64)),
        ];
        fields.extend(stats_json(&stats, Some(flops)));
        sink.push("xla_train_step", fields);

        let mstep = rt.momentum_step(model).expect("momentum");
        let mut r2 = Xoshiro256::seed_from_u64(5);
        let (x, mm, g) = (
            r2.normal_vec(m.d, 1.0),
            r2.normal_vec(m.d, 1.0),
            r2.normal_vec(m.d, 1.0),
        );
        let stats = bench(1, budget(), || {
            black_box(mstep.run(&x, &mm, &g, 0.01, 0.9).expect("exec").0[0]);
        });
        report(
            &format!("xla_momentum model={model} d={}", m.d),
            &stats,
            Some((m.d as f64, "param")),
        );
        let mut fields = vec![
            ("model", Json::Str(model.into())),
            ("d", Json::Num(m.d as f64)),
        ];
        fields.extend(stats_json(&stats, Some(m.d as f64)));
        sink.push("xla_momentum", fields);
    }
}

fn main() {
    let mode = if smoke() { " [--smoke]" } else { "" };
    println!("# hotpath benchmarks (median over repeated runs){mode}\n");
    // Both modes write the same tracked file (CI verifies it appears);
    // the document's "smoke" flag marks shrunken-size records so they
    // are never cross-compared with full-run numbers.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_hotpath.json");
    let mut sink = JsonSink::new(&out);

    bench_algo_step(&mut sink);
    bench_mix_round(&mut sink);
    bench_comm_round(&mut sink);
    bench_largek(&mut sink);

    println!("\n## L3 micro-kernels\n");
    let (d_e2e, d_big) = if smoke() { (100_000usize, 200_000usize) } else { (3_454_464, 16_000_000) };
    for d in [d_e2e, d_big] {
        bench_momentum(d, &mut sink);
    }
    let gossip_cases: [(usize, usize); 2] =
        if smoke() { [(8, 50_000), (16, 25_000)] } else { [(8, 3_454_464), (16, 1_000_000)] };
    for (k, d) in gossip_cases {
        bench_gossip(k, d, &mut sink);
    }
    bench_compressors(d_e2e, &mut sink);
    bench_wire_codecs(d_e2e, &mut sink);
    println!();
    bench_xla_artifacts(&mut sink);

    match sink.flush() {
        Ok(path) => println!("\n{} records -> {}", sink.len(), path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out.display()),
    }
}
