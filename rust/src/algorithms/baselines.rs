//! Baseline algorithms the paper compares against (or that its related
//! work section positions PD-SGDM/CPD-SGDM relative to). Implemented from
//! their original papers — no stubs — so the figure benches can reproduce
//! "who wins by how much" faithfully.

use super::{
    gossip::{self, CompressedExchange, GossipState, ReplicaStore},
    Algorithm, Hyper, StepStats,
};
use crate::arena::ParamArena;
use crate::comm::Network;
use crate::compress::Compressor;
use crate::engine::{LocalStepEngine, LocalUpdate, ScopedTask};
use crate::grad::GradientSource;
use crate::linalg;
use crate::optim::{MomentumBank, MomentumState};
use crate::topology::MixWeights;

// ---------------------------------------------------------------------------
// D-SGD (Lian et al. 2017): plain decentralized SGD, gossip every step.
// ---------------------------------------------------------------------------

pub struct DSgd {
    hyper: Hyper,
    xs: ParamArena,
    gossip: GossipState,
    engine: LocalStepEngine,
}

impl DSgd {
    pub fn new(k: usize, x0: Vec<f32>, w: impl Into<MixWeights>, hyper: Hyper) -> Self {
        let gossip = GossipState::new(w);
        assert_eq!(gossip.k(), k);
        let d = x0.len();
        Self {
            xs: ParamArena::filled(k, &x0),
            gossip,
            engine: LocalStepEngine::new(k, d),
            hyper,
        }
    }
}

impl Algorithm for DSgd {
    fn name(&self) -> String {
        "d-sgd".into()
    }

    fn k(&self) -> usize {
        self.xs.k()
    }

    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats {
        let eta = self.hyper.lr.eta(t);
        let mean_loss = self.engine.local_step(source, &mut self.xs, LocalUpdate::Sgd { eta });
        let bytes = self.gossip.mix(&mut self.xs, net, self.engine.comm_pool());
        StepStats { mean_loss, communicated: true, bytes }
    }

    fn params(&self, k: usize) -> &[f32] {
        self.xs.row(k)
    }

    fn set_parallel(&mut self, on: bool) {
        self.engine.set_parallel(on);
    }

    fn install_shared_pool(&mut self, pool: std::sync::Arc<crate::engine::WorkerPool>) {
        self.engine.install_shared_pool(pool);
    }

    fn set_worker_params(&mut self, k: usize, x: &[f32]) {
        self.xs.row_mut(k).copy_from_slice(x);
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("d-sgd");
        self.xs.state_save(w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("d-sgd")?;
        self.xs.state_load(r, "d-sgd.xs")
    }
}

// ---------------------------------------------------------------------------
// PD-SGD (Li et al. 2019): local SGD + periodic gossip, no momentum.
// ---------------------------------------------------------------------------

pub struct PdSgd {
    hyper: Hyper,
    xs: ParamArena,
    gossip: GossipState,
    engine: LocalStepEngine,
}

impl PdSgd {
    pub fn new(k: usize, x0: Vec<f32>, w: impl Into<MixWeights>, hyper: Hyper) -> Self {
        let gossip = GossipState::new(w);
        assert_eq!(gossip.k(), k);
        let d = x0.len();
        Self {
            xs: ParamArena::filled(k, &x0),
            gossip,
            engine: LocalStepEngine::new(k, d),
            hyper,
        }
    }
}

impl Algorithm for PdSgd {
    fn name(&self) -> String {
        format!("pd-sgd(p={})", self.hyper.period)
    }

    fn k(&self) -> usize {
        self.xs.k()
    }

    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats {
        let eta = self.hyper.lr.eta(t);
        let mean_loss = self.engine.local_step(source, &mut self.xs, LocalUpdate::Sgd { eta });
        let mut stats = StepStats { mean_loss, ..Default::default() };
        if (t + 1) % self.hyper.period == 0 {
            stats.bytes = self.gossip.mix(&mut self.xs, net, self.engine.comm_pool());
            stats.communicated = true;
        }
        stats
    }

    fn params(&self, k: usize) -> &[f32] {
        self.xs.row(k)
    }

    fn set_parallel(&mut self, on: bool) {
        self.engine.set_parallel(on);
    }

    fn install_shared_pool(&mut self, pool: std::sync::Arc<crate::engine::WorkerPool>) {
        self.engine.install_shared_pool(pool);
    }

    fn set_worker_params(&mut self, k: usize, x: &[f32]) {
        self.xs.row_mut(k).copy_from_slice(x);
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("pd-sgd");
        self.xs.state_save(w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("pd-sgd")?;
        self.xs.state_load(r, "pd-sgd.xs")
    }
}

// ---------------------------------------------------------------------------
// D-SGDM (Yu et al. 2019): decentralized momentum SGD, gossip every step.
// With `gossip_momentum = true` the momentum buffers are mixed too —
// the double-payload variant the paper's Related Work criticizes.
// ---------------------------------------------------------------------------

pub struct DSgdm {
    hyper: Hyper,
    xs: ParamArena,
    moms: MomentumBank,
    gossip: GossipState,
    engine: LocalStepEngine,
    gossip_momentum: bool,
}

impl DSgdm {
    pub fn new(
        k: usize,
        x0: Vec<f32>,
        w: impl Into<MixWeights>,
        hyper: Hyper,
        gossip_momentum: bool,
    ) -> Self {
        let gossip = GossipState::new(w);
        assert_eq!(gossip.k(), k);
        let d = x0.len();
        Self {
            xs: ParamArena::filled(k, &x0),
            moms: MomentumBank::new(k, d, hyper.mu, hyper.weight_decay),
            gossip,
            engine: LocalStepEngine::new(k, d),
            hyper,
            gossip_momentum,
        }
    }
}

impl Algorithm for DSgdm {
    fn name(&self) -> String {
        if self.gossip_momentum { "d-sgdm+m".into() } else { "d-sgdm".into() }
    }

    fn k(&self) -> usize {
        self.xs.k()
    }

    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats {
        let eta = self.hyper.lr.eta(t);
        let mean_loss = self.engine.local_step(
            source,
            &mut self.xs,
            LocalUpdate::Momentum { moms: &mut self.moms, eta },
        );
        let mut bytes = self.gossip.mix(&mut self.xs, net, self.engine.comm_pool());
        if self.gossip_momentum {
            // Mix the momentum bank in place — same arena path as the
            // iterates, no per-step clone of K d-length vectors.
            bytes += self.gossip.mix(self.moms.arena_mut(), net, self.engine.comm_pool());
        }
        StepStats { mean_loss, communicated: true, bytes }
    }

    fn params(&self, k: usize) -> &[f32] {
        self.xs.row(k)
    }

    fn set_parallel(&mut self, on: bool) {
        self.engine.set_parallel(on);
    }

    fn install_shared_pool(&mut self, pool: std::sync::Arc<crate::engine::WorkerPool>) {
        self.engine.install_shared_pool(pool);
    }

    fn set_worker_params(&mut self, k: usize, x: &[f32]) {
        self.xs.row_mut(k).copy_from_slice(x);
        self.moms.reset_row(k);
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("d-sgdm");
        w.put_u64(self.gossip_momentum as u64);
        self.xs.state_save(w);
        self.moms.state_save(w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("d-sgdm")?;
        if (r.take_u64()? != 0) != self.gossip_momentum {
            return Err("d-sgdm: gossip_momentum flag mismatch".into());
        }
        self.xs.state_load(r, "d-sgdm.xs")?;
        self.moms.state_load(r)
    }
}

// ---------------------------------------------------------------------------
// C-SGDM: centralized momentum SGD — the Figure 1 comparator. All-reduce
// the average gradient every step, keep one global iterate. Byte
// accounting: parameter-server model, every worker uploads its gradient
// and downloads the average (2 * 4d bytes per worker per step).
// ---------------------------------------------------------------------------

pub struct CSgdm {
    hyper: Hyper,
    k: usize,
    x: Vec<f32>,
    mom: MomentumState,
    engine: LocalStepEngine,
    /// Preallocated average-gradient buffer (zero-allocation step).
    gavg: Vec<f32>,
}

impl CSgdm {
    pub fn new(k: usize, x0: Vec<f32>, hyper: Hyper) -> Self {
        let d = x0.len();
        Self {
            k,
            x: x0,
            mom: MomentumState::new(d, hyper.mu, hyper.weight_decay),
            engine: LocalStepEngine::new(k, d),
            gavg: vec![0.0; d],
            hyper,
        }
    }
}

impl Algorithm for CSgdm {
    fn name(&self) -> String {
        "c-sgdm".into()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn step(&mut self, t: u64, source: &mut dyn GradientSource, _net: &mut Network) -> StepStats {
        let eta = self.hyper.lr.eta(t);
        // All K workers evaluate their minibatch gradient at the single
        // global iterate (in parallel when the source splits); the
        // engine averages them in worker order straight into the
        // preallocated buffer, then the server takes one momentum step.
        let mean_loss = self.engine.grad_at_shared_mean_into(source, &self.x, &mut self.gavg);
        self.mom.step(&mut self.x, &self.gavg, eta);
        StepStats {
            mean_loss,
            communicated: true,
            bytes: (2 * 4 * self.x.len() * self.k) as u64,
        }
    }

    fn set_parallel(&mut self, on: bool) {
        self.engine.set_parallel(on);
    }

    fn install_shared_pool(&mut self, pool: std::sync::Arc<crate::engine::WorkerPool>) {
        self.engine.install_shared_pool(pool);
    }

    fn params(&self, _k: usize) -> &[f32] {
        &self.x
    }

    fn avg_params_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend_from_slice(&self.x);
    }

    fn consensus_error(&self) -> f64 {
        0.0 // single global iterate by construction
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("c-sgdm");
        w.put_f32s(&self.x);
        self.mom.state_save(w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("c-sgdm")?;
        r.take_f32s_into(&mut self.x, "c-sgdm.x")?;
        self.mom.state_load(r)
    }
}

// ---------------------------------------------------------------------------
// CHOCO-SGD (Koloskova et al. 2019): compressed gossip + plain SGD,
// communication every step. Exactly CPD-SGDM's comm protocol with p=1
// and mu=0 — implemented by embedding a CpdSgdm configured that way, so
// the two provably share one code path.
// ---------------------------------------------------------------------------

pub struct ChocoSgd {
    inner: super::CpdSgdm,
}

impl ChocoSgd {
    pub fn new(
        k: usize,
        x0: Vec<f32>,
        w: impl Into<MixWeights>,
        hyper: Hyper,
        compressor: Box<dyn Compressor>,
        seed: u64,
    ) -> Self {
        let choco_hyper = Hyper { mu: 0.0, period: 1, ..hyper };
        Self { inner: super::CpdSgdm::new(k, x0, w, choco_hyper, compressor, seed) }
    }
}

impl Algorithm for ChocoSgd {
    fn name(&self) -> String {
        format!("choco-sgd[{}]", self.inner.name())
    }

    fn k(&self) -> usize {
        self.inner.k()
    }

    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats {
        self.inner.step(t, source, net)
    }

    fn params(&self, k: usize) -> &[f32] {
        self.inner.params(k)
    }

    fn set_parallel(&mut self, on: bool) {
        self.inner.set_parallel(on);
    }

    fn install_shared_pool(&mut self, pool: std::sync::Arc<crate::engine::WorkerPool>) {
        self.inner.install_shared_pool(pool);
    }

    fn set_worker_params(&mut self, k: usize, x: &[f32]) {
        self.inner.set_worker_params(k, x);
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("choco-sgd");
        self.inner.state_save(w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("choco-sgd")?;
        self.inner.state_load(r)
    }
}

// ---------------------------------------------------------------------------
// DeepSqueeze (Tang et al. 2019): error-feedback compression — each
// worker compresses its iterate plus accumulated compression error, and
// the *compressed* values are gossip-averaged:
//
//     v_k = x_{t+1/2}^(k) + e_k
//     c_k = Q(v_k);  e_k = v_k − c_k
//     x_{t+1}^(k) = x_{t+1/2}^(k) + Σ_j w_kj c_j − c_k
//
// (the last line applies the mixing to compressed values while keeping
// the local residual, per the DeepSqueeze recursion).
// ---------------------------------------------------------------------------

pub struct DeepSqueeze {
    hyper: Hyper,
    xs: ParamArena,
    errs: ParamArena,
    gossip: GossipState,
    compressor: Box<dyn Compressor>,
    engine: LocalStepEngine,
    /// Stateful compressed round (per-worker RNG streams + reusable
    /// buffer tables) shared with CPD-SGDM's code path.
    exchange: CompressedExchange,
    /// Reusable K×d scratch: the error-compensated inputs v_k = x_k + e_k.
    vs: ParamArena,
    /// Reusable K×d scratch: the mixed-compressed corrections.
    mixes: ParamArena,
    /// Per-receiver neighbor replicas of the compressed values c_j, used
    /// only under lossy compressed links (`FaultPlan::compressed`): each
    /// slot holds the *last* c_j its receiver decoded (set, not
    /// accumulated — DeepSqueeze ships one-shot values, not CHOCO
    /// deltas), so an unheard neighbor mixes at its stale value. Lazily
    /// materialized at zero ("never heard" mixes as zero, the same
    /// convention the canonical table uses for absent senders).
    replicas: ReplicaStore,
    /// Each worker's own decoded c_k under per-receiver mode (its own
    /// payload never crosses the wire); lazily sized, zeroed for absent
    /// workers so it stays a pure function of the current round.
    own_cs: ParamArena,
}

impl DeepSqueeze {
    pub fn new(
        k: usize,
        x0: Vec<f32>,
        w: impl Into<MixWeights>,
        hyper: Hyper,
        compressor: Box<dyn Compressor>,
        seed: u64,
    ) -> Self {
        let gossip = GossipState::new(w);
        assert_eq!(gossip.k(), k);
        let d = x0.len();
        let replicas = ReplicaStore::new(gossip.weights(), d);
        Self {
            xs: ParamArena::filled(k, &x0),
            errs: ParamArena::zeros(k, d),
            gossip,
            compressor,
            engine: LocalStepEngine::new(k, d),
            exchange: CompressedExchange::new(k, seed),
            vs: ParamArena::zeros(k, d),
            mixes: ParamArena::zeros(k, d),
            replicas,
            own_cs: ParamArena::zeros(0, d),
            hyper,
        }
    }

    fn comm_round(&mut self, net: &mut Network) -> u64 {
        let k = self.k();
        let before = net.total_bytes;
        let pool = self.engine.comm_pool();
        // v_k = x_k + e_k into reusable scratch, then the shared
        // compressed exchange (same compress → encode → send → recv →
        // decode path as CPD-SGDM: charged bytes are measured buffer
        // lengths); the error update e_k = v_k − c_k happens sender-side
        // via the on_compressed hook (always caller-thread, worker
        // order), while the mixing below consumes the receiver-side
        // decodes.
        for ((v, x), e) in self.vs.rows_mut().zip(self.xs.rows()).zip(self.errs.rows()) {
            for ((vv, &xv), &ev) in v.iter_mut().zip(x).zip(e) {
                *vv = xv + ev;
            }
        }
        // Lossy compressed links: switch to per-receiver replicas of the
        // one-shot c values (see field docs). A plan that never opted in
        // keeps the exact canonical code path — byte-for-byte.
        let per_receiver = net.fault_plan().map_or(false, |p| p.compressed);
        if !per_receiver {
            let vs = &self.vs;
            let errs = &mut self.errs;
            let cs = self.exchange.round(
                self.compressor.as_ref(),
                net,
                vs,
                pool,
                |i, c| {
                    for ((e, &vv), &cc) in errs.row_mut(i).iter_mut().zip(vs.row(i)).zip(&c.dense)
                    {
                        *e = vv - cc;
                    }
                },
            );
            // x_i += Σ_j w_ij c_j − c_i: one fused weighted-sum per worker
            // into reusable scratch, fanned over the shared engine pool. The
            // term list walks the sparse weight row (ascending neighbors,
            // self weight spliced in at its natural column position) so the
            // summation order matches the old dense row scan bitwise.
            let w = self.gossip.weights();
            let rows: Vec<ScopedTask<'_, ()>> = self
                .xs
                .rows_mut()
                .zip(self.mixes.rows_mut())
                .enumerate()
                .map(|(i, (x, mixc))| {
                    let mut terms: Vec<(f32, &[f32])> = Vec::with_capacity(k + 1);
                    let sw = w.self_weight(i) as f32;
                    let mut placed_self = false;
                    for &(j, wij) in w.neighbors(i) {
                        if j > i && !placed_self {
                            if sw != 0.0 {
                                terms.push((sw, cs.row(i)));
                            }
                            placed_self = true;
                        }
                        let wij = wij as f32;
                        if wij != 0.0 {
                            terms.push((wij, cs.row(j)));
                        }
                    }
                    if !placed_self && sw != 0.0 {
                        terms.push((sw, cs.row(i)));
                    }
                    terms.push((-1.0, cs.row(i)));
                    Box::new(move || {
                        linalg::weighted_sum_into(mixc, &terms);
                        linalg::axpy(1.0, mixc, x);
                    }) as ScopedTask<'_, ()>
                })
                .collect();
            gossip::run_rows(pool, rows);
        } else {
            if !self.replicas.is_materialized() {
                self.replicas.materialize_zeros();
            }
            let d = self.vs.d();
            if self.own_cs.k() != k || self.own_cs.d() != d {
                self.own_cs = ParamArena::zeros(k, d);
            }
            // An absent worker applies no self payload this round; zero
            // its own-c row so the mix sees the canonical absent-sender
            // convention and own_cs never carries hidden cross-round
            // state (which would have to be checkpointed).
            for i in 0..k {
                if net.is_absent(i) {
                    self.own_cs.row_mut(i).fill(0.0);
                }
            }
            // Error feedback stays sender-side (the on_compressed hook):
            // e_k depends only on the worker's own compression, so it is
            // untouched by what receivers did or did not hear.
            let vs = &self.vs;
            let errs = &mut self.errs;
            let replicas = &mut self.replicas;
            let own_cs = &mut self.own_cs;
            self.exchange.round_per_receiver(
                self.compressor.as_ref(),
                net,
                vs,
                pool,
                |i, c| {
                    for ((e, &vv), &cc) in errs.row_mut(i).iter_mut().zip(vs.row(i)).zip(&c.dense)
                    {
                        *e = vv - cc;
                    }
                },
                |to, from, c| {
                    if to == from {
                        own_cs.row_mut(to).copy_from_slice(c);
                    } else {
                        let slot = replicas
                            .slot_of(to, from)
                            .expect("compressed message arrived off-graph");
                        // Set, not accumulate: a stale delayed copy then a
                        // fresh one leaves the freshest (arrival order).
                        replicas.row_mut(slot).copy_from_slice(c);
                    }
                },
            );
            // x_i += Σ_j w_ij ĉ_j(i) − c_i against receiver i's own
            // views: unheard neighbors mix at their stale (or
            // never-heard zero) replica, full weight. Same splice order
            // as the canonical path, so zero-rate plans stay
            // bit-identical while every replica equals the shared table.
            let w = self.gossip.weights();
            let replicas = &self.replicas;
            let own_cs = &self.own_cs;
            let rows: Vec<ScopedTask<'_, ()>> = self
                .xs
                .rows_mut()
                .zip(self.mixes.rows_mut())
                .enumerate()
                .map(|(i, (x, mixc))| {
                    let mut terms: Vec<(f32, &[f32])> = Vec::with_capacity(k + 1);
                    let sw = w.self_weight(i) as f32;
                    let mut placed_self = false;
                    for &(j, wij) in w.neighbors(i) {
                        if j > i && !placed_self {
                            if sw != 0.0 {
                                terms.push((sw, own_cs.row(i)));
                            }
                            placed_self = true;
                        }
                        let wij = wij as f32;
                        if wij != 0.0 {
                            terms.push((wij, replicas.replica(i, j)));
                        }
                    }
                    if !placed_self && sw != 0.0 {
                        terms.push((sw, own_cs.row(i)));
                    }
                    terms.push((-1.0, own_cs.row(i)));
                    Box::new(move || {
                        linalg::weighted_sum_into(mixc, &terms);
                        linalg::axpy(1.0, mixc, x);
                    }) as ScopedTask<'_, ()>
                })
                .collect();
            gossip::run_rows(pool, rows);
        }
        net.total_bytes - before
    }
}

impl Algorithm for DeepSqueeze {
    fn name(&self) -> String {
        format!("deepsqueeze(Q={})", self.compressor.name())
    }

    fn k(&self) -> usize {
        self.xs.k()
    }

    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats {
        let eta = self.hyper.lr.eta(t);
        let mean_loss = self.engine.local_step(source, &mut self.xs, LocalUpdate::Sgd { eta });
        let mut stats = StepStats { mean_loss, ..Default::default() };
        if (t + 1) % self.hyper.period == 0 {
            stats.bytes = self.comm_round(net);
            stats.communicated = true;
        }
        stats
    }

    fn params(&self, k: usize) -> &[f32] {
        self.xs.row(k)
    }

    fn set_parallel(&mut self, on: bool) {
        self.engine.set_parallel(on);
    }

    fn install_shared_pool(&mut self, pool: std::sync::Arc<crate::engine::WorkerPool>) {
        self.engine.install_shared_pool(pool);
    }

    fn set_worker_params(&mut self, k: usize, x: &[f32]) {
        self.xs.row_mut(k).copy_from_slice(x);
        // A restarted worker carries no accumulated compression residual.
        self.errs.row_mut(k).fill(0.0);
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("deepsqueeze");
        self.xs.state_save(w);
        self.errs.state_save(w);
        // Per-worker compression streams (see CompressedExchange).
        self.exchange.state_save(w);
        // Per-receiver replicas (flag-only unless a lossy compressed run
        // has materialized them). own_cs is not stored: it is rebuilt
        // from scratch every round (absent rows zeroed explicitly).
        self.replicas.state_save(w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("deepsqueeze")?;
        self.xs.state_load(r, "deepsqueeze.xs")?;
        self.errs.state_load(r, "deepsqueeze.errs")?;
        self.exchange.state_load(r)?;
        self.replicas.state_load(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Sign;
    use crate::grad::{GradientSource, Quadratic};
    use crate::linalg::Mat;
    use crate::optim::LrSchedule;
    use crate::topology::{mixing_matrix, Topology, Weighting};

    fn ring(k: usize) -> (Mat, Network) {
        let g = Topology::Ring.build(k, 0);
        (mixing_matrix(&g, Weighting::UniformDegree), Network::new(&g))
    }

    fn hyper(eta: f32, p: u64) -> Hyper {
        Hyper {
            lr: LrSchedule::Constant { eta },
            mu: 0.9,
            weight_decay: 0.0,
            period: p,
            gamma: 0.4,
        }
    }

    fn final_gap(algo: &mut dyn Algorithm, seed: u64, steps: u64) -> f64 {
        let k = algo.k();
        let mut src = Quadratic::new(k, 12, 1.0, 0.05, seed);
        let opt = src.optimum();
        let g = Topology::Ring.build(k, 0);
        let mut net = Network::new(&g);
        for t in 0..steps {
            algo.step(t, &mut src, &mut net);
        }
        crate::linalg::dist(&algo.avg_params(), &opt)
    }

    #[test]
    fn all_baselines_converge_on_quadratic() {
        let k = 8;
        let x0 = Quadratic::new(k, 12, 1.0, 0.05, 77).init(1);
        let (w, _) = ring(k);
        let cases: Vec<(Box<dyn Algorithm>, f64)> = vec![
            (Box::new(DSgd::new(k, x0.clone(), w.clone(), hyper(0.1, 1))), 0.3),
            (Box::new(PdSgd::new(k, x0.clone(), w.clone(), hyper(0.1, 4))), 0.3),
            (Box::new(DSgdm::new(k, x0.clone(), w.clone(), hyper(0.02, 1), false)), 0.3),
            (Box::new(DSgdm::new(k, x0.clone(), w.clone(), hyper(0.02, 1), true)), 0.3),
            (Box::new(CSgdm::new(k, x0.clone(), hyper(0.02, 1))), 0.3),
            (Box::new(ChocoSgd::new(k, x0.clone(), w.clone(), hyper(0.1, 1), Box::new(Sign), 1)), 0.4),
            (Box::new(DeepSqueeze::new(k, x0.clone(), w.clone(), hyper(0.05, 1), Box::new(Sign), 2)), 0.5),
        ];
        for (mut algo, tol) in cases {
            let name = algo.name();
            let gap = final_gap(algo.as_mut(), 77, 2500);
            assert!(gap < tol, "{name}: gap {gap} >= {tol}");
        }
    }

    #[test]
    fn csgdm_workers_share_one_iterate() {
        let k = 4;
        let mut src = Quadratic::new(k, 6, 1.0, 0.1, 5);
        let g = Topology::Ring.build(k, 0);
        let mut net = Network::new(&g);
        let mut algo = CSgdm::new(k, src.init(0), hyper(0.05, 1));
        algo.step(0, &mut src, &mut net);
        assert_eq!(algo.params(0), algo.params(3));
        assert_eq!(algo.consensus_error(), 0.0);
    }

    #[test]
    fn csgdm_bytes_scale_with_k_and_d() {
        let mut src = Quadratic::new(4, 100, 1.0, 0.1, 6);
        let g = Topology::Ring.build(4, 0);
        let mut net = Network::new(&g);
        let mut algo = CSgdm::new(4, src.init(0), hyper(0.05, 1));
        let s = algo.step(0, &mut src, &mut net);
        assert_eq!(s.bytes, 2 * 4 * 100 * 4);
    }

    #[test]
    fn dsgdm_momentum_gossip_doubles_bytes() {
        let k = 6;
        let x0 = vec![0.0f32; 50];
        let (w, mut net_a) = ring(k);
        let mut src = Quadratic::new(k, 50, 1.0, 0.1, 7);
        let mut a = DSgdm::new(k, x0.clone(), w.clone(), hyper(0.01, 1), false);
        let sa = a.step(0, &mut src, &mut net_a);
        let (_, mut net_b) = ring(k);
        let mut b = DSgdm::new(k, x0, w, hyper(0.01, 1), true);
        let sb = b.step(0, &mut src, &mut net_b);
        assert_eq!(sb.bytes, 2 * sa.bytes, "[23]'s x+m payload is exactly 2x");
    }

    #[test]
    fn pd_sgd_is_pd_sgdm_with_zero_momentum() {
        // Same trajectories when mu=0 and the gradient stream is
        // deterministic (noise=0).
        let k = 4;
        let x0 = vec![0.5f32; 8];
        let (w, mut net_a) = ring(k);
        let (w2, mut net_b) = ring(k);
        let mut src_a = Quadratic::new(k, 8, 1.0, 0.0, 8);
        let mut src_b = Quadratic::new(k, 8, 1.0, 0.0, 8);
        let mut a = PdSgd::new(k, x0.clone(), w, hyper(0.05, 4));
        let mut b = super::super::PdSgdm::new(
            k,
            x0,
            w2,
            Hyper { mu: 0.0, ..hyper(0.05, 4) },
        );
        for t in 0..40 {
            a.step(t, &mut src_a, &mut net_a);
            b.step(t, &mut src_b, &mut net_b);
        }
        for kk in 0..k {
            crate::testing::assert_allclose(a.params(kk), b.params(kk), 1e-5, 1e-6);
        }
    }

    #[test]
    fn dsgd_matches_pdsgd_p1() {
        let k = 4;
        let x0 = vec![0.1f32; 8];
        let (w, mut net_a) = ring(k);
        let (w2, mut net_b) = ring(k);
        let mut src_a = Quadratic::new(k, 8, 1.0, 0.0, 9);
        let mut src_b = Quadratic::new(k, 8, 1.0, 0.0, 9);
        let mut a = DSgd::new(k, x0.clone(), w, hyper(0.05, 1));
        let mut b = PdSgd::new(k, x0, w2, hyper(0.05, 1));
        for t in 0..25 {
            a.step(t, &mut src_a, &mut net_a);
            b.step(t, &mut src_b, &mut net_b);
        }
        for kk in 0..k {
            crate::testing::assert_allclose(a.params(kk), b.params(kk), 1e-6, 1e-7);
        }
    }

    #[test]
    fn deepsqueeze_error_feedback_accumulates_residual() {
        let k = 4;
        let (w, mut net) = ring(k);
        let mut src = Quadratic::new(k, 16, 1.0, 0.0, 10);
        let mut algo = DeepSqueeze::new(k, src.init(3), w, hyper(0.02, 1), Box::new(Sign), 3);
        algo.step(0, &mut src, &mut net);
        let err_norm: f64 = algo.errs.rows().map(crate::linalg::norm).sum();
        assert!(err_norm > 0.0, "sign compression must leave a residual");
    }
}
