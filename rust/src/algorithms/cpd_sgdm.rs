//! **Algorithm 2 — CPD-SGDM** (the paper's communication-efficient variant).
//!
//! Local updates are identical to Algorithm 1; communication rounds
//! exchange δ-contraction-compressed differences against auxiliary
//! copies x̂ (CHOCO-style error feedback) instead of raw parameters:
//!
//! ```text
//! (line 6)  x_{t+1}^(k) = x_{t+1/2}^(k) + γ Σ_j w_kj (x̂_t^(j) − x̂_t^(k))
//! (line 7)  q_t^(k) = Q(x_{t+1}^(k) − x̂_t^(k))
//! (line 8)  send q^(k), receive q^(j) for j ∈ N_k
//! (line 9)  x̂_{t+1}^(j) = x̂_t^(j) + q_t^(j)
//! ```
//!
//! Every worker holds x̂ copies for itself and its neighbors. On a
//! reliable fabric all copies of x̂^(j) receive exactly the same q^(j)
//! stream and stay identical, so the simulator stores one canonical x̂
//! per worker (the standard CHOCO implementation trick) while still
//! exchanging every q as its **encoded wire bytes** over the
//! byte-metered network — the x̂ update applies the receiver-side decode
//! of those bytes, so the whole codec path (encode → send → recv →
//! decode) runs end-to-end and the charged byte counts are actual
//! buffer lengths.
//!
//! Under lossy compressed links (`faults.compressed`) that premise
//! fails: a dropped q^(j) reaches some receivers and not others, so the
//! copies genuinely diverge. The algorithm then switches to true
//! per-receiver replicas ([`gossip::ReplicaStore`], Σdegree·d memory,
//! lazily materialized from the canonical table): each receiver's view
//! of each neighbor absorbs only the q's that receiver actually
//! decoded, line 6 mixes against those views (renormalized in f64 over
//! the neighbors present under churn), and lost messages merely let one
//! replica drift until later q's re-contract it. With a zero-rate plan
//! every receiver hears every q, replicas never diverge from the
//! canonical table, and the trajectory is bit-identical to the fast
//! path (property-tested in `rust/tests/fault_injection.rs`).

use super::{
    gossip::{self, CompressedExchange, GossipState, ReplicaStore},
    Algorithm, Hyper, StepStats,
};
use crate::arena::ParamArena;
use crate::comm::Network;
use crate::compress::Compressor;
use crate::engine::{LocalStepEngine, LocalUpdate, ScopedTask};
use crate::grad::GradientSource;
use crate::linalg;
use crate::optim::MomentumBank;
use crate::topology::MixWeights;

pub struct CpdSgdm {
    hyper: Hyper,
    xs: ParamArena,
    /// Canonical auxiliary iterates x̂^(k) (shared view, see module doc).
    hats: ParamArena,
    moms: MomentumBank,
    gossip: GossipState,
    compressor: Box<dyn Compressor>,
    engine: LocalStepEngine,
    /// The stateful compress→encode→send→recv→decode round (per-worker
    /// RNG streams + reusable buffer tables; see `gossip` module docs).
    exchange: CompressedExchange,
    /// Reusable K×d scratch: the q-inputs x_i − x̂_i (line 7).
    diffs: ParamArena,
    /// Reusable K×d scratch: the line-6 consensus corrections.
    corrs: ParamArena,
    /// Per-receiver neighbor replicas of x̂, used only under lossy
    /// compressed links (`FaultPlan::compressed`); lazily materialized
    /// from the canonical table on the first per-receiver round. The
    /// canonical `hats` row i doubles as receiver i's view of itself.
    replicas: ReplicaStore,
}

impl CpdSgdm {
    pub fn new(
        k: usize,
        x0: Vec<f32>,
        w: impl Into<MixWeights>,
        hyper: Hyper,
        compressor: Box<dyn Compressor>,
        seed: u64,
    ) -> Self {
        assert!(hyper.gamma > 0.0, "consensus step size must be positive");
        let gossip = GossipState::new(w);
        assert_eq!(gossip.k(), k);
        let d = x0.len();
        let replicas = ReplicaStore::new(gossip.weights(), d);
        Self {
            xs: ParamArena::filled(k, &x0),
            hats: ParamArena::zeros(k, d), // x̂_0 = 0 per CHOCO convention
            moms: MomentumBank::new(k, d, hyper.mu, hyper.weight_decay),
            gossip,
            replicas,
            compressor,
            engine: LocalStepEngine::new(k, d),
            exchange: CompressedExchange::new(k, seed),
            diffs: ParamArena::zeros(k, d),
            corrs: ParamArena::zeros(k, d),
            hyper,
        }
    }

    /// ||x^(k) − x̂^(k)||² averaged over workers — the compression residual
    /// tracked by the Theorem 2 analysis (Lemma 6's second term).
    pub fn hat_residual(&self) -> f64 {
        self.xs
            .rows()
            .zip(self.hats.rows())
            .map(|(x, h)| {
                let e = linalg::dist(x, h);
                e * e
            })
            .sum::<f64>()
            / self.k() as f64
    }

    fn comm_round(&mut self, net: &mut Network) -> u64 {
        let k = self.k();
        let gamma = self.hyper.gamma;
        let before = net.total_bytes;
        let pool = self.engine.comm_pool();
        // Lossy compressed links: switch to per-receiver replica state
        // (see module doc). A plan that never opted in keeps the exact
        // canonical code path below — byte-for-byte.
        let per_receiver = net.fault_plan().map_or(false, |p| p.compressed);
        if per_receiver && !self.replicas.is_materialized() {
            // First lossy round: every receiver's view still equals the
            // canonical table (nothing has been lost yet).
            self.replicas.materialize_from(&self.hats);
        }

        // Line 6: consensus correction — Σ_j w_ij (x̂_j − x̂_i); w rows
        // sum to 1 so this equals Σ_j w_ij x̂_j − x̂_i. The term list
        // walks the sparse weight row (ascending neighbors) with the
        // self weight spliced in at its natural column position, so the
        // summation order — and hence the f32 result — matches the old
        // dense row scan bitwise. One fused weighted-sum per worker into
        // a reusable scratch row, fanned over the shared engine pool:
        // worker i reads the frozen x̂ state and writes only
        // corrs[i]/xs[i], so the schedule is bit-invisible.
        if !per_receiver {
            let w = self.gossip.weights();
            let hats = &self.hats;
            let rows: Vec<ScopedTask<'_, ()>> = self
                .xs
                .rows_mut()
                .zip(self.corrs.rows_mut())
                .enumerate()
                .map(|(i, (x, corr))| {
                    let mut terms: Vec<(f32, &[f32])> = Vec::with_capacity(k + 1);
                    let sw = w.self_weight(i) as f32;
                    let mut placed_self = false;
                    for &(j, wij) in w.neighbors(i) {
                        if j > i && !placed_self {
                            if sw != 0.0 {
                                terms.push((sw, hats.row(i)));
                            }
                            placed_self = true;
                        }
                        let wij = wij as f32;
                        if wij != 0.0 {
                            terms.push((wij, hats.row(j)));
                        }
                    }
                    if !placed_self && sw != 0.0 {
                        terms.push((sw, hats.row(i)));
                    }
                    terms.push((-1.0, hats.row(i)));
                    Box::new(move || {
                        linalg::weighted_sum_into(corr, &terms);
                        linalg::axpy(gamma, corr, x);
                    }) as ScopedTask<'_, ()>
                })
                .collect();
            gossip::run_rows(pool, rows);
        } else {
            // Per-receiver line 6: receiver i mixes against *its own*
            // replicas of each neighbor (stale if a q was lost) and the
            // canonical hats row for itself (its own q stream is applied
            // locally every round, so hats.row(i) IS its self view).
            // Neighbors absent under churn are excluded and the row is
            // renormalized in f64, mirroring GossipState::mix's hardened
            // path; with no absent neighbor the term order and weights
            // are the canonical splice exactly, so zero-rate plans stay
            // bit-identical while replicas equal the canonical table.
            let w = self.gossip.weights();
            let hats = &self.hats;
            let replicas = &self.replicas;
            let net_ro = &*net;
            let rows: Vec<ScopedTask<'_, ()>> = self
                .xs
                .rows_mut()
                .zip(self.corrs.rows_mut())
                .enumerate()
                .map(|(i, (x, corr))| {
                    let nbrs = w.neighbors(i);
                    let any_absent = nbrs.iter().any(|&(j, _)| net_ro.is_absent(j));
                    let scale = if any_absent {
                        let mut total = w.self_weight(i);
                        for &(j, wij) in nbrs {
                            if !net_ro.is_absent(j) {
                                total += wij;
                            }
                        }
                        // total ≥ w_ii > 0: a fully isolated receiver
                        // degenerates to the identity correction.
                        1.0 / total
                    } else {
                        1.0
                    };
                    let mut terms: Vec<(f32, &[f32])> = Vec::with_capacity(nbrs.len() + 2);
                    let sw = (w.self_weight(i) * scale) as f32;
                    let mut placed_self = false;
                    for &(j, wij) in nbrs {
                        if j > i && !placed_self {
                            if sw != 0.0 {
                                terms.push((sw, hats.row(i)));
                            }
                            placed_self = true;
                        }
                        if any_absent && net_ro.is_absent(j) {
                            continue;
                        }
                        let wij = (wij * scale) as f32;
                        if wij != 0.0 {
                            terms.push((wij, replicas.replica(i, j)));
                        }
                    }
                    if !placed_self && sw != 0.0 {
                        terms.push((sw, hats.row(i)));
                    }
                    terms.push((-1.0, hats.row(i)));
                    Box::new(move || {
                        linalg::weighted_sum_into(corr, &terms);
                        linalg::axpy(gamma, corr, x);
                    }) as ScopedTask<'_, ()>
                })
                .collect();
            gossip::run_rows(pool, rows);
        }

        // Line 7 inputs: q-differences x_i − x̂_i into reusable scratch.
        for ((diff, x), hat) in self.diffs.rows_mut().zip(self.xs.rows()).zip(self.hats.rows()) {
            for ((dv, &xv), &hv) in diff.iter_mut().zip(x).zip(hat) {
                *dv = xv - hv;
            }
        }

        // Lines 7-9: compress the differences and exchange them through
        // the shared compress → encode → send → recv → decode round: the
        // Figure 2 byte counters measure actual buffer lengths, and every
        // copy of x̂^(j) absorbs the *receiver-side decode* of q^(j).
        if !per_receiver {
            let qs =
                self.exchange
                    .round(self.compressor.as_ref(), net, &self.diffs, pool, |_, _| {});
            for (hat, q) in self.hats.rows_mut().zip(qs.rows()) {
                linalg::axpy(1.0, q, hat);
            }
        } else {
            // Per-receiver line 9: every q a receiver actually decoded is
            // *accumulated* into its replica of that sender — CHOCO's x̂
            // update is an incremental delta, so duplicates (a delayed
            // stale copy plus a fresh one) are both applied, in arrival
            // order. A worker's own q lands in the canonical hats row
            // (its self view), decoded from the same wire bytes the
            // receivers saw.
            let hats = &mut self.hats;
            let replicas = &mut self.replicas;
            self.exchange.round_per_receiver(
                self.compressor.as_ref(),
                net,
                &self.diffs,
                pool,
                |_, _| {},
                |to, from, q| {
                    if to == from {
                        linalg::axpy(1.0, q, hats.row_mut(to));
                    } else {
                        let slot = replicas
                            .slot_of(to, from)
                            .expect("compressed message arrived off-graph");
                        linalg::axpy(1.0, q, replicas.row_mut(slot));
                    }
                },
            );
        }
        net.total_bytes - before
    }
}

impl Algorithm for CpdSgdm {
    fn name(&self) -> String {
        format!(
            "cpd-sgdm(p={},Q={},γ={})",
            self.hyper.period,
            self.compressor.name(),
            self.hyper.gamma
        )
    }

    fn k(&self) -> usize {
        self.xs.k()
    }

    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats {
        let eta = self.hyper.lr.eta(t);
        // Lines 2-4: identical to Algorithm 1 (shared parallel engine).
        let mean_loss = self.engine.local_step(
            source,
            &mut self.xs,
            LocalUpdate::Momentum { moms: &mut self.moms, eta },
        );
        let mut stats = StepStats { mean_loss, ..Default::default() };
        // Lines 5-13.
        if (t + 1) % self.hyper.period == 0 {
            stats.bytes = self.comm_round(net);
            stats.communicated = true;
        }
        stats
    }

    fn params(&self, k: usize) -> &[f32] {
        self.xs.row(k)
    }

    fn set_parallel(&mut self, on: bool) {
        self.engine.set_parallel(on);
    }

    fn install_shared_pool(&mut self, pool: std::sync::Arc<crate::engine::WorkerPool>) {
        self.engine.install_shared_pool(pool);
    }

    fn set_worker_params(&mut self, k: usize, x: &[f32]) {
        self.xs.row_mut(k).copy_from_slice(x);
        self.moms.reset_row(k);
        // x̂ is left untouched: every worker holds the same canonical
        // copy of x̂^(k), so rewriting it here would desynchronize the
        // fleet's view. The diff compression q = Q(x − x̂) self-corrects
        // the enlarged residual over the following rounds.
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("cpd-sgdm");
        self.xs.state_save(w);
        self.hats.state_save(w);
        self.moms.state_save(w);
        // Per-worker compression streams (was: one shared stream — the
        // per-worker bank is what keeps pooled compression deterministic).
        self.exchange.state_save(w);
        // Per-receiver replicas (flag-only unless a lossy compressed run
        // has materialized them) so faulty compressed runs resume
        // bit-identically.
        self.replicas.state_save(w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("cpd-sgdm")?;
        self.xs.state_load(r, "cpd-sgdm.xs")?;
        self.hats.state_load(r, "cpd-sgdm.hats")?;
        self.moms.state_load(r)?;
        self.exchange.state_load(r)?;
        self.replicas.state_load(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, Sign, TopK};
    use crate::grad::Quadratic;
    use crate::linalg::Mat;
    use crate::optim::LrSchedule;
    use crate::topology::{mixing_matrix, Topology, Weighting};

    fn ring(k: usize) -> (Mat, Network) {
        let g = Topology::Ring.build(k, 0);
        (mixing_matrix(&g, Weighting::UniformDegree), Network::new(&g))
    }

    fn hyper(eta: f32, p: u64, gamma: f32) -> Hyper {
        Hyper {
            lr: LrSchedule::Constant { eta },
            mu: 0.9,
            weight_decay: 0.0,
            period: p,
            gamma,
        }
    }

    #[test]
    fn average_iterate_evolves_like_pd_sgdm() {
        // Eq. (44)/(45): the communication step never changes x̄, so x̄
        // follows exactly the same recursion as Algorithm 1. With zero
        // gradient noise and the same seed, x̄ trajectories coincide.
        let k = 6;
        let (w, mut net) = ring(k);
        let (w2, mut net2) = ring(k);
        let x0 = Quadratic::new(k, 10, 1.0, 0.0, 3).init(1);
        let mut cpd = CpdSgdm::new(k, x0.clone(), w, hyper(0.05, 4, 0.4), Box::new(Sign), 1);
        let mut pd = super::super::PdSgdm::new(k, x0, w2, hyper(0.05, 4, 0.4));
        // NOTE: identical iterates also require identical gradients; on a
        // *noiseless* quadratic grad depends only on x, but x diverges
        // between the two algorithms after the first comm round. So we
        // check the invariant directly instead: within one algorithm,
        // x̄ before and after a comm round is unchanged.
        let mut src = Quadratic::new(k, 10, 1.0, 0.0, 3);
        for t in 0..3 {
            cpd.step(t, &mut src, &mut net);
            pd.step(t, &mut src, &mut net2);
        }
        let xbar_before = cpd.avg_params();
        // t=3 triggers the round; isolate the comm part by zeroing lr.
        let mut frozen = CpdSgdm::new(
            k,
            vec![0.0; 10],
            ring(k).0,
            hyper(0.0, 1, 0.4),
            Box::new(Sign),
            7,
        );
        frozen.xs = cpd.xs.clone();
        frozen.hats = cpd.hats.clone();
        let mut net3 = ring(k).1;
        frozen.comm_round(&mut net3);
        let xbar_after = frozen.avg_params();
        crate::testing::assert_allclose(&xbar_after, &xbar_before, 1e-4, 1e-5);
    }

    #[test]
    fn converges_near_optimum_with_sign_compression() {
        let k = 8;
        let mut src = Quadratic::new(k, 16, 1.0, 0.05, 5);
        let opt = src.optimum();
        let (w, mut net) = ring(k);
        // paper-style step decay to cut the stochastic floor at the end
        let lr = crate::optim::LrSchedule::StepDecay {
            eta0: 0.02,
            factor: 0.1,
            milestones: vec![0.5, 0.75],
            total_steps: 2500,
        };
        let h = Hyper { lr, ..hyper(0.02, 4, 0.4) };
        let mut algo = CpdSgdm::new(k, src.init(2), w, h, Box::new(Sign), 2);
        for t in 0..2500 {
            algo.step(t, &mut src, &mut net);
        }
        let err = crate::linalg::dist(&algo.avg_params(), &opt);
        assert!(err < 0.35, "x̄ is {err} from x*");
    }

    #[test]
    fn converges_with_topk_compression() {
        let k = 8;
        let mut src = Quadratic::new(k, 16, 1.0, 0.05, 6);
        let opt = src.optimum();
        let (w, mut net) = ring(k);
        let mut algo = CpdSgdm::new(
            k,
            src.init(3),
            w,
            hyper(0.02, 4, 0.3),
            Box::new(TopK { ratio: 0.25 }),
            3,
        );
        for t in 0..3000 {
            algo.step(t, &mut src, &mut net);
        }
        let err = crate::linalg::dist(&algo.avg_params(), &opt);
        assert!(err < 0.5, "x̄ is {err} from x*");
    }

    #[test]
    fn hat_residual_shrinks_during_training() {
        let k = 4;
        let mut src = Quadratic::new(k, 8, 0.5, 0.0, 7);
        let (w, mut net) = ring(k);
        let mut algo = CpdSgdm::new(k, src.init(4), w, hyper(0.02, 2, 0.4), Box::new(Sign), 4);
        for t in 0..100 {
            algo.step(t, &mut src, &mut net);
        }
        let early = algo.hat_residual();
        for t in 100..2000 {
            algo.step(t, &mut src, &mut net);
        }
        let late = algo.hat_residual();
        assert!(late < early, "x̂ residual should contract: {early} -> {late}");
    }

    #[test]
    fn sign_compression_sends_far_fewer_bytes_than_full_precision() {
        let k = 8;
        let d = 10_000;
        let mut src = Quadratic::new(k, d, 1.0, 0.1, 8);
        let (w, mut net) = ring(k);
        let mut algo = CpdSgdm::new(k, src.init(5), w, hyper(0.01, 4, 0.4), Box::new(Sign), 5);
        for t in 0..8 {
            algo.step(t, &mut src, &mut net);
        }
        let compressed = net.total_bytes;
        // full-precision comparator over the same schedule
        let (w2, mut net2) = ring(k);
        let mut full = super::super::PdSgdm::new(k, src.init(5), w2, hyper(0.01, 4, 0.4));
        for t in 0..8 {
            full.step(t, &mut src, &mut net2);
        }
        let dense = net2.total_bytes;
        assert!(
            dense as f64 / compressed as f64 > 25.0,
            "sign should be ~32x smaller: {dense} vs {compressed}"
        );
    }

    #[test]
    fn identity_compressor_with_gamma_one_matches_full_gossip_fixed_point() {
        // With Q = identity and γ = 1, one comm round after x̂ has caught
        // up reproduces exact W-mixing: x ← x + (W−I) x̂ = W x when x̂ = x.
        let k = 5;
        let (w, mut net) = ring(k);
        let mut algo = CpdSgdm::new(
            k,
            vec![0.0; 4],
            w.clone(),
            hyper(0.0, 1, 1.0),
            Box::new(Identity),
            6,
        );
        // set distinct worker states; run one round to sync x̂ = x
        for (i, x) in algo.xs.rows_mut().enumerate() {
            for (c, v) in x.iter_mut().enumerate() {
                *v = (i * 4 + c) as f32;
            }
        }
        // round 1 with x̂=0: x unchanged (correction 0), x̂ <- x exactly.
        let xs_snapshot = algo.xs.clone();
        algo.comm_round(&mut net);
        for (h, x) in algo.hats.rows().zip(xs_snapshot.rows()) {
            crate::testing::assert_allclose(h, x, 1e-6, 1e-7);
        }
        // round 2: x ← x + (Wx̂ − x̂) = W x.
        let expect: Vec<Vec<f32>> = (0..k)
            .map(|i| {
                (0..4)
                    .map(|c| {
                        (0..k)
                            .map(|j| w[(i, j)] as f32 * xs_snapshot.row(j)[c])
                            .sum()
                    })
                    .collect()
            })
            .collect();
        algo.comm_round(&mut net);
        for (got, want) in algo.xs.rows().zip(&expect) {
            crate::testing::assert_allclose(got, want, 1e-5, 1e-6);
        }
    }

    #[test]
    fn respects_period_schedule() {
        let k = 4;
        let mut src = Quadratic::new(k, 8, 1.0, 0.1, 9);
        let (w, mut net) = ring(k);
        let mut algo = CpdSgdm::new(k, src.init(6), w, hyper(0.01, 8, 0.4), Box::new(Sign), 7);
        let stats: Vec<StepStats> = (0..24).map(|t| algo.step(t, &mut src, &mut net)).collect();
        let comm: Vec<usize> = stats
            .iter()
            .enumerate()
            .filter(|(_, s)| s.communicated)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(comm, vec![7, 15, 23]);
    }
}
