//! Shared gossip machinery: the Eq. (4) mixing step over the byte-metered
//! network (full-precision algorithms) and the compressed exchange round
//! (CPD-SGDM / DeepSqueeze) that ships encoded codec bytes end-to-end.
//!
//! §Perf: both rounds are **zero-allocation in steady state** and fan
//! their per-worker work over the session's persistent
//! [`crate::engine::WorkerPool`] when one is supplied — the same pool
//! that runs the local-step phase, so the whole step loop shares one set
//! of parked threads. Determinism is preserved bit-exactly: every task
//! touches only its own worker's buffers, all inputs are read-only
//! snapshots taken before the fan-out, and reductions happen on the
//! caller's thread in worker order (see DESIGN.md §4–5).

use std::sync::Arc;

use crate::arena::ParamArena;
use crate::comm::{Message, Network, Payload};
use crate::compress::{check_wire_size, CompressedVec, Compressor};
use crate::engine::{ScopedTask, WorkerPool};
use crate::rng::Xoshiro256;
use crate::topology::MixWeights;

/// Run one closure per worker: fanned over the pool when present (and
/// worth it), inline otherwise. Each row must touch only its own
/// worker's mutable state — the shared contract of every comm-phase
/// fan-out in this module.
pub(crate) fn run_rows(pool: Option<&WorkerPool>, rows: Vec<ScopedTask<'_, ()>>) {
    match pool {
        Some(pool) if rows.len() > 1 => {
            pool.run_scoped(rows);
        }
        _ => rows.into_iter().for_each(|row| row()),
    }
}

/// Sparse mixing weights + the exchange logic for one full-precision
/// gossip round: every worker broadcasts its vector to its neighbors,
/// then forms `x_k ← w_kk x_k + Σ_{j∈N_k} w_kj x_j` from what it
/// received. Weights live in CSR rows ([`MixWeights`]), so a K=1024
/// fleet never materializes a K×K dense matrix.
#[derive(Clone, Debug)]
pub struct GossipState {
    weights: MixWeights,
    /// Flat K×d arena holding each round's mixing outputs; swapped
    /// wholesale with the iterate arena at the end of the round, so
    /// steady-state rounds allocate nothing in K·d.
    scratch: ParamArena,
    /// Per-worker broadcast staging buffers: each round copies worker
    /// k's arena row in, ships it as a shared (Arc) payload, and
    /// reclaims the allocation once every message clone is dropped.
    bcast: Vec<Vec<f32>>,
}

impl GossipState {
    pub fn new(w: impl Into<MixWeights>) -> Self {
        let weights = w.into();
        assert!(weights.is_doubly_stochastic(1e-6), "Assumption 1 violated");
        Self { weights, scratch: ParamArena::zeros(0, 0), bcast: Vec::new() }
    }

    pub fn k(&self) -> usize {
        self.weights.k()
    }

    /// The CSR mixing weights this state gossips with.
    pub fn weights(&self) -> &MixWeights {
        &self.weights
    }

    /// One communication round over `net`, mixing the K×d iterate arena
    /// `xs` in place. Charges 4·d bytes per directed link (f32 dense
    /// payload). Returns the wire bytes this round consumed.
    ///
    /// §Perf: each worker's arena row is copied into a persistent
    /// per-worker staging buffer (rows of a flat arena cannot be moved
    /// out, so one K·d memcpy per round is the floor) and shipped as a
    /// shared (Arc) payload; the per-receiver fused weighted-sum writes
    /// into this state's scratch arena — fanned over `pool` when one is
    /// supplied — whose storage is then *swapped* wholesale with `xs`.
    /// The staging allocations are recovered from their Arcs once every
    /// message clone is dropped, so a steady-state round performs zero
    /// K·d allocation. Pool and sequential schedules are bit-identical:
    /// receiver k reads frozen inputs and writes only scratch row k, in
    /// the same term order either way. Measured in EXPERIMENTS.md §Perf
    /// (`mix_round`).
    pub fn mix(&mut self, xs: &mut ParamArena, net: &mut Network, pool: Option<&WorkerPool>) -> u64 {
        let k = self.k();
        assert_eq!(xs.k(), k);
        let before = net.total_bytes;
        let d = xs.d();
        if self.scratch.k() != k || self.scratch.d() != d {
            self.scratch = ParamArena::zeros(k, d);
        }
        if self.bcast.len() != k {
            self.bcast.resize_with(k, Vec::new);
        }
        // Phase 1: copy each worker's arena row into its reusable
        // staging buffer and ship that as a shared (Arc) broadcast
        // payload, keeping one reference for the self term.
        let mut own: Vec<Arc<Vec<f32>>> = Vec::with_capacity(k);
        for from in 0..k {
            let mut buf = std::mem::take(&mut self.bcast[from]);
            buf.clear();
            buf.extend_from_slice(xs.row(from));
            let payload = Arc::new(buf);
            own.push(Arc::clone(&payload));
            net.broadcast_shared(from, payload);
        }
        // Phase 2: drain every inbox up front (mail order is fixed by
        // the send loop, not by receiver scheduling), then run one fused
        // weighted-sum pass per worker over (self, received neighbors).
        let inboxes: Vec<Vec<Message>> = (0..k).map(|to| net.recv_all(to)).collect();
        let faults_active = net.faults_active();
        let neighbor_counts: Vec<usize> = (0..k).map(|to| net.neighbors(to).len()).collect();
        {
            let w = &self.weights;
            let terms_table: Vec<Vec<(f32, &[f32])>> = (0..k)
                .map(|to| {
                    let msgs = &inboxes[to];
                    if !faults_active {
                        // Legacy fast path: exactly one message per
                        // neighbor, weights already sum to 1. Messages
                        // arrive in ascending sender order (fixed by the
                        // send loop), so a forward-only cursor over the
                        // CSR row replaces the dense lookup bit-exactly.
                        let mut cursor = w.row_cursor(to);
                        let mut terms: Vec<(f32, &[f32])> = Vec::with_capacity(1 + msgs.len());
                        terms.push((w.self_weight(to) as f32, own[to].as_slice()));
                        for msg in msgs {
                            let x = msg.payload.dense().expect("gossip exchanges dense payloads");
                            terms.push((cursor.weight(msg.from) as f32, x));
                        }
                        return terms;
                    }
                    // Hardened path (fault plan installed): a sender may
                    // be missing (drop/churn) or duplicated (a stale
                    // delayed copy plus a fresh one). Keep the *last*
                    // message per sender — `recv_all` injects delayed
                    // mail before fresh mail, so last is freshest — and
                    // renormalize the mixing weights over the senders
                    // actually heard from, in f64, so each row still
                    // sums to 1 and x̄ drifts only by what was genuinely
                    // lost, never by renormalization error (DESIGN.md §7).
                    let mut last: Vec<Option<&[f32]>> = vec![None; k];
                    for msg in msgs {
                        let x = msg.payload.dense().expect("gossip exchanges dense payloads");
                        last[msg.from] = Some(x);
                    }
                    let heard = last.iter().filter(|m| m.is_some()).count();
                    let mut terms: Vec<(f32, &[f32])> = Vec::with_capacity(1 + heard);
                    if heard == neighbor_counts[to] {
                        // Full house: identical weights *and term order*
                        // as the fast path (messages arrive in sender
                        // order), so a zero-rate plan stays bit-identical.
                        let mut cursor = w.row_cursor(to);
                        terms.push((w.self_weight(to) as f32, own[to].as_slice()));
                        for (from, x) in last.iter().enumerate() {
                            if let Some(x) = x {
                                terms.push((cursor.weight(from) as f32, x));
                            }
                        }
                    } else {
                        let mut cursor = w.row_cursor(to);
                        let mut total = w.self_weight(to);
                        for (from, x) in last.iter().enumerate() {
                            if x.is_some() {
                                total += cursor.weight(from);
                            }
                        }
                        // total ≥ w_to,to > 0 for every supported
                        // weighting; an isolated receiver degenerates to
                        // the identity (keeps computing locally).
                        let scale = 1.0 / total;
                        let mut cursor = w.row_cursor(to);
                        terms.push(((w.self_weight(to) * scale) as f32, own[to].as_slice()));
                        for (from, x) in last.iter().enumerate() {
                            if let Some(x) = x {
                                terms.push(((cursor.weight(from) * scale) as f32, x));
                            }
                        }
                    }
                    terms
                })
                .collect();
            let rows: Vec<ScopedTask<'_, ()>> = self
                .scratch
                .rows_mut()
                .zip(&terms_table)
                .map(|(dst, terms)| {
                    Box::new(move || crate::linalg::weighted_sum_into(dst, terms))
                        as ScopedTask<'_, ()>
                })
                .collect();
            run_rows(pool, rows);
        }
        // Phase 3: every per-edge clone is dropped with the inboxes, so
        // each staging buffer is unique again — reclaim its allocation
        // for next round, then swap the freshly mixed scratch arena
        // wholesale into xs (the old iterate storage becomes scratch).
        drop(inboxes);
        for (from, payload) in own.into_iter().enumerate() {
            self.bcast[from] = Arc::try_unwrap(payload).unwrap_or_default();
        }
        xs.swap_data(&mut self.scratch);
        net.end_round();
        net.total_bytes - before
    }
}

/// One compressed communication round shared by CPD-SGDM and DeepSqueeze:
/// compress each worker's vector, *encode it to wire bytes*, broadcast
/// the encoded buffer to all neighbors, and decode each sender's message
/// exactly once as seen by its receivers. What crosses the network is the
/// codec's byte payload, so the charged byte counts are measured buffer
/// lengths (`wire_bytes == payload.len()`, promoted to a release-mode
/// check via [`check_wire_size`]).
///
/// This is the stateful, zero-allocation successor of the old
/// `exchange_compressed` free function: the per-worker
/// [`CompressedVec`]s, wire byte buffers (recovered from their broadcast
/// Arcs after every round), decode table, and compression RNG streams
/// all persist across rounds, so a steady-state round performs no K·d
/// allocation at all. Worker k draws compression randomness only from
/// stream k — which is what makes the pooled sender-side
/// compress+encode and receiver-side decode bit-identical to the
/// sequential schedule (the old single shared stream would have made
/// parallel compression order-dependent).
pub struct CompressedExchange {
    /// Per-sender compressed scratch (dense + repr reused every round).
    cvs: Vec<CompressedVec>,
    /// Per-sender wire buffers; moved into the broadcast payload each
    /// round and reclaimed once every message clone is dropped.
    wires: Vec<Vec<u8>>,
    /// Per-sender receiver-side decode table (one decode per sender per
    /// round, never one per edge), stored as one flat K×d arena.
    decoded: ParamArena,
    /// Per-worker compression RNG streams, forked once from the
    /// algorithm seed.
    rngs: Vec<Xoshiro256>,
}

impl CompressedExchange {
    pub fn new(k: usize, seed: u64) -> Self {
        let base = Xoshiro256::seed_from_u64(seed);
        Self {
            cvs: (0..k).map(|_| CompressedVec::empty()).collect(),
            wires: vec![Vec::new(); k],
            decoded: ParamArena::zeros(k, 0),
            rngs: (0..k).map(|i| base.fork(i as u64)).collect(),
        }
    }

    pub fn k(&self) -> usize {
        self.rngs.len()
    }

    /// Sender-side phases shared by the canonical and per-receiver
    /// rounds: pooled compress+encode into the per-worker tables (worker
    /// i touches only cvs[i]/wires[i]/rngs[i], so the schedule cannot
    /// reorder anything observable), then the release-mode wire-size
    /// invariant and the observer hook, in worker order on the caller's
    /// thread.
    fn compress_encode_hook(
        &mut self,
        compressor: &dyn Compressor,
        inputs: &ParamArena,
        pool: Option<&WorkerPool>,
        on_compressed: &mut dyn FnMut(usize, &CompressedVec),
    ) {
        {
            let rows: Vec<ScopedTask<'_, ()>> = self
                .cvs
                .iter_mut()
                .zip(self.wires.iter_mut())
                .zip(self.rngs.iter_mut())
                .zip(inputs.rows())
                .map(|(((cv, wire), rng), input)| {
                    Box::new(move || {
                        compressor.compress_into(input, rng, cv);
                        compressor.encode_into(cv, wire);
                    }) as ScopedTask<'_, ()>
                })
                .collect();
            run_rows(pool, rows);
        }
        for i in 0..self.cvs.len() {
            check_wire_size(compressor, &self.cvs[i], self.wires[i].len())
                .unwrap_or_else(|e| panic!("{e}"));
            on_compressed(i, &self.cvs[i]);
        }
    }

    /// Phase shared by both round variants: reclaim the wire buffers
    /// from their broadcast Arcs (every per-edge clone must already be
    /// dropped) and release-assert the byte accounting — a worker's own
    /// message never crosses the wire, so the round must have charged
    /// exactly live_degree(i)·|wire_i| per sender (drops and delays are
    /// charged at send time, so this holds under random encoded faults
    /// too). Returns the bytes charged this round.
    fn reclaim_wires_and_assert(
        &mut self,
        net: &Network,
        before: u64,
        shipped: Vec<Arc<Vec<u8>>>,
    ) -> u64 {
        for (wire, payload) in self.wires.iter_mut().zip(shipped) {
            *wire = Arc::try_unwrap(payload).unwrap_or_default();
        }
        let charged = net.total_bytes - before;
        // `live_degree` == plain degree without churn, so the faultless
        // expectation is literally unchanged; under churn only live
        // links were charged.
        let expected: u64 = (0..self.k())
            .map(|i| net.live_degree(i) as u64 * self.wires[i].len() as u64)
            .sum();
        assert_eq!(
            charged, expected,
            "compressed-round byte accounting drifted: charged {charged}, \
             measured payload lengths total {expected}"
        );
        charged
    }

    /// Run one compress → encode → send → recv → decode round over
    /// `inputs` (one arena row per worker) and return each sender's
    /// message as decoded by its receivers (borrowed from the internal
    /// decode arena; valid until the next round).
    ///
    /// `on_compressed(i, &c)` observes worker i's compressed output on
    /// the sender side — DeepSqueeze uses it for its error-feedback
    /// update. It always runs in worker order on the caller's thread,
    /// after the (possibly pooled) compress+encode fan-out completes.
    /// Every receiver of worker j sees identical bytes, so one decode
    /// per sender suffices; a worker's own message never crosses the
    /// wire (nor does anything in a K=1 fleet), so those are decoded
    /// from the local buffer. Ends the network round and release-asserts
    /// that the charged bytes equal Σ_i degree(i)·|wire_i| — the
    /// measured-accounting regression guard.
    pub fn round(
        &mut self,
        compressor: &dyn Compressor,
        net: &mut Network,
        inputs: &ParamArena,
        pool: Option<&WorkerPool>,
        mut on_compressed: impl FnMut(usize, &CompressedVec),
    ) -> &ParamArena {
        let k = inputs.k();
        assert_eq!(k, self.k(), "exchange sized for a different K");
        let d = inputs.d();
        let before = net.total_bytes;

        // (1)+(2) Sender side: pooled compress + encode, wire-size
        // check, observer hook.
        self.compress_encode_hook(compressor, inputs, pool, &mut on_compressed);

        // (3) Ship: move each wire buffer into a shared payload (one
        // buffer regardless of degree) and keep a local handle.
        let mut shipped: Vec<Arc<Vec<u8>>> = Vec::with_capacity(k);
        for i in 0..k {
            let payload = Arc::new(std::mem::take(&mut self.wires[i]));
            net.broadcast_encoded(i, Arc::clone(&payload));
            shipped.push(payload);
        }

        // (4) Receive: drain every inbox, remembering the first received
        // copy of each sender's payload.
        let mut first_rx: Vec<Option<Arc<Vec<u8>>>> = vec![None; k];
        for to in 0..k {
            for msg in net.recv_all(to) {
                if first_rx[msg.from].is_none() {
                    let Payload::Encoded(bytes) = msg.payload else {
                        panic!("compressed algorithms exchange encoded payloads")
                    };
                    first_rx[msg.from] = Some(bytes);
                }
            }
        }

        // (5) Decode each sender exactly once into its reusable row —
        // from the received bytes where the message crossed a wire, from
        // the local buffer otherwise (own message / K=1 fleet) — fanned
        // over the pool (decoder j writes only decoded[j]). An *absent*
        // sender (churn) decodes to zero instead: falling back to its
        // local buffer would silently repair the outage, and x̂_j must
        // stay frozen for every worker while j is away so the single
        // canonical replica estimate stays consistent (DESIGN.md §7).
        if self.decoded.k() != k || self.decoded.d() != d {
            self.decoded = ParamArena::zeros(k, d);
        }
        {
            let sources: Vec<Option<&[u8]>> = (0..k)
                .map(|j| {
                    if net.is_absent(j) {
                        return None;
                    }
                    Some(
                        first_rx[j]
                            .as_deref()
                            .map(|v| v.as_slice())
                            .unwrap_or_else(|| shipped[j].as_slice()),
                    )
                })
                .collect();
            let rows: Vec<ScopedTask<'_, ()>> = self
                .decoded
                .rows_mut()
                .zip(sources)
                .map(|(dec, bytes)| {
                    Box::new(move || match bytes {
                        Some(bytes) => compressor.decode_into(bytes, dec),
                        None => dec.iter_mut().for_each(|v| *v = 0.0),
                    }) as ScopedTask<'_, ()>
                })
                .collect();
            run_rows(pool, rows);
        }
        net.end_round();

        // (6) Reclaim the wire buffers for next round (every per-edge
        // clone was dropped in (4)/(5)) and release-assert the byte
        // accounting.
        drop(first_rx);
        self.reclaim_wires_and_assert(net, before, shipped);
        &self.decoded
    }

    /// Per-receiver variant of [`CompressedExchange::round`], active
    /// under lossy compressed links ([`crate::comm::FaultPlan`] with
    /// `compressed` enabled). Instead of one decode per sender into a
    /// shared table — only meaningful when every receiver provably sees
    /// the same bytes — every message a receiver *actually got* is
    /// decoded individually and handed to `apply(receiver, sender,
    /// decoded)`: a dropped message simply never reaches `apply` (the
    /// receiver's replica of that sender goes stale), a delayed one
    /// arrives in a later round, and duplicates (stale + fresh) are
    /// applied in arrival order — `recv_all` injects delayed mail before
    /// fresh mail, which is exactly right for CHOCO's incremental
    /// `x̂ += q` deltas. Each present receiver finally applies its *own*
    /// payload, decoded from the local buffer exactly like the canonical
    /// round (it never crosses the wire), so sender-side and
    /// receiver-side replicas use bit-identical decoded bytes. `apply`
    /// runs on the caller's thread in receiver order, deterministically
    /// replayed on resume. Absent (churn) workers neither apply their
    /// own payload nor receive anything — their replicas freeze
    /// everywhere, mirroring the canonical round's zero-decode. Returns
    /// the wire bytes charged this round.
    ///
    /// With a zero-rate plan every receiver hears exactly one fresh copy
    /// of each live neighbor, so `apply` observes byte-identical decodes
    /// to the canonical round and per-receiver replica state evolves
    /// bit-identically to the single canonical x̂ — the zero-rate
    /// contract, property-tested in `rust/tests/fault_injection.rs`.
    pub fn round_per_receiver(
        &mut self,
        compressor: &dyn Compressor,
        net: &mut Network,
        inputs: &ParamArena,
        pool: Option<&WorkerPool>,
        mut on_compressed: impl FnMut(usize, &CompressedVec),
        mut apply: impl FnMut(usize, usize, &[f32]),
    ) -> u64 {
        let k = inputs.k();
        assert_eq!(k, self.k(), "exchange sized for a different K");
        let d = inputs.d();
        let before = net.total_bytes;

        // Sender side and shipping are the canonical phases (1)-(3).
        self.compress_encode_hook(compressor, inputs, pool, &mut on_compressed);
        let mut shipped: Vec<Arc<Vec<u8>>> = Vec::with_capacity(k);
        for i in 0..k {
            let payload = Arc::new(std::mem::take(&mut self.wires[i]));
            net.broadcast_encoded(i, Arc::clone(&payload));
            shipped.push(payload);
        }

        // Receive + decode per (receiver, message). Row 0 of the decode
        // arena doubles as the scratch row — the shared table itself is
        // meaningless in this mode. Sequential by design: per-message
        // decode volume only occurs under an active fault plan, and the
        // apply order (receivers ascending, messages in arrival order,
        // own payload last) is part of the determinism contract.
        if self.decoded.k() != k || self.decoded.d() != d {
            self.decoded = ParamArena::zeros(k, d);
        }
        for to in 0..k {
            // Drain the inbox even for absent receivers so due delayed
            // mail is discarded (and counted) just like the canonical
            // round's phase (4).
            let msgs = net.recv_all(to);
            if net.is_absent(to) {
                continue;
            }
            for msg in msgs {
                let Payload::Encoded(bytes) = msg.payload else {
                    panic!("compressed algorithms exchange encoded payloads")
                };
                compressor.decode_into(&bytes, self.decoded.row_mut(0));
                apply(to, msg.from, self.decoded.row(0));
            }
            compressor.decode_into(shipped[to].as_slice(), self.decoded.row_mut(0));
            apply(to, to, self.decoded.row(0));
        }
        net.end_round();
        self.reclaim_wires_and_assert(net, before, shipped)
    }

    /// Checkpoint the per-worker compression streams (flattened K×4
    /// xoshiro words) — everything a resumed run needs to draw the exact
    /// compression randomness the uninterrupted run would. The tag
    /// distinguishes this bank from the pre-pool single shared stream,
    /// which also serialized as a `put_u64s` list: without it, a K=1
    /// checkpoint from the old format would pass the length check and
    /// silently load old-semantics state (violating bit-identical
    /// resume); with it, any old checkpoint fails with a clear error.
    pub fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("cx-rng-bank");
        let flat: Vec<u64> = self.rngs.iter().flat_map(|r| r.state()).collect();
        w.put_u64s(&flat);
    }

    pub fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("cx-rng-bank").map_err(|e| {
            format!("{e} (pre-worker-pool checkpoints carry a single compression \
                     stream and cannot resume under the per-worker stream bank)")
        })?;
        let flat = r.take_u64s()?;
        if flat.len() != 4 * self.rngs.len() {
            return Err(format!(
                "compressed-exchange rng bank: {} words for K={}",
                flat.len(),
                self.rngs.len()
            ));
        }
        for (rng, c) in self.rngs.iter_mut().zip(flat.chunks_exact(4)) {
            *rng = Xoshiro256::from_state([c[0], c[1], c[2], c[3]]);
        }
        Ok(())
    }
}

/// Per-receiver replica state for lossy compressed links (DESIGN.md §7).
///
/// Under `faults.compressed`, CHOCO-style algorithms abandon the single
/// canonical x̂ table — which is only well-defined while every receiver
/// provably decodes the same q stream — and give each receiver its own
/// view of each in-neighbor, updated solely by the messages that
/// receiver actually decoded. Storage is one flat arena with
/// Σ_i degree(i) rows keyed by the sparse [`MixWeights`] neighbor lists
/// (receiver-major, neighbors ascending), so memory is Σdegree·d — never
/// K²·d: a K=1024 expgraph fleet pays ~2·log₂K·K·d ≈ 20·K·d, the same
/// order as the iterates themselves. A receiver's view of *itself* stays
/// in the algorithm's canonical arena (its own payload never crosses the
/// wire and is applied every round), so the store holds exactly the
/// neighbor slots. Allocation is lazy: the layout costs O(Σdegree)
/// indices up front, but the replica rows are only materialized when
/// per-receiver mode first activates, so a faultless run never pays K·d
/// memory for it.
pub struct ReplicaStore {
    /// CSR row pointers: receiver i's slots are `[row_ptr[i], row_ptr[i+1])`.
    row_ptr: Vec<usize>,
    /// Flat neighbor ids, ascending within each receiver's block (the
    /// same order as `MixWeights::neighbors`).
    nbrs: Vec<usize>,
    d: usize,
    /// Σdegree × d replica rows; 0×d until materialized.
    arena: ParamArena,
    materialized: bool,
}

impl ReplicaStore {
    /// Lay out the slots from the mixing weights' neighbor lists without
    /// allocating any replica memory yet.
    pub fn new(w: &MixWeights, d: usize) -> Self {
        let k = w.k();
        let mut row_ptr = Vec::with_capacity(k + 1);
        let mut nbrs = Vec::new();
        row_ptr.push(0);
        for i in 0..k {
            nbrs.extend(w.neighbors(i).iter().map(|&(j, _)| j));
            row_ptr.push(nbrs.len());
        }
        Self { row_ptr, nbrs, d, arena: ParamArena::zeros(0, d), materialized: false }
    }

    pub fn is_materialized(&self) -> bool {
        self.materialized
    }

    /// Total replica rows (Σ_i degree(i)).
    pub fn slots(&self) -> usize {
        self.nbrs.len()
    }

    /// Allocate the replica rows, seeding each receiver's view of
    /// neighbor j from `seed.row(j)` — the canonical table, which is
    /// every receiver's exact view at the moment per-receiver mode
    /// activates (no message has been lost yet).
    pub fn materialize_from(&mut self, seed: &ParamArena) {
        self.arena = ParamArena::zeros(self.slots(), self.d);
        for (slot, &j) in self.nbrs.iter().enumerate() {
            self.arena.row_mut(slot).copy_from_slice(seed.row(j));
        }
        self.materialized = true;
    }

    /// Allocate the replica rows at zero. DeepSqueeze replicas hold the
    /// last heard one-shot payload, and "never heard" decodes as zero —
    /// the same convention the canonical table uses for absent senders.
    pub fn materialize_zeros(&mut self) {
        self.arena = ParamArena::zeros(self.slots(), self.d);
        self.materialized = true;
    }

    /// Receiver i's slot index for sender j, if j is one of its
    /// neighbors (binary search in the ascending CSR row).
    #[inline]
    pub fn slot_of(&self, i: usize, j: usize) -> Option<usize> {
        let row = &self.nbrs[self.row_ptr[i]..self.row_ptr[i + 1]];
        row.binary_search(&j).ok().map(|p| self.row_ptr[i] + p)
    }

    #[inline]
    pub fn row(&self, slot: usize) -> &[f32] {
        self.arena.row(slot)
    }

    #[inline]
    pub fn row_mut(&mut self, slot: usize) -> &mut [f32] {
        self.arena.row_mut(slot)
    }

    /// Receiver i's replica of neighbor j (panics if j is not one of
    /// i's neighbors).
    #[inline]
    pub fn replica(&self, i: usize, j: usize) -> &[f32] {
        self.row(self.slot_of(i, j).expect("sender is not a neighbor of this receiver"))
    }

    /// Checkpoint: the layout is derived from config (the mixing
    /// weights), so only the materialization flag and — when set — the
    /// replica payload are stored, as a tagged section so pre-replica
    /// checkpoints of the compressed algorithms fail with a clear error
    /// instead of misparsing.
    pub fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("hat-replicas");
        w.put_u64(self.materialized as u64);
        if self.materialized {
            self.arena.state_save(w);
        }
    }

    pub fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("hat-replicas").map_err(|e| {
            format!(
                "{e} (checkpoints written before per-receiver replica support cannot \
                 resume under lossy compressed links)"
            )
        })?;
        if r.take_u64()? != 0 {
            self.arena = ParamArena::zeros(self.slots(), self.d);
            self.arena.state_load(r, "hat-replicas")?;
            self.materialized = true;
        } else {
            self.arena = ParamArena::zeros(0, self.d);
            self.materialized = false;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Network;
    use crate::compress::{Identity, Sign};
    use crate::linalg;
    use crate::linalg::Mat;
    use crate::testing::forall;
    use crate::topology::{mixing_matrix, Topology, Weighting};

    fn setup(k: usize) -> (GossipState, Network) {
        let g = Topology::Ring.build(k, 0);
        let w = mixing_matrix(&g, Weighting::UniformDegree);
        (GossipState::new(w), Network::new(&g))
    }

    fn arena_of(rows: &[Vec<f32>]) -> ParamArena {
        ParamArena::from_rows(rows)
    }

    #[test]
    fn mix_equals_matrix_product() {
        let g = Topology::Ring.build(5, 0);
        let w = mixing_matrix(&g, Weighting::UniformDegree);
        let mut net = Network::new(&g);
        let rows: Vec<Vec<f32>> = (0..5).map(|k| vec![k as f32, -(k as f32)]).collect();
        let expect: Vec<Vec<f32>> = (0..5)
            .map(|i| {
                (0..2)
                    .map(|c| {
                        (0..5).map(|j| w[(i, j)] as f32 * rows[j][c]).sum::<f32>()
                    })
                    .collect()
            })
            .collect();
        let mut gs = GossipState::new(w);
        let mut xs = arena_of(&rows);
        gs.mix(&mut xs, &mut net, None);
        for (got, want) in xs.rows().zip(&expect) {
            crate::testing::assert_allclose(got, want, 1e-6, 1e-7);
        }
    }

    #[test]
    fn prop_mix_preserves_average() {
        // The Eq. (18) invariant: x̄ is untouched by communication.
        forall(0xA11CE, 20, |rng| {
            let k = 3 + rng.below(8);
            let (mut gs, mut net) = setup(k);
            let d = 1 + rng.below(50);
            let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
            let mut xs = arena_of(&rows);
            let before = linalg::mean_of_rows(xs.rows(), d);
            gs.mix(&mut xs, &mut net, None);
            let after = linalg::mean_of_rows(xs.rows(), d);
            crate::testing::assert_allclose(&after, &before, 1e-4, 1e-5);
        });
    }

    #[test]
    fn prop_mix_contracts_consensus() {
        // Lemma 1: one round shrinks Σ||x_k − x̄||² by ≥ (1−ρ)² … we
        // check the weaker monotone form which holds for every sample.
        forall(0xB0B, 20, |rng| {
            let k = 3 + rng.below(8);
            let (mut gs, mut net) = setup(k);
            let d = 1 + rng.below(50);
            let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
            let mut xs = arena_of(&rows);
            let before = linalg::consensus_error_rows(xs.rows(), d);
            gs.mix(&mut xs, &mut net, None);
            let after = linalg::consensus_error_rows(xs.rows(), d);
            assert!(after <= before * (1.0 + 1e-6), "consensus grew: {before} -> {after}");
        });
    }

    #[test]
    fn prop_mix_pooled_is_bit_identical_to_sequential() {
        // The tentpole determinism contract, at the gossip layer: the
        // pool fan-out must reproduce the sequential round bit-for-bit
        // on regular AND irregular (star: hub degree K−1) topologies.
        let pool = WorkerPool::new(3);
        forall(0x90551F, 10, |rng| {
            let k = 3 + rng.below(6);
            let d = 1 + rng.below(60);
            for topo in [Topology::Ring, Topology::Star, Topology::Chain] {
                let g = topo.build(k, 0);
                let w = mixing_matrix(&g, Weighting::UniformDegree);
                let xs0: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
                let mut gs_seq = GossipState::new(w.clone());
                let mut gs_pool = GossipState::new(w);
                let mut net_seq = Network::new(&g);
                let mut net_pool = Network::new(&g);
                let mut xs_seq = arena_of(&xs0);
                let mut xs_pool = arena_of(&xs0);
                // two rounds so the scratch-reuse path is exercised
                for _ in 0..2 {
                    let b_seq = gs_seq.mix(&mut xs_seq, &mut net_seq, None);
                    let b_pool = gs_pool.mix(&mut xs_pool, &mut net_pool, Some(&pool));
                    assert_eq!(b_seq, b_pool, "{topo:?}: bytes diverged");
                }
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(
                    bits(xs_seq.as_slice()),
                    bits(xs_pool.as_slice()),
                    "{topo:?}: pooled mix diverged"
                );
            }
        });
    }

    #[test]
    fn mix_charges_exact_bytes() {
        let (mut gs, mut net) = setup(6);
        let mut xs = ParamArena::zeros(6, 100);
        let bytes = gs.mix(&mut xs, &mut net, None);
        // 6 workers x 2 ring links x 400 bytes
        assert_eq!(bytes, 6 * 2 * 400);
        assert_eq!(net.rounds, 1);
    }

    #[test]
    fn mix_reuses_buffers_across_rounds() {
        // Steady-state zero-allocation: the iterate arena and the
        // scratch arena must simply swap storage between consecutive
        // rounds, and the K broadcast staging buffers must be reclaimed
        // from their Arcs — no fresh K·d allocations.
        let (mut gs, mut net) = setup(4);
        let rows: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; 64]).collect();
        let mut xs = arena_of(&rows);
        gs.mix(&mut xs, &mut net, None); // materializes scratch + staging
        let gen1 = xs.data_ptr();
        let scratch1 = gs.scratch.data_ptr();
        let bcast1: Vec<*const f32> = gs.bcast.iter().map(|b| b.as_ptr()).collect();
        gs.mix(&mut xs, &mut net, None);
        assert_eq!(xs.data_ptr(), scratch1, "round output must land in the old scratch arena");
        assert_eq!(gs.scratch.data_ptr(), gen1, "old iterate storage must be recovered as scratch");
        let bcast2: Vec<*const f32> = gs.bcast.iter().map(|b| b.as_ptr()).collect();
        assert_eq!(bcast2, bcast1, "staging buffers must be reclaimed, not reallocated");
    }

    #[test]
    fn mix_with_zero_rate_plan_is_bit_identical() {
        use crate::comm::FaultPlan;
        forall(0xFA0171, 10, |rng| {
            let k = 3 + rng.below(6);
            let d = 1 + rng.below(40);
            for topo in [Topology::Ring, Topology::Star, Topology::Chain] {
                let g = topo.build(k, 0);
                let w = mixing_matrix(&g, Weighting::UniformDegree);
                let xs0: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
                let mut gs_a = GossipState::new(w.clone());
                let mut gs_b = GossipState::new(w);
                let mut net_a = Network::new(&g);
                let mut net_b = Network::new(&g);
                net_b.set_fault_plan(FaultPlan::new(k, 0.0, 0.0, 1, 0.0, 1));
                let mut xs_a = arena_of(&xs0);
                let mut xs_b = arena_of(&xs0);
                for _ in 0..2 {
                    let ba = gs_a.mix(&mut xs_a, &mut net_a, None);
                    let bb = gs_b.mix(&mut xs_b, &mut net_b, None);
                    assert_eq!(ba, bb, "{topo:?}: bytes diverged under zero-rate plan");
                }
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(
                    bits(xs_a.as_slice()),
                    bits(xs_b.as_slice()),
                    "{topo:?}: zero-rate plan changed the mix"
                );
            }
        });
    }

    #[test]
    fn mix_renormalizes_over_heard_neighbors() {
        use crate::comm::FaultPlan;
        // Every dense message dropped: each worker hears nobody, so the
        // renormalized round must degenerate to the identity — never a
        // shrunk iterate (un-renormalized rows would sum to w_kk < 1).
        let (mut gs, mut net) = setup(5);
        net.set_fault_plan(FaultPlan::new(5, 1.0, 0.0, 1, 0.0, 3));
        let xs0: Vec<Vec<f32>> = (0..5).map(|i| vec![1.0 + i as f32; 8]).collect();
        let mut xs = arena_of(&xs0);
        let bytes = gs.mix(&mut xs, &mut net, None);
        assert!(bytes > 0, "drops are lost in flight, still charged");
        for (got, want) in xs.rows().zip(&xs0) {
            crate::testing::assert_allclose(got, want, 1e-6, 1e-7);
        }
    }

    #[test]
    fn mix_under_churn_keeps_the_average_of_present_workers_stable() {
        use crate::comm::FaultPlan;
        // With worker 2 absent the remaining workers renormalize; the
        // absent worker's iterate must be untouched and no weight mass
        // may leak (each surviving row still sums to 1, so iterates stay
        // inside the convex hull of the inputs).
        let (mut gs, mut net) = setup(6);
        net.set_fault_plan(FaultPlan::new(6, 0.0, 0.0, 1, 0.0, 3));
        net.fault_plan_mut().unwrap().set_absent(2, true);
        let rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32; 4]).collect();
        let mut xs = arena_of(&rows);
        let lo = 0.0f32;
        let hi = 5.0f32;
        gs.mix(&mut xs, &mut net, None);
        assert_eq!(xs.row(2), &[2.0; 4][..], "absent worker mixes with nobody");
        for x in xs.rows() {
            assert!(x.iter().all(|&v| (lo..=hi).contains(&v)), "left the hull: {x:?}");
        }
    }

    #[test]
    fn compressed_round_freezes_absent_senders() {
        use crate::comm::FaultPlan;
        let k = 4;
        let d = 8;
        let rows: Vec<Vec<f32>> = (0..k).map(|i| vec![1.0 + i as f32; d]).collect();
        let inputs = arena_of(&rows);
        let mut net = ring_net(k);
        net.set_fault_plan(FaultPlan::new(k, 0.0, 0.0, 1, 0.0, 9));
        net.fault_plan_mut().unwrap().set_absent(1, true);
        let mut ex = CompressedExchange::new(k, 3);
        let qs = ex.round(&Identity, &mut net, &inputs, None, |_, _| {});
        assert_eq!(qs.row(1), &vec![0.0; d][..], "absent sender decodes to zero everywhere");
        for j in [0usize, 2, 3] {
            assert_eq!(qs.row(j), inputs.row(j), "present senders decode normally");
        }
    }

    #[test]
    #[should_panic(expected = "Assumption 1")]
    fn rejects_non_stochastic_w() {
        let mut w = Mat::eye(3);
        w[(0, 0)] = 0.5; // rows no longer sum to 1
        GossipState::new(w);
    }

    // -----------------------------------------------------------------
    // CompressedExchange
    // -----------------------------------------------------------------

    fn ring_net(k: usize) -> Network {
        Network::new(&Topology::Ring.build(k, 0))
    }

    #[test]
    fn exchange_decodes_every_sender_once_with_exact_bytes() {
        let k = 5;
        let d = 40;
        let mut rng = Xoshiro256::seed_from_u64(9);
        let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
        let inputs = arena_of(&rows);
        let mut net = ring_net(k);
        let mut ex = CompressedExchange::new(k, 3);
        let mut hook_order = Vec::new();
        let qs =
            ex.round(&Sign, &mut net, &inputs, None, |i, c| {
                assert_eq!(c.dense.len(), d);
                hook_order.push(i);
            });
        assert_eq!(hook_order, (0..k).collect::<Vec<_>>(), "hook runs in worker order");
        assert_eq!(qs.k(), k);
        // Sign decode of x: ±(||x||₁/d) with x's signs
        for (q, x) in qs.rows().zip(inputs.rows()) {
            let scale = x.iter().map(|v| v.abs() as f64).sum::<f64>() / d as f64;
            for (qi, xi) in q.iter().zip(x) {
                assert!((qi.abs() as f64 - scale).abs() < 1e-4);
                assert_eq!(qi.is_sign_positive(), *xi >= 0.0);
            }
        }
        // ring: every worker ships its Sign payload over 2 links
        let per_msg = Sign.encoded_bytes(d) as u64;
        assert_eq!(net.total_bytes, k as u64 * 2 * per_msg);
        assert_eq!(net.rounds, 1);
    }

    #[test]
    fn exchange_reuses_wire_buffers_across_rounds() {
        let k = 4;
        let d = 32;
        let mut rng = Xoshiro256::seed_from_u64(10);
        let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
        let inputs = arena_of(&rows);
        let mut net = ring_net(k);
        let mut ex = CompressedExchange::new(k, 5);
        ex.round(&Sign, &mut net, &inputs, None, |_, _| {});
        let wires1: Vec<*const u8> = ex.wires.iter().map(|w| w.as_ptr()).collect();
        let decoded1 = ex.decoded.data_ptr();
        assert!(ex.wires.iter().all(|w| w.len() == Sign.encoded_bytes(d)));
        ex.round(&Sign, &mut net, &inputs, None, |_, _| {});
        let wires2: Vec<*const u8> = ex.wires.iter().map(|w| w.as_ptr()).collect();
        let decoded2 = ex.decoded.data_ptr();
        assert_eq!(wires1, wires2, "wire buffers must be recovered, not reallocated");
        assert_eq!(decoded1, decoded2, "decode arena must be reused");
    }

    #[test]
    fn prop_exchange_pooled_is_bit_identical_to_sequential() {
        use crate::compress::{Qsgd, RandK, TopK};
        let pool = WorkerPool::new(3);
        let ops: Vec<Box<dyn Compressor>> = vec![
            Box::new(Sign),
            Box::new(TopK { ratio: 0.25 }),
            Box::new(RandK { ratio: 0.25 }),
            Box::new(Qsgd { levels: 4 }),
            Box::new(Identity),
        ];
        forall(0xE8C0DE, 6, |rng| {
            let k = 2 + rng.below(6);
            let d = 1 + rng.below(50);
            let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
            let inputs = arena_of(&rows);
            for op in &ops {
                for topo in [Topology::Ring, Topology::Star, Topology::Chain] {
                    let g = topo.build(k, 0);
                    let mut ex_seq = CompressedExchange::new(k, 77);
                    let mut ex_pool = CompressedExchange::new(k, 77);
                    let mut net_seq = Network::new(&g);
                    let mut net_pool = Network::new(&g);
                    for _ in 0..2 {
                        let a = ex_seq
                            .round(op.as_ref(), &mut net_seq, &inputs, None, |_, _| {})
                            .clone();
                        let b = ex_pool.round(
                            op.as_ref(),
                            &mut net_pool,
                            &inputs,
                            Some(&pool),
                            |_, _| {},
                        );
                        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                        assert_eq!(
                            bits(a.as_slice()),
                            bits(b.as_slice()),
                            "{} {topo:?}",
                            op.name()
                        );
                    }
                    assert_eq!(net_seq.total_bytes, net_pool.total_bytes);
                }
            }
        });
    }

    #[test]
    fn exchange_k1_decodes_from_local_buffer() {
        // A 1-worker fleet has no edges: nothing crosses the wire, but
        // the worker still sees its own decoded message.
        let mut net = Network::new(&Topology::Ring.build(1, 0));
        let mut ex = CompressedExchange::new(1, 1);
        let inputs = arena_of(&[vec![1.0f32, -2.0, 3.0, -4.0]]);
        let qs = ex.round(&Identity, &mut net, &inputs, None, |_, _| {});
        assert_eq!(qs.row(0), inputs.row(0));
        assert_eq!(net.total_bytes, 0, "own message never crosses the wire");
    }

    #[test]
    #[should_panic(expected = "wire-size invariant")]
    fn exchange_rejects_miscosted_codec_in_release_builds() {
        // A codec that charges one byte more than it emits must abort
        // the round in release builds (the old debug_assert let it skew
        // Figure 2 silently).
        let mut net = ring_net(3);
        let mut ex = CompressedExchange::new(3, 2);
        let inputs = arena_of(&vec![vec![1.0f32; 8]; 3]);
        ex.round(&crate::testing::MisCosted, &mut net, &inputs, None, |_, _| {});
    }

    #[test]
    fn exchange_state_roundtrip_preserves_streams() {
        use crate::state::{StateReader, StateWriter};
        let k = 4;
        let d = 16;
        let mut rng = Xoshiro256::seed_from_u64(12);
        let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
        let inputs = arena_of(&rows);
        let mut a = CompressedExchange::new(k, 9);
        // advance the streams, snapshot, then compare the next round of
        // a restored twin against the original
        let mut net = ring_net(k);
        a.round(&crate::compress::RandK { ratio: 0.5 }, &mut net, &inputs, None, |_, _| {});
        let mut w = StateWriter::new();
        a.state_save(&mut w);
        let buf = w.into_bytes();
        let mut b = CompressedExchange::new(k, 12345); // wrong seed on purpose
        b.state_load(&mut StateReader::new(&buf)).unwrap();
        let op = crate::compress::RandK { ratio: 0.5 };
        let mut net_a = ring_net(k);
        let mut net_b = ring_net(k);
        let qa = a.round(&op, &mut net_a, &inputs, None, |_, _| {}).clone();
        let qb = b.round(&op, &mut net_b, &inputs, None, |_, _| {});
        assert_eq!(&qa, qb, "restored streams must continue identically");
        // and a K-mismatched bank errors instead of corrupting
        let mut c = CompressedExchange::new(k + 1, 0);
        let err = c.state_load(&mut StateReader::new(&buf)).unwrap_err();
        assert!(err.contains("rng bank"), "{err}");
    }

    // -----------------------------------------------------------------
    // ReplicaStore + per-receiver rounds
    // -----------------------------------------------------------------

    #[test]
    fn replica_store_layout_and_lookup() {
        let g = Topology::Ring.build(5, 0);
        let w = MixWeights::from_graph(&g, Weighting::UniformDegree);
        let mut store = ReplicaStore::new(&w, 3);
        assert_eq!(store.slots(), 10, "ring of 5: Σdegree = 10");
        assert!(!store.is_materialized(), "layout alone must not allocate replicas");
        assert!(store.slot_of(0, 1).is_some());
        assert!(store.slot_of(0, 4).is_some(), "ring wraps");
        assert_eq!(store.slot_of(0, 2), None, "non-neighbors have no slot");
        assert_eq!(store.slot_of(0, 0), None, "self view lives in the canonical arena");
        let seed = arena_of(&(0..5).map(|i| vec![i as f32; 3]).collect::<Vec<_>>());
        store.materialize_from(&seed);
        assert!(store.is_materialized());
        for i in 0..5usize {
            for j in [(i + 1) % 5, (i + 4) % 5] {
                assert_eq!(store.replica(i, j), seed.row(j), "view of {j} seeded from canon");
            }
        }
        // slots are independent: receiver 0's view of 1 drifts alone
        let slot = store.slot_of(0, 1).unwrap();
        store.row_mut(slot)[0] = 99.0;
        assert_eq!(store.replica(2, 1)[0], 1.0, "receiver 2's view of 1 untouched");
    }

    #[test]
    fn replica_store_state_roundtrips_and_rejects_old_checkpoints() {
        use crate::state::{StateReader, StateWriter};
        let g = Topology::Star.build(4, 0);
        let w = MixWeights::from_graph(&g, Weighting::UniformDegree);
        // Unmaterialized round-trip: flag off, nothing else stored.
        let store = ReplicaStore::new(&w, 2);
        let mut sw = StateWriter::new();
        store.state_save(&mut sw);
        let buf = sw.into_bytes();
        let mut back = ReplicaStore::new(&w, 2);
        back.materialize_zeros(); // must be reset by the load
        back.state_load(&mut StateReader::new(&buf)).unwrap();
        assert!(!back.is_materialized());
        // Materialized round-trip: payload restored bit-exactly.
        let seed = arena_of(&(0..4).map(|i| vec![i as f32 + 0.5; 2]).collect::<Vec<_>>());
        let mut store = ReplicaStore::new(&w, 2);
        store.materialize_from(&seed);
        let slot = store.slot_of(0, 2).unwrap();
        store.row_mut(slot)[1] = -7.25;
        let mut sw = StateWriter::new();
        store.state_save(&mut sw);
        let buf = sw.into_bytes();
        let mut back = ReplicaStore::new(&w, 2);
        back.state_load(&mut StateReader::new(&buf)).unwrap();
        assert!(back.is_materialized());
        for s in 0..store.slots() {
            assert_eq!(back.row(s), store.row(s), "slot {s} drifted through the round-trip");
        }
        // A section written under any other tag (e.g. a pre-replica
        // checkpoint layout) fails loudly, with the migration hint.
        let mut sw = StateWriter::new();
        sw.tag("cx-rng-bank");
        let bad = sw.into_bytes();
        let err = back.state_load(&mut StateReader::new(&bad)).unwrap_err();
        assert!(err.contains("per-receiver replica"), "{err}");
    }

    #[test]
    fn per_receiver_round_at_zero_rate_applies_canonical_decodes() {
        use crate::comm::FaultPlan;
        // Zero-rate contract at the exchange layer: every (receiver,
        // sender) apply sees byte-identical decodes to the canonical
        // shared-table round, each live edge exactly once, self last.
        let k = 5;
        let d = 24;
        let mut rng = Xoshiro256::seed_from_u64(21);
        let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
        let inputs = arena_of(&rows);
        let mut net_canon = ring_net(k);
        let mut ex_canon = CompressedExchange::new(k, 7);
        let qs = ex_canon.round(&Sign, &mut net_canon, &inputs, None, |_, _| {}).clone();
        let mut net = ring_net(k);
        let mut plan = FaultPlan::new(k, 0.0, 0.0, 1, 0.0, 11);
        plan.compressed = true;
        net.set_fault_plan(plan);
        let mut ex = CompressedExchange::new(k, 7);
        let mut applied: Vec<(usize, usize)> = Vec::new();
        let bytes = ex.round_per_receiver(&Sign, &mut net, &inputs, None, |_, _| {}, |to, from, dec| {
            assert_eq!(dec, qs.row(from), "({to},{from}): decode diverged from canonical");
            applied.push((to, from));
        });
        assert_eq!(bytes, net_canon.total_bytes, "zero-rate plan changed the byte bill");
        let mut expect: Vec<(usize, usize)> = Vec::new();
        for to in 0..k {
            expect.push((to, (to + k - 1) % k)); // ring mail arrives in sender-send order
            expect.push((to, (to + 1) % k));
            expect.push((to, to)); // own payload last
        }
        expect.sort_unstable();
        applied.sort_unstable();
        assert_eq!(applied, expect, "each live edge + self applied exactly once");
    }

    #[test]
    fn per_receiver_round_under_full_drop_applies_only_self() {
        use crate::comm::FaultPlan;
        // drop_prob = 1 on an opted-in plan: no cross-wire apply ever
        // fires (replicas of neighbors go stale), but every present
        // worker still applies its own payload, and the drops are still
        // charged at send time.
        let k = 4;
        let d = 8;
        let inputs = arena_of(&(0..k).map(|i| vec![1.0 + i as f32; d]).collect::<Vec<_>>());
        let mut net = ring_net(k);
        let mut plan = FaultPlan::new(k, 1.0, 0.0, 1, 0.0, 5);
        plan.compressed = true;
        net.set_fault_plan(plan);
        let mut ex = CompressedExchange::new(k, 3);
        let mut applied = Vec::new();
        let bytes =
            ex.round_per_receiver(&Identity, &mut net, &inputs, None, |_, _| {}, |to, from, dec| {
                assert_eq!(dec, inputs.row(from));
                applied.push((to, from));
            });
        assert_eq!(applied, (0..k).map(|i| (i, i)).collect::<Vec<_>>());
        assert_eq!(bytes, (k * 2 * 4 * d) as u64, "drops are lost in flight, still charged");
        assert_eq!(net.fault_plan().unwrap().counters().dropped_encoded, (k * 2) as u64);
    }

    #[test]
    fn per_receiver_round_delivers_delayed_payloads_in_arrival_order() {
        use crate::comm::FaultPlan;
        // delay_prob = 1, max_delay = 1: every cross-wire payload lands
        // exactly one round late, so round 1 applies only self payloads
        // and round 2 applies round-1's q's (stale) before round-2 drops
        // them entirely... here rates are deterministic so round 2 sees
        // each neighbor's round-1 payload plus its own fresh one.
        let k = 3;
        let d = 4;
        let in1 = arena_of(&(0..k).map(|i| vec![1.0 + i as f32; d]).collect::<Vec<_>>());
        let in2 = arena_of(&(0..k).map(|i| vec![-(1.0 + i as f32); d]).collect::<Vec<_>>());
        let mut net = ring_net(k);
        let mut plan = FaultPlan::new(k, 0.0, 1.0, 1, 0.0, 5);
        plan.compressed = true;
        net.set_fault_plan(plan);
        let mut ex = CompressedExchange::new(k, 3);
        let mut first = Vec::new();
        ex.round_per_receiver(&Identity, &mut net, &in1, None, |_, _| {}, |to, from, _| {
            first.push((to, from));
        });
        assert_eq!(first, (0..k).map(|i| (i, i)).collect::<Vec<_>>(), "round 1: all mail delayed");
        let mut second: Vec<(usize, usize, f32)> = Vec::new();
        ex.round_per_receiver(&Identity, &mut net, &in2, None, |_, _| {}, |to, from, dec| {
            second.push((to, from, dec[0]));
        });
        // Each receiver: both neighbors' *round-1* payloads (positive
        // values) then its own fresh round-2 payload (negative).
        assert_eq!(second.len(), k * 3);
        for &(to, from, v) in &second {
            if to == from {
                assert_eq!(v, in2.row(to)[0], "self payload is fresh");
            } else {
                assert_eq!(v, in1.row(from)[0], "delayed payload carries round-1 bytes");
            }
        }
    }
}
