//! Shared gossip machinery: the Eq. (4) mixing step over the byte-metered
//! network (full-precision algorithms) and the compressed exchange round
//! (CPD-SGDM / DeepSqueeze) that ships encoded codec bytes end-to-end.

use std::sync::Arc;

use crate::comm::Network;
use crate::compress::{CompressedVec, Compressor};
use crate::linalg::Mat;
use crate::rng::Xoshiro256;

/// One compressed communication round shared by CPD-SGDM and DeepSqueeze:
/// compress each worker's vector in `inputs`, *encode it to wire bytes*,
/// broadcast the encoded buffer to all neighbors, and return each
/// worker's message as decoded by its receivers. What crosses the network
/// is the codec's byte payload, so the charged byte counts are measured
/// buffer lengths (`wire_bytes == payload.len()`).
///
/// `on_compressed(i, &c)` runs on the sender side before encoding —
/// DeepSqueeze uses it for its error-feedback update. Every receiver of
/// worker j sees identical bytes, so one decode per sender suffices; a
/// worker's own message never crosses the wire (nor does anything in a
/// K=1 fleet), so those are decoded from the local buffer. Ends the
/// network round.
pub(crate) fn exchange_compressed(
    compressor: &dyn Compressor,
    rng: &mut Xoshiro256,
    net: &mut Network,
    inputs: &[Vec<f32>],
    mut on_compressed: impl FnMut(usize, &CompressedVec),
) -> Vec<Vec<f32>> {
    let k = inputs.len();
    let d = inputs.first().map(Vec::len).unwrap_or(0);
    let mut encoded: Vec<Arc<Vec<u8>>> = Vec::with_capacity(k);
    for (i, v) in inputs.iter().enumerate() {
        let c = compressor.compress(v, rng);
        on_compressed(i, &c);
        let bytes = Arc::new(compressor.encode(&c));
        debug_assert_eq!(bytes.len(), c.wire_bytes, "codec wire-size invariant");
        net.broadcast_encoded(i, Arc::clone(&bytes));
        encoded.push(bytes);
    }
    let mut decoded: Vec<Option<Vec<f32>>> = (0..k).map(|_| None).collect();
    for i in 0..k {
        for msg in net.recv_all(i) {
            if decoded[msg.from].is_none() {
                let payload = msg
                    .payload
                    .encoded()
                    .expect("compressed algorithms exchange encoded payloads");
                decoded[msg.from] = Some(compressor.decode(payload, d));
            }
        }
    }
    net.end_round();
    decoded
        .into_iter()
        .enumerate()
        .map(|(j, q)| q.unwrap_or_else(|| compressor.decode(&encoded[j], d)))
        .collect()
}

/// Mixing matrix + the exchange logic for one full-precision gossip
/// round: every worker broadcasts its vector to its neighbors, then
/// forms `x_k ← w_kk x_k + Σ_{j∈N_k} w_kj x_j` from what it received.
#[derive(Clone, Debug)]
pub struct GossipState {
    pub w: Mat,
}

impl GossipState {
    pub fn new(w: Mat) -> Self {
        assert!(w.is_doubly_stochastic(1e-6), "Assumption 1 violated");
        Self { w }
    }

    pub fn k(&self) -> usize {
        self.w.rows
    }

    /// One communication round over `net`, mixing `xs` in place.
    /// Charges 4·d bytes per directed link (f32 dense payload).
    /// Returns the wire bytes this round consumed.
    ///
    /// §Perf: each worker's buffer is *moved* into a shared (Arc)
    /// broadcast payload after seeding the self-term, and results are
    /// swapped rather than copied back — zero deep copies per round
    /// (before: degree+1 full-vector copies per worker). Measured
    /// before/after in EXPERIMENTS.md §Perf.
    pub fn mix(&self, xs: &mut [Vec<f32>], net: &mut Network) -> u64 {
        let k = self.k();
        assert_eq!(xs.len(), k);
        let before = net.total_bytes;
        let d = xs.first().map(Vec::len).unwrap_or(0);
        // Phase 1: each worker *moves* its buffer into a shared (Arc)
        // broadcast payload and keeps one reference for its own self
        // term — zero deep copies regardless of degree.
        let mut own: Vec<std::sync::Arc<Vec<f32>>> = Vec::with_capacity(k);
        for from in 0..k {
            let payload = std::sync::Arc::new(std::mem::take(&mut xs[from]));
            own.push(std::sync::Arc::clone(&payload));
            net.broadcast_shared(from, payload);
        }
        // Phase 2: one fused weighted-sum pass per worker over
        // (self, received neighbors) — a single write sweep of memory.
        for to in 0..k {
            let msgs = net.recv_all(to);
            let mut terms: Vec<(f32, &[f32])> = Vec::with_capacity(1 + msgs.len());
            terms.push((self.w[(to, to)] as f32, own[to].as_slice()));
            for msg in &msgs {
                let x = msg.payload.dense().expect("gossip exchanges dense payloads");
                terms.push((self.w[(to, msg.from)] as f32, x));
            }
            xs[to] = crate::linalg::weighted_sum(&terms, d);
        }
        net.end_round();
        net.total_bytes - before
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Network;
    use crate::linalg;
    use crate::testing::forall;
    use crate::topology::{mixing_matrix, Topology, Weighting};

    fn setup(k: usize) -> (GossipState, Network) {
        let g = Topology::Ring.build(k, 0);
        let w = mixing_matrix(&g, Weighting::UniformDegree);
        (GossipState::new(w), Network::new(&g))
    }

    #[test]
    fn mix_equals_matrix_product() {
        let (gs, mut net) = setup(5);
        let mut xs: Vec<Vec<f32>> = (0..5).map(|k| vec![k as f32, -(k as f32)]).collect();
        let expect: Vec<Vec<f32>> = (0..5)
            .map(|i| {
                (0..2)
                    .map(|c| {
                        (0..5).map(|j| gs.w[(i, j)] as f32 * xs[j][c]).sum::<f32>()
                    })
                    .collect()
            })
            .collect();
        gs.mix(&mut xs, &mut net);
        for (got, want) in xs.iter().zip(&expect) {
            crate::testing::assert_allclose(got, want, 1e-6, 1e-7);
        }
    }

    #[test]
    fn prop_mix_preserves_average() {
        // The Eq. (18) invariant: x̄ is untouched by communication.
        forall(0xA11CE, 20, |rng| {
            let k = 3 + rng.below(8);
            let (gs, mut net) = setup(k);
            let d = 1 + rng.below(50);
            let mut xs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
            let before = linalg::mean_of(&xs);
            gs.mix(&mut xs, &mut net);
            let after = linalg::mean_of(&xs);
            crate::testing::assert_allclose(&after, &before, 1e-4, 1e-5);
        });
    }

    #[test]
    fn prop_mix_contracts_consensus() {
        // Lemma 1: one round shrinks Σ||x_k − x̄||² by ≥ (1−ρ)² … we
        // check the weaker monotone form which holds for every sample.
        forall(0xB0B, 20, |rng| {
            let k = 3 + rng.below(8);
            let (gs, mut net) = setup(k);
            let d = 1 + rng.below(50);
            let mut xs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
            let before = linalg::consensus_error(&xs);
            gs.mix(&mut xs, &mut net);
            let after = linalg::consensus_error(&xs);
            assert!(after <= before * (1.0 + 1e-6), "consensus grew: {before} -> {after}");
        });
    }

    #[test]
    fn mix_charges_exact_bytes() {
        let (gs, mut net) = setup(6);
        let mut xs = vec![vec![0.0f32; 100]; 6];
        let bytes = gs.mix(&mut xs, &mut net);
        // 6 workers x 2 ring links x 400 bytes
        assert_eq!(bytes, 6 * 2 * 400);
        assert_eq!(net.rounds, 1);
    }

    #[test]
    #[should_panic(expected = "Assumption 1")]
    fn rejects_non_stochastic_w() {
        let mut w = Mat::eye(3);
        w[(0, 0)] = 0.5; // rows no longer sum to 1
        GossipState::new(w);
    }
}
