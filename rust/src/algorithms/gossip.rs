//! Shared gossip machinery: the Eq. (4) mixing step over the byte-metered
//! network (full-precision algorithms) and the compressed exchange round
//! (CPD-SGDM / DeepSqueeze) that ships encoded codec bytes end-to-end.
//!
//! §Perf: both rounds are **zero-allocation in steady state** and fan
//! their per-worker work over the session's persistent
//! [`crate::engine::WorkerPool`] when one is supplied — the same pool
//! that runs the local-step phase, so the whole step loop shares one set
//! of parked threads. Determinism is preserved bit-exactly: every task
//! touches only its own worker's buffers, all inputs are read-only
//! snapshots taken before the fan-out, and reductions happen on the
//! caller's thread in worker order (see DESIGN.md §4–5).

use std::sync::Arc;

use crate::arena::ParamArena;
use crate::comm::{Message, Network, Payload};
use crate::compress::{check_wire_size, CompressedVec, Compressor};
use crate::engine::{ScopedTask, WorkerPool};
use crate::rng::Xoshiro256;
use crate::topology::MixWeights;

/// Run one closure per worker: fanned over the pool when present (and
/// worth it), inline otherwise. Each row must touch only its own
/// worker's mutable state — the shared contract of every comm-phase
/// fan-out in this module.
pub(crate) fn run_rows(pool: Option<&WorkerPool>, rows: Vec<ScopedTask<'_, ()>>) {
    match pool {
        Some(pool) if rows.len() > 1 => {
            pool.run_scoped(rows);
        }
        _ => rows.into_iter().for_each(|row| row()),
    }
}

/// Sparse mixing weights + the exchange logic for one full-precision
/// gossip round: every worker broadcasts its vector to its neighbors,
/// then forms `x_k ← w_kk x_k + Σ_{j∈N_k} w_kj x_j` from what it
/// received. Weights live in CSR rows ([`MixWeights`]), so a K=1024
/// fleet never materializes a K×K dense matrix.
#[derive(Clone, Debug)]
pub struct GossipState {
    weights: MixWeights,
    /// Flat K×d arena holding each round's mixing outputs; swapped
    /// wholesale with the iterate arena at the end of the round, so
    /// steady-state rounds allocate nothing in K·d.
    scratch: ParamArena,
    /// Per-worker broadcast staging buffers: each round copies worker
    /// k's arena row in, ships it as a shared (Arc) payload, and
    /// reclaims the allocation once every message clone is dropped.
    bcast: Vec<Vec<f32>>,
}

impl GossipState {
    pub fn new(w: impl Into<MixWeights>) -> Self {
        let weights = w.into();
        assert!(weights.is_doubly_stochastic(1e-6), "Assumption 1 violated");
        Self { weights, scratch: ParamArena::zeros(0, 0), bcast: Vec::new() }
    }

    pub fn k(&self) -> usize {
        self.weights.k()
    }

    /// The CSR mixing weights this state gossips with.
    pub fn weights(&self) -> &MixWeights {
        &self.weights
    }

    /// One communication round over `net`, mixing the K×d iterate arena
    /// `xs` in place. Charges 4·d bytes per directed link (f32 dense
    /// payload). Returns the wire bytes this round consumed.
    ///
    /// §Perf: each worker's arena row is copied into a persistent
    /// per-worker staging buffer (rows of a flat arena cannot be moved
    /// out, so one K·d memcpy per round is the floor) and shipped as a
    /// shared (Arc) payload; the per-receiver fused weighted-sum writes
    /// into this state's scratch arena — fanned over `pool` when one is
    /// supplied — whose storage is then *swapped* wholesale with `xs`.
    /// The staging allocations are recovered from their Arcs once every
    /// message clone is dropped, so a steady-state round performs zero
    /// K·d allocation. Pool and sequential schedules are bit-identical:
    /// receiver k reads frozen inputs and writes only scratch row k, in
    /// the same term order either way. Measured in EXPERIMENTS.md §Perf
    /// (`mix_round`).
    pub fn mix(&mut self, xs: &mut ParamArena, net: &mut Network, pool: Option<&WorkerPool>) -> u64 {
        let k = self.k();
        assert_eq!(xs.k(), k);
        let before = net.total_bytes;
        let d = xs.d();
        if self.scratch.k() != k || self.scratch.d() != d {
            self.scratch = ParamArena::zeros(k, d);
        }
        if self.bcast.len() != k {
            self.bcast.resize_with(k, Vec::new);
        }
        // Phase 1: copy each worker's arena row into its reusable
        // staging buffer and ship that as a shared (Arc) broadcast
        // payload, keeping one reference for the self term.
        let mut own: Vec<Arc<Vec<f32>>> = Vec::with_capacity(k);
        for from in 0..k {
            let mut buf = std::mem::take(&mut self.bcast[from]);
            buf.clear();
            buf.extend_from_slice(xs.row(from));
            let payload = Arc::new(buf);
            own.push(Arc::clone(&payload));
            net.broadcast_shared(from, payload);
        }
        // Phase 2: drain every inbox up front (mail order is fixed by
        // the send loop, not by receiver scheduling), then run one fused
        // weighted-sum pass per worker over (self, received neighbors).
        let inboxes: Vec<Vec<Message>> = (0..k).map(|to| net.recv_all(to)).collect();
        let faults_active = net.faults_active();
        let neighbor_counts: Vec<usize> = (0..k).map(|to| net.neighbors(to).len()).collect();
        {
            let w = &self.weights;
            let terms_table: Vec<Vec<(f32, &[f32])>> = (0..k)
                .map(|to| {
                    let msgs = &inboxes[to];
                    if !faults_active {
                        // Legacy fast path: exactly one message per
                        // neighbor, weights already sum to 1. Messages
                        // arrive in ascending sender order (fixed by the
                        // send loop), so a forward-only cursor over the
                        // CSR row replaces the dense lookup bit-exactly.
                        let mut cursor = w.row_cursor(to);
                        let mut terms: Vec<(f32, &[f32])> = Vec::with_capacity(1 + msgs.len());
                        terms.push((w.self_weight(to) as f32, own[to].as_slice()));
                        for msg in msgs {
                            let x = msg.payload.dense().expect("gossip exchanges dense payloads");
                            terms.push((cursor.weight(msg.from) as f32, x));
                        }
                        return terms;
                    }
                    // Hardened path (fault plan installed): a sender may
                    // be missing (drop/churn) or duplicated (a stale
                    // delayed copy plus a fresh one). Keep the *last*
                    // message per sender — `recv_all` injects delayed
                    // mail before fresh mail, so last is freshest — and
                    // renormalize the mixing weights over the senders
                    // actually heard from, in f64, so each row still
                    // sums to 1 and x̄ drifts only by what was genuinely
                    // lost, never by renormalization error (DESIGN.md §7).
                    let mut last: Vec<Option<&[f32]>> = vec![None; k];
                    for msg in msgs {
                        let x = msg.payload.dense().expect("gossip exchanges dense payloads");
                        last[msg.from] = Some(x);
                    }
                    let heard = last.iter().filter(|m| m.is_some()).count();
                    let mut terms: Vec<(f32, &[f32])> = Vec::with_capacity(1 + heard);
                    if heard == neighbor_counts[to] {
                        // Full house: identical weights *and term order*
                        // as the fast path (messages arrive in sender
                        // order), so a zero-rate plan stays bit-identical.
                        let mut cursor = w.row_cursor(to);
                        terms.push((w.self_weight(to) as f32, own[to].as_slice()));
                        for (from, x) in last.iter().enumerate() {
                            if let Some(x) = x {
                                terms.push((cursor.weight(from) as f32, x));
                            }
                        }
                    } else {
                        let mut cursor = w.row_cursor(to);
                        let mut total = w.self_weight(to);
                        for (from, x) in last.iter().enumerate() {
                            if x.is_some() {
                                total += cursor.weight(from);
                            }
                        }
                        // total ≥ w_to,to > 0 for every supported
                        // weighting; an isolated receiver degenerates to
                        // the identity (keeps computing locally).
                        let scale = 1.0 / total;
                        let mut cursor = w.row_cursor(to);
                        terms.push(((w.self_weight(to) * scale) as f32, own[to].as_slice()));
                        for (from, x) in last.iter().enumerate() {
                            if let Some(x) = x {
                                terms.push(((cursor.weight(from) * scale) as f32, x));
                            }
                        }
                    }
                    terms
                })
                .collect();
            let rows: Vec<ScopedTask<'_, ()>> = self
                .scratch
                .rows_mut()
                .zip(&terms_table)
                .map(|(dst, terms)| {
                    Box::new(move || crate::linalg::weighted_sum_into(dst, terms))
                        as ScopedTask<'_, ()>
                })
                .collect();
            run_rows(pool, rows);
        }
        // Phase 3: every per-edge clone is dropped with the inboxes, so
        // each staging buffer is unique again — reclaim its allocation
        // for next round, then swap the freshly mixed scratch arena
        // wholesale into xs (the old iterate storage becomes scratch).
        drop(inboxes);
        for (from, payload) in own.into_iter().enumerate() {
            self.bcast[from] = Arc::try_unwrap(payload).unwrap_or_default();
        }
        xs.swap_data(&mut self.scratch);
        net.end_round();
        net.total_bytes - before
    }
}

/// One compressed communication round shared by CPD-SGDM and DeepSqueeze:
/// compress each worker's vector, *encode it to wire bytes*, broadcast
/// the encoded buffer to all neighbors, and decode each sender's message
/// exactly once as seen by its receivers. What crosses the network is the
/// codec's byte payload, so the charged byte counts are measured buffer
/// lengths (`wire_bytes == payload.len()`, promoted to a release-mode
/// check via [`check_wire_size`]).
///
/// This is the stateful, zero-allocation successor of the old
/// `exchange_compressed` free function: the per-worker
/// [`CompressedVec`]s, wire byte buffers (recovered from their broadcast
/// Arcs after every round), decode table, and compression RNG streams
/// all persist across rounds, so a steady-state round performs no K·d
/// allocation at all. Worker k draws compression randomness only from
/// stream k — which is what makes the pooled sender-side
/// compress+encode and receiver-side decode bit-identical to the
/// sequential schedule (the old single shared stream would have made
/// parallel compression order-dependent).
pub struct CompressedExchange {
    /// Per-sender compressed scratch (dense + repr reused every round).
    cvs: Vec<CompressedVec>,
    /// Per-sender wire buffers; moved into the broadcast payload each
    /// round and reclaimed once every message clone is dropped.
    wires: Vec<Vec<u8>>,
    /// Per-sender receiver-side decode table (one decode per sender per
    /// round, never one per edge), stored as one flat K×d arena.
    decoded: ParamArena,
    /// Per-worker compression RNG streams, forked once from the
    /// algorithm seed.
    rngs: Vec<Xoshiro256>,
}

impl CompressedExchange {
    pub fn new(k: usize, seed: u64) -> Self {
        let base = Xoshiro256::seed_from_u64(seed);
        Self {
            cvs: (0..k).map(|_| CompressedVec::empty()).collect(),
            wires: vec![Vec::new(); k],
            decoded: ParamArena::zeros(k, 0),
            rngs: (0..k).map(|i| base.fork(i as u64)).collect(),
        }
    }

    pub fn k(&self) -> usize {
        self.rngs.len()
    }

    /// Run one compress → encode → send → recv → decode round over
    /// `inputs` (one arena row per worker) and return each sender's
    /// message as decoded by its receivers (borrowed from the internal
    /// decode arena; valid until the next round).
    ///
    /// `on_compressed(i, &c)` observes worker i's compressed output on
    /// the sender side — DeepSqueeze uses it for its error-feedback
    /// update. It always runs in worker order on the caller's thread,
    /// after the (possibly pooled) compress+encode fan-out completes.
    /// Every receiver of worker j sees identical bytes, so one decode
    /// per sender suffices; a worker's own message never crosses the
    /// wire (nor does anything in a K=1 fleet), so those are decoded
    /// from the local buffer. Ends the network round and release-asserts
    /// that the charged bytes equal Σ_i degree(i)·|wire_i| — the
    /// measured-accounting regression guard.
    pub fn round(
        &mut self,
        compressor: &dyn Compressor,
        net: &mut Network,
        inputs: &ParamArena,
        pool: Option<&WorkerPool>,
        mut on_compressed: impl FnMut(usize, &CompressedVec),
    ) -> &ParamArena {
        let k = inputs.k();
        assert_eq!(k, self.k(), "exchange sized for a different K");
        let d = inputs.d();
        let before = net.total_bytes;

        // (1) Sender side: compress + encode into the per-worker tables,
        // fanned over the pool (worker i touches only cvs[i]/wires[i]/
        // rngs[i], so the schedule cannot reorder anything observable).
        {
            let rows: Vec<ScopedTask<'_, ()>> = self
                .cvs
                .iter_mut()
                .zip(self.wires.iter_mut())
                .zip(self.rngs.iter_mut())
                .zip(inputs.rows())
                .map(|(((cv, wire), rng), input)| {
                    Box::new(move || {
                        compressor.compress_into(input, rng, cv);
                        compressor.encode_into(cv, wire);
                    }) as ScopedTask<'_, ()>
                })
                .collect();
            run_rows(pool, rows);
        }

        // (2) Sender-side hook + the wire-size invariant, in worker
        // order. The check runs in release builds: a codec that costs
        // bytes it does not emit would silently skew Figure 2.
        for i in 0..k {
            check_wire_size(compressor, &self.cvs[i], self.wires[i].len())
                .unwrap_or_else(|e| panic!("{e}"));
            on_compressed(i, &self.cvs[i]);
        }

        // (3) Ship: move each wire buffer into a shared payload (one
        // buffer regardless of degree) and keep a local handle.
        let mut shipped: Vec<Arc<Vec<u8>>> = Vec::with_capacity(k);
        for i in 0..k {
            let payload = Arc::new(std::mem::take(&mut self.wires[i]));
            net.broadcast_encoded(i, Arc::clone(&payload));
            shipped.push(payload);
        }

        // (4) Receive: drain every inbox, remembering the first received
        // copy of each sender's payload.
        let mut first_rx: Vec<Option<Arc<Vec<u8>>>> = vec![None; k];
        for to in 0..k {
            for msg in net.recv_all(to) {
                if first_rx[msg.from].is_none() {
                    let Payload::Encoded(bytes) = msg.payload else {
                        panic!("compressed algorithms exchange encoded payloads")
                    };
                    first_rx[msg.from] = Some(bytes);
                }
            }
        }

        // (5) Decode each sender exactly once into its reusable row —
        // from the received bytes where the message crossed a wire, from
        // the local buffer otherwise (own message / K=1 fleet) — fanned
        // over the pool (decoder j writes only decoded[j]). An *absent*
        // sender (churn) decodes to zero instead: falling back to its
        // local buffer would silently repair the outage, and x̂_j must
        // stay frozen for every worker while j is away so the single
        // canonical replica estimate stays consistent (DESIGN.md §7).
        if self.decoded.k() != k || self.decoded.d() != d {
            self.decoded = ParamArena::zeros(k, d);
        }
        {
            let sources: Vec<Option<&[u8]>> = (0..k)
                .map(|j| {
                    if net.is_absent(j) {
                        return None;
                    }
                    Some(
                        first_rx[j]
                            .as_deref()
                            .map(|v| v.as_slice())
                            .unwrap_or_else(|| shipped[j].as_slice()),
                    )
                })
                .collect();
            let rows: Vec<ScopedTask<'_, ()>> = self
                .decoded
                .rows_mut()
                .zip(sources)
                .map(|(dec, bytes)| {
                    Box::new(move || match bytes {
                        Some(bytes) => compressor.decode_into(bytes, dec),
                        None => dec.iter_mut().for_each(|v| *v = 0.0),
                    }) as ScopedTask<'_, ()>
                })
                .collect();
            run_rows(pool, rows);
        }
        net.end_round();

        // (6) Reclaim the wire buffers for next round (every per-edge
        // clone was dropped in (4)/(5)), then release-assert the byte
        // accounting: a worker's own message never crosses the wire, so
        // the round must have charged exactly degree(i)·|wire_i| per
        // sender.
        drop(first_rx);
        for (wire, payload) in self.wires.iter_mut().zip(shipped) {
            *wire = Arc::try_unwrap(payload).unwrap_or_default();
        }
        let charged = net.total_bytes - before;
        // `live_degree` == plain degree without churn, so the faultless
        // expectation is literally unchanged; under churn only live
        // links were charged.
        let expected: u64 = (0..k)
            .map(|i| net.live_degree(i) as u64 * self.wires[i].len() as u64)
            .sum();
        assert_eq!(
            charged, expected,
            "compressed-round byte accounting drifted: charged {charged}, \
             measured payload lengths total {expected}"
        );
        &self.decoded
    }

    /// Checkpoint the per-worker compression streams (flattened K×4
    /// xoshiro words) — everything a resumed run needs to draw the exact
    /// compression randomness the uninterrupted run would. The tag
    /// distinguishes this bank from the pre-pool single shared stream,
    /// which also serialized as a `put_u64s` list: without it, a K=1
    /// checkpoint from the old format would pass the length check and
    /// silently load old-semantics state (violating bit-identical
    /// resume); with it, any old checkpoint fails with a clear error.
    pub fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("cx-rng-bank");
        let flat: Vec<u64> = self.rngs.iter().flat_map(|r| r.state()).collect();
        w.put_u64s(&flat);
    }

    pub fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("cx-rng-bank").map_err(|e| {
            format!("{e} (pre-worker-pool checkpoints carry a single compression \
                     stream and cannot resume under the per-worker stream bank)")
        })?;
        let flat = r.take_u64s()?;
        if flat.len() != 4 * self.rngs.len() {
            return Err(format!(
                "compressed-exchange rng bank: {} words for K={}",
                flat.len(),
                self.rngs.len()
            ));
        }
        for (rng, c) in self.rngs.iter_mut().zip(flat.chunks_exact(4)) {
            *rng = Xoshiro256::from_state([c[0], c[1], c[2], c[3]]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Network;
    use crate::compress::{Identity, Sign};
    use crate::linalg;
    use crate::linalg::Mat;
    use crate::testing::forall;
    use crate::topology::{mixing_matrix, Topology, Weighting};

    fn setup(k: usize) -> (GossipState, Network) {
        let g = Topology::Ring.build(k, 0);
        let w = mixing_matrix(&g, Weighting::UniformDegree);
        (GossipState::new(w), Network::new(&g))
    }

    fn arena_of(rows: &[Vec<f32>]) -> ParamArena {
        ParamArena::from_rows(rows)
    }

    #[test]
    fn mix_equals_matrix_product() {
        let g = Topology::Ring.build(5, 0);
        let w = mixing_matrix(&g, Weighting::UniformDegree);
        let mut net = Network::new(&g);
        let rows: Vec<Vec<f32>> = (0..5).map(|k| vec![k as f32, -(k as f32)]).collect();
        let expect: Vec<Vec<f32>> = (0..5)
            .map(|i| {
                (0..2)
                    .map(|c| {
                        (0..5).map(|j| w[(i, j)] as f32 * rows[j][c]).sum::<f32>()
                    })
                    .collect()
            })
            .collect();
        let mut gs = GossipState::new(w);
        let mut xs = arena_of(&rows);
        gs.mix(&mut xs, &mut net, None);
        for (got, want) in xs.rows().zip(&expect) {
            crate::testing::assert_allclose(got, want, 1e-6, 1e-7);
        }
    }

    #[test]
    fn prop_mix_preserves_average() {
        // The Eq. (18) invariant: x̄ is untouched by communication.
        forall(0xA11CE, 20, |rng| {
            let k = 3 + rng.below(8);
            let (mut gs, mut net) = setup(k);
            let d = 1 + rng.below(50);
            let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
            let mut xs = arena_of(&rows);
            let before = linalg::mean_of_rows(xs.rows(), d);
            gs.mix(&mut xs, &mut net, None);
            let after = linalg::mean_of_rows(xs.rows(), d);
            crate::testing::assert_allclose(&after, &before, 1e-4, 1e-5);
        });
    }

    #[test]
    fn prop_mix_contracts_consensus() {
        // Lemma 1: one round shrinks Σ||x_k − x̄||² by ≥ (1−ρ)² … we
        // check the weaker monotone form which holds for every sample.
        forall(0xB0B, 20, |rng| {
            let k = 3 + rng.below(8);
            let (mut gs, mut net) = setup(k);
            let d = 1 + rng.below(50);
            let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
            let mut xs = arena_of(&rows);
            let before = linalg::consensus_error_rows(xs.rows(), d);
            gs.mix(&mut xs, &mut net, None);
            let after = linalg::consensus_error_rows(xs.rows(), d);
            assert!(after <= before * (1.0 + 1e-6), "consensus grew: {before} -> {after}");
        });
    }

    #[test]
    fn prop_mix_pooled_is_bit_identical_to_sequential() {
        // The tentpole determinism contract, at the gossip layer: the
        // pool fan-out must reproduce the sequential round bit-for-bit
        // on regular AND irregular (star: hub degree K−1) topologies.
        let pool = WorkerPool::new(3);
        forall(0x90551F, 10, |rng| {
            let k = 3 + rng.below(6);
            let d = 1 + rng.below(60);
            for topo in [Topology::Ring, Topology::Star, Topology::Chain] {
                let g = topo.build(k, 0);
                let w = mixing_matrix(&g, Weighting::UniformDegree);
                let xs0: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
                let mut gs_seq = GossipState::new(w.clone());
                let mut gs_pool = GossipState::new(w);
                let mut net_seq = Network::new(&g);
                let mut net_pool = Network::new(&g);
                let mut xs_seq = arena_of(&xs0);
                let mut xs_pool = arena_of(&xs0);
                // two rounds so the scratch-reuse path is exercised
                for _ in 0..2 {
                    let b_seq = gs_seq.mix(&mut xs_seq, &mut net_seq, None);
                    let b_pool = gs_pool.mix(&mut xs_pool, &mut net_pool, Some(&pool));
                    assert_eq!(b_seq, b_pool, "{topo:?}: bytes diverged");
                }
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(
                    bits(xs_seq.as_slice()),
                    bits(xs_pool.as_slice()),
                    "{topo:?}: pooled mix diverged"
                );
            }
        });
    }

    #[test]
    fn mix_charges_exact_bytes() {
        let (mut gs, mut net) = setup(6);
        let mut xs = ParamArena::zeros(6, 100);
        let bytes = gs.mix(&mut xs, &mut net, None);
        // 6 workers x 2 ring links x 400 bytes
        assert_eq!(bytes, 6 * 2 * 400);
        assert_eq!(net.rounds, 1);
    }

    #[test]
    fn mix_reuses_buffers_across_rounds() {
        // Steady-state zero-allocation: the iterate arena and the
        // scratch arena must simply swap storage between consecutive
        // rounds, and the K broadcast staging buffers must be reclaimed
        // from their Arcs — no fresh K·d allocations.
        let (mut gs, mut net) = setup(4);
        let rows: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32; 64]).collect();
        let mut xs = arena_of(&rows);
        gs.mix(&mut xs, &mut net, None); // materializes scratch + staging
        let gen1 = xs.data_ptr();
        let scratch1 = gs.scratch.data_ptr();
        let bcast1: Vec<*const f32> = gs.bcast.iter().map(|b| b.as_ptr()).collect();
        gs.mix(&mut xs, &mut net, None);
        assert_eq!(xs.data_ptr(), scratch1, "round output must land in the old scratch arena");
        assert_eq!(gs.scratch.data_ptr(), gen1, "old iterate storage must be recovered as scratch");
        let bcast2: Vec<*const f32> = gs.bcast.iter().map(|b| b.as_ptr()).collect();
        assert_eq!(bcast2, bcast1, "staging buffers must be reclaimed, not reallocated");
    }

    #[test]
    fn mix_with_zero_rate_plan_is_bit_identical() {
        use crate::comm::FaultPlan;
        forall(0xFA0171, 10, |rng| {
            let k = 3 + rng.below(6);
            let d = 1 + rng.below(40);
            for topo in [Topology::Ring, Topology::Star, Topology::Chain] {
                let g = topo.build(k, 0);
                let w = mixing_matrix(&g, Weighting::UniformDegree);
                let xs0: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
                let mut gs_a = GossipState::new(w.clone());
                let mut gs_b = GossipState::new(w);
                let mut net_a = Network::new(&g);
                let mut net_b = Network::new(&g);
                net_b.set_fault_plan(FaultPlan::new(k, 0.0, 0.0, 1, 0.0, 1));
                let mut xs_a = arena_of(&xs0);
                let mut xs_b = arena_of(&xs0);
                for _ in 0..2 {
                    let ba = gs_a.mix(&mut xs_a, &mut net_a, None);
                    let bb = gs_b.mix(&mut xs_b, &mut net_b, None);
                    assert_eq!(ba, bb, "{topo:?}: bytes diverged under zero-rate plan");
                }
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(
                    bits(xs_a.as_slice()),
                    bits(xs_b.as_slice()),
                    "{topo:?}: zero-rate plan changed the mix"
                );
            }
        });
    }

    #[test]
    fn mix_renormalizes_over_heard_neighbors() {
        use crate::comm::FaultPlan;
        // Every dense message dropped: each worker hears nobody, so the
        // renormalized round must degenerate to the identity — never a
        // shrunk iterate (un-renormalized rows would sum to w_kk < 1).
        let (mut gs, mut net) = setup(5);
        net.set_fault_plan(FaultPlan::new(5, 1.0, 0.0, 1, 0.0, 3));
        let xs0: Vec<Vec<f32>> = (0..5).map(|i| vec![1.0 + i as f32; 8]).collect();
        let mut xs = arena_of(&xs0);
        let bytes = gs.mix(&mut xs, &mut net, None);
        assert!(bytes > 0, "drops are lost in flight, still charged");
        for (got, want) in xs.rows().zip(&xs0) {
            crate::testing::assert_allclose(got, want, 1e-6, 1e-7);
        }
    }

    #[test]
    fn mix_under_churn_keeps_the_average_of_present_workers_stable() {
        use crate::comm::FaultPlan;
        // With worker 2 absent the remaining workers renormalize; the
        // absent worker's iterate must be untouched and no weight mass
        // may leak (each surviving row still sums to 1, so iterates stay
        // inside the convex hull of the inputs).
        let (mut gs, mut net) = setup(6);
        net.set_fault_plan(FaultPlan::new(6, 0.0, 0.0, 1, 0.0, 3));
        net.fault_plan_mut().unwrap().set_absent(2, true);
        let rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32; 4]).collect();
        let mut xs = arena_of(&rows);
        let lo = 0.0f32;
        let hi = 5.0f32;
        gs.mix(&mut xs, &mut net, None);
        assert_eq!(xs.row(2), &[2.0; 4][..], "absent worker mixes with nobody");
        for x in xs.rows() {
            assert!(x.iter().all(|&v| (lo..=hi).contains(&v)), "left the hull: {x:?}");
        }
    }

    #[test]
    fn compressed_round_freezes_absent_senders() {
        use crate::comm::FaultPlan;
        let k = 4;
        let d = 8;
        let rows: Vec<Vec<f32>> = (0..k).map(|i| vec![1.0 + i as f32; d]).collect();
        let inputs = arena_of(&rows);
        let mut net = ring_net(k);
        net.set_fault_plan(FaultPlan::new(k, 0.0, 0.0, 1, 0.0, 9));
        net.fault_plan_mut().unwrap().set_absent(1, true);
        let mut ex = CompressedExchange::new(k, 3);
        let qs = ex.round(&Identity, &mut net, &inputs, None, |_, _| {});
        assert_eq!(qs.row(1), &vec![0.0; d][..], "absent sender decodes to zero everywhere");
        for j in [0usize, 2, 3] {
            assert_eq!(qs.row(j), inputs.row(j), "present senders decode normally");
        }
    }

    #[test]
    #[should_panic(expected = "Assumption 1")]
    fn rejects_non_stochastic_w() {
        let mut w = Mat::eye(3);
        w[(0, 0)] = 0.5; // rows no longer sum to 1
        GossipState::new(w);
    }

    // -----------------------------------------------------------------
    // CompressedExchange
    // -----------------------------------------------------------------

    fn ring_net(k: usize) -> Network {
        Network::new(&Topology::Ring.build(k, 0))
    }

    #[test]
    fn exchange_decodes_every_sender_once_with_exact_bytes() {
        let k = 5;
        let d = 40;
        let mut rng = Xoshiro256::seed_from_u64(9);
        let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
        let inputs = arena_of(&rows);
        let mut net = ring_net(k);
        let mut ex = CompressedExchange::new(k, 3);
        let mut hook_order = Vec::new();
        let qs =
            ex.round(&Sign, &mut net, &inputs, None, |i, c| {
                assert_eq!(c.dense.len(), d);
                hook_order.push(i);
            });
        assert_eq!(hook_order, (0..k).collect::<Vec<_>>(), "hook runs in worker order");
        assert_eq!(qs.k(), k);
        // Sign decode of x: ±(||x||₁/d) with x's signs
        for (q, x) in qs.rows().zip(inputs.rows()) {
            let scale = x.iter().map(|v| v.abs() as f64).sum::<f64>() / d as f64;
            for (qi, xi) in q.iter().zip(x) {
                assert!((qi.abs() as f64 - scale).abs() < 1e-4);
                assert_eq!(qi.is_sign_positive(), *xi >= 0.0);
            }
        }
        // ring: every worker ships its Sign payload over 2 links
        let per_msg = Sign.encoded_bytes(d) as u64;
        assert_eq!(net.total_bytes, k as u64 * 2 * per_msg);
        assert_eq!(net.rounds, 1);
    }

    #[test]
    fn exchange_reuses_wire_buffers_across_rounds() {
        let k = 4;
        let d = 32;
        let mut rng = Xoshiro256::seed_from_u64(10);
        let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
        let inputs = arena_of(&rows);
        let mut net = ring_net(k);
        let mut ex = CompressedExchange::new(k, 5);
        ex.round(&Sign, &mut net, &inputs, None, |_, _| {});
        let wires1: Vec<*const u8> = ex.wires.iter().map(|w| w.as_ptr()).collect();
        let decoded1 = ex.decoded.data_ptr();
        assert!(ex.wires.iter().all(|w| w.len() == Sign.encoded_bytes(d)));
        ex.round(&Sign, &mut net, &inputs, None, |_, _| {});
        let wires2: Vec<*const u8> = ex.wires.iter().map(|w| w.as_ptr()).collect();
        let decoded2 = ex.decoded.data_ptr();
        assert_eq!(wires1, wires2, "wire buffers must be recovered, not reallocated");
        assert_eq!(decoded1, decoded2, "decode arena must be reused");
    }

    #[test]
    fn prop_exchange_pooled_is_bit_identical_to_sequential() {
        use crate::compress::{Qsgd, RandK, TopK};
        let pool = WorkerPool::new(3);
        let ops: Vec<Box<dyn Compressor>> = vec![
            Box::new(Sign),
            Box::new(TopK { ratio: 0.25 }),
            Box::new(RandK { ratio: 0.25 }),
            Box::new(Qsgd { levels: 4 }),
            Box::new(Identity),
        ];
        forall(0xE8C0DE, 6, |rng| {
            let k = 2 + rng.below(6);
            let d = 1 + rng.below(50);
            let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
            let inputs = arena_of(&rows);
            for op in &ops {
                for topo in [Topology::Ring, Topology::Star, Topology::Chain] {
                    let g = topo.build(k, 0);
                    let mut ex_seq = CompressedExchange::new(k, 77);
                    let mut ex_pool = CompressedExchange::new(k, 77);
                    let mut net_seq = Network::new(&g);
                    let mut net_pool = Network::new(&g);
                    for _ in 0..2 {
                        let a = ex_seq
                            .round(op.as_ref(), &mut net_seq, &inputs, None, |_, _| {})
                            .clone();
                        let b = ex_pool.round(
                            op.as_ref(),
                            &mut net_pool,
                            &inputs,
                            Some(&pool),
                            |_, _| {},
                        );
                        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                        assert_eq!(
                            bits(a.as_slice()),
                            bits(b.as_slice()),
                            "{} {topo:?}",
                            op.name()
                        );
                    }
                    assert_eq!(net_seq.total_bytes, net_pool.total_bytes);
                }
            }
        });
    }

    #[test]
    fn exchange_k1_decodes_from_local_buffer() {
        // A 1-worker fleet has no edges: nothing crosses the wire, but
        // the worker still sees its own decoded message.
        let mut net = Network::new(&Topology::Ring.build(1, 0));
        let mut ex = CompressedExchange::new(1, 1);
        let inputs = arena_of(&[vec![1.0f32, -2.0, 3.0, -4.0]]);
        let qs = ex.round(&Identity, &mut net, &inputs, None, |_, _| {});
        assert_eq!(qs.row(0), inputs.row(0));
        assert_eq!(net.total_bytes, 0, "own message never crosses the wire");
    }

    #[test]
    #[should_panic(expected = "wire-size invariant")]
    fn exchange_rejects_miscosted_codec_in_release_builds() {
        // A codec that charges one byte more than it emits must abort
        // the round in release builds (the old debug_assert let it skew
        // Figure 2 silently).
        let mut net = ring_net(3);
        let mut ex = CompressedExchange::new(3, 2);
        let inputs = arena_of(&vec![vec![1.0f32; 8]; 3]);
        ex.round(&crate::testing::MisCosted, &mut net, &inputs, None, |_, _| {});
    }

    #[test]
    fn exchange_state_roundtrip_preserves_streams() {
        use crate::state::{StateReader, StateWriter};
        let k = 4;
        let d = 16;
        let mut rng = Xoshiro256::seed_from_u64(12);
        let rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
        let inputs = arena_of(&rows);
        let mut a = CompressedExchange::new(k, 9);
        // advance the streams, snapshot, then compare the next round of
        // a restored twin against the original
        let mut net = ring_net(k);
        a.round(&crate::compress::RandK { ratio: 0.5 }, &mut net, &inputs, None, |_, _| {});
        let mut w = StateWriter::new();
        a.state_save(&mut w);
        let buf = w.into_bytes();
        let mut b = CompressedExchange::new(k, 12345); // wrong seed on purpose
        b.state_load(&mut StateReader::new(&buf)).unwrap();
        let op = crate::compress::RandK { ratio: 0.5 };
        let mut net_a = ring_net(k);
        let mut net_b = ring_net(k);
        let qa = a.round(&op, &mut net_a, &inputs, None, |_, _| {}).clone();
        let qb = b.round(&op, &mut net_b, &inputs, None, |_, _| {});
        assert_eq!(&qa, qb, "restored streams must continue identically");
        // and a K-mismatched bank errors instead of corrupting
        let mut c = CompressedExchange::new(k + 1, 0);
        let err = c.state_load(&mut StateReader::new(&buf)).unwrap_err();
        assert!(err.contains("rng bank"), "{err}");
    }
}
