//! **MAC-SGD** (Balu et al. 2020, "Decentralized Deep Learning using
//! Momentum-Accelerated Consensus", arXiv:2010.11166) — decentralized
//! SGD whose momentum accelerates the *consensus* direction instead of
//! the gradient: each worker keeps a momentum buffer over its gossip
//! disagreement `Wx − x` and descends the plain stochastic gradient on
//! top. ROADMAP item 3's second baseline, closing the comparison set
//! for the fault/heterogeneity sweeps alongside Momentum Tracking.
//!
//! Per worker k, with doubly stochastic W and m_0 = 0:
//!
//! ```text
//! g_t^(k) = grad F(x_t^(k); xi_t^(k))
//! m_t^(k) = mu * m_{t-1}^(k) + ((W x_t)^(k) − x_t^(k))   (consensus momentum)
//! x_{t+1}^(k) = x_t^(k) + m_t^(k) − eta * g_t^(k)
//! ```
//!
//! Communication is every step and carries **one** dense payload (the
//! iterates), i.e. exactly D-SGD's bytes — momentum acceleration of the
//! mixing comes for free on the wire. Because W is doubly stochastic,
//! Σ_k ((Wx)^(k) − x^(k)) = 0 every step, so Σ_k m^(k) = 0 forever:
//! the accelerated consensus never perturbs the averaged iterate, and
//! x̄ follows the plain SGD recursion (the conservation law the tests
//! pin, mirroring Momentum Tracking's Σc = Σg invariant). A worker
//! restarted after churn re-enters with m = 0; the resulting Σm ≠ 0
//! transient decays geometrically (Σm_{t+1} = mu Σm_t), so the law
//! self-heals.

use super::{gossip::GossipState, Algorithm, Hyper, StepStats};
use crate::arena::ParamArena;
use crate::comm::Network;
use crate::grad::GradientSource;
use crate::topology::MixWeights;

pub struct MacSgd {
    hyper: Hyper,
    xs: ParamArena,
    /// Consensus-momentum buffers m^(k) (local, never communicated).
    ms: ParamArena,
    /// Reusable K×d scratch holding this step's mixed iterates W x.
    mixed: ParamArena,
    gossip: GossipState,
    /// Reusable d-length gradient scratch.
    grad: Vec<f32>,
}

impl MacSgd {
    /// All workers start from the same `x0`; momenta start at zero.
    pub fn new(k: usize, x0: Vec<f32>, w: impl Into<MixWeights>, hyper: Hyper) -> Self {
        let gossip = GossipState::new(w);
        assert_eq!(gossip.k(), k);
        let d = x0.len();
        Self {
            xs: ParamArena::filled(k, &x0),
            ms: ParamArena::zeros(k, d),
            mixed: ParamArena::zeros(k, d),
            gossip,
            grad: vec![0.0; d],
            hyper,
        }
    }
}

impl Algorithm for MacSgd {
    fn name(&self) -> String {
        "mac-sgd".into()
    }

    fn k(&self) -> usize {
        self.xs.k()
    }

    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats {
        let k = self.k();
        let eta = self.hyper.lr.eta(t);
        let mu = self.hyper.mu;
        let wd = self.hyper.weight_decay;
        // Consensus half over the metered network: mixed ← W x (the
        // iterate arena itself must stay at x_t for the gradient and
        // momentum updates, so the mix runs on a persistent copy).
        for (dst, src_row) in self.mixed.rows_mut().zip(self.xs.rows()) {
            dst.copy_from_slice(src_row);
        }
        let bytes = self.gossip.mix(&mut self.mixed, net, None);
        let mut loss_sum = 0.0;
        for i in 0..k {
            loss_sum += source.grad_into(i, self.xs.row(i), &mut self.grad);
            if wd != 0.0 {
                for (g, &x) in self.grad.iter_mut().zip(self.xs.row(i)) {
                    *g += wd * x;
                }
            }
            // m = mu*m + (Wx − x); x += m − eta*g.
            for (((m, &mx), &g), x) in self
                .ms
                .row_mut(i)
                .iter_mut()
                .zip(self.mixed.row(i))
                .zip(&self.grad)
                .zip(self.xs.row_mut(i).iter_mut())
            {
                *m = mu * *m + (mx - *x);
                *x += *m - eta * g;
            }
        }
        StepStats { mean_loss: loss_sum / k as f64, communicated: true, bytes }
    }

    fn params(&self, k: usize) -> &[f32] {
        self.xs.row(k)
    }

    fn set_worker_params(&mut self, k: usize, x: &[f32]) {
        self.xs.row_mut(k).copy_from_slice(x);
        // A rejoining worker restarts its consensus momentum; the Σm = 0
        // law re-contracts geometrically (see module doc).
        self.ms.row_mut(k).fill(0.0);
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("mac-sgd");
        self.xs.state_save(w);
        self.ms.state_save(w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("mac-sgd")?;
        self.xs.state_load(r, "mac-sgd.xs")?;
        self.ms.state_load(r, "mac-sgd.ms")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::{GradientSource as _, Quadratic};
    use crate::linalg::Mat;
    use crate::optim::LrSchedule;
    use crate::topology::{mixing_matrix, Topology, Weighting};

    fn ring(k: usize) -> (Mat, Network) {
        let g = Topology::Ring.build(k, 0);
        (mixing_matrix(&g, Weighting::UniformDegree), Network::new(&g))
    }

    fn hyper(eta: f32) -> Hyper {
        Hyper { lr: LrSchedule::Constant { eta }, mu: 0.9, ..Default::default() }
    }

    #[test]
    fn consensus_momentum_sums_to_zero() {
        // Σ_k m^(k) = 0 after every step: doubly stochastic W makes the
        // per-step impulses Σ_k (Wx − x)^(k) vanish, and m_0 = 0.
        let k = 4;
        let d = 8;
        let mut src = Quadratic::new(k, d, 2.0, 0.0, 11);
        let (w, mut net) = ring(k);
        let mut algo = MacSgd::new(k, src.init(1), w, hyper(0.01));
        for t in 0..10 {
            algo.step(t, &mut src, &mut net);
            let mut m_sum = vec![0.0f64; d];
            for i in 0..k {
                for (s, &v) in m_sum.iter_mut().zip(algo.ms.row(i)) {
                    *s += v as f64;
                }
            }
            for m in &m_sum {
                assert!(m.abs() < 1e-3, "momentum sum drifted: {m}");
            }
        }
    }

    #[test]
    fn converges_on_heterogeneous_quadratic() {
        let k = 8;
        let mut src = Quadratic::new(k, 16, 2.0, 0.05, 12);
        let opt = src.optimum();
        let (w, mut net) = ring(k);
        let mut algo = MacSgd::new(k, src.init(2), w, hyper(0.02));
        for t in 0..1500 {
            algo.step(t, &mut src, &mut net);
        }
        let err = crate::linalg::dist(&algo.avg_params(), &opt);
        assert!(err < 0.3, "x̄ is {err} from x*");
    }

    #[test]
    fn sends_exactly_dsgd_bytes_per_step() {
        let k = 6;
        let d = 50;
        let mut src = Quadratic::new(k, d, 1.0, 0.1, 13);
        let (w, mut net) = ring(k);
        let mut algo = MacSgd::new(k, src.init(3), w, hyper(0.01));
        let s = algo.step(0, &mut src, &mut net);
        assert!(s.communicated);
        // ring degree 2, one dense payload: k * 2 * 4d bytes — the
        // momentum acceleration is wire-free (half of Momentum Tracking).
        assert_eq!(s.bytes, (k * 2 * 4 * d) as u64);
    }

    #[test]
    fn rejoin_hook_resets_iterate_and_momentum() {
        let k = 4;
        let mut src = Quadratic::new(k, 8, 1.0, 0.0, 14);
        let (w, mut net) = ring(k);
        let mut algo = MacSgd::new(k, src.init(4), w, hyper(0.02));
        for t in 0..5 {
            algo.step(t, &mut src, &mut net);
        }
        assert!(algo.ms.row(2).iter().any(|&v| v != 0.0), "momentum should be live");
        algo.set_worker_params(2, &vec![0.25; 8]);
        assert_eq!(algo.params(2), &[0.25; 8][..]);
        assert!(algo.ms.row(2).iter().all(|&v| v == 0.0));
        // the Σm = 0 law re-contracts geometrically after the reset
        let d = 8;
        let sum_abs = |a: &MacSgd| -> f64 {
            (0..d)
                .map(|c| (0..k).map(|i| a.ms.row(i)[c] as f64).sum::<f64>().abs())
                .sum()
        };
        let after_reset = sum_abs(&algo);
        for t in 5..45 {
            algo.step(t, &mut src, &mut net);
        }
        assert!(
            sum_abs(&algo) < after_reset * 0.2 + 1e-9,
            "Σm must decay back toward zero after a restart"
        );
    }
}
