//! The paper's algorithms and their baselines.
//!
//! | struct | paper / reference | momentum | comm schedule | payload |
//! |---|---|---|---|---|
//! | [`PdSgdm`]      | **Algorithm 1** (this paper)        | yes | every p steps | full x |
//! | [`CpdSgdm`]     | **Algorithm 2** (this paper)        | yes | every p steps | Q(x−x̂) |
//! | [`DSgd`]        | D-SGD, Lian et al. 2017 [12]        | no  | every step    | full x |
//! | [`PdSgd`]       | PD-SGD / local SGD, Li et al. [11]  | no  | every p steps | full x |
//! | [`DSgdm`]       | momentum gossip, Yu et al. [23]     | yes | every step    | x (+m) |
//! | [`CSgdm`]       | centralized momentum SGD (C-SGDM)   | yes | every step    | grad up+down |
//! | [`ChocoSgd`]    | CHOCO-SGD, Koloskova et al. [8,9]   | no  | every step    | Q(x−x̂) |
//! | [`DeepSqueeze`] | DeepSqueeze, Tang et al. [21]       | no  | every step    | Q(x+e) |
//! | [`MomentumTracking`] | Takezawa et al. 2022           | yes | every step    | x and c |
//! | [`MacSgd`]      | Balu et al. 2020 [MAC]              | yes (consensus) | every step | full x |
//!
//! All decentralized algorithms drive a byte-metered [`crate::comm::Network`]
//! and may only exchange data along topology edges; every struct
//! implements [`Algorithm`], so the drivers in [`crate::coordinator`] and
//! every figure bench are generic over the whole table.

mod baselines;
mod cpd_sgdm;
mod gossip;
mod mac_sgd;
mod momentum_tracking;
mod pd_sgdm;

pub use baselines::{CSgdm, ChocoSgd, DSgd, DSgdm, DeepSqueeze, PdSgd};
pub use cpd_sgdm::CpdSgdm;
pub use gossip::{CompressedExchange, GossipState, ReplicaStore};
pub use mac_sgd::MacSgd;
pub use momentum_tracking::MomentumTracking;
pub use pd_sgdm::PdSgdm;

use crate::comm::Network;
use crate::grad::GradientSource;
use crate::state::{StateReader, StateWriter};

/// Shared hyper-parameters (paper §5.1 defaults where applicable).
#[derive(Clone, Debug)]
pub struct Hyper {
    /// Learning-rate schedule (paper: 0.1 with step decay).
    pub lr: crate::optim::LrSchedule,
    /// Momentum coefficient mu (paper: 0.9).
    pub mu: f32,
    /// Weight decay (paper: 1e-4).
    pub weight_decay: f32,
    /// Communication period p (paper sweeps 4, 8, 16).
    pub period: u64,
    /// Consensus step size gamma for compressed variants
    /// (paper: 0.4 CIFAR-10 / 0.5 ImageNet).
    pub gamma: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            lr: crate::optim::LrSchedule::Constant { eta: 0.1 },
            mu: 0.9,
            weight_decay: 0.0,
            period: 4,
            gamma: 0.4,
        }
    }
}

/// Per-step observability record returned by [`Algorithm::step`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// Mean minibatch loss across workers at this step.
    pub mean_loss: f64,
    /// Whether a communication round happened this step.
    pub communicated: bool,
    /// Wire bytes this step added (all links, all workers).
    pub bytes: u64,
}

/// A decentralized (or centralized-baseline) training algorithm over K
/// workers, advanced one synchronous global iteration at a time.
pub trait Algorithm {
    fn name(&self) -> String;

    /// Number of workers.
    fn k(&self) -> usize;

    /// Execute global iteration `t`: every worker draws a stochastic
    /// gradient at its own iterate from `source` and performs the
    /// algorithm's local update + (scheduled) communication over `net`.
    /// The per-worker phase (Alg. 1/2 lines 2–4) runs through the shared
    /// [`crate::engine::LocalStepEngine`].
    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats;

    /// Toggle the parallel local-step engine. Parallel and sequential
    /// modes produce bit-identical traces (see
    /// rust/tests/engine_determinism.rs); sequential exists for
    /// profiling baselines and the determinism tests themselves.
    fn set_parallel(&mut self, _on: bool) {}

    /// Adopt a shared [`crate::engine::WorkerPool`] for the local-step
    /// and communication fan-outs (and engage the parallel path). The
    /// service daemon uses this to multiplex N concurrent sessions onto
    /// one thread budget. Default is a no-op for algorithms with no
    /// engine (e.g. Momentum Tracking, MAC-SGD run their phases on the
    /// caller thread).
    fn install_shared_pool(&mut self, _pool: std::sync::Arc<crate::engine::WorkerPool>) {}

    /// Worker k's current iterate x_t^(k).
    fn params(&self, k: usize) -> &[f32];

    /// Overwrite worker `k`'s iterate with `x`, resetting that worker's
    /// per-worker optimizer state (momentum, error feedback) where one
    /// exists — the churn rejoin hook: a worker coming back from an
    /// absence restarts from a checkpointed x̄ as if freshly
    /// initialized there. The default is a no-op for algorithms with no
    /// per-worker iterate to reset (e.g. the centralized baseline).
    fn set_worker_params(&mut self, k: usize, x: &[f32]) {
        let _ = (k, x);
    }

    /// Write the averaged iterate x̄_t into `out` (resized to d). This is
    /// the evaluation hot path: the default accumulates straight from the
    /// borrowed `params(k)` slices — no per-worker clones, so an eval
    /// point costs zero K×d allocations (the old default cloned every
    /// worker's iterate into fresh `Vec`s at every TracePoint).
    fn avg_params_into(&self, out: &mut Vec<f32>) {
        let k = self.k();
        let d = self.params(0).len();
        out.clear();
        out.resize(d, 0.0);
        for i in 0..k {
            crate::linalg::axpy(1.0, self.params(i), out);
        }
        crate::linalg::scale(1.0 / k as f32, out);
    }

    /// The averaged iterate x̄_t the paper's theorems track (allocating
    /// convenience over [`Algorithm::avg_params_into`]).
    fn avg_params(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.avg_params_into(&mut out);
        out
    }

    /// Consensus error Σ_k ||x_k − x̄||² about a *precomputed* x̄ — the
    /// eval path already holds x̄ from [`Algorithm::avg_params_into`], so
    /// recording a TracePoint never averages the K iterates twice.
    fn consensus_error_about(&self, xbar: &[f32]) -> f64 {
        (0..self.k())
            .map(|k| {
                let e = crate::linalg::dist(self.params(k), xbar);
                e * e
            })
            .sum()
    }

    /// Consensus error Σ_k ||x_k − x̄||² (bounded by Lemma 5/6). The
    /// default computes x̄ into one d-length scratch from the borrowed
    /// worker slices — never a K×d copy.
    fn consensus_error(&self) -> f64 {
        let mut xbar = Vec::new();
        self.avg_params_into(&mut xbar);
        self.consensus_error_about(&xbar)
    }

    /// Serialize the algorithm's *full* mutable state — iterates,
    /// momentum buffers, error-feedback/x̂ copies, internal RNG streams —
    /// into `w`. Together with the gradient source's state this is
    /// everything a `PDSGDM02` checkpoint needs for a resumed session to
    /// reproduce the uninterrupted trace bit-identically (the old
    /// checkpoint kept only x̄ and could resume nothing).
    fn state_save(&self, w: &mut StateWriter);

    /// Restore state written by [`Algorithm::state_save`] into an
    /// identically-configured instance. Errs (never panics) on a shape or
    /// algorithm-tag mismatch.
    fn state_load(&mut self, r: &mut StateReader) -> Result<(), String>;
}

// ---------------------------------------------------------------------------
// Typed construction: AlgorithmSpec + builder registry
// ---------------------------------------------------------------------------

/// Typed, named construction parameters for any algorithm in the table —
/// replaces the old seven-positional-argument `by_name` bag. Build one
/// with [`AlgorithmSpec::new`] and the chainable setters, then call
/// [`AlgorithmSpec::build`]:
///
/// ```ignore
/// let algo = AlgorithmSpec::new("cpd-sgdm", k, x0)
///     .mixing(w)
///     .hyper(hyper)
///     .compressor(Box::new(compress::Sign))
///     .seed(7)
///     .build()?;
/// ```
pub struct AlgorithmSpec {
    pub name: String,
    pub workers: usize,
    pub x0: Vec<f32>,
    /// Sparse mixing weights W (defaults to I_K — fine for `c-sgdm`,
    /// required doubly stochastic for the decentralized algorithms).
    /// Accepts a dense [`crate::linalg::Mat`] through the setter's
    /// `Into` bound, but never stores one: at K=1024 the CSR rows are
    /// the only K-scalable representation (DESIGN.md §8).
    pub mixing: crate::topology::MixWeights,
    pub hyper: Hyper,
    /// δ-contraction operator for the compressed algorithms; `None`
    /// falls back to the paper's choice ([`crate::compress::Sign`]).
    pub compressor: Option<Box<dyn crate::compress::Compressor>>,
    pub seed: u64,
}

impl AlgorithmSpec {
    pub fn new(name: impl Into<String>, workers: usize, x0: Vec<f32>) -> Self {
        Self {
            name: name.into(),
            workers,
            x0,
            mixing: crate::topology::MixWeights::identity(workers),
            hyper: Hyper::default(),
            compressor: None,
            seed: 0,
        }
    }

    pub fn mixing(mut self, w: impl Into<crate::topology::MixWeights>) -> Self {
        self.mixing = w.into();
        self
    }

    pub fn hyper(mut self, hyper: Hyper) -> Self {
        self.hyper = hyper;
        self
    }

    pub fn compressor(mut self, c: Box<dyn crate::compress::Compressor>) -> Self {
        self.compressor = Some(c);
        self
    }

    pub fn compressor_opt(mut self, c: Option<Box<dyn crate::compress::Compressor>>) -> Self {
        self.compressor = c;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Look the name up in [`REGISTRY`] and construct the algorithm.
    pub fn build(self) -> Result<Box<dyn Algorithm>, String> {
        let b = builder(&self.name).ok_or_else(|| {
            format!("unknown algorithm {:?}; options: {:?}", self.name, ALL_NAMES)
        })?;
        Ok((b.build)(self))
    }

    fn compressor_or_sign(&self) -> Box<dyn crate::compress::Compressor> {
        self.compressor
            .as_ref()
            .map(|c| c.box_clone())
            .unwrap_or_else(|| Box::new(crate::compress::Sign))
    }
}

/// One registry row: the CLI-facing name, a one-line summary (printed by
/// `pdsgdm algorithms`), and the constructor.
pub struct AlgorithmBuilder {
    pub name: &'static str,
    pub summary: &'static str,
    build: fn(AlgorithmSpec) -> Box<dyn Algorithm>,
}

/// The algorithm table (same rows as the module doc) as a data-driven
/// registry — the config system, CLI, and checkpoint loader all route
/// through this instead of a hand-maintained match.
pub static REGISTRY: &[AlgorithmBuilder] = &[
    AlgorithmBuilder {
        name: "pd-sgdm",
        summary: "Algorithm 1: local momentum + periodic gossip (this paper)",
        build: |s| Box::new(PdSgdm::new(s.workers, s.x0, s.mixing, s.hyper)),
    },
    AlgorithmBuilder {
        name: "cpd-sgdm",
        summary: "Algorithm 2: PD-SGDM with compressed comm rounds (this paper)",
        build: |s| {
            let c = s.compressor_or_sign();
            Box::new(CpdSgdm::new(s.workers, s.x0, s.mixing, s.hyper, c, s.seed))
        },
    },
    AlgorithmBuilder {
        name: "d-sgd",
        summary: "D-SGD (Lian et al. 2017): plain gossip SGD, comm every step",
        build: |s| Box::new(DSgd::new(s.workers, s.x0, s.mixing, s.hyper)),
    },
    AlgorithmBuilder {
        name: "pd-sgd",
        summary: "PD-SGD / local SGD (Li et al. 2019): periodic gossip, no momentum",
        build: |s| Box::new(PdSgd::new(s.workers, s.x0, s.mixing, s.hyper)),
    },
    AlgorithmBuilder {
        name: "d-sgdm",
        summary: "D-SGDM (Yu et al. 2019): momentum gossip every step",
        build: |s| Box::new(DSgdm::new(s.workers, s.x0, s.mixing, s.hyper, false)),
    },
    AlgorithmBuilder {
        name: "d-sgdm-pm",
        summary: "D-SGDM + momentum gossip (the double-payload variant of [23])",
        build: |s| Box::new(DSgdm::new(s.workers, s.x0, s.mixing, s.hyper, true)),
    },
    AlgorithmBuilder {
        name: "c-sgdm",
        summary: "centralized momentum SGD (parameter-server comparator)",
        build: |s| Box::new(CSgdm::new(s.workers, s.x0, s.hyper)),
    },
    AlgorithmBuilder {
        name: "choco-sgd",
        summary: "CHOCO-SGD (Koloskova et al. 2019): compressed gossip, p=1, mu=0",
        build: |s| {
            let c = s.compressor_or_sign();
            Box::new(ChocoSgd::new(s.workers, s.x0, s.mixing, s.hyper, c, s.seed))
        },
    },
    AlgorithmBuilder {
        name: "deepsqueeze",
        summary: "DeepSqueeze (Tang et al. 2019): error-feedback compressed gossip",
        build: |s| {
            let c = s.compressor_or_sign();
            Box::new(DeepSqueeze::new(s.workers, s.x0, s.mixing, s.hyper, c, s.seed))
        },
    },
    AlgorithmBuilder {
        name: "momentum-tracking",
        summary: "Momentum Tracking (Takezawa et al. 2022): gradient-tracked momentum, heterogeneity-robust",
        build: |s| Box::new(MomentumTracking::new(s.workers, s.x0, s.mixing, s.hyper)),
    },
    AlgorithmBuilder {
        name: "mac-sgd",
        summary: "MAC-SGD (Balu et al. 2020): momentum-accelerated consensus, D-SGD bytes",
        build: |s| Box::new(MacSgd::new(s.workers, s.x0, s.mixing, s.hyper)),
    },
];

/// Registry lookup by CLI name.
pub fn builder(name: &str) -> Option<&'static AlgorithmBuilder> {
    REGISTRY.iter().find(|b| b.name == name)
}

/// All algorithm names the registry accepts (for CLI help and sweeps).
pub const ALL_NAMES: &[&str] = &[
    "pd-sgdm", "cpd-sgdm", "d-sgd", "pd-sgd", "d-sgdm", "d-sgdm-pm",
    "c-sgdm", "choco-sgd", "deepsqueeze", "momentum-tracking", "mac-sgd",
];

/// Legacy positional constructor, kept as a thin shim over
/// [`AlgorithmSpec`] during the migration — new call sites should build a
/// spec instead.
pub fn by_name(
    name: &str,
    k: usize,
    x0: Vec<f32>,
    w: impl Into<crate::topology::MixWeights>,
    hyper: Hyper,
    compressor: Option<Box<dyn crate::compress::Compressor>>,
    seed: u64,
) -> Option<Box<dyn Algorithm>> {
    AlgorithmSpec::new(name, k, x0)
        .mixing(w)
        .hyper(hyper)
        .compressor_opt(compressor)
        .seed(seed)
        .build()
        .ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::GradientSource as _;
    use crate::topology::{mixing_matrix, Topology, Weighting};

    #[test]
    fn by_name_builds_every_algorithm() {
        for name in ALL_NAMES {
            let g = Topology::Ring.build(4, 0);
            let w = mixing_matrix(&g, Weighting::UniformDegree);
            let a = by_name(name, 4, vec![0.0; 8], w, Hyper::default(), None, 1)
                .unwrap_or_else(|| panic!("{name}"));
            assert_eq!(a.k(), 4);
            assert!(!a.name().is_empty());
        }
        assert!(by_name("nope", 2, vec![], crate::linalg::Mat::eye(2), Hyper::default(), None, 0).is_none());
    }

    #[test]
    fn registry_matches_all_names() {
        assert_eq!(
            REGISTRY.iter().map(|b| b.name).collect::<Vec<_>>(),
            ALL_NAMES.to_vec()
        );
        for b in REGISTRY {
            assert!(!b.summary.is_empty());
            assert!(builder(b.name).is_some());
        }
        assert!(builder("nope").is_none());
    }

    #[test]
    fn spec_builder_constructs_with_typed_fields() {
        let g = Topology::Ring.build(4, 0);
        let w = mixing_matrix(&g, Weighting::UniformDegree);
        let a = AlgorithmSpec::new("cpd-sgdm", 4, vec![0.0; 8])
            .mixing(w)
            .hyper(Hyper { period: 8, ..Hyper::default() })
            .compressor(Box::new(crate::compress::TopK { ratio: 0.25 }))
            .seed(9)
            .build()
            .unwrap();
        assert_eq!(a.k(), 4);
        assert!(a.name().contains("top0.250"), "{}", a.name());
        let err = AlgorithmSpec::new("nope", 2, vec![]).build().unwrap_err();
        assert!(err.contains("unknown algorithm"), "{err}");
    }

    #[test]
    fn avg_params_into_matches_avg_params_without_clones() {
        let g = Topology::Ring.build(4, 0);
        let w = mixing_matrix(&g, Weighting::UniformDegree);
        let mut src = crate::grad::Quadratic::new(4, 8, 1.0, 0.1, 3);
        let mut net = crate::comm::Network::new(&Topology::Ring.build(4, 0));
        let mut a = by_name("pd-sgdm", 4, src.init(1), w, Hyper::default(), None, 1).unwrap();
        for t in 0..10 {
            a.step(t, &mut src, &mut net);
        }
        let alloc = a.avg_params();
        let mut buf = vec![42.0f32; 3]; // wrong size, dirty: must be reset
        a.avg_params_into(&mut buf);
        assert_eq!(alloc, buf);
        assert!(a.consensus_error() >= 0.0);
    }
}
