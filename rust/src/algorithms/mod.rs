//! The paper's algorithms and their baselines.
//!
//! | struct | paper / reference | momentum | comm schedule | payload |
//! |---|---|---|---|---|
//! | [`PdSgdm`]      | **Algorithm 1** (this paper)        | yes | every p steps | full x |
//! | [`CpdSgdm`]     | **Algorithm 2** (this paper)        | yes | every p steps | Q(x−x̂) |
//! | [`DSgd`]        | D-SGD, Lian et al. 2017 [12]        | no  | every step    | full x |
//! | [`PdSgd`]       | PD-SGD / local SGD, Li et al. [11]  | no  | every p steps | full x |
//! | [`DSgdm`]       | momentum gossip, Yu et al. [23]     | yes | every step    | x (+m) |
//! | [`CSgdm`]       | centralized momentum SGD (C-SGDM)   | yes | every step    | grad up+down |
//! | [`ChocoSgd`]    | CHOCO-SGD, Koloskova et al. [8,9]   | no  | every step    | Q(x−x̂) |
//! | [`DeepSqueeze`] | DeepSqueeze, Tang et al. [21]       | no  | every step    | Q(x+e) |
//!
//! All decentralized algorithms drive a byte-metered [`crate::comm::Network`]
//! and may only exchange data along topology edges; every struct
//! implements [`Algorithm`], so the drivers in [`crate::coordinator`] and
//! every figure bench are generic over the whole table.

mod baselines;
mod cpd_sgdm;
mod gossip;
mod pd_sgdm;

pub use baselines::{CSgdm, ChocoSgd, DSgd, DSgdm, DeepSqueeze, PdSgd};
pub use cpd_sgdm::CpdSgdm;
pub use gossip::GossipState;
pub use pd_sgdm::PdSgdm;

use crate::comm::Network;
use crate::grad::GradientSource;

/// Shared hyper-parameters (paper §5.1 defaults where applicable).
#[derive(Clone, Debug)]
pub struct Hyper {
    /// Learning-rate schedule (paper: 0.1 with step decay).
    pub lr: crate::optim::LrSchedule,
    /// Momentum coefficient mu (paper: 0.9).
    pub mu: f32,
    /// Weight decay (paper: 1e-4).
    pub weight_decay: f32,
    /// Communication period p (paper sweeps 4, 8, 16).
    pub period: u64,
    /// Consensus step size gamma for compressed variants
    /// (paper: 0.4 CIFAR-10 / 0.5 ImageNet).
    pub gamma: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            lr: crate::optim::LrSchedule::Constant { eta: 0.1 },
            mu: 0.9,
            weight_decay: 0.0,
            period: 4,
            gamma: 0.4,
        }
    }
}

/// Per-step observability record returned by [`Algorithm::step`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// Mean minibatch loss across workers at this step.
    pub mean_loss: f64,
    /// Whether a communication round happened this step.
    pub communicated: bool,
    /// Wire bytes this step added (all links, all workers).
    pub bytes: u64,
}

/// A decentralized (or centralized-baseline) training algorithm over K
/// workers, advanced one synchronous global iteration at a time.
pub trait Algorithm {
    fn name(&self) -> String;

    /// Number of workers.
    fn k(&self) -> usize;

    /// Execute global iteration `t`: every worker draws a stochastic
    /// gradient at its own iterate from `source` and performs the
    /// algorithm's local update + (scheduled) communication over `net`.
    /// The per-worker phase (Alg. 1/2 lines 2–4) runs through the shared
    /// [`crate::engine::LocalStepEngine`].
    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats;

    /// Toggle the parallel local-step engine. Parallel and sequential
    /// modes produce bit-identical traces (see
    /// rust/tests/engine_determinism.rs); sequential exists for
    /// profiling baselines and the determinism tests themselves.
    fn set_parallel(&mut self, _on: bool) {}

    /// Worker k's current iterate x_t^(k).
    fn params(&self, k: usize) -> &[f32];

    /// The averaged iterate x̄_t the paper's theorems track.
    fn avg_params(&self) -> Vec<f32> {
        crate::linalg::mean_of(&(0..self.k()).map(|k| self.params(k).to_vec()).collect::<Vec<_>>())
    }

    /// Consensus error Σ_k ||x_k − x̄||² (bounded by Lemma 5/6).
    fn consensus_error(&self) -> f64 {
        let xs: Vec<Vec<f32>> = (0..self.k()).map(|k| self.params(k).to_vec()).collect();
        crate::linalg::consensus_error(&xs)
    }
}

/// Construct any algorithm in the table by name — the config system and
/// CLI route through this.
pub fn by_name(
    name: &str,
    k: usize,
    x0: Vec<f32>,
    w: crate::linalg::Mat,
    hyper: Hyper,
    compressor: Option<Box<dyn crate::compress::Compressor>>,
    seed: u64,
) -> Option<Box<dyn Algorithm>> {
    let comp = || compressor_or_sign(compressor_opt_clone(&compressor));
    match name {
        "pd-sgdm" => Some(Box::new(PdSgdm::new(k, x0, w, hyper))),
        "cpd-sgdm" => Some(Box::new(CpdSgdm::new(k, x0, w, hyper, comp(), seed))),
        "d-sgd" => Some(Box::new(DSgd::new(k, x0, w, hyper))),
        "pd-sgd" => Some(Box::new(PdSgd::new(k, x0, w, hyper))),
        "d-sgdm" => Some(Box::new(DSgdm::new(k, x0, w, hyper, false))),
        "d-sgdm-pm" => Some(Box::new(DSgdm::new(k, x0, w, hyper, true))),
        "c-sgdm" => Some(Box::new(CSgdm::new(k, x0, hyper))),
        "choco-sgd" => Some(Box::new(ChocoSgd::new(k, x0, w, hyper, comp(), seed))),
        "deepsqueeze" => Some(Box::new(DeepSqueeze::new(k, x0, w, hyper, comp(), seed))),
        _ => None,
    }
}

/// All algorithm names `by_name` accepts (for CLI help and sweeps).
pub const ALL_NAMES: &[&str] = &[
    "pd-sgdm", "cpd-sgdm", "d-sgd", "pd-sgd", "d-sgdm", "d-sgdm-pm",
    "c-sgdm", "choco-sgd", "deepsqueeze",
];

fn compressor_opt_clone(
    c: &Option<Box<dyn crate::compress::Compressor>>,
) -> Option<Box<dyn crate::compress::Compressor>> {
    // Compressors are tiny value types; re-parse by name to clone.
    c.as_ref().and_then(|c| crate::compress::parse(&c.name()))
}

fn compressor_or_sign(
    c: Option<Box<dyn crate::compress::Compressor>>,
) -> Box<dyn crate::compress::Compressor> {
    c.unwrap_or_else(|| Box::new(crate::compress::Sign))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{mixing_matrix, Topology, Weighting};

    #[test]
    fn by_name_builds_every_algorithm() {
        for name in ALL_NAMES {
            let g = Topology::Ring.build(4, 0);
            let w = mixing_matrix(&g, Weighting::UniformDegree);
            let a = by_name(name, 4, vec![0.0; 8], w, Hyper::default(), None, 1)
                .unwrap_or_else(|| panic!("{name}"));
            assert_eq!(a.k(), 4);
            assert!(!a.name().is_empty());
        }
        assert!(by_name("nope", 2, vec![], crate::linalg::Mat::eye(2), Hyper::default(), None, 0).is_none());
    }
}
