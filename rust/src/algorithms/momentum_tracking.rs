//! **Momentum Tracking** (Takezawa et al. 2022) — decentralized momentum
//! SGD whose momentum is driven by a *gradient tracker* instead of the
//! local stochastic gradient, making convergence provably independent of
//! data heterogeneity (the property PD-SGDM's analysis assumes away).
//! The fault/heterogeneity suite registers it as the designed-for-skew
//! comparator for the Dirichlet non-IID sweeps.
//!
//! Per worker k, with doubly stochastic W and trackers initialized to the
//! first gradients (c_0 = g_0, so mean(c_t) = mean(g_t) forever):
//!
//! ```text
//! g_t^(k) = grad F(x_t^(k); xi_t^(k))
//! c_t^(k) = c_{t-1}^(k) + g_t^(k) − g_{t-1}^(k)      (tracker update)
//! u_t^(k) = mu * u_{t-1}^(k) + c_t^(k)               (momentum on tracker)
//! x_{t+1/2}^(k) = x_t^(k) − eta * u_t^(k)
//! x_{t+1} = W x_{t+1/2},  c_t ← W c_t                (gossip both)
//! ```
//!
//! Communication is every step and carries **two** dense payloads (x and
//! c), i.e. 2× D-SGD's bytes — the same trade-off the original paper
//! reports. The doubly stochastic mix preserves Σ_k c_t^(k) = Σ_k
//! g_t^(k), so every worker's momentum integrates an unbiased running
//! estimate of the *global* gradient even under extreme data skew.

use super::{gossip::GossipState, Algorithm, Hyper, StepStats};
use crate::arena::ParamArena;
use crate::comm::Network;
use crate::grad::GradientSource;
use crate::topology::MixWeights;

pub struct MomentumTracking {
    hyper: Hyper,
    xs: ParamArena,
    /// Gradient trackers c^(k) (gossip-averaged alongside x).
    trackers: ParamArena,
    /// Momentum buffers u^(k) (local, never communicated).
    us: ParamArena,
    /// Previous step's stochastic gradients g_{t-1}^(k).
    prev_g: ParamArena,
    /// Whether the trackers were seeded with the first gradients.
    started: bool,
    gossip: GossipState,
    /// Reusable d-length gradient scratch.
    grad: Vec<f32>,
}

impl MomentumTracking {
    /// All workers start from the same `x0`; trackers/momenta start at
    /// zero and the trackers are seeded with the first gradients.
    pub fn new(k: usize, x0: Vec<f32>, w: impl Into<MixWeights>, hyper: Hyper) -> Self {
        let gossip = GossipState::new(w);
        assert_eq!(gossip.k(), k);
        let d = x0.len();
        Self {
            xs: ParamArena::filled(k, &x0),
            trackers: ParamArena::zeros(k, d),
            us: ParamArena::zeros(k, d),
            prev_g: ParamArena::zeros(k, d),
            started: false,
            gossip,
            grad: vec![0.0; d],
            hyper,
        }
    }
}

impl Algorithm for MomentumTracking {
    fn name(&self) -> String {
        "momentum-tracking".into()
    }

    fn k(&self) -> usize {
        self.xs.k()
    }

    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats {
        let k = self.k();
        let eta = self.hyper.lr.eta(t);
        let mu = self.hyper.mu;
        let wd = self.hyper.weight_decay;
        let mut loss_sum = 0.0;
        for i in 0..k {
            loss_sum += source.grad_into(i, self.xs.row(i), &mut self.grad);
            if wd != 0.0 {
                for (g, &x) in self.grad.iter_mut().zip(self.xs.row(i)) {
                    *g += wd * x;
                }
            }
            if self.started {
                // c += g_t − g_{t-1}: the tracking recursion.
                for ((c, &g), &pg) in self
                    .trackers
                    .row_mut(i)
                    .iter_mut()
                    .zip(&self.grad)
                    .zip(self.prev_g.row(i))
                {
                    *c += g - pg;
                }
            } else {
                self.trackers.row_mut(i).copy_from_slice(&self.grad);
            }
            self.prev_g.row_mut(i).copy_from_slice(&self.grad);
            // u = mu*u + c; x -= eta*u.
            for ((u, &c), x) in self
                .us
                .row_mut(i)
                .iter_mut()
                .zip(self.trackers.row(i))
                .zip(self.xs.row_mut(i).iter_mut())
            {
                *u = mu * *u + c;
                *x -= eta * *u;
            }
        }
        self.started = true;
        // Gossip both the iterates and the trackers, every step.
        let mut bytes = self.gossip.mix(&mut self.xs, net, None);
        bytes += self.gossip.mix(&mut self.trackers, net, None);
        StepStats { mean_loss: loss_sum / k as f64, communicated: true, bytes }
    }

    fn params(&self, k: usize) -> &[f32] {
        self.xs.row(k)
    }

    fn set_worker_params(&mut self, k: usize, x: &[f32]) {
        self.xs.row_mut(k).copy_from_slice(x);
        self.us.row_mut(k).fill(0.0);
        // trackers/prev_g stay: the tracking recursion only ever adds
        // g_t − g_{t-1}, so leaving both preserves the conservation law
        // Σ_k c^(k) = Σ_k g^(k) across the restart.
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("momentum-tracking");
        w.put_u64(self.started as u64);
        self.xs.state_save(w);
        self.trackers.state_save(w);
        self.us.state_save(w);
        self.prev_g.state_save(w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("momentum-tracking")?;
        self.started = r.take_u64()? != 0;
        self.xs.state_load(r, "momentum-tracking.xs")?;
        self.trackers.state_load(r, "momentum-tracking.trackers")?;
        self.us.state_load(r, "momentum-tracking.us")?;
        self.prev_g.state_load(r, "momentum-tracking.prev_g")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::{GradientSource as _, Quadratic};
    use crate::linalg::Mat;
    use crate::optim::LrSchedule;
    use crate::topology::{mixing_matrix, Topology, Weighting};

    fn ring(k: usize) -> (Mat, Network) {
        let g = Topology::Ring.build(k, 0);
        (mixing_matrix(&g, Weighting::UniformDegree), Network::new(&g))
    }

    fn hyper(eta: f32) -> Hyper {
        Hyper { lr: LrSchedule::Constant { eta }, mu: 0.9, ..Default::default() }
    }

    #[test]
    fn trackers_conserve_the_gradient_sum() {
        // Σ_k c^(k) = Σ_k g^(k) after every step (doubly stochastic W
        // preserves column sums; the recursion adds exactly g_t − g_{t-1}).
        let k = 4;
        let d = 8;
        let mut src = Quadratic::new(k, d, 2.0, 0.0, 11);
        let (w, mut net) = ring(k);
        let mut algo = MomentumTracking::new(k, src.init(1), w, hyper(0.01));
        for t in 0..10 {
            algo.step(t, &mut src, &mut net);
            let mut c_sum = vec![0.0f64; d];
            let mut g_sum = vec![0.0f64; d];
            for i in 0..k {
                // prev_g holds g at the *pre-gossip* iterate, so compare
                // against the stored gradients, not fresh ones.
                for (s, &v) in c_sum.iter_mut().zip(algo.trackers.row(i)) {
                    *s += v as f64;
                }
                for (s, &v) in g_sum.iter_mut().zip(algo.prev_g.row(i)) {
                    *s += v as f64;
                }
            }
            for (c, g) in c_sum.iter().zip(&g_sum) {
                assert!((c - g).abs() < 1e-3, "tracker sum drifted: {c} vs {g}");
            }
        }
    }

    #[test]
    fn converges_on_heterogeneous_quadratic() {
        let k = 8;
        let mut src = Quadratic::new(k, 16, 2.0, 0.05, 12);
        let opt = src.optimum();
        let (w, mut net) = ring(k);
        let mut algo = MomentumTracking::new(k, src.init(2), w, hyper(0.01));
        for t in 0..1500 {
            algo.step(t, &mut src, &mut net);
        }
        let err = crate::linalg::dist(&algo.avg_params(), &opt);
        assert!(err < 0.3, "x̄ is {err} from x*");
    }

    #[test]
    fn sends_twice_dsgd_bytes_per_step() {
        let k = 6;
        let d = 50;
        let mut src = Quadratic::new(k, d, 1.0, 0.1, 13);
        let (w, mut net) = ring(k);
        let mut algo = MomentumTracking::new(k, src.init(3), w.clone(), hyper(0.01));
        let s = algo.step(0, &mut src, &mut net);
        assert!(s.communicated);
        // ring degree 2, two dense payloads: 2 * k * 2 * 4d bytes.
        assert_eq!(s.bytes, (2 * k * 2 * 4 * d) as u64);
    }

    #[test]
    fn rejoin_hook_resets_iterate_and_momentum_only() {
        let k = 4;
        let mut src = Quadratic::new(k, 8, 1.0, 0.0, 14);
        let (w, mut net) = ring(k);
        let mut algo = MomentumTracking::new(k, src.init(4), w, hyper(0.02));
        for t in 0..5 {
            algo.step(t, &mut src, &mut net);
        }
        let c_before = algo.trackers.row(2).to_vec();
        algo.set_worker_params(2, &vec![0.25; 8]);
        assert_eq!(algo.params(2), &[0.25; 8][..]);
        assert!(algo.us.row(2).iter().all(|&v| v == 0.0));
        assert_eq!(algo.trackers.row(2), &c_before[..], "trackers must survive a restart");
    }
}
