//! **Algorithm 1 — PD-SGDM** (the paper's primary contribution).
//!
//! Each worker runs the heavy-ball update Eq. (8) locally:
//!
//! ```text
//! m_t^(k)       = mu * m_{t-1}^(k) + grad F(x_t^(k); xi_t^(k))
//! x_{t+1/2}^(k) = x_t^(k) - eta * m_t^(k)
//! ```
//!
//! and when `mod(t+1, p) == 0` gossip-averages the intermediate iterates
//! with its topology neighbors (Alg. 1 line 6):
//!
//! ```text
//! x_{t+1}^(k) = Σ_{j∈N_k} w_kj x_{t+1/2}^(j)
//! ```
//!
//! otherwise `x_{t+1} = x_{t+1/2}`. Momentum buffers are **local** — they
//! are never communicated (that is the difference from Yu et al. [23],
//! which doubles the payload; see `DSgdm` with `gossip_momentum=true`).

use super::{gossip::GossipState, Algorithm, Hyper, StepStats};
use crate::arena::ParamArena;
use crate::comm::Network;
use crate::engine::{LocalStepEngine, LocalUpdate};
use crate::grad::GradientSource;
use crate::optim::MomentumBank;
use crate::topology::MixWeights;

pub struct PdSgdm {
    hyper: Hyper,
    /// K×d iterate arena (one worker per row).
    xs: ParamArena,
    moms: MomentumBank,
    gossip: GossipState,
    engine: LocalStepEngine,
}

impl PdSgdm {
    /// All workers start from the same `x0` (Alg. 1 input).
    pub fn new(k: usize, x0: Vec<f32>, w: impl Into<MixWeights>, hyper: Hyper) -> Self {
        assert!(hyper.period >= 1, "p >= 1 (p=1 degenerates to D-SGDM)");
        let gossip = GossipState::new(w);
        assert_eq!(gossip.k(), k);
        let d = x0.len();
        Self {
            xs: ParamArena::filled(k, &x0),
            moms: MomentumBank::new(k, d, hyper.mu, hyper.weight_decay),
            gossip,
            engine: LocalStepEngine::new(k, d),
            hyper,
        }
    }

    /// ||m_t^(k)||² of worker k (Lemma 3 diagnostics).
    pub fn momentum_norm_sq(&self, k: usize) -> f64 {
        self.moms.momentum_norm_sq(k)
    }

    /// Overwrite one worker's iterate — used only by failure-injection
    /// tests (simulating corruption); not part of the algorithm.
    pub fn set_params_for_test(&mut self, k: usize, x: Vec<f32>) {
        assert_eq!(x.len(), self.xs.d());
        self.xs.row_mut(k).copy_from_slice(&x);
    }
}

impl Algorithm for PdSgdm {
    fn name(&self) -> String {
        format!("pd-sgdm(p={})", self.hyper.period)
    }

    fn k(&self) -> usize {
        self.xs.k()
    }

    fn step(&mut self, t: u64, source: &mut dyn GradientSource, net: &mut Network) -> StepStats {
        let eta = self.hyper.lr.eta(t);
        // Lines 2-4: local momentum step on every worker (parallel engine).
        let mean_loss = self.engine.local_step(
            source,
            &mut self.xs,
            LocalUpdate::Momentum { moms: &mut self.moms, eta },
        );
        // Lines 5-9: periodic gossip on the intermediate iterates,
        // fanned over the engine's pool (one pool for both phases).
        let mut stats = StepStats { mean_loss, ..Default::default() };
        if (t + 1) % self.hyper.period == 0 {
            stats.bytes = self.gossip.mix(&mut self.xs, net, self.engine.comm_pool());
            stats.communicated = true;
        }
        stats
    }

    fn params(&self, k: usize) -> &[f32] {
        self.xs.row(k)
    }

    fn set_parallel(&mut self, on: bool) {
        self.engine.set_parallel(on);
    }

    fn install_shared_pool(&mut self, pool: std::sync::Arc<crate::engine::WorkerPool>) {
        self.engine.install_shared_pool(pool);
    }

    fn set_worker_params(&mut self, k: usize, x: &[f32]) {
        self.xs.row_mut(k).copy_from_slice(x);
        self.moms.reset_row(k);
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("pd-sgdm");
        self.xs.state_save(w);
        self.moms.state_save(w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("pd-sgdm")?;
        self.xs.state_load(r, "pd-sgdm.xs")?;
        self.moms.state_load(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::Quadratic;
    use crate::linalg::Mat;
    use crate::optim::LrSchedule;
    use crate::topology::{mixing_matrix, Topology, Weighting};

    fn ring_w(k: usize) -> Mat {
        mixing_matrix(&Topology::Ring.build(k, 0), Weighting::UniformDegree)
    }

    fn run(
        algo: &mut dyn Algorithm,
        source: &mut dyn GradientSource,
        net: &mut Network,
        steps: u64,
    ) -> Vec<StepStats> {
        (0..steps).map(|t| algo.step(t, source, net)).collect()
    }

    #[test]
    fn communicates_exactly_every_p_steps() {
        let k = 4;
        let mut src = Quadratic::new(k, 8, 1.0, 0.1, 1);
        let g = Topology::Ring.build(k, 0);
        let mut net = Network::new(&g);
        let hyper = Hyper { period: 4, ..Default::default() };
        let mut algo = PdSgdm::new(k, src.init(0), ring_w(k), hyper);
        let stats = run(&mut algo, &mut src, &mut net, 16);
        let comm_steps: Vec<usize> = stats
            .iter()
            .enumerate()
            .filter(|(_, s)| s.communicated)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(comm_steps, vec![3, 7, 11, 15]); // mod(t+1, 4) == 0
        assert_eq!(net.rounds, 4);
        assert!(stats.iter().all(|s| s.communicated == (s.bytes > 0)));
    }

    #[test]
    fn converges_near_quadratic_optimum() {
        let k = 8;
        let mut src = Quadratic::new(k, 16, 1.0, 0.05, 2);
        let opt = src.optimum();
        let g = Topology::Ring.build(k, 0);
        let mut net = Network::new(&g);
        let hyper = Hyper {
            lr: LrSchedule::Constant { eta: 0.02 },
            mu: 0.9,
            period: 4,
            ..Default::default()
        };
        let mut algo = PdSgdm::new(k, src.init(3), ring_w(k), hyper);
        run(&mut algo, &mut src, &mut net, 1500);
        let xbar = algo.avg_params();
        let err = crate::linalg::dist(&xbar, &opt);
        assert!(err < 0.25, "x̄ is {err} from x*");
    }

    #[test]
    fn momentum_accelerates_over_plain_sgd() {
        // On a noiseless quadratic, mu=0.9 reaches a given gap in fewer
        // iterations than mu=0 at the same (stable) step size.
        let k = 4;
        let gap_after = |mu: f32| -> f64 {
            let mut src = Quadratic::new(k, 16, 1.0, 0.0, 4);
            let opt = src.optimum();
            let g = Topology::Ring.build(k, 0);
            let mut net = Network::new(&g);
            let hyper = Hyper {
                lr: LrSchedule::Constant { eta: 0.01 },
                mu,
                period: 4,
                ..Default::default()
            };
            let mut algo = PdSgdm::new(k, src.init(5), ring_w(k), hyper);
            run(&mut algo, &mut src, &mut net, 300);
            crate::linalg::dist(&algo.avg_params(), &opt)
        };
        assert!(gap_after(0.9) < 0.5 * gap_after(0.0));
    }

    #[test]
    fn consensus_error_bounded_by_lemma5_shape() {
        // Lemma 5: Σ_k ||x_k − x̄||² <= 2 η² p² G² K (1 + 4/ρ²) / (1-μ)².
        // We verify the *measured* consensus error respects the bound
        // with G = max observed grad norm.
        let k = 8;
        let mut src = Quadratic::new(k, 8, 2.0, 0.1, 6);
        let graph = Topology::Ring.build(k, 0);
        let w = ring_w(k);
        let rho = crate::linalg::spectral_gap(&w, 1);
        let mut net = Network::new(&graph);
        let (eta, mu, p) = (0.05f64, 0.9f64, 8u64);
        let hyper = Hyper {
            lr: LrSchedule::Constant { eta: eta as f32 },
            mu: mu as f32,
            period: p,
            ..Default::default()
        };
        let mut algo = PdSgdm::new(k, src.init(7), w, hyper);
        let mut max_g_sq: f64 = 0.0;
        let mut max_consensus: f64 = 0.0;
        for t in 0..400 {
            // track worker gradient norms (for G)
            for kk in 0..k {
                let (_, g) = src.grad(kk, algo.params(kk));
                max_g_sq = max_g_sq.max(crate::linalg::dot(&g, &g));
            }
            algo.step(t, &mut src, &mut net);
            max_consensus = max_consensus.max(algo.consensus_error());
        }
        let bound = 2.0 * eta * eta * (p * p) as f64 * max_g_sq * k as f64
            * (1.0 + 4.0 / (rho * rho))
            / (1.0 - mu).powi(2);
        assert!(
            max_consensus <= bound,
            "consensus {max_consensus} exceeds Lemma 5 bound {bound}"
        );
        assert!(max_consensus > 0.0, "workers should disagree between rounds");
    }

    #[test]
    fn larger_p_sends_fewer_bytes() {
        let k = 8;
        let bytes_for = |p: u64| -> u64 {
            let mut src = Quadratic::new(k, 32, 1.0, 0.1, 8);
            let g = Topology::Ring.build(k, 0);
            let mut net = Network::new(&g);
            let hyper = Hyper { period: p, ..Default::default() };
            let mut algo = PdSgdm::new(k, src.init(9), ring_w(k), hyper);
            run(&mut algo, &mut src, &mut net, 64);
            net.total_bytes
        };
        let (b4, b8, b16) = (bytes_for(4), bytes_for(8), bytes_for(16));
        assert_eq!(b4, 2 * b8);
        assert_eq!(b8, 2 * b16);
    }

    #[test]
    fn workers_agree_immediately_after_complete_graph_round() {
        // With the complete topology, one gossip round = exact averaging.
        let k = 5;
        let mut src = Quadratic::new(k, 6, 1.0, 0.2, 10);
        let g = Topology::Complete.build(k, 0);
        let w = mixing_matrix(&g, Weighting::UniformDegree);
        let mut net = Network::new(&g);
        let hyper = Hyper { period: 2, ..Default::default() };
        let mut algo = PdSgdm::new(k, src.init(11), w, hyper);
        algo.step(0, &mut src, &mut net); // local only
        assert!(algo.consensus_error() > 0.0);
        algo.step(1, &mut src, &mut net); // communication step
        assert!(algo.consensus_error() < 1e-9);
    }
}
