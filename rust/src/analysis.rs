//! Theoretical-bound evaluators for Theorems 1–2 and Corollaries 1–2.
//!
//! These turn the paper's convergence statements into executable
//! predictions: given problem constants (L, σ, G, f(x₀)−f*) and run
//! parameters (K, T, η, μ, p, ρ, δ), compute the right-hand sides the
//! experiments can be checked against. Used by the ablation benches and
//! the docs; the Lemma 5 consensus bound is additionally asserted
//! step-by-step in `algorithms::pd_sgdm` tests.

/// Problem-level constants of Assumptions 2–4 plus the initial gap.
#[derive(Clone, Copy, Debug)]
pub struct ProblemConstants {
    /// Smoothness L (Assumption 2).
    pub l_smooth: f64,
    /// Gradient-variance bound σ² (Assumption 3).
    pub sigma_sq: f64,
    /// Second-moment bound G² with ‖∇F‖² ≤ G² (Assumption 4).
    pub g_sq: f64,
    /// f(x₀) − f*.
    pub init_gap: f64,
}

/// Run-level parameters shared by both theorems.
#[derive(Clone, Copy, Debug)]
pub struct RunParams {
    pub workers: usize,
    pub steps: u64,
    pub eta: f64,
    pub mu: f64,
    pub period: u64,
    /// Spectral gap ρ of the mixing matrix.
    pub rho: f64,
}

impl RunParams {
    /// Theorem 1/2 step-size condition η < (1−μ)²/(2L).
    pub fn eta_admissible(&self, c: &ProblemConstants) -> bool {
        self.eta < (1.0 - self.mu).powi(2) / (2.0 * c.l_smooth)
    }
}

/// Theorem 1 RHS: the bound on (1/T) Σ‖∇f(x̄_t)‖² for PD-SGDM.
///
/// (2(1−μ)(f(x₀)−f*))/(ηT) + μησ²L/((1−μ)²K) + ησ²L/((1−μ)K)
///   + 2η²p²G²L²/(1−μ)² · (1 + 4/ρ²)
pub fn theorem1_bound(c: &ProblemConstants, r: &RunParams) -> f64 {
    let (k, t) = (r.workers as f64, r.steps as f64);
    let om = 1.0 - r.mu;
    let p2 = (r.period * r.period) as f64;
    2.0 * om * c.init_gap / (r.eta * t)
        + r.mu * r.eta * c.sigma_sq * c.l_smooth / (om * om * k)
        + r.eta * c.sigma_sq * c.l_smooth / (om * k)
        + 2.0 * r.eta * r.eta * p2 * c.g_sq * c.l_smooth * c.l_smooth / (om * om)
            * (1.0 + 4.0 / (r.rho * r.rho))
}

/// Theorem 2's effective gap α = ρ²δ/82 for CPD-SGDM.
pub fn alpha(rho: f64, delta: f64) -> f64 {
    rho * rho * delta / 82.0
}

/// Theorem 2 RHS — identical structure with (1+4/ρ²) → (1+4/α²) and the
/// consensus coefficient 2 → 4.
pub fn theorem2_bound(c: &ProblemConstants, r: &RunParams, delta: f64) -> f64 {
    let (k, t) = (r.workers as f64, r.steps as f64);
    let om = 1.0 - r.mu;
    let p2 = (r.period * r.period) as f64;
    let a = alpha(r.rho, delta);
    2.0 * om * c.init_gap / (r.eta * t)
        + r.mu * r.eta * c.sigma_sq * c.l_smooth / (om * om * k)
        + r.eta * c.sigma_sq * c.l_smooth / (om * k)
        + 4.0 * r.eta * r.eta * p2 * c.g_sq * c.l_smooth * c.l_smooth / (om * om)
            * (1.0 + 4.0 / (a * a))
}

/// Lemma 5: bound on Σ_k ‖x_k − x̄‖² for PD-SGDM.
pub fn lemma5_consensus_bound(c: &ProblemConstants, r: &RunParams) -> f64 {
    let om = 1.0 - r.mu;
    2.0 * r.eta * r.eta * ((r.period * r.period) as f64) * c.g_sq * (r.workers as f64)
        / (om * om)
        * (1.0 + 4.0 / (r.rho * r.rho))
}

/// Corollary 1 parameter schedule: η = √(K/T), p = T^{1/4}/K^τ (≥1).
pub fn corollary1_schedule(k: usize, t: u64, tau: f64) -> (f64, u64) {
    let eta = ((k as f64) / (t as f64)).sqrt();
    let p = ((t as f64).powf(0.25) / (k as f64).powf(tau)).max(1.0).round() as u64;
    (eta, p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn consts() -> ProblemConstants {
        ProblemConstants { l_smooth: 1.5, sigma_sq: 4.0, g_sq: 25.0, init_gap: 10.0 }
    }

    fn params() -> RunParams {
        RunParams { workers: 8, steps: 10_000, eta: 0.002, mu: 0.9, period: 4, rho: 0.25 }
    }

    #[test]
    fn eta_condition() {
        let c = consts();
        assert!(params().eta_admissible(&c));
        let mut r = params();
        r.eta = 0.01; // (1-0.9)^2/(2*1.5) = 0.0033
        assert!(!r.eta_admissible(&c));
    }

    #[test]
    fn theorem1_monotonicities() {
        // The bound must grow with p, shrink with rho and K, and shrink
        // in T — the qualitative content of Theorem 1.
        let c = consts();
        let base = theorem1_bound(&c, &params());
        let mut r = params();
        r.period = 16;
        assert!(theorem1_bound(&c, &r) > base);
        let mut r = params();
        r.rho = 1.0;
        assert!(theorem1_bound(&c, &r) < base);
        let mut r = params();
        r.workers = 64;
        assert!(theorem1_bound(&c, &r) < base);
        let mut r = params();
        r.steps = 1_000_000;
        assert!(theorem1_bound(&c, &r) < base);
    }

    #[test]
    fn theorem2_dominates_theorem1() {
        // Same parameters, δ < 1: compressed communication can only widen
        // the bound (α ≤ ρ and coefficient 4 ≥ 2).
        let c = consts();
        let r = params();
        assert!(theorem2_bound(&c, &r, 0.5) > theorem1_bound(&c, &r));
        // ... and improves as δ -> 1
        assert!(theorem2_bound(&c, &r, 0.9) < theorem2_bound(&c, &r, 0.1));
    }

    #[test]
    fn alpha_formula() {
        assert!((alpha(0.5, 0.4) - 0.25 * 0.4 / 82.0).abs() < 1e-15);
        assert!(alpha(1.0, 1.0) < 1.0, "paper: alpha < 1 always");
    }

    #[test]
    fn lemma5_matches_hand_computation() {
        let c = consts();
        let r = params();
        let expect = 2.0 * 0.002f64.powi(2) * 16.0 * 25.0 * 8.0 / 0.1f64.powi(2)
            * (1.0 + 4.0 / 0.0625);
        assert!((lemma5_consensus_bound(&c, &r) - expect).abs() < 1e-9 * expect);
    }

    #[test]
    fn corollary1_schedule_shapes() {
        let (eta, p) = corollary1_schedule(8, 10_000, 0.75);
        assert!((eta - (8.0f64 / 10_000.0).sqrt()).abs() < 1e-12);
        assert!(p >= 1);
        // larger tau => smaller p
        let (_, p_small_tau) = corollary1_schedule(8, 10_000, 0.25);
        assert!(p_small_tau >= p);
        // K=1 => p = T^{1/4}
        let (_, p1) = corollary1_schedule(1, 10_000, 0.75);
        assert_eq!(p1, 10);
    }
}
