//! Flat K×d parameter arena — the memory layout that scales the
//! simulator to K=1024 (ROADMAP item 1, DESIGN.md §8).
//!
//! Every algorithm used to hold worker state as `Vec<Vec<f32>>`: K
//! separately heap-allocated rows, scattered across the allocator, with
//! a pointer chase per worker access. [`ParamArena`] replaces that with
//! ONE contiguous `K*d` buffer plus per-worker row views, so
//!
//! * row sweeps (local step, gossip accumulation, checkpointing) walk
//!   memory linearly — the prefetcher sees one stream, not K;
//! * the whole bank serializes as a single contiguous section
//!   ([`ParamArena::state_save`], with a shim that still loads the v2
//!   per-worker layout — see `state.rs`);
//! * steady-state code paths hold ZERO per-round allocations: rows are
//!   reused in place and whole banks exchange via [`ParamArena::swap_data`].
//!
//! Row views are plain `&[f32]` / `&mut [f32]`, so every slice kernel in
//! [`crate::linalg`] applies unchanged.

use crate::state::{StateReader, StateWriter};

/// One contiguous K×d worker-state bank with per-worker row views.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamArena {
    k: usize,
    d: usize,
    data: Vec<f32>,
}

impl ParamArena {
    /// K zero rows of length d.
    pub fn zeros(k: usize, d: usize) -> Self {
        Self { k, d, data: vec![0.0; k * d] }
    }

    /// K copies of the shared start iterate `x0` (the paper's common x_0).
    pub fn filled(k: usize, x0: &[f32]) -> Self {
        let d = x0.len();
        let mut data = Vec::with_capacity(k * d);
        for _ in 0..k {
            data.extend_from_slice(x0);
        }
        Self { k, d, data }
    }

    /// Build from per-worker rows (interop/test helper; rows must agree
    /// in length).
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let k = rows.len();
        let d = rows.first().map_or(0, Vec::len);
        let mut data = Vec::with_capacity(k * d);
        for r in rows {
            assert_eq!(r.len(), d, "ragged arena rows");
            data.extend_from_slice(r);
        }
        Self { k, d, data }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Worker i's iterate as a borrowed row view.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// All rows in worker order. (`d.max(1)` keeps the chunk size legal
    /// for degenerate d=0 arenas, which then simply yield no rows.)
    #[inline]
    pub fn rows(&self) -> std::slice::ChunksExact<'_, f32> {
        self.data.chunks_exact(self.d.max(1))
    }

    /// All rows in worker order, mutably — disjoint `&mut [f32]` views,
    /// ready to fan across a worker pool.
    #[inline]
    pub fn rows_mut(&mut self) -> std::slice::ChunksExactMut<'_, f32> {
        self.data.chunks_exact_mut(self.d.max(1))
    }

    /// The whole flat buffer (checkpointing, norms over the full bank).
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Base address of the backing buffer — benches assert allocation
    /// stability (no per-round reallocation) by comparing this across
    /// rounds.
    pub fn data_ptr(&self) -> *const f32 {
        self.data.as_ptr()
    }

    /// Exchange backing buffers with another same-shape arena without
    /// copying — the gossip generation swap.
    pub fn swap_data(&mut self, other: &mut ParamArena) {
        assert_eq!((self.k, self.d), (other.k, other.d), "arena shape mismatch in swap");
        std::mem::swap(&mut self.data, &mut other.data);
    }

    /// Per-worker copies (interop/test helper).
    pub fn to_rows(&self) -> Vec<Vec<f32>> {
        self.rows().map(<[f32]>::to_vec).collect()
    }

    /// Serialize as ONE contiguous section (v3 layout; see state.rs).
    pub fn state_save(&self, w: &mut StateWriter) {
        w.put_f32_flat_mat(self.k, self.d, &self.data);
    }

    /// Restore in place; accepts both the contiguous v3 layout and the
    /// legacy v2 per-worker layout (strict shape check either way).
    pub fn state_load(&mut self, r: &mut StateReader, what: &str) -> Result<(), String> {
        r.take_f32_flat_mat_into(self.k, self.d, &mut self.data, what)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_view_the_flat_buffer() {
        let mut a = ParamArena::filled(3, &[1.0, 2.0]);
        assert_eq!((a.k(), a.d()), (3, 2));
        a.row_mut(1)[0] = 9.0;
        assert_eq!(a.row(0), &[1.0, 2.0]);
        assert_eq!(a.row(1), &[9.0, 2.0]);
        assert_eq!(a.as_slice(), &[1.0, 2.0, 9.0, 2.0, 1.0, 2.0]);
        let collected: Vec<&[f32]> = a.rows().collect();
        assert_eq!(collected.len(), 3);
        assert_eq!(collected[1], &[9.0, 2.0]);
    }

    #[test]
    fn rows_mut_are_disjoint_and_cover_everything() {
        let mut a = ParamArena::zeros(4, 3);
        for (i, row) in a.rows_mut().enumerate() {
            for v in row.iter_mut() {
                *v = i as f32;
            }
        }
        for i in 0..4 {
            assert!(a.row(i).iter().all(|&v| v == i as f32));
        }
    }

    #[test]
    fn from_rows_round_trips() {
        let rows = vec![vec![1.0f32, -2.0], vec![0.5, f32::NAN]];
        let a = ParamArena::from_rows(&rows);
        let back = a.to_rows();
        assert_eq!(back[0], rows[0]);
        assert_eq!(back[1][0], 0.5);
        assert!(back[1][1].is_nan());
    }

    #[test]
    fn swap_data_exchanges_buffers_without_moving_shape() {
        let mut a = ParamArena::filled(2, &[1.0; 4]);
        let mut b = ParamArena::filled(2, &[2.0; 4]);
        let (pa, pb) = (a.data_ptr(), b.data_ptr());
        a.swap_data(&mut b);
        assert_eq!(a.data_ptr(), pb);
        assert_eq!(b.data_ptr(), pa);
        assert!(a.as_slice().iter().all(|&v| v == 2.0));
        assert!(b.as_slice().iter().all(|&v| v == 1.0));
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn swap_rejects_shape_mismatch() {
        let mut a = ParamArena::zeros(2, 3);
        let mut b = ParamArena::zeros(3, 2);
        a.swap_data(&mut b);
    }

    #[test]
    fn checkpoint_round_trip_is_bit_exact() {
        let a = ParamArena::from_rows(&[vec![1.5, -0.0], vec![f32::NAN, 3.25]]);
        let mut w = StateWriter::new();
        a.state_save(&mut w);
        let bytes = w.into_bytes();
        let mut b = ParamArena::zeros(2, 2);
        b.state_load(&mut StateReader::new(&bytes), "xs").unwrap();
        let bits = |a: &ParamArena| a.as_slice().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b));
    }

    #[test]
    fn legacy_v2_per_worker_layout_still_loads() {
        // A v2 checkpoint section written with put_f32_mat (u64 K, then
        // K length-prefixed rows) must load into an arena via the shim.
        let rows = vec![vec![1.0f32, 2.0, 3.0], vec![-4.0, 5.0, -6.0]];
        let mut w = StateWriter::new();
        w.put_f32_mat(&rows);
        let bytes = w.into_bytes();
        let mut a = ParamArena::zeros(2, 3);
        a.state_load(&mut StateReader::new(&bytes), "xs").unwrap();
        assert_eq!(a.to_rows(), rows);
    }

    #[test]
    fn checkpoint_shape_mismatch_is_an_error() {
        let a = ParamArena::zeros(2, 4);
        let mut w = StateWriter::new();
        a.state_save(&mut w);
        let bytes = w.into_bytes();
        let mut wrong_k = ParamArena::zeros(3, 4);
        assert!(wrong_k.state_load(&mut StateReader::new(&bytes), "xs").is_err());
        let mut wrong_d = ParamArena::zeros(2, 5);
        assert!(wrong_d.state_load(&mut StateReader::new(&bytes), "xs").is_err());
    }
}
