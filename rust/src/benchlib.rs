//! In-crate benchmark harness (no criterion in this offline environment).
//!
//! Two kinds of benchmarks live under `benches/`:
//!
//! 1. **Figure benches** — regenerate a paper figure's series; they use
//!    the sim driver + [`crate::metrics`] and print CSV. Timing is not
//!    the point there.
//! 2. **Hot-path benches** — measure throughput of the L3 kernels
//!    (gossip, compression, momentum); they use [`bench`] below, which
//!    reports min/median/p95 over warmed-up timed runs — the numbers in
//!    EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub mean: Duration,
}

impl BenchStats {
    /// Throughput in "units/s" given units of work per iteration.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "min {:>10.3?}  median {:>10.3?}  p95 {:>10.3?}  ({} iters)",
            self.min, self.median, self.p95, self.iters
        )
    }
}

/// Run `body` repeatedly: `warmup` untimed runs, then timed runs until
/// `budget` elapses (at least 5, at most 10_000).
pub fn bench<F: FnMut()>(warmup: usize, budget: Duration, mut body: F) -> BenchStats {
    for _ in 0..warmup {
        body();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < budget || samples.len() < 5) && samples.len() < 10_000 {
        let t0 = Instant::now();
        body();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let n = samples.len();
    BenchStats {
        iters: n,
        min: samples[0],
        median: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        mean: samples.iter().sum::<Duration>() / n as u32,
    }
}

/// Prevent the optimizer from discarding a computed value
/// (std::hint::black_box stabilized — thin alias for bench ergonomics).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Pretty-print one bench row: `name  stats  [throughput]`.
pub fn report(name: &str, stats: &BenchStats, throughput: Option<(f64, &str)>) {
    match throughput {
        Some((units, unit_name)) => println!(
            "{name:<44} {stats}  {:.3e} {unit_name}/s",
            stats.throughput(units)
        ),
        None => println!("{name:<44} {stats}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_at_least_five_samples() {
        let stats = bench(1, Duration::from_millis(1), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(stats.iters >= 5);
        assert!(stats.min <= stats.median && stats.median <= stats.p95);
    }

    #[test]
    fn throughput_is_positive() {
        let stats = bench(0, Duration::from_millis(1), || {
            black_box(vec![0u8; 1024]);
        });
        assert!(stats.throughput(1024.0) > 0.0);
    }

    #[test]
    fn display_is_nonempty() {
        let stats = bench(0, Duration::from_millis(1), || {});
        assert!(!format!("{stats}").is_empty());
    }
}
