//! In-crate benchmark harness (no criterion in this offline environment).
//!
//! Two kinds of benchmarks live under `benches/`:
//!
//! 1. **Figure benches** — regenerate a paper figure's series; they use
//!    the sim driver + [`crate::metrics`] and print CSV. Timing is not
//!    the point there.
//! 2. **Hot-path benches** — measure throughput of the L3 kernels
//!    (gossip, compression, momentum); they use [`bench`] below, which
//!    reports min/median/p95 over warmed-up timed runs — the numbers in
//!    EXPERIMENTS.md §Perf.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::json::{obj, Json};

/// Timing statistics over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub mean: Duration,
}

impl BenchStats {
    /// Throughput in "units/s" given units of work per iteration.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "min {:>10.3?}  median {:>10.3?}  p95 {:>10.3?}  ({} iters)",
            self.min, self.median, self.p95, self.iters
        )
    }
}

/// Run `body` repeatedly: `warmup` untimed runs, then timed runs until
/// `budget` elapses (at least 5, at most 10_000).
pub fn bench<F: FnMut()>(warmup: usize, budget: Duration, mut body: F) -> BenchStats {
    for _ in 0..warmup {
        body();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < budget || samples.len() < 5) && samples.len() < 10_000 {
        let t0 = Instant::now();
        body();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let n = samples.len();
    BenchStats {
        iters: n,
        min: samples[0],
        median: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        mean: samples.iter().sum::<Duration>() / n as u32,
    }
}

/// Prevent the optimizer from discarding a computed value
/// (std::hint::black_box stabilized — thin alias for bench ergonomics).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Pretty-print one bench row: `name  stats  [throughput]`.
pub fn report(name: &str, stats: &BenchStats, throughput: Option<(f64, &str)>) {
    match throughput {
        Some((units, unit_name)) => println!(
            "{name:<44} {stats}  {:.3e} {unit_name}/s",
            stats.throughput(units)
        ),
        None => println!("{name:<44} {stats}"),
    }
}

/// Whether the bench binary was invoked with `--smoke`
/// (`cargo bench --bench hotpath -- --smoke`): CI-speed mode — shrunken
/// problem sizes + short timing budgets, same code paths.
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// The [`bench`] timing budget honoring `--smoke`.
pub fn budget() -> Duration {
    if smoke() { Duration::from_millis(40) } else { Duration::from_millis(400) }
}

/// JSON form of one timing result (`*_ns` integers, median-based
/// throughput when `units_per_iter` is given).
pub fn stats_json(stats: &BenchStats, units_per_iter: Option<f64>) -> Vec<(&'static str, Json)> {
    let mut pairs = vec![
        ("iters", Json::Num(stats.iters as f64)),
        ("min_ns", Json::Num(stats.min.as_nanos() as f64)),
        ("median_ns", Json::Num(stats.median.as_nanos() as f64)),
        ("p95_ns", Json::Num(stats.p95.as_nanos() as f64)),
        ("mean_ns", Json::Num(stats.mean.as_nanos() as f64)),
    ];
    if let Some(units) = units_per_iter {
        pairs.push(("throughput_per_s", Json::Num(stats.throughput(units))));
    }
    pairs
}

/// Accumulates machine-readable bench records and flushes them as one
/// JSON document (`{"smoke": bool, "results": [...]}`) — the repo's
/// tracked perf trajectory (BENCH_hotpath.json; see EXPERIMENTS.md §Perf).
pub struct JsonSink {
    path: PathBuf,
    entries: Vec<Json>,
}

impl JsonSink {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self { path: path.into(), entries: Vec::new() }
    }

    /// Record one result: a `bench` name plus arbitrary fields.
    pub fn push(&mut self, bench_name: &str, fields: Vec<(&str, Json)>) {
        let mut pairs = vec![("bench", Json::Str(bench_name.to_string()))];
        pairs.extend(fields);
        self.entries.push(obj(pairs));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Write the document; returns the path it wrote to.
    pub fn flush(&self) -> std::io::Result<&std::path::Path> {
        let doc = obj(vec![
            ("smoke", Json::Bool(smoke())),
            ("results", Json::Arr(self.entries.clone())),
        ]);
        std::fs::write(&self.path, doc.to_string_compact() + "\n")?;
        Ok(&self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_at_least_five_samples() {
        let stats = bench(1, Duration::from_millis(1), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(stats.iters >= 5);
        assert!(stats.min <= stats.median && stats.median <= stats.p95);
    }

    #[test]
    fn throughput_is_positive() {
        let stats = bench(0, Duration::from_millis(1), || {
            black_box(vec![0u8; 1024]);
        });
        assert!(stats.throughput(1024.0) > 0.0);
    }

    #[test]
    fn display_is_nonempty() {
        let stats = bench(0, Duration::from_millis(1), || {});
        assert!(!format!("{stats}").is_empty());
    }

    #[test]
    fn json_sink_roundtrips_through_parser() {
        let stats = bench(0, Duration::from_millis(1), || {
            black_box((0..64).sum::<u64>());
        });
        let path = std::env::temp_dir()
            .join(format!("pdsgdm_bench_{}.json", std::process::id()));
        let mut sink = JsonSink::new(&path);
        assert!(sink.is_empty());
        let mut fields = vec![("k", Json::Num(8.0))];
        fields.extend(stats_json(&stats, Some(1000.0)));
        sink.push("algo_step", fields);
        assert_eq!(sink.len(), 1);
        sink.flush().unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let results = doc.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("bench").and_then(Json::as_str), Some("algo_step"));
        assert_eq!(results[0].get("k").and_then(Json::as_usize), Some(8));
        assert!(results[0].get("median_ns").and_then(Json::as_f64).is_some());
        assert!(results[0].get("throughput_per_s").and_then(Json::as_f64).is_some());
        std::fs::remove_file(&path).unwrap();
    }
}
