//! Simulated decentralized network: per-edge mailboxes, exact byte
//! accounting, and an α–β communication cost model.
//!
//! The paper ran 8 GPUs with a real interconnect; the repro band (0/5)
//! gates that hardware, so per DESIGN.md §2 we substitute an in-process
//! network whose **accounting** is exact: a message's wire cost is
//! *measured from its payload* ([`Payload::wire_bytes`]) — encoded codec
//! buffers charge their literal length, dense f32 vectors charge 4 bytes
//! per coordinate — and the cost model converts (rounds, bytes) into
//! simulated wall-clock with the standard `latency + bytes / bandwidth`
//! α–β model priced at the busiest worker. All of Figure 2's x-axes
//! (communication MB) come from these counters.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::topology::Graph;

/// What a message carries across an edge.
///
/// Payloads are reference-counted: a broadcast to `deg` neighbors shares
/// one buffer instead of deep-copying it per edge — at the e2e model
/// size (d = 3.45M, 13.8 MB payloads) the per-round memcpy savings are
/// the §Perf gossip optimization (see EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub enum Payload {
    /// Full-precision f32 vector (uncompressed gossip fast path — the
    /// simulator skips the trivial raw-f32 serialization and charges
    /// 4 bytes per coordinate).
    Dense(Arc<Vec<f32>>),
    /// Encoded wire-codec buffer (see [`crate::compress`]): exactly the
    /// bytes a real transport would carry, so `wire_bytes == len()` by
    /// construction.
    Encoded(Arc<Vec<u8>>),
}

impl Payload {
    /// Exact bytes this payload occupies on the wire.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::Dense(v) => 4 * v.len(),
            Payload::Encoded(b) => b.len(),
        }
    }

    /// The dense view, if this is an uncompressed payload.
    pub fn dense(&self) -> Option<&[f32]> {
        match self {
            Payload::Dense(v) => Some(v),
            Payload::Encoded(_) => None,
        }
    }

    /// The encoded byte view, if this is a codec payload.
    pub fn encoded(&self) -> Option<&[u8]> {
        match self {
            Payload::Dense(_) => None,
            Payload::Encoded(b) => Some(b),
        }
    }
}

/// A point-to-point message between neighboring workers.
#[derive(Clone, Debug)]
pub struct Message {
    pub from: usize,
    pub to: usize,
    pub payload: Payload,
}

impl Message {
    /// Exact bytes this message occupied on the wire (measured from the
    /// payload — an invariant, not a caller-supplied claim).
    pub fn wire_bytes(&self) -> usize {
        self.payload.wire_bytes()
    }
}

/// Per-destination FIFO mailboxes over the topology's edges, with
/// cumulative traffic statistics.
#[derive(Debug)]
pub struct Network {
    k: usize,
    edges: Vec<Vec<usize>>, // adjacency (copied from the Graph)
    inbox: Vec<VecDeque<Message>>,
    /// Total payload bytes ever sent (sum over messages).
    pub total_bytes: u64,
    /// Per-worker bytes sent (for load-imbalance analysis, e.g. star hub).
    pub bytes_sent: Vec<u64>,
    /// Number of completed communication rounds (bulk exchanges).
    pub rounds: u64,
    /// Total messages sent.
    pub messages: u64,
}

impl Network {
    pub fn new(g: &Graph) -> Self {
        Self {
            k: g.k,
            edges: (0..g.k).map(|i| g.neighbors(i).to_vec()).collect(),
            inbox: (0..g.k).map(|_| VecDeque::new()).collect(),
            total_bytes: 0,
            bytes_sent: vec![0; g.k],
            rounds: 0,
            messages: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.edges[i]
    }

    /// Degree of the busiest worker — the per-round link count the α–β
    /// model prices (on irregular graphs like the star this differs from
    /// any single node's degree, so never use `neighbors(0).len()`).
    pub fn max_degree(&self) -> usize {
        self.edges.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Send a dense f32 payload from `from` to `to` (wire cost 4·d).
    pub fn send(&mut self, from: usize, to: usize, payload: Vec<f32>) {
        self.send_payload(from, to, Payload::Dense(Arc::new(payload)));
    }

    /// Send any payload; panics if (from, to) is not an edge —
    /// decentralized algorithms may only talk to graph neighbors. The
    /// wire charge is measured from the payload itself.
    pub fn send_payload(&mut self, from: usize, to: usize, payload: Payload) {
        assert!(
            self.edges[from].contains(&to),
            "({from} -> {to}) is not an edge of the topology"
        );
        let wire_bytes = payload.wire_bytes() as u64;
        self.total_bytes += wire_bytes;
        self.bytes_sent[from] += wire_bytes;
        self.messages += 1;
        self.inbox[to].push_back(Message { from, to, payload });
    }

    /// Broadcast a dense payload from `from` to all its neighbors,
    /// charging wire bytes per link (gossip is point-to-point). The
    /// buffer is allocated once and shared across edges.
    pub fn broadcast(&mut self, from: usize, payload: &[f32]) {
        self.broadcast_shared(from, Arc::new(payload.to_vec()));
    }

    /// Zero-copy dense broadcast of an already-owned buffer.
    pub fn broadcast_shared(&mut self, from: usize, payload: Arc<Vec<f32>>) {
        self.broadcast_payload(from, Payload::Dense(payload));
    }

    /// Broadcast an encoded codec buffer; every link charges exactly
    /// `payload.len()` bytes.
    pub fn broadcast_encoded(&mut self, from: usize, payload: Arc<Vec<u8>>) {
        self.broadcast_payload(from, Payload::Encoded(payload));
    }

    fn broadcast_payload(&mut self, from: usize, payload: Payload) {
        for i in 0..self.edges[from].len() {
            let to = self.edges[from][i];
            self.send_payload(from, to, payload.clone());
        }
    }

    /// Drain worker `to`'s inbox.
    pub fn recv_all(&mut self, to: usize) -> Vec<Message> {
        self.inbox[to].drain(..).collect()
    }

    /// Mark the end of a bulk exchange (one paper "communication round").
    pub fn end_round(&mut self) {
        self.rounds += 1;
        debug_assert!(
            self.inbox.iter().all(|q| q.is_empty()),
            "round ended with undelivered messages"
        );
    }

    pub fn total_megabytes(&self) -> f64 {
        self.total_bytes as f64 / (1024.0 * 1024.0)
    }
}

/// α–β communication cost model priced at the **busiest worker**: a
/// round in which that worker sends `b` bytes over `m` links costs
/// `alpha * m + b / beta` seconds (workers transmit in parallel; one
/// worker's links are serialized on its NIC — conservative, matches
/// all-neighbor gossip). Defaults approximate the paper's testbed NIC
/// (10 GbE-class).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Bandwidth (bytes/second).
    pub beta: f64,
    /// Simulated seconds for one local gradient step (compute).
    pub step_seconds: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            alpha: 50e-6,          // 50 us per message
            beta: 1.25e9,          // 10 Gbit/s
            step_seconds: 50e-3,   // 50 ms minibatch fwd+bwd
        }
    }
}

impl CostModel {
    /// Simulated time of one communication round in which the busiest
    /// worker sends `worker_bytes` bytes (its *measured* per-round
    /// traffic, in f64 — integer division truncated small compressed
    /// payloads to a zero bandwidth term) over `links` serial links.
    pub fn round_seconds(&self, links: usize, worker_bytes: f64) -> f64 {
        links as f64 * self.alpha + worker_bytes / self.beta
    }

    /// Simulated time for `steps` local steps with a communication round
    /// every `period` steps, the busiest worker moving `worker_bytes`
    /// per round.
    pub fn simulated_seconds(
        &self,
        steps: u64,
        period: u64,
        links: usize,
        worker_bytes: f64,
    ) -> f64 {
        let rounds = steps / period.max(1);
        steps as f64 * self.step_seconds + rounds as f64 * self.round_seconds(links, worker_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ring8() -> Network {
        Network::new(&Topology::Ring.build(8, 0))
    }

    #[test]
    fn send_recv_roundtrip() {
        let mut net = ring8();
        net.send(0, 1, vec![1.0, 2.0]);
        net.send(2, 1, vec![3.0]);
        let msgs = net.recv_all(1);
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].from, 0);
        assert_eq!(msgs[1].payload.dense().unwrap(), &[3.0]);
        assert!(net.recv_all(1).is_empty(), "inbox drained");
    }

    #[test]
    #[should_panic(expected = "not an edge")]
    fn non_edge_send_panics() {
        let mut net = ring8();
        net.send(0, 4, vec![1.0]); // 0 and 4 are not ring neighbors
    }

    #[test]
    fn byte_accounting_is_exact() {
        let mut net = ring8();
        net.broadcast(0, &[1.0; 100]); // 400 wire bytes per link
        assert_eq!(net.total_bytes, 2 * 400); // ring degree 2
        assert_eq!(net.bytes_sent[0], 800);
        assert_eq!(net.messages, 2);
        assert!((net.total_megabytes() - 800.0 / 1048576.0).abs() < 1e-12);
    }

    #[test]
    fn encoded_payload_charges_its_length() {
        // The tentpole invariant: wire_bytes == payload.len(), measured,
        // not asserted by the sender.
        let mut net = ring8();
        let buf = Arc::new(vec![0xABu8; 57]);
        net.broadcast_encoded(0, Arc::clone(&buf));
        assert_eq!(net.total_bytes, 2 * 57);
        for to in [1usize, 7] {
            let msgs = net.recv_all(to);
            assert_eq!(msgs.len(), 1);
            assert_eq!(msgs[0].wire_bytes(), 57);
            assert_eq!(msgs[0].payload.encoded().unwrap(), buf.as_slice());
            assert!(msgs[0].payload.dense().is_none());
        }
        net.end_round();
    }

    #[test]
    fn max_degree_sees_the_star_hub() {
        let star = Network::new(&Topology::Star.build(8, 0));
        assert_eq!(star.max_degree(), 7); // hub, not a leaf
        assert_eq!(ring8().max_degree(), 2);
    }

    #[test]
    fn round_counter() {
        let mut net = ring8();
        net.broadcast(3, &[0.0]);
        net.recv_all(2);
        net.recv_all(4);
        net.end_round();
        assert_eq!(net.rounds, 1);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "undelivered")]
    fn end_round_checks_delivery() {
        let mut net = ring8();
        net.send(0, 1, vec![1.0]);
        net.end_round();
    }

    #[test]
    fn cost_model_scales_linearly() {
        let cm = CostModel::default();
        let r1 = cm.round_seconds(2, 1_000_000.0);
        let r2 = cm.round_seconds(2, 2_000_000.0);
        assert!(r2 > r1);
        assert!((r2 - r1 - 1_000_000.0 / cm.beta).abs() < 1e-12);
    }

    #[test]
    fn tiny_payloads_keep_a_nonzero_bandwidth_term() {
        // Regression: integer bytes_per_link truncated (e.g. Sign at
        // small d) to 0, silently zeroing the bandwidth term.
        let cm = CostModel::default();
        let latency_only = 2.0 * cm.alpha;
        assert!(cm.round_seconds(2, 0.5) > latency_only);
        assert!((cm.round_seconds(2, 0.5) - latency_only - 0.5 / cm.beta).abs() < 1e-18);
    }

    #[test]
    fn periodic_communication_saves_simulated_time() {
        // The motivation for p > 1: same steps, fewer rounds, less time.
        let cm = CostModel::default();
        let t_p1 = cm.simulated_seconds(1000, 1, 2, 8_000_000.0);
        let t_p8 = cm.simulated_seconds(1000, 8, 2, 8_000_000.0);
        assert!(t_p8 < t_p1);
        let compute_only = 1000.0 * cm.step_seconds;
        assert!(t_p8 < compute_only + (1000 / 8 + 1) as f64 * cm.round_seconds(2, 8_000_000.0));
    }
}
