//! Simulated decentralized network: per-edge mailboxes, exact byte
//! accounting, and an α–β communication cost model.
//!
//! The paper ran 8 GPUs with a real interconnect; the repro band (0/5)
//! gates that hardware, so per DESIGN.md §2 we substitute an in-process
//! network whose **accounting** is exact: every message carries the wire
//! size its codec would use (see [`crate::compress`]), and the cost model
//! converts (rounds, bytes) into simulated wall-clock with the standard
//! `latency + bytes / bandwidth` α–β model. All of Figure 2's x-axes
//! (communication MB) come from these counters.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::topology::Graph;

/// A point-to-point message between neighboring workers.
///
/// The payload is reference-counted: a broadcast to `deg` neighbors
/// shares one buffer instead of deep-copying it per edge — at the e2e
/// model size (d = 3.45M, 13.8 MB payloads) the per-round memcpy savings
/// are the §Perf gossip optimization (see EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct Message {
    pub from: usize,
    pub to: usize,
    /// Payload the receiver applies (already decoded — the simulator
    /// skips the byte-level encode/decode but charges for it).
    pub payload: Arc<Vec<f32>>,
    /// Exact bytes this payload occupies on the wire.
    pub wire_bytes: usize,
}

/// Per-destination FIFO mailboxes over the topology's edges, with
/// cumulative traffic statistics.
#[derive(Debug)]
pub struct Network {
    k: usize,
    edges: Vec<Vec<usize>>, // adjacency (copied from the Graph)
    inbox: Vec<VecDeque<Message>>,
    /// Total payload bytes ever sent (sum over messages).
    pub total_bytes: u64,
    /// Per-worker bytes sent (for load-imbalance analysis, e.g. star hub).
    pub bytes_sent: Vec<u64>,
    /// Number of completed communication rounds (bulk exchanges).
    pub rounds: u64,
    /// Total messages sent.
    pub messages: u64,
}

impl Network {
    pub fn new(g: &Graph) -> Self {
        Self {
            k: g.k,
            edges: (0..g.k).map(|i| g.neighbors(i).to_vec()).collect(),
            inbox: (0..g.k).map(|_| VecDeque::new()).collect(),
            total_bytes: 0,
            bytes_sent: vec![0; g.k],
            rounds: 0,
            messages: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.edges[i]
    }

    /// Send `payload` from `from` to `to`; panics if (from, to) is not an
    /// edge — decentralized algorithms may only talk to graph neighbors.
    pub fn send(&mut self, from: usize, to: usize, payload: Vec<f32>, wire_bytes: usize) {
        self.send_shared(from, to, Arc::new(payload), wire_bytes);
    }

    /// Like [`Network::send`] but with a pre-shared buffer (no copy).
    pub fn send_shared(
        &mut self,
        from: usize,
        to: usize,
        payload: Arc<Vec<f32>>,
        wire_bytes: usize,
    ) {
        assert!(
            self.edges[from].contains(&to),
            "({from} -> {to}) is not an edge of the topology"
        );
        self.total_bytes += wire_bytes as u64;
        self.bytes_sent[from] += wire_bytes as u64;
        self.messages += 1;
        self.inbox[to].push_back(Message { from, to, payload, wire_bytes });
    }

    /// Broadcast the same payload from `from` to all its neighbors,
    /// charging wire bytes per link (gossip is point-to-point). The
    /// buffer is allocated once and shared across edges.
    pub fn broadcast(&mut self, from: usize, payload: &[f32], wire_bytes: usize) {
        self.broadcast_shared(from, Arc::new(payload.to_vec()), wire_bytes);
    }

    /// Zero-copy broadcast of an already-owned buffer.
    pub fn broadcast_shared(&mut self, from: usize, payload: Arc<Vec<f32>>, wire_bytes: usize) {
        for i in 0..self.edges[from].len() {
            let to = self.edges[from][i];
            self.send_shared(from, to, Arc::clone(&payload), wire_bytes);
        }
    }

    /// Drain worker `to`'s inbox.
    pub fn recv_all(&mut self, to: usize) -> Vec<Message> {
        self.inbox[to].drain(..).collect()
    }

    /// Mark the end of a bulk exchange (one paper "communication round").
    pub fn end_round(&mut self) {
        self.rounds += 1;
        debug_assert!(
            self.inbox.iter().all(|q| q.is_empty()),
            "round ended with undelivered messages"
        );
    }

    pub fn total_megabytes(&self) -> f64 {
        self.total_bytes as f64 / (1024.0 * 1024.0)
    }
}

/// α–β communication cost model: a round in which the busiest worker
/// sends `b` bytes over `m` links costs `alpha * m + b / beta` seconds.
/// Defaults approximate the paper's testbed NIC (10 GbE-class).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Bandwidth (bytes/second).
    pub beta: f64,
    /// Simulated seconds for one local gradient step (compute).
    pub step_seconds: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            alpha: 50e-6,          // 50 us per message
            beta: 1.25e9,          // 10 Gbit/s
            step_seconds: 50e-3,   // 50 ms minibatch fwd+bwd
        }
    }
}

impl CostModel {
    /// Simulated time of one communication round in which each worker
    /// sends `bytes_per_link` over `links` links in parallel workers but
    /// serial links (conservative, matches ring all-neighbor gossip).
    pub fn round_seconds(&self, links: usize, bytes_per_link: usize) -> f64 {
        links as f64 * (self.alpha + bytes_per_link as f64 / self.beta)
    }

    /// Simulated time for `t` local steps with a communication round
    /// every `p` steps.
    pub fn simulated_seconds(&self, steps: u64, period: u64, links: usize, bytes_per_link: usize) -> f64 {
        let rounds = steps / period.max(1);
        steps as f64 * self.step_seconds + rounds as f64 * self.round_seconds(links, bytes_per_link)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ring8() -> Network {
        Network::new(&Topology::Ring.build(8, 0))
    }

    #[test]
    fn send_recv_roundtrip() {
        let mut net = ring8();
        net.send(0, 1, vec![1.0, 2.0], 8);
        net.send(2, 1, vec![3.0], 4);
        let msgs = net.recv_all(1);
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].from, 0);
        assert_eq!(*msgs[1].payload, vec![3.0]);
        assert!(net.recv_all(1).is_empty(), "inbox drained");
    }

    #[test]
    #[should_panic(expected = "not an edge")]
    fn non_edge_send_panics() {
        let mut net = ring8();
        net.send(0, 4, vec![1.0], 4); // 0 and 4 are not ring neighbors
    }

    #[test]
    fn byte_accounting_is_exact() {
        let mut net = ring8();
        net.broadcast(0, &[1.0; 100], 57);
        assert_eq!(net.total_bytes, 2 * 57); // ring degree 2
        assert_eq!(net.bytes_sent[0], 114);
        assert_eq!(net.messages, 2);
        assert!((net.total_megabytes() - 114.0 / 1048576.0).abs() < 1e-12);
    }

    #[test]
    fn round_counter() {
        let mut net = ring8();
        net.broadcast(3, &[0.0], 4);
        net.recv_all(2);
        net.recv_all(4);
        net.end_round();
        assert_eq!(net.rounds, 1);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "undelivered")]
    fn end_round_checks_delivery() {
        let mut net = ring8();
        net.send(0, 1, vec![1.0], 4);
        net.end_round();
    }

    #[test]
    fn cost_model_scales_linearly() {
        let cm = CostModel::default();
        let r1 = cm.round_seconds(2, 1_000_000);
        let r2 = cm.round_seconds(2, 2_000_000);
        assert!(r2 > r1);
        assert!((r2 - r1 - 2.0 * 1_000_000.0 / cm.beta).abs() < 1e-12);
    }

    #[test]
    fn periodic_communication_saves_simulated_time() {
        // The motivation for p > 1: same steps, fewer rounds, less time.
        let cm = CostModel::default();
        let t_p1 = cm.simulated_seconds(1000, 1, 2, 4_000_000);
        let t_p8 = cm.simulated_seconds(1000, 8, 2, 4_000_000);
        assert!(t_p8 < t_p1);
        let compute_only = 1000.0 * cm.step_seconds;
        assert!(t_p8 < compute_only + (1000 / 8 + 1) as f64 * cm.round_seconds(2, 4_000_000));
    }
}
