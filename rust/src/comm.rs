//! Simulated decentralized network: per-edge mailboxes, exact byte
//! accounting, and an α–β communication cost model.
//!
//! The paper ran 8 GPUs with a real interconnect; the repro band (0/5)
//! gates that hardware, so per DESIGN.md §2 we substitute an in-process
//! network whose **accounting** is exact: a message's wire cost is
//! *measured from its payload* ([`Payload::wire_bytes`]) — encoded codec
//! buffers charge their literal length, dense f32 vectors charge 4 bytes
//! per coordinate — and the cost model converts (rounds, bytes) into
//! simulated wall-clock with the standard `latency + bytes / bandwidth`
//! α–β model priced at the busiest worker. All of Figure 2's x-axes
//! (communication MB) come from these counters.

pub mod transport;

use std::sync::Arc;

use crate::rng::Xoshiro256;
use crate::state::{StateReader, StateWriter};
use crate::topology::Graph;

use transport::{InProc, Transport, TransportCounters};

/// What a message carries across an edge.
///
/// Payloads are reference-counted: a broadcast to `deg` neighbors shares
/// one buffer instead of deep-copying it per edge — at the e2e model
/// size (d = 3.45M, 13.8 MB payloads) the per-round memcpy savings are
/// the §Perf gossip optimization (see EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub enum Payload {
    /// Full-precision f32 vector (uncompressed gossip fast path — the
    /// simulator skips the trivial raw-f32 serialization and charges
    /// 4 bytes per coordinate).
    Dense(Arc<Vec<f32>>),
    /// Encoded wire-codec buffer (see [`crate::compress`]): exactly the
    /// bytes a real transport would carry, so `wire_bytes == len()` by
    /// construction.
    Encoded(Arc<Vec<u8>>),
}

impl Payload {
    /// Exact bytes this payload occupies on the wire.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::Dense(v) => 4 * v.len(),
            Payload::Encoded(b) => b.len(),
        }
    }

    /// The dense view, if this is an uncompressed payload.
    pub fn dense(&self) -> Option<&[f32]> {
        match self {
            Payload::Dense(v) => Some(v),
            Payload::Encoded(_) => None,
        }
    }

    /// The encoded byte view, if this is a codec payload.
    pub fn encoded(&self) -> Option<&[u8]> {
        match self {
            Payload::Dense(_) => None,
            Payload::Encoded(b) => Some(b),
        }
    }
}

/// A point-to-point message between neighboring workers.
#[derive(Clone, Debug)]
pub struct Message {
    pub from: usize,
    pub to: usize,
    pub payload: Payload,
}

impl Message {
    /// Exact bytes this message occupied on the wire (measured from the
    /// payload — an invariant, not a caller-supplied claim).
    pub fn wire_bytes(&self) -> usize {
        self.payload.wire_bytes()
    }
}

/// Distribution of per-worker latency multipliers for straggler
/// modeling. A worker with multiplier `m` takes `m×` the nominal time
/// for both compute and communication; the cost model prices each round
/// at the slowest participant (DESIGN.md §7).
#[derive(Clone, Debug, PartialEq)]
pub enum StragglerDist {
    /// Every worker runs at `factor ×` nominal speed (factor ≥ 1 models
    /// a uniformly degraded fleet).
    Constant { factor: f64 },
    /// Multipliers drawn iid from U[lo, hi].
    Uniform { lo: f64, hi: f64 },
    /// Multipliers drawn iid from exp(N(mu, sigma²)) — the classic
    /// heavy-tailed straggler model (median e^mu, occasional stragglers
    /// several × slower).
    LogNormal { mu: f64, sigma: f64 },
}

impl StragglerDist {
    /// Parse a CLI/config spec: `constant:F`, `uniform:LO,HI`, or
    /// `lognormal:MU,SIGMA`. Rejects non-positive or inverted ranges
    /// with an actionable message.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let bad = |msg: &str| {
            Err(format!(
                "straggler spec {spec:?}: {msg} (expected constant:F | uniform:LO,HI | lognormal:MU,SIGMA)"
            ))
        };
        let Some((kind, params)) = spec.split_once(':') else {
            return bad("missing ':'");
        };
        let nums: Vec<f64> = match params
            .split(',')
            .map(|p| p.trim().parse::<f64>())
            .collect::<Result<Vec<_>, _>>()
        {
            Ok(v) => v,
            Err(_) => return bad("parameters must be numbers"),
        };
        let dist = match (kind, nums.as_slice()) {
            ("constant", [factor]) => StragglerDist::Constant { factor: *factor },
            ("uniform", [lo, hi]) => StragglerDist::Uniform { lo: *lo, hi: *hi },
            ("lognormal", [mu, sigma]) => StragglerDist::LogNormal { mu: *mu, sigma: *sigma },
            _ => return bad("unknown kind or wrong parameter count"),
        };
        dist.validate().map_err(|e| format!("straggler spec {spec:?}: {e}"))?;
        Ok(dist)
    }

    /// Check parameter ranges (latency multipliers must be positive).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            StragglerDist::Constant { factor } => {
                if !(factor > 0.0 && factor.is_finite()) {
                    return Err(format!("latency factor must be positive and finite, got {factor}"));
                }
            }
            StragglerDist::Uniform { lo, hi } => {
                if !(lo > 0.0 && hi.is_finite() && hi >= lo) {
                    return Err(format!(
                        "uniform range must satisfy 0 < lo <= hi < inf, got [{lo}, {hi}]"
                    ));
                }
            }
            StragglerDist::LogNormal { mu, sigma } => {
                if !(mu.is_finite() && sigma.is_finite() && sigma >= 0.0) {
                    return Err(format!(
                        "lognormal needs finite mu and sigma >= 0, got mu={mu} sigma={sigma}"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Draw one latency multiplier.
    pub fn sample(&self, rng: &mut Xoshiro256) -> f64 {
        match *self {
            StragglerDist::Constant { factor } => factor,
            StragglerDist::Uniform { lo, hi } => lo + (hi - lo) * rng.next_f64(),
            StragglerDist::LogNormal { mu, sigma } => (mu + sigma * rng.normal()).exp(),
        }
    }

    /// Per-worker multipliers for a fleet of `k` (worker i gets draw i).
    pub fn sample_all(&self, k: usize, rng: &mut Xoshiro256) -> Vec<f64> {
        (0..k).map(|_| self.sample(rng)).collect()
    }
}

/// Deterministic fault injector wrapped around [`Network`] delivery.
///
/// Owns its own seeded RNG stream (independent of data/model seeds) and
/// applies, per message, per-edge: **drop** (message charged to the wire
/// but never delivered — lost in flight), **delay** (buffered across
/// communication rounds, delivered at a later `recv_all`), and
/// **reorder** (inbox shuffled before the receiver drains it). Workers
/// marked *absent* (churn) have every incident link down: sends to or
/// from them are silently discarded *without* charging bytes.
///
/// Determinism contract: a plan whose rates are all zero consumes **no**
/// RNG draws and takes the exact pre-fault code path, so it is
/// bit-identical to running with no plan at all (property-tested in
/// rust/tests/fault_injection.rs). The RNG stream, the in-flight delayed
/// messages, and the absence flags are all checkpointable via
/// [`FaultPlan::state_save`] so resumed runs replay faults exactly.
///
/// Compressed (`Payload::Encoded`) traffic participates in random
/// drop/delay only when the plan opts in via [`FaultPlan::compressed`]
/// (config `faults.compressed`, CLI `--fault-compressed`). CHOCO-style
/// algorithms then switch from the single canonical replica estimate x̂
/// to per-receiver replicas keyed by the sparse neighbor lists
/// (Σdegree·d memory, see `algorithms::gossip::ReplicaStore`), so a
/// lost q merely lets one receiver's replica drift instead of
/// corrupting a shared table. With the flag off (the default), encoded
/// traffic stays exempt and the canonical single-x̂ fast path is
/// bit-identical to the pre-fault code. Absence (churn) applies to
/// encoded traffic regardless of the flag, and the decode paths freeze
/// or renormalize around absent senders (see
/// `algorithms::gossip::CompressedExchange`).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Probability an individual dense message is lost in flight.
    pub drop_prob: f64,
    /// Probability an individual dense message is delayed.
    pub delay_prob: f64,
    /// Delay lag is drawn uniformly from {1, …, max_delay} comm rounds.
    pub max_delay: u64,
    /// Probability a receiver's inbox is shuffled before draining.
    pub reorder_prob: f64,
    /// Whether random drop/delay also applies to `Payload::Encoded`
    /// messages (lossy compressed links). Off by default so dense-only
    /// plans keep the exact pre-existing RNG draw sequence; set by
    /// `Session::build` from `faults.compressed`.
    pub compressed: bool,
    rng: Xoshiro256,
    /// In-flight delayed messages: (deliver at round, message). Delivery
    /// keys off `Network::rounds` so a message delayed by L rounds is
    /// visible to the L-th subsequent `recv_all`, however many local
    /// steps pass in between.
    delayed: Vec<(u64, Message)>,
    absent: Vec<bool>,
    /// Messages dropped so far (random drops + absence discards),
    /// across both payload kinds.
    pub dropped: u64,
    /// Messages that entered the delay buffer so far, across both
    /// payload kinds.
    pub delayed_total: u64,
    /// The `Payload::Encoded` subset of `dropped` (dense drops are
    /// `dropped - dropped_encoded`).
    pub dropped_encoded: u64,
    /// The `Payload::Encoded` subset of `delayed_total`.
    pub delayed_encoded: u64,
}

/// A point-in-time snapshot of what the fault fabric actually did,
/// split dense vs encoded — surfaced through `coordinator::Observer`
/// and the CLI summary so faulty runs report fabric activity instead of
/// only loss curves.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultCounters {
    pub dropped: u64,
    pub dropped_encoded: u64,
    pub delayed_total: u64,
    pub delayed_encoded: u64,
}

impl FaultPlan {
    pub fn new(
        k: usize,
        drop_prob: f64,
        delay_prob: f64,
        max_delay: u64,
        reorder_prob: f64,
        seed: u64,
    ) -> Self {
        assert!((0.0..=1.0).contains(&drop_prob), "drop_prob must be in [0,1]");
        assert!((0.0..=1.0).contains(&delay_prob), "delay_prob must be in [0,1]");
        assert!((0.0..=1.0).contains(&reorder_prob), "reorder_prob must be in [0,1]");
        assert!(max_delay >= 1, "max_delay must be >= 1 round");
        Self {
            drop_prob,
            delay_prob,
            max_delay,
            reorder_prob,
            compressed: false,
            rng: Xoshiro256::seed_from_u64(seed).fork(0xFA17),
            delayed: Vec::new(),
            absent: vec![false; k],
            dropped: 0,
            delayed_total: 0,
            dropped_encoded: 0,
            delayed_encoded: 0,
        }
    }

    /// Snapshot the dense/encoded counter split for reporting.
    pub fn counters(&self) -> FaultCounters {
        FaultCounters {
            dropped: self.dropped,
            dropped_encoded: self.dropped_encoded,
            delayed_total: self.delayed_total,
            delayed_encoded: self.delayed_encoded,
        }
    }

    /// Mark a worker as departed (true) or rejoined (false). While
    /// absent, all its links are down and it neither sends nor receives.
    pub fn set_absent(&mut self, w: usize, gone: bool) {
        self.absent[w] = gone;
    }

    pub fn is_absent(&self, w: usize) -> bool {
        self.absent[w]
    }

    pub fn any_absent(&self) -> bool {
        self.absent.iter().any(|&b| b)
    }

    /// Number of delayed messages still in flight.
    pub fn in_flight(&self) -> usize {
        self.delayed.len()
    }

    /// Serialize the mutable fault state (RNG stream, counters, absence
    /// flags, and every in-flight delayed message) for a `PDSGDM02`
    /// checkpoint. The rates themselves are config, covered by the
    /// session fingerprint, and are rebuilt at `Session::build`.
    pub fn state_save(&self) -> Vec<u8> {
        let mut w = StateWriter::new();
        w.tag("fault-plan");
        w.put_u64s(&self.rng.state());
        w.put_u64(self.dropped);
        w.put_u64(self.delayed_total);
        w.put_u64(self.dropped_encoded);
        w.put_u64(self.delayed_encoded);
        let absent: Vec<u64> = self.absent.iter().map(|&b| b as u64).collect();
        w.put_u64s(&absent);
        w.put_u64(self.delayed.len() as u64);
        for (due, m) in &self.delayed {
            w.put_u64(*due);
            w.put_u64(m.from as u64);
            w.put_u64(m.to as u64);
            match &m.payload {
                Payload::Dense(v) => {
                    w.put_u64(0);
                    w.put_f32s(v);
                }
                Payload::Encoded(b) => {
                    w.put_u64(1);
                    w.put_bytes(b);
                }
            }
        }
        w.into_bytes()
    }

    /// Restore the state written by [`FaultPlan::state_save`].
    pub fn state_load(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut r = StateReader::new(bytes);
        r.expect_tag("fault-plan")?;
        let s = r.take_u64s()?;
        let s: [u64; 4] = s
            .as_slice()
            .try_into()
            .map_err(|_| "fault-plan: rng state must be 4 words".to_string())?;
        self.rng = Xoshiro256::from_state(s);
        self.dropped = r.take_u64()?;
        self.delayed_total = r.take_u64()?;
        self.dropped_encoded = r.take_u64()?;
        self.delayed_encoded = r.take_u64()?;
        let absent = r.take_u64s()?;
        if absent.len() != self.absent.len() {
            return Err(format!(
                "fault-plan: saved K {} != live K {}",
                absent.len(),
                self.absent.len()
            ));
        }
        self.absent = absent.iter().map(|&x| x != 0).collect();
        let n = r.take_u64()? as usize;
        self.delayed.clear();
        for _ in 0..n {
            let due = r.take_u64()?;
            let from = r.take_u64()? as usize;
            let to = r.take_u64()? as usize;
            let payload = match r.take_u64()? {
                0 => Payload::Dense(Arc::new(r.take_f32s()?)),
                1 => Payload::Encoded(Arc::new(r.take_bytes()?.to_vec())),
                other => return Err(format!("fault-plan: unknown payload kind {other}")),
            };
            if from >= self.absent.len() || to >= self.absent.len() {
                return Err("fault-plan: delayed message endpoint out of range".to_string());
            }
            self.delayed.push((due, Message { from, to, payload }));
        }
        Ok(())
    }
}

/// Per-destination FIFO mailboxes over the topology's edges, with
/// cumulative traffic statistics.
#[derive(Debug)]
pub struct Network {
    k: usize,
    edges: Vec<Vec<usize>>, // adjacency (copied from the Graph)
    /// How messages move: the in-memory inbox (`InProc`, default — the
    /// exact legacy path) or a socket fabric between OS processes.
    transport: Box<dyn Transport>,
    /// Optional fault injector; `None` is the exact pre-fault fast path.
    faults: Option<FaultPlan>,
    /// Total payload bytes ever sent (sum over messages).
    pub total_bytes: u64,
    /// Per-worker bytes sent (for load-imbalance analysis, e.g. star hub).
    pub bytes_sent: Vec<u64>,
    /// Number of completed communication rounds (bulk exchanges).
    pub rounds: u64,
    /// Total messages sent.
    pub messages: u64,
}

impl Network {
    pub fn new(g: &Graph) -> Self {
        Self::with_transport(g, Box::new(InProc::new(g.k)))
    }

    /// A network whose messages move through `transport` instead of the
    /// in-memory inbox. Byte accounting, fault injection, and edge
    /// checks are identical — only delivery changes.
    pub fn with_transport(g: &Graph, transport: Box<dyn Transport>) -> Self {
        Self {
            k: g.k,
            edges: (0..g.k).map(|i| g.neighbors(i).to_vec()).collect(),
            transport,
            faults: None,
            total_bytes: 0,
            bytes_sent: vec![0; g.k],
            rounds: 0,
            messages: 0,
        }
    }

    /// Backend-specific access (round tags, death notices on the
    /// socket transport).
    pub fn transport_mut(&mut self) -> &mut dyn Transport {
        self.transport.as_mut()
    }

    /// The transport's robustness counters (all-zero for in-proc).
    pub fn transport_counters(&self) -> TransportCounters {
        self.transport.counters()
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.edges[i]
    }

    /// Degree of the busiest worker — the per-round link count the α–β
    /// model prices (on irregular graphs like the star this differs from
    /// any single node's degree, so never use `neighbors(0).len()`).
    pub fn max_degree(&self) -> usize {
        self.edges.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Install a fault injector. All subsequent sends/receives route
    /// through it; `None` (the default) is the exact legacy path.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        assert_eq!(plan.absent.len(), self.k, "fault plan sized for wrong K");
        self.faults = Some(plan);
    }

    /// Whether a fault plan is installed (gates the hardened recv paths
    /// in `algorithms::gossip` so faultless runs stay bit-identical).
    pub fn faults_active(&self) -> bool {
        self.faults.is_some()
    }

    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    pub fn fault_plan_mut(&mut self) -> Option<&mut FaultPlan> {
        self.faults.as_mut()
    }

    /// Whether worker `i` is currently departed (churn). Always false
    /// without a fault plan.
    pub fn is_absent(&self, i: usize) -> bool {
        self.faults.as_ref().is_some_and(|p| p.absent[i])
    }

    /// Neighbors of `i` that are currently present (equals `neighbors`
    /// exactly when no churn is active). Returns 0 links for an absent
    /// worker — all its edges are down.
    pub fn live_degree(&self, i: usize) -> usize {
        match self.faults.as_ref() {
            None => self.edges[i].len(),
            Some(p) if p.absent[i] => 0,
            Some(p) => self.edges[i].iter().filter(|&&j| !p.absent[j]).count(),
        }
    }

    /// Send a dense f32 payload from `from` to `to` (wire cost 4·d).
    pub fn send(&mut self, from: usize, to: usize, payload: Vec<f32>) {
        self.send_payload(from, to, Payload::Dense(Arc::new(payload)));
    }

    /// Send any payload; panics if (from, to) is not an edge —
    /// decentralized algorithms may only talk to graph neighbors. The
    /// wire charge is measured from the payload itself.
    pub fn send_payload(&mut self, from: usize, to: usize, payload: Payload) {
        assert!(
            self.edges[from].contains(&to),
            "({from} -> {to}) is not an edge of the topology"
        );
        if let Some(plan) = self.faults.as_mut() {
            if plan.absent[from] || plan.absent[to] {
                // Link down (churn): the message never enters the fabric,
                // so nothing is charged to the wire.
                plan.dropped += 1;
                if matches!(payload, Payload::Encoded(_)) {
                    plan.dropped_encoded += 1;
                }
                return;
            }
        }
        let wire_bytes = payload.wire_bytes() as u64;
        self.total_bytes += wire_bytes;
        self.bytes_sent[from] += wire_bytes;
        self.messages += 1;
        let msg = Message { from, to, payload };
        if let Some(plan) = self.faults.as_mut() {
            // Random faults apply to dense gossip always, and to encoded
            // traffic only when the plan opts in (see FaultPlan docs);
            // every draw is gated on its rate so a zero-rate plan
            // consumes no RNG and stays bit-identical to the `None` path.
            let encoded = matches!(msg.payload, Payload::Encoded(_));
            if !encoded || plan.compressed {
                if plan.drop_prob > 0.0 && plan.rng.next_f64() < plan.drop_prob {
                    // Lost in flight: the sender's NIC already paid for it.
                    plan.dropped += 1;
                    if encoded {
                        plan.dropped_encoded += 1;
                    }
                    return;
                }
                if plan.delay_prob > 0.0 && plan.rng.next_f64() < plan.delay_prob {
                    let lag = 1 + plan.rng.below(plan.max_delay as usize) as u64;
                    plan.delayed_total += 1;
                    if encoded {
                        plan.delayed_encoded += 1;
                    }
                    plan.delayed.push((self.rounds + lag, msg));
                    return;
                }
            }
        }
        self.transport.enqueue(msg);
    }

    /// Broadcast a dense payload from `from` to all its neighbors,
    /// charging wire bytes per link (gossip is point-to-point). The
    /// buffer is allocated once and shared across edges.
    pub fn broadcast(&mut self, from: usize, payload: &[f32]) {
        self.broadcast_shared(from, Arc::new(payload.to_vec()));
    }

    /// Zero-copy dense broadcast of an already-owned buffer.
    pub fn broadcast_shared(&mut self, from: usize, payload: Arc<Vec<f32>>) {
        self.broadcast_payload(from, Payload::Dense(payload));
    }

    /// Broadcast an encoded codec buffer; every link charges exactly
    /// `payload.len()` bytes.
    pub fn broadcast_encoded(&mut self, from: usize, payload: Arc<Vec<u8>>) {
        self.broadcast_payload(from, Payload::Encoded(payload));
    }

    fn broadcast_payload(&mut self, from: usize, payload: Payload) {
        for i in 0..self.edges[from].len() {
            let to = self.edges[from][i];
            self.send_payload(from, to, payload.clone());
        }
    }

    /// Drain worker `to`'s inbox. With a fault plan installed, due
    /// delayed messages are injected first (stale before fresh, so the
    /// hardened gossip paths that keep the *last* message per sender see
    /// the freshest data), then the whole batch may be reordered.
    pub fn recv_all(&mut self, to: usize) -> Vec<Message> {
        let rounds = self.rounds;
        let Some(plan) = self.faults.as_mut() else {
            return self.transport.drain(to);
        };
        let mut out: Vec<Message> = Vec::new();
        let mut i = 0;
        while i < plan.delayed.len() {
            if plan.delayed[i].1.to == to && plan.delayed[i].0 <= rounds {
                let (_, msg) = plan.delayed.remove(i);
                // Liveness is re-checked at delivery time: a message in
                // flight when either endpoint departed is lost.
                if plan.absent[msg.from] || plan.absent[to] {
                    plan.dropped += 1;
                    if matches!(msg.payload, Payload::Encoded(_)) {
                        plan.dropped_encoded += 1;
                    }
                } else {
                    out.push(msg);
                }
            } else {
                i += 1;
            }
        }
        out.extend(self.transport.drain(to));
        if plan.reorder_prob > 0.0 && out.len() > 1 && plan.rng.next_f64() < plan.reorder_prob {
            plan.rng.shuffle(&mut out);
        }
        out
    }

    /// Mark the end of a bulk exchange (one paper "communication round").
    pub fn end_round(&mut self) {
        self.rounds += 1;
        debug_assert!(self.transport.is_empty(), "round ended with undelivered messages");
    }

    pub fn total_megabytes(&self) -> f64 {
        self.total_bytes as f64 / (1024.0 * 1024.0)
    }
}

/// α–β communication cost model priced at the **busiest worker**: a
/// round in which that worker sends `b` bytes over `m` links costs
/// `alpha * m + b / beta` seconds (workers transmit in parallel; one
/// worker's links are serialized on its NIC — conservative, matches
/// all-neighbor gossip). Defaults approximate the paper's testbed NIC
/// (10 GbE-class).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Bandwidth (bytes/second).
    pub beta: f64,
    /// Simulated seconds for one local gradient step (compute).
    pub step_seconds: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            alpha: 50e-6,          // 50 us per message
            beta: 1.25e9,          // 10 Gbit/s
            step_seconds: 50e-3,   // 50 ms minibatch fwd+bwd
        }
    }
}

impl CostModel {
    /// Simulated time of one communication round in which the busiest
    /// worker sends `worker_bytes` bytes (its *measured* per-round
    /// traffic, in f64 — integer division truncated small compressed
    /// payloads to a zero bandwidth term) over `links` serial links.
    pub fn round_seconds(&self, links: usize, worker_bytes: f64) -> f64 {
        links as f64 * self.alpha + worker_bytes / self.beta
    }

    /// `round_seconds` under straggler skew: a synchronous gossip round
    /// completes only when the slowest participant does, so the whole
    /// round is scaled by that worker's latency multiplier. Callers must
    /// take the plain `round_seconds` path when no straggler model is
    /// configured — `x * 1.0` is bit-identical in IEEE 754, but the
    /// branch keeps the faultless code path literally unchanged.
    pub fn straggled_round_seconds(
        &self,
        links: usize,
        worker_bytes: f64,
        slowest_mult: f64,
    ) -> f64 {
        self.round_seconds(links, worker_bytes) * slowest_mult
    }

    /// Simulated time for `steps` local steps with a communication round
    /// every `period` steps, the busiest worker moving `worker_bytes`
    /// per round.
    pub fn simulated_seconds(
        &self,
        steps: u64,
        period: u64,
        links: usize,
        worker_bytes: f64,
    ) -> f64 {
        let rounds = steps / period.max(1);
        steps as f64 * self.step_seconds + rounds as f64 * self.round_seconds(links, worker_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ring8() -> Network {
        Network::new(&Topology::Ring.build(8, 0))
    }

    #[test]
    fn send_recv_roundtrip() {
        let mut net = ring8();
        net.send(0, 1, vec![1.0, 2.0]);
        net.send(2, 1, vec![3.0]);
        let msgs = net.recv_all(1);
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].from, 0);
        assert_eq!(msgs[1].payload.dense().unwrap(), &[3.0]);
        assert!(net.recv_all(1).is_empty(), "inbox drained");
    }

    #[test]
    #[should_panic(expected = "not an edge")]
    fn non_edge_send_panics() {
        let mut net = ring8();
        net.send(0, 4, vec![1.0]); // 0 and 4 are not ring neighbors
    }

    #[test]
    fn byte_accounting_is_exact() {
        let mut net = ring8();
        net.broadcast(0, &[1.0; 100]); // 400 wire bytes per link
        assert_eq!(net.total_bytes, 2 * 400); // ring degree 2
        assert_eq!(net.bytes_sent[0], 800);
        assert_eq!(net.messages, 2);
        assert!((net.total_megabytes() - 800.0 / 1048576.0).abs() < 1e-12);
    }

    #[test]
    fn encoded_payload_charges_its_length() {
        // The tentpole invariant: wire_bytes == payload.len(), measured,
        // not asserted by the sender.
        let mut net = ring8();
        let buf = Arc::new(vec![0xABu8; 57]);
        net.broadcast_encoded(0, Arc::clone(&buf));
        assert_eq!(net.total_bytes, 2 * 57);
        for to in [1usize, 7] {
            let msgs = net.recv_all(to);
            assert_eq!(msgs.len(), 1);
            assert_eq!(msgs[0].wire_bytes(), 57);
            assert_eq!(msgs[0].payload.encoded().unwrap(), buf.as_slice());
            assert!(msgs[0].payload.dense().is_none());
        }
        net.end_round();
    }

    #[test]
    fn max_degree_sees_the_star_hub() {
        let star = Network::new(&Topology::Star.build(8, 0));
        assert_eq!(star.max_degree(), 7); // hub, not a leaf
        assert_eq!(ring8().max_degree(), 2);
    }

    #[test]
    fn round_counter() {
        let mut net = ring8();
        net.broadcast(3, &[0.0]);
        net.recv_all(2);
        net.recv_all(4);
        net.end_round();
        assert_eq!(net.rounds, 1);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "undelivered")]
    fn end_round_checks_delivery() {
        let mut net = ring8();
        net.send(0, 1, vec![1.0]);
        net.end_round();
    }

    #[test]
    fn zero_rate_fault_plan_is_transparent_and_draws_no_rng() {
        let mut plain = ring8();
        let mut faulty = ring8();
        faulty.set_fault_plan(FaultPlan::new(8, 0.0, 0.0, 1, 0.0, 99));
        let before = faulty.fault_plan().unwrap().state_save();
        for net in [&mut plain, &mut faulty] {
            net.broadcast(0, &[1.0, 2.0, 3.0]);
            net.broadcast(3, &[4.0; 5]);
        }
        for to in 0..8 {
            let a = plain.recv_all(to);
            let b = faulty.recv_all(to);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.from, y.from);
                assert_eq!(x.payload.dense().unwrap(), y.payload.dense().unwrap());
            }
        }
        plain.end_round();
        faulty.end_round();
        assert_eq!(plain.total_bytes, faulty.total_bytes);
        assert_eq!(plain.messages, faulty.messages);
        // No RNG draw happened: the serialized stream state is untouched.
        assert_eq!(before, faulty.fault_plan().unwrap().state_save());
    }

    #[test]
    fn dropped_messages_are_charged_but_never_delivered() {
        let mut net = ring8();
        net.set_fault_plan(FaultPlan::new(8, 1.0, 0.0, 1, 0.0, 7));
        net.broadcast(0, &[1.0; 10]);
        assert_eq!(net.total_bytes, 2 * 40, "lost-in-flight still pays the wire");
        assert!(net.recv_all(1).is_empty());
        assert!(net.recv_all(7).is_empty());
        assert_eq!(net.fault_plan().unwrap().dropped, 2);
        net.end_round();
    }

    #[test]
    fn delayed_messages_arrive_a_later_round() {
        let mut net = ring8();
        net.set_fault_plan(FaultPlan::new(8, 0.0, 1.0, 1, 0.0, 7));
        net.send(0, 1, vec![5.0]);
        assert!(net.recv_all(1).is_empty(), "delayed past this round");
        assert_eq!(net.fault_plan().unwrap().in_flight(), 1);
        net.end_round();
        // Next round: the stashed message is due.
        let msgs = net.recv_all(1);
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].payload.dense().unwrap(), &[5.0]);
        assert_eq!(net.fault_plan().unwrap().in_flight(), 0);
        net.end_round();
    }

    #[test]
    fn absent_worker_links_are_down_and_uncharged() {
        let mut net = ring8();
        net.set_fault_plan(FaultPlan::new(8, 0.0, 0.0, 1, 0.0, 7));
        net.fault_plan_mut().unwrap().set_absent(1, true);
        assert!(net.is_absent(1));
        assert_eq!(net.live_degree(1), 0);
        assert_eq!(net.live_degree(0), 1, "edge to absent 1 is down");
        assert_eq!(net.live_degree(4), 2);
        net.send(0, 1, vec![1.0]); // into the void
        net.send(1, 2, vec![2.0]); // from the void
        net.send(0, 7, vec![3.0]); // live edge
        assert_eq!(net.total_bytes, 4, "only the live edge is charged");
        assert!(net.recv_all(1).is_empty());
        assert!(net.recv_all(2).is_empty());
        assert_eq!(net.recv_all(7).len(), 1);
        net.end_round();
        // Rejoin restores the full degree.
        net.fault_plan_mut().unwrap().set_absent(1, false);
        assert_eq!(net.live_degree(0), 2);
    }

    #[test]
    fn compressed_flag_gates_encoded_faults() {
        // Default: encoded traffic is exempt from random faults, and the
        // exemption consumes no RNG draws.
        let mut net = ring8();
        net.set_fault_plan(FaultPlan::new(8, 1.0, 0.0, 1, 0.0, 7));
        let before = net.fault_plan().unwrap().state_save();
        net.broadcast_encoded(0, Arc::new(vec![1u8; 16]));
        assert_eq!(net.recv_all(1).len(), 1, "exempt without the opt-in");
        assert_eq!(net.recv_all(7).len(), 1);
        assert_eq!(before, net.fault_plan().unwrap().state_save());
        net.end_round();

        // Opt-in: encoded messages now drop on the same 0xFA17 stream,
        // still pay the wire, and the encoded counter splits them out.
        let mut net = ring8();
        let mut plan = FaultPlan::new(8, 1.0, 0.0, 1, 0.0, 7);
        plan.compressed = true;
        net.set_fault_plan(plan);
        net.broadcast_encoded(0, Arc::new(vec![1u8; 16]));
        assert_eq!(net.total_bytes, 2 * 16, "lost-in-flight still pays the wire");
        assert!(net.recv_all(1).is_empty());
        assert!(net.recv_all(7).is_empty());
        let c = net.fault_plan().unwrap().counters();
        assert_eq!(c.dropped, 2);
        assert_eq!(c.dropped_encoded, 2);
        net.end_round();
    }

    #[test]
    fn encoded_delays_arrive_and_split_counters_roundtrip() {
        let mut net = ring8();
        let mut plan = FaultPlan::new(8, 0.0, 1.0, 1, 0.0, 7);
        plan.compressed = true;
        net.set_fault_plan(plan);
        net.send_payload(0, 1, Payload::Encoded(Arc::new(vec![9u8; 5])));
        assert!(net.recv_all(1).is_empty(), "delayed past this round");
        let c = net.fault_plan().unwrap().counters();
        assert_eq!((c.delayed_total, c.delayed_encoded), (1, 1));
        net.end_round();
        let msgs = net.recv_all(1);
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].payload.encoded().unwrap(), &[9u8; 5]);
        // The split counters survive a checkpoint round-trip.
        let saved = net.fault_plan().unwrap().state_save();
        let mut fresh = FaultPlan::new(8, 0.0, 1.0, 1, 0.0, 0);
        fresh.state_load(&saved).unwrap();
        assert_eq!(fresh.counters(), net.fault_plan().unwrap().counters());
        net.end_round();
    }

    #[test]
    fn fault_plan_state_roundtrips_in_flight_messages() {
        let mut net = ring8();
        net.set_fault_plan(FaultPlan::new(8, 0.3, 0.7, 3, 0.5, 41));
        for _ in 0..4 {
            net.broadcast(0, &[1.0; 8]);
            net.broadcast(2, &[2.0; 8]);
            net.recv_all(1);
            net.recv_all(3);
            net.recv_all(7);
            net.end_round();
        }
        net.fault_plan_mut().unwrap().set_absent(5, true);
        let saved = net.fault_plan().unwrap().state_save();
        let mut fresh = FaultPlan::new(8, 0.3, 0.7, 3, 0.5, 0);
        fresh.state_load(&saved).unwrap();
        assert_eq!(fresh.state_save(), saved, "save -> load -> save is a fixpoint");
        assert!(fresh.is_absent(5));
        assert_eq!(fresh.in_flight(), net.fault_plan().unwrap().in_flight());
        // Wrong-K plans are rejected, as are truncated payloads.
        let mut wrong_k = FaultPlan::new(4, 0.0, 0.0, 1, 0.0, 0);
        assert!(wrong_k.state_load(&saved).is_err());
        assert!(fresh.state_load(&saved[..saved.len() - 3]).is_err());
    }

    #[test]
    fn reorder_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let mut net = Network::new(&Topology::Complete.build(6, 0));
            net.set_fault_plan(FaultPlan::new(6, 0.0, 0.0, 1, 1.0, seed));
            for from in 1..6 {
                net.send(from, 0, vec![from as f32]);
            }
            let order: Vec<usize> = net.recv_all(0).iter().map(|m| m.from).collect();
            net.end_round();
            order
        };
        assert_eq!(run(11), run(11), "same seed, same shuffle");
        let mut sorted = run(11);
        sorted.sort_unstable();
        assert_eq!(sorted, vec![1, 2, 3, 4, 5], "reorder is a permutation");
    }

    #[test]
    fn straggler_parse_and_sample() {
        assert_eq!(
            StragglerDist::parse("constant:2.5").unwrap(),
            StragglerDist::Constant { factor: 2.5 }
        );
        assert_eq!(
            StragglerDist::parse("uniform:1,3").unwrap(),
            StragglerDist::Uniform { lo: 1.0, hi: 3.0 }
        );
        assert_eq!(
            StragglerDist::parse("lognormal:0,0.5").unwrap(),
            StragglerDist::LogNormal { mu: 0.0, sigma: 0.5 }
        );
        for bad in [
            "constant:-1", "constant:0", "uniform:3,1", "uniform:-1,2", "lognormal:0,-1",
            "gaussian:1", "constant", "uniform:1", "constant:abc",
        ] {
            assert!(StragglerDist::parse(bad).is_err(), "{bad} should be rejected");
        }
        let mut rng = Xoshiro256::seed_from_u64(5);
        let d = StragglerDist::parse("uniform:1,3").unwrap();
        let mults = d.sample_all(64, &mut rng);
        assert!(mults.iter().all(|&m| (1.0..=3.0).contains(&m)));
        let ln = StragglerDist::parse("lognormal:0,0.5").unwrap();
        assert!(ln.sample_all(64, &mut rng).iter().all(|&m| m > 0.0));
        assert_eq!(
            StragglerDist::Constant { factor: 4.0 }.sample(&mut rng),
            4.0
        );
    }

    #[test]
    fn straggled_round_costs_scale_with_slowest() {
        let cm = CostModel::default();
        let base = cm.round_seconds(2, 1_000_000.0);
        assert_eq!(cm.straggled_round_seconds(2, 1_000_000.0, 1.0), base);
        assert!((cm.straggled_round_seconds(2, 1_000_000.0, 3.0) - 3.0 * base).abs() < 1e-15);
    }

    #[test]
    fn cost_model_scales_linearly() {
        let cm = CostModel::default();
        let r1 = cm.round_seconds(2, 1_000_000.0);
        let r2 = cm.round_seconds(2, 2_000_000.0);
        assert!(r2 > r1);
        assert!((r2 - r1 - 1_000_000.0 / cm.beta).abs() < 1e-12);
    }

    #[test]
    fn tiny_payloads_keep_a_nonzero_bandwidth_term() {
        // Regression: integer bytes_per_link truncated (e.g. Sign at
        // small d) to 0, silently zeroing the bandwidth term.
        let cm = CostModel::default();
        let latency_only = 2.0 * cm.alpha;
        assert!(cm.round_seconds(2, 0.5) > latency_only);
        assert!((cm.round_seconds(2, 0.5) - latency_only - 0.5 / cm.beta).abs() < 1e-18);
    }

    #[test]
    fn periodic_communication_saves_simulated_time() {
        // The motivation for p > 1: same steps, fewer rounds, less time.
        let cm = CostModel::default();
        let t_p1 = cm.simulated_seconds(1000, 1, 2, 8_000_000.0);
        let t_p8 = cm.simulated_seconds(1000, 8, 2, 8_000_000.0);
        assert!(t_p8 < t_p1);
        let compute_only = 1000.0 * cm.step_seconds;
        assert!(t_p8 < compute_only + (1000 / 8 + 1) as f64 * cm.round_seconds(2, 8_000_000.0));
    }
}
