//! Real multi-process transport behind the `Network` abstraction.
//!
//! Everything the simulator moves through an in-memory inbox can also
//! move over an actual wire: this module defines the [`Transport`]
//! trait (how a [`Network`](crate::comm::Network) delivers
//! [`Message`]s), the [`InProc`] backend (the legacy `VecDeque` inbox,
//! byte-for-byte identical to the pre-trait code), and
//! [`SocketTransport`], a TCP / Unix-domain-socket backend carrying
//! length-prefixed, CRC32-checked frames between worker OS processes.
//!
//! The contract (DESIGN.md §10): a loopback socket run on the same
//! seed reproduces the in-memory run **bit-identically** — same CSV,
//! same byte accounting, same sim-seconds — because every worker
//! process replays the exact sequential schedule (`engine::
//! momentum_row_step` + the gossip term order of `GossipState::mix`)
//! for its own row, and the coordinator replays the exact `Session`
//! accounting over a real in-proc `Network`.
//!
//! Robustness is built in, not bolted on: connect/send retries with
//! exponential backoff + deterministic jitter, read/write deadlines on
//! every socket op, heartbeat frames with a miss threshold, and
//! peer-death detection that maps a lost peer onto the existing
//! churn machinery (`FaultPlan::set_absent` → renormalized mixing), so
//! a crashed worker degrades the round instead of hanging the fabric.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::algorithms::{Algorithm, StepStats};
use crate::comm::{FaultPlan, Message, Network, Payload};
use crate::config::{ExperimentConfig, TransportBackend, TransportConfig};
use crate::grad::GradientSource;
use crate::topology::MixWeights;

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected polynomial) — hand-rolled, std-only.
// ---------------------------------------------------------------------------

const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut b = 0;
        while b < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            b += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC32-IEEE of `bytes` (the common `cksum`/zlib polynomial).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------------
// Frame codec.
// ---------------------------------------------------------------------------

/// Largest frame body this implementation accepts (guards against a
/// corrupt length prefix allocating gigabytes).
pub const MAX_FRAME_BYTES: usize = 1 << 28; // 256 MiB

/// Fixed header after the length prefix: kind u8 + from u32 + to u32 +
/// step u64.
const FRAME_HEADER: usize = 1 + 4 + 4 + 8;
/// Minimum body length: header + trailing CRC32.
const MIN_BODY: usize = FRAME_HEADER + 4;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Worker → coordinator (or worker → worker) introduction. Payload:
    /// UTF-8 listen address (may be empty on worker-to-worker links).
    Hello = 1,
    /// Coordinator → worker: the full worker address book. Payload:
    /// UTF-8 lines `"<idx> <addr>"`.
    PeerTable = 2,
    /// One gossip payload for communication round `step`. Payload:
    /// f32-LE parameter vector.
    Dense = 3,
    /// Liveness probe; empty payload.
    Heartbeat = 4,
    /// Worker → coordinator row report at eval step `step`. Payload:
    /// `loss f64 | d u32 | x f32·d | n u32 | counters u64·n`.
    Eval = 5,
    /// Graceful goodbye; empty payload.
    Bye = 6,
}

impl FrameKind {
    pub fn from_u8(b: u8) -> Option<FrameKind> {
        match b {
            1 => Some(FrameKind::Hello),
            2 => Some(FrameKind::PeerTable),
            3 => Some(FrameKind::Dense),
            4 => Some(FrameKind::Heartbeat),
            5 => Some(FrameKind::Eval),
            6 => Some(FrameKind::Bye),
            _ => None,
        }
    }
}

/// One wire frame. Layout:
/// `len u32 LE | kind u8 | from u32 | to u32 | step u64 | payload | crc32 u32`
/// where `len` counts everything after itself (so `kind..=crc`) and the
/// CRC covers `kind..payload` (everything the CRC itself doesn't).
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub from: u32,
    pub to: u32,
    pub step: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(kind: FrameKind, from: usize, to: usize, step: u64, payload: Vec<u8>) -> Frame {
        Frame { kind, from: from as u32, to: to as u32, step, payload }
    }
}

/// Why a frame could not be decoded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Not enough bytes yet — keep the buffer, read more.
    Incomplete,
    /// The stream is damaged (bad CRC, bad kind, absurd length). The
    /// link cannot be resynchronized and should be torn down.
    Corrupt(String),
}

pub fn encode_frame(f: &Frame) -> Vec<u8> {
    let body_len = FRAME_HEADER + f.payload.len() + 4;
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(f.kind as u8);
    out.extend_from_slice(&f.from.to_le_bytes());
    out.extend_from_slice(&f.to.to_le_bytes());
    out.extend_from_slice(&f.step.to_le_bytes());
    out.extend_from_slice(&f.payload);
    let crc = crc32(&out[4..]);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Decode one frame from the front of `buf`. Returns the frame and the
/// number of bytes consumed, `Incomplete` if more bytes are needed, or
/// `Corrupt` if the stream is unrecoverable.
pub fn decode_frame(buf: &[u8]) -> Result<(Frame, usize), FrameError> {
    if buf.len() < 4 {
        return Err(FrameError::Incomplete);
    }
    let body_len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if body_len < MIN_BODY {
        return Err(FrameError::Corrupt(format!("frame body {body_len} below minimum {MIN_BODY}")));
    }
    if body_len > MAX_FRAME_BYTES {
        return Err(FrameError::Corrupt(format!(
            "frame body {body_len} exceeds cap {MAX_FRAME_BYTES}"
        )));
    }
    if buf.len() < 4 + body_len {
        return Err(FrameError::Incomplete);
    }
    let body = &buf[4..4 + body_len];
    let (content, crc_bytes) = body.split_at(body_len - 4);
    let want = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
    let got = crc32(content);
    if want != got {
        return Err(FrameError::Corrupt(format!("crc mismatch: stored {want:#010x} computed {got:#010x}")));
    }
    let kind = FrameKind::from_u8(content[0])
        .ok_or_else(|| FrameError::Corrupt(format!("unknown frame kind {}", content[0])))?;
    let from = u32::from_le_bytes([content[1], content[2], content[3], content[4]]);
    let to = u32::from_le_bytes([content[5], content[6], content[7], content[8]]);
    let step = u64::from_le_bytes([
        content[9], content[10], content[11], content[12], content[13], content[14], content[15],
        content[16],
    ]);
    let payload = content[FRAME_HEADER..].to_vec();
    Ok((Frame { kind, from, to, step, payload }, 4 + body_len))
}

/// Dense gossip payload: raw f32 little-endian, `4·d` bytes — the same
/// wire size `Payload::Dense::wire_bytes` charges, so measured socket
/// traffic equals the simulated byte accounting.
pub fn encode_dense(x: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 * x.len());
    for v in x {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub fn decode_dense(b: &[u8]) -> Result<Vec<f32>, String> {
    if b.len() % 4 != 0 {
        return Err(format!("dense payload length {} not a multiple of 4", b.len()));
    }
    Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

// ---------------------------------------------------------------------------
// Transport counters.
// ---------------------------------------------------------------------------

/// Cumulative robustness counters, surfaced through
/// `Observer::on_transport_counters` into the CLI summary and
/// `/metrics` (DESIGN.md §10).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportCounters {
    pub connect_retries: u64,
    pub send_retries: u64,
    pub reconnects: u64,
    pub timeouts: u64,
    pub heartbeats_sent: u64,
    pub heartbeat_misses: u64,
    pub peers_dead: u64,
    pub frames_sent: u64,
    pub frames_received: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
    pub crc_errors: u64,
}

impl TransportCounters {
    pub fn merge(&mut self, o: &TransportCounters) {
        self.connect_retries += o.connect_retries;
        self.send_retries += o.send_retries;
        self.reconnects += o.reconnects;
        self.timeouts += o.timeouts;
        self.heartbeats_sent += o.heartbeats_sent;
        self.heartbeat_misses += o.heartbeat_misses;
        self.peers_dead += o.peers_dead;
        self.frames_sent += o.frames_sent;
        self.frames_received += o.frames_received;
        self.bytes_sent += o.bytes_sent;
        self.bytes_received += o.bytes_received;
        self.crc_errors += o.crc_errors;
    }

    fn fields(&self) -> [u64; 12] {
        [
            self.connect_retries,
            self.send_retries,
            self.reconnects,
            self.timeouts,
            self.heartbeats_sent,
            self.heartbeat_misses,
            self.peers_dead,
            self.frames_sent,
            self.frames_received,
            self.bytes_sent,
            self.bytes_received,
            self.crc_errors,
        ]
    }

    /// `(snake_case name, value)` pairs in wire order — the single list
    /// the CLI summary and the `/metrics` exporter both walk, so a new
    /// counter shows up everywhere by construction.
    pub fn named(&self) -> [(&'static str, u64); 12] {
        let f = self.fields();
        [
            ("connect_retries", f[0]),
            ("send_retries", f[1]),
            ("reconnects", f[2]),
            ("timeouts", f[3]),
            ("heartbeats_sent", f[4]),
            ("heartbeat_misses", f[5]),
            ("peers_dead", f[6]),
            ("frames_sent", f[7]),
            ("frames_received", f[8]),
            ("bytes_sent", f[9]),
            ("bytes_received", f[10]),
            ("crc_errors", f[11]),
        ]
    }

    /// Count-prefixed u64 list; decoders skip fields they don't know,
    /// so old readers tolerate new counters.
    pub fn encode(&self) -> Vec<u8> {
        let fs = self.fields();
        let mut out = Vec::with_capacity(4 + 8 * fs.len());
        out.extend_from_slice(&(fs.len() as u32).to_le_bytes());
        for f in fs {
            out.extend_from_slice(&f.to_le_bytes());
        }
        out
    }

    /// Decode from the front of `b`; returns (counters, bytes consumed).
    pub fn decode(b: &[u8]) -> Result<(TransportCounters, usize), String> {
        if b.len() < 4 {
            return Err("counters: truncated count".into());
        }
        let n = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;
        if n > 1024 {
            return Err(format!("counters: absurd field count {n}"));
        }
        let need = 4 + 8 * n;
        if b.len() < need {
            return Err("counters: truncated fields".into());
        }
        let mut vals = [0u64; 12];
        for i in 0..n.min(12) {
            let o = 4 + 8 * i;
            vals[i] = u64::from_le_bytes([
                b[o], b[o + 1], b[o + 2], b[o + 3], b[o + 4], b[o + 5], b[o + 6], b[o + 7],
            ]);
        }
        let c = TransportCounters {
            connect_retries: vals[0],
            send_retries: vals[1],
            reconnects: vals[2],
            timeouts: vals[3],
            heartbeats_sent: vals[4],
            heartbeat_misses: vals[5],
            peers_dead: vals[6],
            frames_sent: vals[7],
            frames_received: vals[8],
            bytes_sent: vals[9],
            bytes_received: vals[10],
            crc_errors: vals[11],
        };
        Ok((c, need))
    }
}

/// Eval report payload: `loss f64 | d u32 | x f32·d | counters`.
pub fn encode_eval(loss: f64, x: &[f32], counters: &TransportCounters) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + 4 + 4 * x.len() + 4 + 96);
    out.extend_from_slice(&loss.to_le_bytes());
    out.extend_from_slice(&(x.len() as u32).to_le_bytes());
    for v in x {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(&counters.encode());
    out
}

pub fn decode_eval(b: &[u8]) -> Result<(f64, Vec<f32>, TransportCounters), String> {
    if b.len() < 12 {
        return Err("eval payload: truncated header".into());
    }
    let loss = f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]);
    let d = u32::from_le_bytes([b[8], b[9], b[10], b[11]]) as usize;
    if d > MAX_FRAME_BYTES / 4 {
        return Err(format!("eval payload: absurd dimension {d}"));
    }
    let xs_end = 12 + 4 * d;
    if b.len() < xs_end {
        return Err("eval payload: truncated parameter vector".into());
    }
    let x = decode_dense(&b[12..xs_end])?;
    let (counters, _) = TransportCounters::decode(&b[xs_end..])?;
    Ok((loss, x, counters))
}

// ---------------------------------------------------------------------------
// Transport trait + the in-memory backend.
// ---------------------------------------------------------------------------

/// How a `Network` moves messages between workers. `InProc` is the
/// default (the legacy in-memory inbox); `SocketTransport` puts the
/// same messages on a real wire between OS processes.
pub trait Transport: std::fmt::Debug + Send {
    /// Queue `msg` for delivery to `msg.to`.
    fn enqueue(&mut self, msg: Message);
    /// Remove and return every deliverable message addressed to `to`,
    /// in the transport's canonical order (ascending sender for the
    /// socket backend; arrival order for in-proc).
    fn drain(&mut self, to: usize) -> Vec<Message>;
    /// True when no undelivered messages remain (end-of-round check).
    fn is_empty(&self) -> bool;
    /// Robustness counters; all-zero for the in-memory backend.
    fn counters(&self) -> TransportCounters {
        TransportCounters::default()
    }
    /// Escape hatch for backend-specific control (round tags, death
    /// notices) without widening the trait.
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}

/// The legacy per-destination FIFO mailboxes — the Arc fast path. This
/// is exactly the `Vec<VecDeque<Message>>` the `Network` used to own,
/// so the default path is byte-for-byte identical to the pre-trait
/// code.
#[derive(Debug, Default)]
pub struct InProc {
    inbox: Vec<VecDeque<Message>>,
}

impl InProc {
    pub fn new(k: usize) -> InProc {
        InProc { inbox: (0..k).map(|_| VecDeque::new()).collect() }
    }
}

impl Transport for InProc {
    fn enqueue(&mut self, msg: Message) {
        self.inbox[msg.to].push_back(msg);
    }

    fn drain(&mut self, to: usize) -> Vec<Message> {
        self.inbox[to].drain(..).collect()
    }

    fn is_empty(&self) -> bool {
        self.inbox.iter().all(|q| q.is_empty())
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

// ---------------------------------------------------------------------------
// Backoff, streams, listeners.
// ---------------------------------------------------------------------------

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Exponential backoff with deterministic jitter: attempt `a` waits in
/// `[cap/2, cap]` where `cap = min(base · 2^a, max)`. Jitter is hashed
/// from `(attempt, salt)` so tests are reproducible but concurrent
/// workers (distinct salts) still desynchronize.
pub fn backoff_delay_ms(attempt: u32, base_ms: u64, max_ms: u64, salt: u64) -> u64 {
    let base = base_ms.max(1);
    let cap = base.saturating_mul(1u64 << attempt.min(20)).min(max_ms.max(base)).max(1);
    let half = cap / 2;
    half + splitmix64(salt ^ ((attempt as u64) << 32)) % (cap - half + 1)
}

/// A connected byte stream over either backend.
#[derive(Debug)]
pub enum Stream {
    Tcp(std::net::TcpStream),
    Unix(std::os::unix::net::UnixStream),
}

impl Stream {
    /// Connect to `"tcp:host:port"` or `"unix:/path"`, with a connect
    /// timeout for TCP (Unix sockets connect locally or fail fast).
    pub fn connect(addr: &str, timeout: Duration) -> std::io::Result<Stream> {
        if let Some(hostport) = addr.strip_prefix("tcp:") {
            use std::net::ToSocketAddrs;
            let sa = hostport
                .to_socket_addrs()?
                .next()
                .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidInput, "no address"))?;
            let s = std::net::TcpStream::connect_timeout(&sa, timeout)?;
            s.set_nodelay(true)?;
            Ok(Stream::Tcp(s))
        } else if let Some(path) = addr.strip_prefix("unix:") {
            Ok(Stream::Unix(std::os::unix::net::UnixStream::connect(path)?))
        } else {
            Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("address {addr:?} must start with tcp: or unix:"),
            ))
        }
    }

    /// Read/write deadlines applied to every subsequent socket op.
    pub fn set_deadlines(&self, read: Option<Duration>, write: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => {
                s.set_read_timeout(read)?;
                s.set_write_timeout(write)
            }
            Stream::Unix(s) => {
                s.set_read_timeout(read)?;
                s.set_write_timeout(write)
            }
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A bound accept socket over either backend.
#[derive(Debug)]
pub enum Listener {
    Tcp(std::net::TcpListener),
    Unix(std::os::unix::net::UnixListener, PathBuf),
}

impl Listener {
    pub fn bind(backend: TransportBackend, host: &str, sock_path: &Path) -> Result<Listener, String> {
        match backend {
            TransportBackend::Tcp => {
                let l = std::net::TcpListener::bind((host, 0))
                    .map_err(|e| format!("bind tcp {host}: {e}"))?;
                l.set_nonblocking(true).map_err(|e| format!("tcp nonblocking: {e}"))?;
                Ok(Listener::Tcp(l))
            }
            TransportBackend::Unix => {
                let _ = std::fs::remove_file(sock_path);
                let l = std::os::unix::net::UnixListener::bind(sock_path)
                    .map_err(|e| format!("bind unix {sock_path:?}: {e}"))?;
                l.set_nonblocking(true).map_err(|e| format!("unix nonblocking: {e}"))?;
                Ok(Listener::Unix(l, sock_path.to_path_buf()))
            }
        }
    }

    /// The `tcp:`/`unix:` address peers dial to reach this listener.
    pub fn addr_string(&self) -> Result<String, String> {
        match self {
            Listener::Tcp(l) => {
                let a = l.local_addr().map_err(|e| e.to_string())?;
                Ok(format!("tcp:{a}"))
            }
            Listener::Unix(_, p) => Ok(format!("unix:{}", p.display())),
        }
    }

    /// Accept one connection, polling until `deadline`.
    pub fn accept(&self, deadline: Instant) -> Result<Stream, String> {
        loop {
            let r = match self {
                Listener::Tcp(l) => l.accept().map(|(s, _)| {
                    let _ = s.set_nodelay(true);
                    Stream::Tcp(s)
                }),
                Listener::Unix(l, _) => l.accept().map(|(s, _)| Stream::Unix(s)),
            };
            match r {
                Ok(s) => return Ok(s),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err("accept timed out".into());
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(format!("accept: {e}")),
            }
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix(_, p) = self {
            let _ = std::fs::remove_file(&*p);
        }
    }
}

fn io_timeout(tcfg: &TransportConfig) -> Duration {
    Duration::from_millis(tcfg.io_timeout_ms.max(1))
}

/// Dial `addr` with per-attempt backoff + jitter; counts retries.
pub fn connect_with_retry(
    addr: &str,
    tcfg: &TransportConfig,
    salt: u64,
    counters: &mut TransportCounters,
) -> Result<Stream, String> {
    let mut last = String::new();
    for attempt in 0..=tcfg.connect_retries {
        match Stream::connect(addr, io_timeout(tcfg)) {
            Ok(s) => {
                s.set_deadlines(Some(io_timeout(tcfg)), Some(io_timeout(tcfg)))
                    .map_err(|e| format!("deadlines on {addr}: {e}"))?;
                return Ok(s);
            }
            Err(e) => {
                last = e.to_string();
                if attempt < tcfg.connect_retries {
                    counters.connect_retries += 1;
                    std::thread::sleep(Duration::from_millis(backoff_delay_ms(
                        attempt,
                        tcfg.retry_base_ms,
                        tcfg.retry_max_ms,
                        salt,
                    )));
                }
            }
        }
    }
    Err(format!("connect {addr}: gave up after {} attempts: {last}", tcfg.connect_retries + 1))
}

// ---------------------------------------------------------------------------
// PeerLink: one framed, supervised connection.
// ---------------------------------------------------------------------------

/// What `PeerLink::pump` produced this poll.
#[derive(Debug)]
enum LinkEvent {
    Frame(Frame),
    /// Nothing available inside the poll slice.
    Idle,
    /// The peer is gone (EOF, hard error, or corrupt stream).
    Dead(String),
}

#[derive(Debug)]
struct PeerLink {
    peer: usize,
    stream: Option<Stream>,
    /// Dial address, when this side is the dialer (enables reconnect).
    addr: Option<String>,
    buf: Vec<u8>,
    last_heard: Instant,
    last_sent: Instant,
    misses: u32,
    salt: u64,
}

impl PeerLink {
    fn new(peer: usize, stream: Stream, addr: Option<String>, salt: u64) -> PeerLink {
        let now = Instant::now();
        PeerLink { peer, stream: Some(stream), addr, buf: Vec::new(), last_heard: now, last_sent: now, misses: 0, salt }
    }

    fn alive(&self) -> bool {
        self.stream.is_some()
    }

    fn kill(&mut self) {
        self.stream = None;
        self.buf.clear();
    }

    /// Write a whole frame, retrying transient timeouts with backoff
    /// and attempting one reconnect (fresh dial + Hello) on a hard
    /// error when this side owns the dial address. Returns false when
    /// the link is declared dead.
    fn send_frame(&mut self, f: &Frame, tcfg: &TransportConfig, c: &mut TransportCounters) -> bool {
        let bytes = encode_frame(f);
        for attempt in 0..=tcfg.connect_retries {
            let Some(s) = self.stream.as_mut() else { return false };
            match s.write_all(&bytes).and_then(|()| s.flush()) {
                Ok(()) => {
                    self.last_sent = Instant::now();
                    c.frames_sent += 1;
                    c.bytes_sent += bytes.len() as u64;
                    return true;
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    c.send_retries += 1;
                    c.timeouts += 1;
                    std::thread::sleep(Duration::from_millis(backoff_delay_ms(
                        attempt,
                        tcfg.retry_base_ms,
                        tcfg.retry_max_ms,
                        self.salt,
                    )));
                }
                Err(_) => {
                    // Hard error: try to re-dial once, then resend from
                    // the top of the retry budget.
                    if !self.reconnect(tcfg, c) {
                        self.kill();
                        return false;
                    }
                }
            }
        }
        self.kill();
        false
    }

    fn reconnect(&mut self, tcfg: &TransportConfig, c: &mut TransportCounters) -> bool {
        let Some(addr) = self.addr.clone() else { return false };
        match connect_with_retry(&addr, tcfg, self.salt ^ 0xDEAD, c) {
            Ok(s) => {
                self.stream = Some(s);
                self.buf.clear();
                c.reconnects += 1;
                self.last_heard = Instant::now();
                self.misses = 0;
                true
            }
            Err(_) => false,
        }
    }

    /// Poll the socket for up to `slice`, append whatever arrived, and
    /// decode at most one frame from the front of the buffer.
    fn pump(&mut self, slice: Duration, c: &mut TransportCounters) -> LinkEvent {
        // A complete frame may already be buffered from a prior poll.
        match self.try_decode(c) {
            Some(ev) => return ev,
            None => {}
        }
        let Some(s) = self.stream.as_mut() else { return LinkEvent::Dead("link closed".into()) };
        if s.set_deadlines(Some(slice.max(Duration::from_millis(1))), None).is_err() {
            self.kill();
            return LinkEvent::Dead("deadline set failed".into());
        }
        let mut tmp = [0u8; 64 * 1024];
        match s.read(&mut tmp) {
            Ok(0) => {
                self.kill();
                LinkEvent::Dead("peer closed connection".into())
            }
            Ok(n) => {
                c.bytes_received += n as u64;
                self.buf.extend_from_slice(&tmp[..n]);
                self.last_heard = Instant::now();
                self.misses = 0;
                self.try_decode(c).unwrap_or(LinkEvent::Idle)
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                LinkEvent::Idle
            }
            Err(e) => {
                self.kill();
                LinkEvent::Dead(format!("read: {e}"))
            }
        }
    }

    fn try_decode(&mut self, c: &mut TransportCounters) -> Option<LinkEvent> {
        match decode_frame(&self.buf) {
            Ok((f, used)) => {
                self.buf.drain(..used);
                c.frames_received += 1;
                Some(LinkEvent::Frame(f))
            }
            Err(FrameError::Incomplete) => None,
            Err(FrameError::Corrupt(why)) => {
                c.crc_errors += 1;
                self.kill();
                Some(LinkEvent::Dead(format!("corrupt stream: {why}")))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// SocketTransport: the worker-process backend.
// ---------------------------------------------------------------------------

/// Socket backend for ONE worker process: a framed link per topology
/// neighbor. `enqueue` ships this worker's round payload; `drain`
/// blocks until every live neighbor's payload for the current round
/// arrived (or the neighbor is declared dead via heartbeat misses /
/// the round deadline), returning messages in ascending sender order —
/// the same order the in-proc send loop produces.
pub struct SocketTransport {
    me: usize,
    cfg: TransportConfig,
    links: BTreeMap<usize, PeerLink>,
    /// Round tag stamped on outgoing Dense frames and required on
    /// incoming ones.
    round_step: u64,
    /// Current round's received payloads, keyed by sender.
    pending: BTreeMap<usize, Vec<f32>>,
    /// Peers declared dead but not yet reported via `take_newly_dead`.
    fresh_deaths: BTreeSet<usize>,
    counters: TransportCounters,
}

impl std::fmt::Debug for SocketTransport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SocketTransport")
            .field("me", &self.me)
            .field("links", &self.links.keys().collect::<Vec<_>>())
            .field("round_step", &self.round_step)
            .finish()
    }
}

impl SocketTransport {
    pub fn new(me: usize, cfg: TransportConfig) -> SocketTransport {
        SocketTransport {
            me,
            cfg,
            links: BTreeMap::new(),
            round_step: 0,
            pending: BTreeMap::new(),
            fresh_deaths: BTreeSet::new(),
            counters: TransportCounters::default(),
        }
    }

    fn add_link(&mut self, peer: usize, stream: Stream, addr: Option<String>) {
        let salt = (self.me as u64) << 32 | peer as u64;
        self.links.insert(peer, PeerLink::new(peer, stream, addr, salt));
    }

    /// Tag the upcoming communication round. Must be called before the
    /// round's broadcast.
    pub fn begin_round(&mut self, step: u64) {
        self.round_step = step;
    }

    /// Peers that died since the last call — the caller maps these onto
    /// `FaultPlan::set_absent` before mixing.
    pub fn take_newly_dead(&mut self) -> Vec<usize> {
        let out: Vec<usize> = self.fresh_deaths.iter().copied().collect();
        self.fresh_deaths.clear();
        out
    }

    pub fn live_peers(&self) -> usize {
        self.links.values().filter(|l| l.alive()).count()
    }

    fn declare_dead(&mut self, peer: usize, _why: &str) {
        if let Some(l) = self.links.get_mut(&peer) {
            if l.alive() {
                l.kill();
            }
        }
        if self.fresh_deaths.insert(peer) {
            self.counters.peers_dead += 1;
        }
    }

    /// Send `Bye` on every live link (graceful teardown).
    pub fn send_bye(&mut self) {
        let cfg = self.cfg.clone();
        let mut c = std::mem::take(&mut self.counters);
        for l in self.links.values_mut() {
            if l.alive() {
                let f = Frame::new(FrameKind::Bye, 0, 0, 0, Vec::new());
                let _ = l.send_frame(&f, &cfg, &mut c);
            }
        }
        self.counters = c;
    }
}

impl Transport for SocketTransport {
    fn enqueue(&mut self, msg: Message) {
        let x = msg
            .payload
            .dense()
            .expect("socket transport carries dense gossip only (validated by config)");
        let frame = Frame::new(FrameKind::Dense, msg.from, msg.to, self.round_step, encode_dense(x));
        let cfg = self.cfg.clone();
        let mut c = std::mem::take(&mut self.counters);
        let ok = match self.links.get_mut(&msg.to) {
            Some(l) if l.alive() => l.send_frame(&frame, &cfg, &mut c),
            _ => false,
        };
        self.counters = c;
        if !ok {
            self.declare_dead(msg.to, "send failed");
        }
    }

    fn drain(&mut self, to: usize) -> Vec<Message> {
        assert_eq!(to, self.me, "a worker process drains only its own mailbox");
        let cfg = self.cfg.clone();
        let heartbeat = Duration::from_millis(cfg.heartbeat_ms.max(1));
        let deadline = Instant::now() + Duration::from_millis(cfg.round_timeout_ms.max(1));
        let slice = Duration::from_millis(10);
        loop {
            let waiting: Vec<usize> = self
                .links
                .iter()
                .filter(|(p, l)| l.alive() && !self.pending.contains_key(*p))
                .map(|(p, _)| *p)
                .collect();
            if waiting.is_empty() {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                // Hard round deadline: whoever is still silent is gone.
                let mut c = std::mem::take(&mut self.counters);
                c.timeouts += waiting.len() as u64;
                self.counters = c;
                for p in waiting {
                    self.declare_dead(p, "round deadline");
                }
                break;
            }
            for p in waiting {
                let mut c = std::mem::take(&mut self.counters);
                let link = self.links.get_mut(&p).expect("link exists");
                // Keepalive: prove liveness to a peer we're waiting on.
                if now.duration_since(link.last_sent) >= heartbeat && link.alive() {
                    let hb = Frame::new(FrameKind::Heartbeat, self.me, p, 0, Vec::new());
                    if link.send_frame(&hb, &cfg, &mut c) {
                        c.heartbeats_sent += 1;
                    }
                }
                let ev = link.pump(slice, &mut c);
                // Miss accounting: one miss per elapsed heartbeat
                // interval of silence; threshold crossings kill the link.
                let silent = now.duration_since(link.last_heard);
                let intervals = (silent.as_millis() as u64) / cfg.heartbeat_ms.max(1);
                let mut crossed = false;
                if intervals > link.misses as u64 {
                    link.misses = intervals as u32;
                    c.heartbeat_misses += 1;
                    crossed = link.misses >= cfg.heartbeat_misses;
                }
                self.counters = c;
                match ev {
                    LinkEvent::Frame(f) => self.handle_frame(p, f),
                    LinkEvent::Idle => {
                        if crossed {
                            self.declare_dead(p, "heartbeat misses");
                        }
                    }
                    LinkEvent::Dead(why) => self.declare_dead(p, &why),
                }
            }
        }
        let me = self.me;
        std::mem::take(&mut self.pending)
            .into_iter()
            .map(|(from, x)| Message { from, to: me, payload: Payload::Dense(Arc::new(x)) })
            .collect()
    }

    fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    fn counters(&self) -> TransportCounters {
        self.counters
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

impl SocketTransport {
    fn handle_frame(&mut self, peer: usize, f: Frame) {
        match f.kind {
            FrameKind::Dense => {
                if f.step != self.round_step {
                    // Per-link FIFO makes this unreachable in a healthy
                    // run; a tagged mismatch means the stream is skewed.
                    self.counters.crc_errors += 1;
                    self.declare_dead(peer, "round tag mismatch");
                    return;
                }
                match decode_dense(&f.payload) {
                    Ok(x) => {
                        self.pending.insert(f.from as usize, x);
                    }
                    Err(_) => {
                        self.counters.crc_errors += 1;
                        self.declare_dead(peer, "bad dense payload");
                    }
                }
            }
            FrameKind::Heartbeat => {}
            FrameKind::Bye => self.declare_dead(peer, "peer said goodbye"),
            _ => {
                // Hello/PeerTable/Eval never appear on worker-worker
                // links after the handshake.
                self.declare_dead(peer, "protocol violation");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Single-row gossip mixing (the worker-process half of GossipState::mix).
// ---------------------------------------------------------------------------

/// Mix one worker's row from its own pre-mix copy plus the messages it
/// received, with the exact term order and arithmetic of
/// `GossipState::mix` (self term first, then senders ascending; full
/// house uses the raw weights, a partial house renormalizes in f64) —
/// so a socket worker's row stays bit-identical to the in-proc run
/// while degrading exactly like churn when peers are lost.
///
/// `msgs` must be sorted by ascending sender (the socket drain order).
pub fn mix_one_row(
    w: &MixWeights,
    to: usize,
    own: &[f32],
    msgs: &[Message],
    neighbor_count: usize,
    out: &mut [f32],
) {
    let heard = msgs.len();
    let mut terms: Vec<(f32, &[f32])> = Vec::with_capacity(1 + heard);
    if heard == neighbor_count {
        let mut cursor = w.row_cursor(to);
        terms.push((w.self_weight(to) as f32, own));
        for msg in msgs {
            let x = msg.payload.dense().expect("gossip exchanges dense payloads");
            terms.push((cursor.weight(msg.from) as f32, x));
        }
    } else {
        let mut cursor = w.row_cursor(to);
        let mut total = w.self_weight(to);
        for msg in msgs {
            total += cursor.weight(msg.from);
        }
        let scale = 1.0 / total;
        let mut cursor = w.row_cursor(to);
        terms.push(((w.self_weight(to) * scale) as f32, own));
        for msg in msgs {
            let x = msg.payload.dense().expect("gossip exchanges dense payloads");
            terms.push(((cursor.weight(msg.from) * scale) as f32, x));
        }
    }
    crate::linalg::weighted_sum_into(out, &terms);
}

// ---------------------------------------------------------------------------
// Worker process runtime (`pdsgdm worker`).
// ---------------------------------------------------------------------------

fn unix_sock_dir(coordinator_addr: &str) -> Option<PathBuf> {
    coordinator_addr
        .strip_prefix("unix:")
        .and_then(|p| Path::new(p).parent().map(Path::to_path_buf))
}

/// Run ONE worker as this OS process: replay the exact sequential
/// schedule for row `me` (local momentum steps + gossip mixing over the
/// socket fabric) and report rows to the coordinator at eval steps.
pub fn run_worker(cfg: &ExperimentConfig, me: usize, coordinator: &str) -> Result<(), String> {
    let tcfg = cfg.transport.clone().ok_or("config has no [transport] section")?;
    let k = cfg.workers;
    if me >= k {
        return Err(format!("worker index {me} out of range for K={k}"));
    }
    let (graph, weights, _rho) =
        crate::topology::build_sparse(cfg.topology, k, cfg.weighting, cfg.seed);
    let mut source = crate::coordinator::build_source(cfg).map_err(|e| e.to_string())?;
    let mut x = source.init(cfg.seed);
    let d = x.len();
    let mut m = vec![0.0f32; d];
    let mut scratch = vec![0.0f32; d];
    let mut premix = vec![0.0f32; d];
    let mut mixed = vec![0.0f32; d];

    // -- Handshake ---------------------------------------------------------
    let sock_path = unix_sock_dir(coordinator)
        .map(|dir| dir.join(format!("w{me}.sock")))
        .unwrap_or_default();
    let listener = Listener::bind(tcfg.backend, &tcfg.host, &sock_path)?;
    let my_addr = listener.addr_string()?;

    let mut counters = TransportCounters::default();
    let coord_stream = connect_with_retry(coordinator, &tcfg, 0xC0 ^ me as u64, &mut counters)?;
    let mut coord = PeerLink::new(usize::MAX, coord_stream, Some(coordinator.to_string()), me as u64);
    {
        let hello = Frame::new(FrameKind::Hello, me, 0, 0, my_addr.clone().into_bytes());
        if !coord.send_frame(&hello, &tcfg, &mut counters) {
            return Err("failed to send Hello to coordinator".into());
        }
    }
    // Wait for the address book.
    let table_deadline = Instant::now() + Duration::from_millis(tcfg.round_timeout_ms.max(1));
    let peers: BTreeMap<usize, String> = loop {
        match coord.pump(Duration::from_millis(20), &mut counters) {
            LinkEvent::Frame(f) if f.kind == FrameKind::PeerTable => {
                let text = String::from_utf8(f.payload)
                    .map_err(|_| "peer table is not UTF-8".to_string())?;
                let mut map = BTreeMap::new();
                for line in text.lines().filter(|l| !l.is_empty()) {
                    let (idx, addr) = line
                        .split_once(' ')
                        .ok_or_else(|| format!("malformed peer table line {line:?}"))?;
                    let idx: usize =
                        idx.parse().map_err(|_| format!("bad worker index in {line:?}"))?;
                    map.insert(idx, addr.to_string());
                }
                break map;
            }
            LinkEvent::Frame(f) => return Err(format!("unexpected {:?} before peer table", f.kind)),
            LinkEvent::Idle => {
                if Instant::now() >= table_deadline {
                    return Err("timed out waiting for peer table".into());
                }
            }
            LinkEvent::Dead(why) => return Err(format!("lost coordinator: {why}")),
        }
    };

    // Neighbor links: dial every lower-id neighbor (its listener was
    // bound before the coordinator released the peer table), accept
    // from every higher-id one, identifying each accepted stream by its
    // Hello frame.
    let mut st = SocketTransport::new(me, tcfg.clone());
    let neighbors: Vec<usize> = graph.neighbors(me).to_vec();
    for &j in neighbors.iter().filter(|&&j| j < me) {
        let addr = peers.get(&j).ok_or_else(|| format!("no address for worker {j}"))?;
        let s = connect_with_retry(addr, &tcfg, ((me as u64) << 16) | j as u64, &mut counters)?;
        let mut link = PeerLink::new(j, s, Some(addr.clone()), ((me as u64) << 16) | j as u64);
        let hello = Frame::new(FrameKind::Hello, me, j, 0, Vec::new());
        if !link.send_frame(&hello, &tcfg, &mut counters) {
            return Err(format!("failed Hello to worker {j}"));
        }
        st.links.insert(j, link);
    }
    let expect_accepts = neighbors.iter().filter(|&&j| j > me).count();
    let accept_deadline = Instant::now() + Duration::from_millis(tcfg.round_timeout_ms.max(1));
    for _ in 0..expect_accepts {
        let s = listener.accept(accept_deadline)?;
        s.set_deadlines(Some(io_timeout(&tcfg)), Some(io_timeout(&tcfg)))
            .map_err(|e| format!("deadlines: {e}"))?;
        // Identify the dialer.
        let mut tmp = PeerLink::new(usize::MAX, s, None, me as u64 ^ 0xACCE);
        let hello_deadline = Instant::now() + io_timeout(&tcfg);
        let from = loop {
            match tmp.pump(Duration::from_millis(20), &mut counters) {
                LinkEvent::Frame(f) if f.kind == FrameKind::Hello => break f.from as usize,
                LinkEvent::Frame(f) => return Err(format!("expected Hello, got {:?}", f.kind)),
                LinkEvent::Idle => {
                    if Instant::now() >= hello_deadline {
                        return Err("timed out waiting for neighbor Hello".into());
                    }
                }
                LinkEvent::Dead(why) => return Err(format!("neighbor lost during Hello: {why}")),
            }
        };
        if !neighbors.contains(&from) || from <= me {
            return Err(format!("unexpected Hello from worker {from}"));
        }
        tmp.peer = from;
        st.links.insert(from, tmp);
    }

    // -- Training loop -----------------------------------------------------
    let mut net = Network::with_transport(&graph, Box::new(st));
    // Zero-rate plan from step 0: bit-identical to no plan (DESIGN.md
    // §7) and gives peer deaths a place to land (`set_absent`).
    net.set_fault_plan(FaultPlan::new(k, 0.0, 0.0, 1, 0.0, cfg.seed));

    let mu = cfg.hyper.mu;
    let wd = cfg.hyper.weight_decay;
    let period = cfg.hyper.period.max(1);
    let steps = cfg.steps;
    let mut last_loss = f64::NAN;
    for t in 0..steps {
        let eta = cfg.hyper.lr.eta(t);
        last_loss =
            crate::engine::momentum_row_step(source.as_mut(), me, &mut x, &mut m, &mut scratch, mu, wd, eta);
        if (t + 1) % period == 0 {
            let sock = net
                .transport_mut()
                .as_any_mut()
                .downcast_mut::<SocketTransport>()
                .expect("worker network runs on SocketTransport");
            sock.begin_round(t);
            premix.copy_from_slice(&x);
            net.broadcast_shared(me, Arc::new(x.clone()));
            let mut msgs = net.recv_all(me);
            msgs.sort_by_key(|m| m.from);
            let newly_dead = net
                .transport_mut()
                .as_any_mut()
                .downcast_mut::<SocketTransport>()
                .expect("worker network runs on SocketTransport")
                .take_newly_dead();
            for j in newly_dead {
                if let Some(plan) = net.fault_plan_mut() {
                    plan.set_absent(j, true);
                }
            }
            mix_one_row(&weights, me, &premix, &msgs, neighbors.len(), &mut mixed);
            x.copy_from_slice(&mixed);
            net.end_round();
        }
        let s = t + 1;
        if s % cfg.eval_every == 0 || s >= steps {
            // Snapshot = fabric counters + this process's coordinator-link
            // and handshake counters, embedded in the report.
            let mut snapshot = net.transport_counters();
            snapshot.merge(&counters);
            let eval = Frame::new(FrameKind::Eval, me, 0, s, encode_eval(last_loss, &x, &snapshot));
            if !coord.send_frame(&eval, &tcfg, &mut counters) {
                return Err("lost coordinator while reporting eval".into());
            }
        }
    }

    // -- Teardown ----------------------------------------------------------
    {
        let bye = Frame::new(FrameKind::Bye, me, 0, steps, Vec::new());
        let _ = coord.send_frame(&bye, &tcfg, &mut counters);
    }
    if let Some(sock) = net.transport_mut().as_any_mut().downcast_mut::<SocketTransport>() {
        sock.send_bye();
    }
    // Linger until the coordinator hangs up so slower neighbors never
    // see a premature EOF mid-round; bounded so a dead coordinator
    // can't wedge the process.
    let linger = Instant::now() + Duration::from_millis(tcfg.round_timeout_ms.max(1));
    loop {
        let mut c = TransportCounters::default();
        match coord.pump(Duration::from_millis(50), &mut c) {
            LinkEvent::Dead(_) => break,
            _ if Instant::now() >= linger => break,
            _ => {}
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Coordinator: child supervision + the Session-side Algorithm.
// ---------------------------------------------------------------------------

struct WorkerSlot {
    link: PeerLink,
    child: std::process::Child,
    live: bool,
    counters: TransportCounters,
}

/// Supervises K worker processes: spawn, handshake, collect eval
/// reports, detect deaths, optional scripted kill (the fault-injection
/// hook the peer-loss tests and the CI kill leg use).
pub struct CoordinatorHub {
    tcfg: TransportConfig,
    slots: Vec<WorkerSlot>,
    counters: TransportCounters,
    kill: Option<(usize, u64)>,
    killed: bool,
    _listener: Listener,
    scratch_dir: Option<PathBuf>,
}

impl CoordinatorHub {
    /// Kill every child (used on error paths and at teardown).
    fn kill_all(&mut self) {
        for s in self.slots.iter_mut() {
            let _ = s.child.kill();
            let _ = s.child.wait();
        }
    }

    fn cleanup(&mut self) {
        if let Some(dir) = self.scratch_dir.take() {
            let _ = std::fs::remove_dir_all(dir);
        }
    }

    /// Blocking collect of every live worker's Eval report for `step`.
    /// Returns workers that died during the collect.
    fn collect(&mut self, step: u64, rows: &mut [Vec<f32>], losses: &mut [f64]) -> Vec<usize> {
        let deadline = Instant::now() + Duration::from_millis(self.tcfg.round_timeout_ms.max(1));
        let mut got: Vec<bool> = self.slots.iter().map(|s| !s.live).collect();
        let mut newly_dead = Vec::new();
        while got.iter().any(|g| !g) {
            let timed_out = Instant::now() >= deadline;
            for (w, slot) in self.slots.iter_mut().enumerate() {
                if got[w] {
                    continue;
                }
                // A reaped child that can no longer report is dead even
                // if its socket lingers.
                let exited = matches!(slot.child.try_wait(), Ok(Some(_)));
                match slot.link.pump(Duration::from_millis(10), &mut self.counters) {
                    LinkEvent::Frame(f) => match f.kind {
                        FrameKind::Eval if f.step == step => {
                            match decode_eval(&f.payload) {
                                Ok((loss, x, c)) => {
                                    if x.len() == rows[w].len() {
                                        rows[w].copy_from_slice(&x);
                                    }
                                    losses[w] = loss;
                                    slot.counters = c;
                                }
                                Err(_) => {
                                    self.counters.crc_errors += 1;
                                }
                            }
                            got[w] = true;
                        }
                        FrameKind::Eval => { /* stale report; keep reading */ }
                        FrameKind::Heartbeat | FrameKind::Hello => {}
                        FrameKind::Bye => {
                            slot.live = false;
                            got[w] = true;
                            newly_dead.push(w);
                        }
                        _ => {}
                    },
                    LinkEvent::Idle => {
                        if exited || timed_out {
                            if timed_out && !exited {
                                self.counters.timeouts += 1;
                                let _ = slot.child.kill();
                            }
                            slot.live = false;
                            got[w] = true;
                            newly_dead.push(w);
                        }
                    }
                    LinkEvent::Dead(_) => {
                        slot.live = false;
                        got[w] = true;
                        newly_dead.push(w);
                    }
                }
            }
        }
        for &w in &newly_dead {
            self.counters.peers_dead += 1;
            let _ = w;
        }
        // Scripted kill: SIGKILL one worker after its report at the
        // first eval step ≥ the trigger — peers then discover the death
        // through the transport, which is exactly what the peer-loss
        // tests assert.
        if let Some((kw, ks)) = self.kill {
            if !self.killed && step >= ks {
                if let Some(slot) = self.slots.get_mut(kw) {
                    let _ = slot.child.kill();
                }
                self.killed = true;
            }
        }
        newly_dead
    }

    /// Aggregate coordinator-side + latest per-worker counters.
    fn aggregate(&self) -> TransportCounters {
        let mut total = self.counters;
        for s in &self.slots {
            total.merge(&s.counters);
        }
        total
    }
}

impl Drop for CoordinatorHub {
    fn drop(&mut self) {
        self.kill_all();
        self.cleanup();
    }
}

/// The coordinator-side `Algorithm`: holds the authoritative K×d row
/// set (synced from worker Eval reports at eval steps), replays the
/// in-proc byte accounting on its local `Network` so `Session`'s
/// sim-seconds/comm-MB stay bit-identical, and maps worker deaths onto
/// the absence machinery.
pub struct RemoteGossip {
    k: usize,
    period: u64,
    eval_every: u64,
    steps: u64,
    rows: Vec<Vec<f32>>,
    losses: Vec<f64>,
    dummy: Arc<Vec<f32>>,
    hub: CoordinatorHub,
    shared: Arc<Mutex<TransportCounters>>,
    name: String,
    pub peers_lost: usize,
}

impl RemoteGossip {
    pub fn shared_counters(&self) -> Arc<Mutex<TransportCounters>> {
        Arc::clone(&self.shared)
    }
}

impl Algorithm for RemoteGossip {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn step(&mut self, t: u64, _source: &mut dyn GradientSource, net: &mut Network) -> StepStats {
        let mut stats = StepStats::default();
        if (t + 1) % self.period == 0 {
            // Replay the exact in-proc wire charge on the local
            // accounting Network: a full dense broadcast per worker.
            let before = net.total_bytes;
            for from in 0..self.k {
                net.broadcast_shared(from, Arc::clone(&self.dummy));
            }
            for to in 0..self.k {
                let _ = net.recv_all(to);
            }
            net.end_round();
            stats.communicated = true;
            stats.bytes = net.total_bytes - before;
        }
        let s = t + 1;
        if s % self.eval_every == 0 || s >= self.steps {
            let dead = self.hub.collect(s, &mut self.rows, &mut self.losses);
            for w in dead {
                if !net.faults_active() {
                    net.set_fault_plan(FaultPlan::new(self.k, 0.0, 0.0, 1, 0.0, 0));
                }
                if let Some(plan) = net.fault_plan_mut() {
                    plan.set_absent(w, true);
                }
                self.peers_lost += 1;
            }
            let live = self.hub.slots.iter().filter(|s| s.live).count();
            if live > 0 {
                stats.mean_loss = self
                    .hub
                    .slots
                    .iter()
                    .zip(&self.losses)
                    .filter(|(s, _)| s.live)
                    .map(|(_, l)| *l)
                    .sum::<f64>()
                    / live as f64;
            }
            *self.shared.lock().unwrap() = self.hub.aggregate();
        }
        stats
    }

    fn params(&self, k: usize) -> &[f32] {
        &self.rows[k]
    }

    fn state_save(&self, _w: &mut crate::state::StateWriter) {
        // Socket sessions are not checkpointable: the momentum banks
        // live in the worker processes. `cmd_train` rejects --ckpt.
    }

    fn state_load(&mut self, _r: &mut crate::state::StateReader) -> Result<(), String> {
        Err("socket-transport sessions cannot restore checkpoints".into())
    }
}

/// Everything `pdsgdm train` needs back from a socket run.
pub struct TransportRunOutcome {
    pub trace: crate::metrics::Trace,
    pub counters: TransportCounters,
    pub peers_lost: usize,
    pub rho: f64,
    pub wall_seconds: f64,
}

static SCRATCH_NONCE: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Collect K worker Hellos (each carrying its listen address), send
/// every worker the full address book, and adopt the children into
/// supervised slots. Children stay in `children` until adopted, so the
/// caller can kill the stragglers on error.
fn handshake(
    hub: &mut CoordinatorHub,
    k: usize,
    children: &mut Vec<std::process::Child>,
) -> Result<(), String> {
    let tcfg = hub.tcfg.clone();
    let mut hellos: BTreeMap<usize, (PeerLink, String)> = BTreeMap::new();
    let deadline = Instant::now() + Duration::from_millis(tcfg.round_timeout_ms.max(1)) * 2;
    while hellos.len() < k {
        // A child that died before saying Hello aborts the run.
        for (w, c) in children.iter_mut().enumerate() {
            if !hellos.contains_key(&w) {
                if let Ok(Some(status)) = c.try_wait() {
                    return Err(format!("worker {w} exited during handshake: {status}"));
                }
            }
        }
        let stream = hub._listener.accept(deadline).map_err(|e| format!("handshake: {e}"))?;
        let _ = stream.set_deadlines(Some(io_timeout(&tcfg)), Some(io_timeout(&tcfg)));
        let mut link = PeerLink::new(usize::MAX, stream, None, 0xC00D);
        let hello_deadline = Instant::now() + io_timeout(&tcfg);
        loop {
            match link.pump(Duration::from_millis(20), &mut hub.counters) {
                LinkEvent::Frame(f) if f.kind == FrameKind::Hello => {
                    let w = f.from as usize;
                    let addr = String::from_utf8(f.payload).unwrap_or_default();
                    if w >= k || addr.is_empty() {
                        return Err(format!("bad Hello from worker {w}"));
                    }
                    link.peer = w;
                    hellos.insert(w, (link, addr));
                    break;
                }
                LinkEvent::Frame(f) => return Err(format!("expected Hello, got {:?}", f.kind)),
                LinkEvent::Idle => {
                    if Instant::now() >= hello_deadline {
                        return Err("timed out waiting for worker Hello".into());
                    }
                }
                LinkEvent::Dead(why) => return Err(format!("worker died in handshake: {why}")),
            }
        }
    }
    let table: String = (0..k).map(|w| format!("{w} {}\n", hellos[&w].1)).collect();
    for w in 0..k {
        let (mut link, _) = hellos.remove(&w).expect("hello collected");
        let f = Frame::new(FrameKind::PeerTable, 0, w, 0, table.clone().into_bytes());
        if !link.send_frame(&f, &tcfg, &mut hub.counters) {
            return Err(format!("failed to send peer table to worker {w}"));
        }
        hub.slots.push(WorkerSlot {
            link,
            child: children.remove(0),
            live: true,
            counters: TransportCounters::default(),
        });
    }
    Ok(())
}

/// Spawn K `pdsgdm worker` processes, wire them up over
/// loopback-TCP/Unix sockets, and drive a full `Session` run whose
/// trace is bit-identical to the in-memory run on the same seed.
/// `worker_exe` is the binary to spawn (`std::env::current_exe()` from
/// the CLI; `env!("CARGO_BIN_EXE_pdsgdm")` from integration tests).
pub fn run_coordinator(
    cfg: &ExperimentConfig,
    worker_exe: &Path,
    verbose: bool,
) -> Result<TransportRunOutcome, String> {
    let tcfg = cfg.transport.clone().ok_or("config has no [transport] section")?;
    let k = cfg.workers;
    let (graph, _weights, rho) =
        crate::topology::build_sparse(cfg.topology, k, cfg.weighting, cfg.seed);
    let mut source = crate::coordinator::build_source(cfg).map_err(|e| e.to_string())?;
    let x0 = source.init(cfg.seed);
    let d = x0.len();

    // Scratch dir: worker config + Unix sockets live here for the run.
    let nonce = SCRATCH_NONCE.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let scratch = tcfg
        .socket_dir
        .as_ref()
        .map(PathBuf::from)
        .unwrap_or_else(std::env::temp_dir)
        .join(format!("pdsgdm-{}-{nonce}", std::process::id()));
    std::fs::create_dir_all(&scratch).map_err(|e| format!("create {scratch:?}: {e}"))?;
    let cfg_path = scratch.join("config.toml");
    std::fs::write(&cfg_path, cfg.to_toml()?).map_err(|e| format!("write {cfg_path:?}: {e}"))?;

    let listener = Listener::bind(tcfg.backend, &tcfg.host, &scratch.join("coord.sock"))?;
    let coord_addr = listener.addr_string()?;

    let mut children = Vec::with_capacity(k);
    for w in 0..k {
        let child = std::process::Command::new(worker_exe)
            .arg("worker")
            .arg("--config")
            .arg(&cfg_path)
            .arg("--worker")
            .arg(w.to_string())
            .arg("--coordinator")
            .arg(&coord_addr)
            .stdin(std::process::Stdio::null())
            .stdout(std::process::Stdio::null())
            .stderr(if verbose { std::process::Stdio::inherit() } else { std::process::Stdio::null() })
            .spawn()
            .map_err(|e| format!("spawn worker {w}: {e}"))?;
        children.push(child);
    }
    let mut hub = CoordinatorHub {
        tcfg: tcfg.clone(),
        slots: Vec::new(),
        counters: TransportCounters::default(),
        kill: tcfg.kill_worker,
        killed: false,
        _listener: listener,
        scratch_dir: Some(scratch),
    };

    // Handshake: K Hellos carrying listen addresses, then the table.
    // On failure, kill every child the handshake didn't adopt into a
    // slot (the hub's Drop reaps the adopted ones).
    if let Err(e) = handshake(&mut hub, k, &mut children) {
        for c in children.iter_mut() {
            let _ = c.kill();
            let _ = c.wait();
        }
        return Err(e);
    }

    // Session: exact in-proc accounting over a local InProc Network.
    let mut net = Network::new(&graph);
    let shared = Arc::new(Mutex::new(TransportCounters::default()));
    let mut algo = RemoteGossip {
        k,
        period: cfg.hyper.period.max(1),
        eval_every: cfg.eval_every.max(1),
        steps: cfg.steps,
        rows: (0..k).map(|_| x0.clone()).collect(),
        losses: vec![f64::NAN; k],
        dummy: Arc::new(x0),
        hub,
        shared: Arc::clone(&shared),
        name: format!("pd-sgdm(p={})", cfg.hyper.period),
        peers_lost: 0,
    };
    let wall = Instant::now();
    let trace = {
        let mut session = crate::coordinator::Session::from_parts(
            &mut algo,
            source.as_mut(),
            &mut net,
            cfg.eval_every,
            cfg.cost_model,
        );
        session.rho = rho;
        session.set_transport_counters(Arc::clone(&shared));
        if verbose {
            session.observe(Box::new(crate::coordinator::VerboseObserver::stderr()));
        }
        session.run_until(crate::coordinator::StopCondition::Steps(cfg.steps)).clone()
    };
    let wall_seconds = wall.elapsed().as_secs_f64();
    let peers_lost = algo.peers_lost;

    // Graceful teardown: hang up (workers linger on the coordinator
    // link), then reap with a bounded wait.
    for s in algo.hub.slots.iter_mut() {
        s.link.kill();
    }
    let reap_deadline = Instant::now() + Duration::from_secs(10);
    for s in algo.hub.slots.iter_mut() {
        loop {
            match s.child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) if Instant::now() < reap_deadline => {
                    std::thread::sleep(Duration::from_millis(10))
                }
                _ => {
                    let _ = s.child.kill();
                    let _ = s.child.wait();
                    break;
                }
            }
        }
    }
    let counters = algo.hub.aggregate();
    algo.hub.cleanup();
    Ok(TransportRunOutcome { trace, counters, peers_lost, rho, wall_seconds })
}

// ---------------------------------------------------------------------------
// Tests.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Topology, Weighting};

    #[test]
    fn crc32_check_value() {
        // The standard CRC-32/ISO-HDLC check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip_all_kinds() {
        for (kind, payload) in [
            (FrameKind::Hello, b"tcp:127.0.0.1:9".to_vec()),
            (FrameKind::PeerTable, b"0 tcp:a\n1 tcp:b\n".to_vec()),
            (FrameKind::Dense, encode_dense(&[1.0, -2.5, 3.25])),
            (FrameKind::Heartbeat, Vec::new()),
            (FrameKind::Eval, encode_eval(0.5, &[1.0], &TransportCounters::default())),
            (FrameKind::Bye, Vec::new()),
        ] {
            let f = Frame::new(kind, 3, 7, 42, payload);
            let bytes = encode_frame(&f);
            let (g, used) = decode_frame(&bytes).expect("decodes");
            assert_eq!(used, bytes.len());
            assert_eq!(f, g);
        }
    }

    #[test]
    fn frame_decode_is_incremental() {
        let f = Frame::new(FrameKind::Dense, 0, 1, 9, encode_dense(&[4.0; 10]));
        let bytes = encode_frame(&f);
        for cut in 0..bytes.len() {
            assert_eq!(decode_frame(&bytes[..cut]), Err(FrameError::Incomplete), "cut={cut}");
        }
        // Two concatenated frames decode one at a time.
        let mut two = bytes.clone();
        two.extend_from_slice(&bytes);
        let (_, used) = decode_frame(&two).unwrap();
        assert_eq!(used, bytes.len());
        let (g, _) = decode_frame(&two[used..]).unwrap();
        assert_eq!(g, f);
    }

    #[test]
    fn frame_rejects_corruption() {
        let f = Frame::new(FrameKind::Dense, 2, 3, 5, encode_dense(&[1.0, 2.0]));
        let mut bytes = encode_frame(&f);
        let last = bytes.len() - 6;
        bytes[last] ^= 0x40;
        assert!(matches!(decode_frame(&bytes), Err(FrameError::Corrupt(_))));
        // Absurd length prefix must not allocate.
        let huge = u32::MAX.to_le_bytes();
        assert!(matches!(decode_frame(&huge), Err(FrameError::Corrupt(_))));
    }

    #[test]
    fn counters_roundtrip_and_truncation() {
        let mut c = TransportCounters::default();
        c.connect_retries = 1;
        c.heartbeat_misses = 7;
        c.bytes_sent = 1 << 40;
        c.crc_errors = 3;
        let b = c.encode();
        let (d, used) = TransportCounters::decode(&b).unwrap();
        assert_eq!(used, b.len());
        assert_eq!(c, d);
        for cut in 0..b.len() {
            let _ = TransportCounters::decode(&b[..cut]);
        }
    }

    #[test]
    fn eval_payload_roundtrip() {
        let mut c = TransportCounters::default();
        c.timeouts = 2;
        let x = vec![0.5f32, -1.5, 2.25];
        let b = encode_eval(-0.125, &x, &c);
        let (loss, y, d) = decode_eval(&b).unwrap();
        assert_eq!(loss, -0.125);
        assert_eq!(x, y);
        assert_eq!(c, d);
    }

    #[test]
    fn backoff_stays_in_bounds_and_grows() {
        let mut prev_cap = 0;
        for a in 0..10 {
            let d = backoff_delay_ms(a, 25, 1600, 0x5EED);
            let cap = (25u64 << a.min(20)).min(1600);
            assert!(d >= cap / 2 && d <= cap, "attempt {a}: {d} not in [{}, {cap}]", cap / 2);
            assert!(cap >= prev_cap);
            prev_cap = cap;
        }
        // Deterministic for a fixed (attempt, salt).
        assert_eq!(backoff_delay_ms(3, 25, 1600, 9), backoff_delay_ms(3, 25, 1600, 9));
    }

    #[test]
    fn inproc_transport_is_fifo_per_destination() {
        let mut t = InProc::new(3);
        for from in [2usize, 0, 1] {
            t.enqueue(Message { from, to: 1, payload: Payload::Dense(Arc::new(vec![from as f32])) });
        }
        assert!(!t.is_empty());
        let msgs = t.drain(1);
        assert_eq!(msgs.iter().map(|m| m.from).collect::<Vec<_>>(), vec![2, 0, 1]);
        assert!(t.is_empty());
        assert!(t.drain(0).is_empty());
    }

    /// `mix_one_row` must reproduce `GossipState::mix` bit-exactly —
    /// both with a full house (the bit-identity contract) and with a
    /// missing sender (the renormalized degradation path).
    #[test]
    fn mix_one_row_matches_gossip_state() {
        use crate::algorithms::GossipState;
        use crate::arena::ParamArena;

        let k = 5;
        let d = 7;
        let g = Topology::Ring.build(k, 0);
        let w = MixWeights::from_graph(&g, Weighting::UniformDegree);
        let rows: Vec<Vec<f32>> =
            (0..k).map(|i| (0..d).map(|j| (i * d + j) as f32 * 0.25 - 3.0).collect()).collect();

        // Reference: the real mixer over an in-proc network.
        let mut arena = ParamArena::zeros(k, d);
        for i in 0..k {
            arena.row_mut(i).copy_from_slice(&rows[i]);
        }
        let mut net = Network::new(&g);
        let mut gs = GossipState::new(w.clone());
        gs.mix(&mut arena, &mut net, None);

        // Full house: each row mixed in isolation from its messages.
        for to in 0..k {
            let msgs: Vec<Message> = g
                .neighbors(to)
                .iter()
                .map(|&from| Message {
                    from,
                    to,
                    payload: Payload::Dense(Arc::new(rows[from].clone())),
                })
                .collect();
            let mut msgs = msgs;
            msgs.sort_by_key(|m| m.from);
            let mut out = vec![0.0f32; d];
            mix_one_row(&w, to, &rows[to], &msgs, g.neighbors(to).len(), &mut out);
            assert_eq!(out, arena.row(to), "row {to} diverged from GossipState::mix");
        }

        // Partial house: drop sender `lost` for receiver `to`; compare
        // against the hardened in-proc path under churn absence.
        let to = 2usize;
        let lost = g.neighbors(to)[0];
        let mut arena2 = ParamArena::zeros(k, d);
        for i in 0..k {
            arena2.row_mut(i).copy_from_slice(&rows[i]);
        }
        let mut net2 = Network::new(&g);
        net2.set_fault_plan(FaultPlan::new(k, 0.0, 0.0, 1, 0.0, 0));
        net2.fault_plan_mut().unwrap().set_absent(lost, true);
        let mut gs2 = GossipState::new(w.clone());
        gs2.mix(&mut arena2, &mut net2, None);

        let mut msgs: Vec<Message> = g
            .neighbors(to)
            .iter()
            .filter(|&&from| from != lost)
            .map(|&from| Message { from, to, payload: Payload::Dense(Arc::new(rows[from].clone())) })
            .collect();
        msgs.sort_by_key(|m| m.from);
        let mut out = vec![0.0f32; d];
        mix_one_row(&w, to, &rows[to], &msgs, g.neighbors(to).len(), &mut out);
        assert_eq!(out, arena2.row(to), "renormalized partial-house mix diverged");
    }

    #[test]
    fn dense_payload_rejects_ragged_length() {
        assert!(decode_dense(&[0u8; 5]).is_err());
        assert_eq!(decode_dense(&[]).unwrap(), Vec::<f32>::new());
    }
}
