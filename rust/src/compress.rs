//! δ-contraction compression operators (paper Definition 1) + wire codecs.
//!
//! CPD-SGDM (Algorithm 2) communicates `q = Q(x - x̂)` where `Q` satisfies
//! `||x - Q(x)||^2 <= (1 - δ) ||x||^2` for some δ in (0, 1]. This module
//! implements the operators the compression literature (and the paper's
//! experiments) use:
//!
//! * [`Sign`] — scaled sign compression (the paper's choice, after
//!   signSGD [5]): `Q(x) = (||x||_1 / d) · sign(x)`, δ = ||x||_1² / (d·||x||²).
//! * [`TopK`] — keep the k largest-magnitude coordinates, δ = k/d.
//! * [`RandK`] — keep k uniformly random coordinates (rescaled variant is
//!   unbiased but *not* a contraction, so we use the plain projection).
//! * [`Qsgd`] — stochastic s-level quantization (QSGD [3]).
//! * [`Identity`] — δ = 1, turning CPD-SGDM into exact-communication
//!   gossip (used by tests to cross-check against PD-SGDM-style mixing).
//!
//! Every operator is a real wire codec: [`Compressor::compress_into`]
//! produces both the dense decode and the exact symbols its natural
//! format packs ([`WireRepr`]), [`Compressor::encode_into`] serializes
//! them to the byte buffer that actually crosses the simulated network,
//! and [`Compressor::decode_into`] reconstructs the dense vector
//! **bit-identically** (property-tested in `rust/tests/wire_roundtrip.rs`).
//! All three overwrite caller-owned buffers, so the per-round comm hot
//! path ([`crate::algorithms::CompressedExchange`]) is allocation-free in
//! steady state; the allocating `compress`/`encode`/`decode` forms remain
//! as provided wrappers. The byte counters driving Figure 2's x-axes
//! measure real buffer lengths — `wire_bytes == encode(..).len() ==
//! encoded_bytes(d)` is an invariant enforced in **release** builds via
//! [`check_wire_size`] (it was a debug-only assert before), not an honor
//! system.
//!
//! Wire formats (all little-endian):
//!
//! | operator | layout | bytes |
//! |---|---|---|
//! | `Sign` | f32 scale + d-bit sign bitmap | `4 + ⌈d/8⌉` |
//! | `TopK`/`RandK` | k × (u32 index, f32 value) | `8k` |
//! | `Qsgd` | f32 norm + d packed signed levels (⌈log2(2s+1)⌉ bits each) | `4 + ⌈d·b/8⌉` |
//! | `Identity` | raw f32 | `4d` |

use crate::rng::Xoshiro256;

/// A compressed vector: the dense decode target, its wire cost, and the
/// exact symbols the operator's codec packs.
///
/// All three fields are **reusable**: [`Compressor::compress_into`]
/// overwrites them in place, so a long-lived `CompressedVec` (one per
/// worker in [`crate::algorithms::CompressedExchange`]) makes the whole
/// compress phase allocation-free in steady state.
#[derive(Clone, Debug)]
pub struct CompressedVec {
    /// Dense decode of Q(x) (the simulator applies it directly).
    pub dense: Vec<f32>,
    /// Bytes this message occupies on the wire — always equal to
    /// `encode(..).len()` for the producing operator.
    pub wire_bytes: usize,
    /// Codec sidecar consumed by [`Compressor::encode`]; carrying the
    /// symbols explicitly means encode never re-derives them lossily
    /// from `dense`.
    pub repr: WireRepr,
}

impl CompressedVec {
    /// An empty, reusable target for [`Compressor::compress_into`].
    pub fn empty() -> Self {
        Self { dense: Vec::new(), wire_bytes: 0, repr: WireRepr::Dense }
    }
}

/// The operator-natural wire symbols produced by compression.
#[derive(Clone, Debug)]
pub enum WireRepr {
    /// Identity: `dense` itself is the wire content (raw f32 LE).
    Dense,
    /// Sign: one f32 scale; per-coordinate signs are read from `dense`.
    SignBitmap { scale: f32 },
    /// TopK/RandK: ascending kept-coordinate indices; values are read
    /// from `dense` (indices are stored so all-zero selections still
    /// round-trip — `dense` alone cannot say *which* zeros were kept).
    Sparse { indices: Vec<u32> },
    /// QSGD: f32 L2 norm + one signed level in [-s, s] per coordinate.
    Levels { norm: f32, symbols: Vec<i32> },
}

/// A δ-contraction operator Q: R^d -> R^d (paper Definition 1).
///
/// The `*_into` methods are the hot path: they overwrite caller-owned
/// buffers and never allocate in d (capacity growth on first use aside),
/// so a comm round that reuses its `CompressedVec`/byte/dense tables is
/// allocation-free in steady state. The allocating `compress`/`encode`/
/// `decode` forms are provided wrappers for tests and one-shot callers.
pub trait Compressor: Send + Sync {
    fn name(&self) -> String;

    /// Apply Q, overwriting every field of `out` (the zero-allocation
    /// form — `out.dense` and any repr-side buffers are reused).
    /// `rng` is used only by stochastic operators.
    fn compress_into(&self, x: &[f32], rng: &mut Xoshiro256, out: &mut CompressedVec);

    /// Allocating convenience form of [`Compressor::compress_into`].
    fn compress(&self, x: &[f32], rng: &mut Xoshiro256) -> CompressedVec {
        let mut out = CompressedVec::empty();
        self.compress_into(x, rng, &mut out);
        out
    }

    /// Serialize `c` into the operator's natural wire format, overwriting
    /// `out` (cleared and resized; capacity is reused). The resulting
    /// length equals `c.wire_bytes` (and `encoded_bytes(d)`) — checked in
    /// release mode by [`check_wire_size`] wherever bytes are charged to
    /// the network; panics if `c` was produced by a different operator
    /// (its [`WireRepr`] would not match).
    fn encode_into(&self, c: &CompressedVec, out: &mut Vec<u8>);

    /// Allocating convenience form of [`Compressor::encode_into`].
    fn encode(&self, c: &CompressedVec) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(c, &mut out);
        out
    }

    /// Inverse of [`Compressor::encode_into`] for a d-dimensional message
    /// (`d == out.len()`): fully overwrites `out` with the dense decode,
    /// reconstructing `c.dense` bit-identically from the wire bytes.
    /// Panics on a payload whose length does not match `encoded_bytes(d)`.
    fn decode_into(&self, bytes: &[u8], out: &mut [f32]);

    /// Allocating convenience form of [`Compressor::decode_into`].
    fn decode(&self, bytes: &[u8], d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; d];
        self.decode_into(bytes, &mut out);
        out
    }

    /// The operator's contraction parameter δ (a priori lower bound;
    /// `measured_delta` checks it empirically).
    fn delta(&self, d: usize) -> f64;

    /// Wire bytes for a d-dim message (without materializing one).
    fn encoded_bytes(&self, d: usize) -> usize;

    /// True for operators whose Definition-1 contraction holds in
    /// expectation over their internal randomness (RandK, QSGD) rather
    /// than per-sample (Sign, TopK, Identity).
    fn is_stochastic(&self) -> bool {
        false
    }

    /// Clone this operator behind the trait object. Every operator is a
    /// tiny value type, so this is a direct copy — the old
    /// clone-by-reparse hack (round-tripping `name()` through `parse`)
    /// is gone; `Box<dyn Compressor>` implements `Clone` via this.
    fn box_clone(&self) -> Box<dyn Compressor>;
}

impl Clone for Box<dyn Compressor> {
    fn clone(&self) -> Self {
        self.box_clone()
    }
}

/// Empirical 1 - ||x - Q(x)||²/||x||² for a concrete x (>= delta() must
/// hold; property-tested in every operator's test module).
pub fn measured_delta(c: &dyn Compressor, x: &[f32], rng: &mut Xoshiro256) -> f64 {
    let q = c.compress(x, rng);
    let nx: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
    if nx == 0.0 {
        return 1.0;
    }
    let err: f64 = x
        .iter()
        .zip(&q.dense)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    1.0 - err / nx
}

/// The codec wire-size invariant as a **release-mode error path**: a
/// codec whose `encode` emitted a buffer that disagrees with the
/// `wire_bytes` it costed would silently skew every Figure 2 byte axis
/// (the old guard was a `debug_assert!`, i.e. absent from the release
/// binaries that produce the figures). Comm rounds call this before
/// charging the network and panic with the returned message; tests
/// exercise the `Err` arm directly with a deliberately miscosted codec.
pub fn check_wire_size(
    op: &dyn Compressor,
    c: &CompressedVec,
    encoded_len: usize,
) -> Result<(), String> {
    if encoded_len == c.wire_bytes {
        Ok(())
    } else {
        Err(format!(
            "codec wire-size invariant violated: {} encoded {} bytes for a \
             message costed at {} wire bytes (d={})",
            op.name(),
            encoded_len,
            c.wire_bytes,
            c.dense.len()
        ))
    }
}

/// Reclaim the index buffer of a previous `Sparse` repr (cleared), or a
/// fresh one — the TopK/RandK `compress_into` reuse path.
fn reuse_sparse_indices(repr: &mut WireRepr) -> Vec<u32> {
    match std::mem::replace(repr, WireRepr::Dense) {
        WireRepr::Sparse { mut indices } => {
            indices.clear();
            indices
        }
        _ => Vec::new(),
    }
}

/// (u32 index, f32 value) pair serialization shared by TopK and RandK.
fn encode_sparse_into(c: &CompressedVec, out: &mut Vec<u8>) {
    let indices = match &c.repr {
        WireRepr::Sparse { indices } => indices,
        _ => panic!("sparse encode needs a Sparse repr (foreign CompressedVec?)"),
    };
    out.clear();
    out.reserve(indices.len() * 8);
    for &i in indices {
        out.extend_from_slice(&i.to_le_bytes());
        out.extend_from_slice(&c.dense[i as usize].to_le_bytes());
    }
}

fn decode_sparse_into(bytes: &[u8], out: &mut [f32], k: usize) {
    assert_eq!(bytes.len(), k * 8, "sparse payload: want {} bytes, got {}", k * 8, bytes.len());
    let d = out.len();
    out.iter_mut().for_each(|v| *v = 0.0);
    for pair in bytes.chunks_exact(8) {
        let i = u32::from_le_bytes(pair[..4].try_into().unwrap()) as usize;
        assert!(i < d, "sparse payload: index {i} out of range for d={d}");
        out[i] = f32::from_le_bytes(pair[4..].try_into().unwrap());
    }
}

/// Scaled sign compression: Q(x) = (||x||_1 / d) sign(x).
///
/// Wire format: one f32 scale + d-bit sign bitmap => 4 + ceil(d/8) bytes,
/// a ~32x reduction. This is the operator the paper's experiments use.
#[derive(Clone, Copy, Debug, Default)]
pub struct Sign;

impl Compressor for Sign {
    fn name(&self) -> String {
        "sign".into()
    }

    fn compress_into(&self, x: &[f32], _rng: &mut Xoshiro256, out: &mut CompressedVec) {
        let d = x.len();
        let l1: f64 = x.iter().map(|&v| (v as f64).abs()).sum();
        let scale = (l1 / d.max(1) as f64) as f32;
        out.dense.clear();
        out.dense.extend(x.iter().map(|&v| if v >= 0.0 { scale } else { -scale }));
        out.wire_bytes = self.encoded_bytes(d);
        out.repr = WireRepr::SignBitmap { scale };
    }

    fn encode_into(&self, c: &CompressedVec, out: &mut Vec<u8>) {
        let scale = match c.repr {
            WireRepr::SignBitmap { scale } => scale,
            _ => panic!("sign encode needs a SignBitmap repr (foreign CompressedVec?)"),
        };
        let d = c.dense.len();
        out.clear();
        out.resize(self.encoded_bytes(d), 0);
        out[..4].copy_from_slice(&scale.to_le_bytes());
        for (i, v) in c.dense.iter().enumerate() {
            // dense is ±scale; the bitmap stores the IEEE sign bit so
            // decode reproduces ±0.0 (and ±NaN) bit-exactly.
            if v.is_sign_positive() {
                out[4 + i / 8] |= 1 << (i % 8);
            }
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) {
        let d = out.len();
        assert_eq!(
            bytes.len(),
            self.encoded_bytes(d),
            "sign payload: want {} bytes, got {}",
            self.encoded_bytes(d),
            bytes.len()
        );
        let scale = f32::from_le_bytes(bytes[..4].try_into().unwrap());
        for (i, o) in out.iter_mut().enumerate() {
            *o = if bytes[4 + i / 8] >> (i % 8) & 1 == 1 { scale } else { -scale };
        }
    }

    fn delta(&self, d: usize) -> f64 {
        // ||x||_1^2 / (d ||x||_2^2) >= 1/d always; equality when x is
        // 1-sparse. Typical gradients are dense, giving δ near 1 — the
        // paper's Definition 1 needs only δ > 0.
        1.0 / d.max(1) as f64
    }

    fn encoded_bytes(&self, d: usize) -> usize {
        4 + d.div_ceil(8)
    }

    fn box_clone(&self) -> Box<dyn Compressor> {
        Box::new(*self)
    }
}

/// Top-k sparsification: keep the k largest |x_i|, zero the rest. δ = k/d.
///
/// Wire format: k * (4-byte index + 4-byte value).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    /// Fraction of coordinates kept, in (0, 1].
    pub ratio: f64,
}

impl TopK {
    pub fn k_for(&self, d: usize) -> usize {
        ((self.ratio * d as f64).ceil() as usize).clamp(1, d.max(1))
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("top{:.3}", self.ratio)
    }

    fn compress_into(&self, x: &[f32], _rng: &mut Xoshiro256, out: &mut CompressedVec) {
        let d = x.len();
        let k = self.k_for(d);
        // The selection scratch IS the wire index buffer (u32 fits — the
        // sparse wire format already caps d at u32 range), reclaimed from
        // the previous round's repr: no per-call index allocation.
        let mut indices = reuse_sparse_indices(&mut out.repr);
        indices.extend(0..d as u32);
        if !indices.is_empty() {
            // total_cmp on |x_i|: a deterministic total order even with
            // NaN gradients (NaN sorts largest, so poisoned coordinates
            // are selected — and surfaced — instead of silently
            // reordering).
            indices.select_nth_unstable_by(
                k.saturating_sub(1).min(d.saturating_sub(1)),
                |&a, &b| x[b as usize].abs().total_cmp(&x[a as usize].abs()),
            );
        }
        indices.truncate(k.min(d));
        indices.sort_unstable(); // canonical ascending wire order
        out.dense.clear();
        out.dense.resize(d, 0.0);
        for &i in &indices {
            out.dense[i as usize] = x[i as usize];
        }
        out.wire_bytes = self.encoded_bytes(d);
        out.repr = WireRepr::Sparse { indices };
    }

    fn encode_into(&self, c: &CompressedVec, out: &mut Vec<u8>) {
        encode_sparse_into(c, out);
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) {
        decode_sparse_into(bytes, out, self.k_for(out.len()));
    }

    fn delta(&self, d: usize) -> f64 {
        self.k_for(d) as f64 / d.max(1) as f64
    }

    fn encoded_bytes(&self, d: usize) -> usize {
        self.k_for(d) * 8
    }

    fn box_clone(&self) -> Box<dyn Compressor> {
        Box::new(*self)
    }
}

/// Random-k sparsification (projection form; δ = k/d in expectation and
/// the projection never expands, so Definition 1 holds per-sample with
/// δ_sample >= 0; we report the expectation).
#[derive(Clone, Copy, Debug)]
pub struct RandK {
    pub ratio: f64,
}

impl RandK {
    pub fn k_for(&self, d: usize) -> usize {
        ((self.ratio * d as f64).ceil() as usize).clamp(1, d.max(1))
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("rand{:.3}", self.ratio)
    }

    fn compress_into(&self, x: &[f32], rng: &mut Xoshiro256, out: &mut CompressedVec) {
        let d = x.len();
        let k = self.k_for(d).min(d);
        // Partial Fisher–Yates directly on the reclaimed u32 index buffer
        // — draw-for-draw identical to `rng.sample_indices(d, k)` but
        // without its per-call `Vec<usize>` allocation.
        let mut indices = reuse_sparse_indices(&mut out.repr);
        indices.extend(0..d as u32);
        for i in 0..k {
            let j = i + rng.below(d - i);
            indices.swap(i, j);
        }
        indices.truncate(k);
        indices.sort_unstable(); // canonical ascending wire order
        out.dense.clear();
        out.dense.resize(d, 0.0);
        for &i in &indices {
            out.dense[i as usize] = x[i as usize];
        }
        out.wire_bytes = self.encoded_bytes(d);
        out.repr = WireRepr::Sparse { indices };
    }

    fn encode_into(&self, c: &CompressedVec, out: &mut Vec<u8>) {
        encode_sparse_into(c, out);
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) {
        decode_sparse_into(bytes, out, self.k_for(out.len()));
    }

    fn delta(&self, d: usize) -> f64 {
        self.k_for(d) as f64 / d.max(1) as f64
    }

    fn encoded_bytes(&self, d: usize) -> usize {
        self.k_for(d) * 8
    }

    fn is_stochastic(&self) -> bool {
        true
    }

    fn box_clone(&self) -> Box<dyn Compressor> {
        Box::new(*self)
    }
}

/// QSGD-style stochastic quantization with `levels` levels per sign,
/// damped into contraction form.
///
/// Raw QSGD `R(x)_i = ||x|| sign(x_i) xi_i` (xi quantizes |x_i|/||x||
/// stochastically to multiples of 1/levels) is unbiased with variance
/// `E||R(x)-x||² <= ω ||x||²`, ω = min(d/levels², √d/levels)
/// (Alistarh et al. 2017) — which can *expand*, so it is not itself a
/// Definition-1 contraction. Following the CHOCO-SGD treatment we emit
/// the damped operator `Q(x) = R(x)/(1+ω)`, a δ-contraction in
/// expectation with δ = 1/(1+ω). Wire: 4-byte norm +
/// d·⌈log2(2·levels+1)⌉ bits.
#[derive(Clone, Copy, Debug)]
pub struct Qsgd {
    pub levels: u32,
}

impl Qsgd {
    fn omega(&self, d: usize) -> f64 {
        let s = self.levels as f64;
        let dd = d.max(1) as f64;
        (dd / (s * s)).min(dd.sqrt() / s)
    }

    fn bits_per_symbol(&self) -> usize {
        (2.0 * self.levels as f64 + 1.0).log2().ceil() as usize
    }

    /// Dense value of one signed level — the single dequantization rule
    /// shared by `compress` and `decode`, so the wire round-trip is
    /// bit-identical by construction. `norm` is the f32 the wire carries
    /// (quantizing against the full-precision f64 norm would make the
    /// receiver's reconstruction differ in the last bit).
    fn dequant(&self, norm: f32, d: usize, symbol: i32) -> f32 {
        let damp = 1.0 / (1.0 + self.omega(d));
        (damp * norm as f64 * (symbol as f64 / self.levels as f64)) as f32
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd{}", self.levels)
    }

    fn compress_into(&self, x: &[f32], rng: &mut Xoshiro256, out: &mut CompressedVec) {
        let d = x.len();
        out.wire_bytes = self.encoded_bytes(d);
        // Reclaim the symbol buffer from the previous round's repr.
        let mut symbols = match std::mem::replace(&mut out.repr, WireRepr::Dense) {
            WireRepr::Levels { mut symbols, .. } => {
                symbols.clear();
                symbols
            }
            _ => Vec::new(),
        };
        out.dense.clear();
        let nrm2: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        if nrm2 == 0.0 {
            out.dense.resize(d, 0.0);
            symbols.resize(d, 0);
            out.repr = WireRepr::Levels { norm: 0.0, symbols };
            return;
        }
        let norm = nrm2.sqrt() as f32;
        let s = self.levels as f64;
        for &v in x {
            let r = (v as f64).abs() / norm as f64 * s; // in [0, s(1+ε)]
            let low = r.floor();
            let level = if rng.next_f64() < r - low { low + 1.0 } else { low };
            // f32-rounding of the norm can push r past s; clamp so the
            // symbol stays in the packed alphabet [-s, s].
            let level = level.min(s) as i32;
            let symbol = if v < 0.0 { -level } else { level };
            symbols.push(symbol);
            out.dense.push(self.dequant(norm, d, symbol));
        }
        out.repr = WireRepr::Levels { norm, symbols };
    }

    fn encode_into(&self, c: &CompressedVec, out: &mut Vec<u8>) {
        let (norm, symbols) = match &c.repr {
            WireRepr::Levels { norm, symbols } => (*norm, symbols),
            _ => panic!("qsgd encode needs a Levels repr (foreign CompressedVec?)"),
        };
        let d = c.dense.len();
        let bits = self.bits_per_symbol();
        let s = self.levels as i32;
        out.clear();
        out.resize(self.encoded_bytes(d), 0);
        out[..4].copy_from_slice(&norm.to_le_bytes());
        for (i, &sym) in symbols.iter().enumerate() {
            debug_assert!((-s..=s).contains(&sym), "symbol {sym} outside [-{s}, {s}]");
            let code = (sym + s) as u32; // in [0, 2s]
            for b in 0..bits {
                if code >> b & 1 == 1 {
                    let p = i * bits + b;
                    out[4 + p / 8] |= 1 << (p % 8);
                }
            }
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) {
        let d = out.len();
        assert_eq!(
            bytes.len(),
            self.encoded_bytes(d),
            "qsgd payload: want {} bytes, got {}",
            self.encoded_bytes(d),
            bytes.len()
        );
        let norm = f32::from_le_bytes(bytes[..4].try_into().unwrap());
        let bits = self.bits_per_symbol();
        let s = self.levels as i32;
        for (i, o) in out.iter_mut().enumerate() {
            let mut code = 0u32;
            for b in 0..bits {
                let p = i * bits + b;
                if bytes[4 + p / 8] >> (p % 8) & 1 == 1 {
                    code |= 1 << b;
                }
            }
            *o = self.dequant(norm, d, code as i32 - s);
        }
    }

    fn delta(&self, d: usize) -> f64 {
        1.0 / (1.0 + self.omega(d))
    }

    fn encoded_bytes(&self, d: usize) -> usize {
        4 + (d * self.bits_per_symbol()).div_ceil(8)
    }

    fn is_stochastic(&self) -> bool {
        true
    }

    fn box_clone(&self) -> Box<dyn Compressor> {
        Box::new(*self)
    }
}

/// No-op compression (δ = 1): turns Algorithm 2 into exact communication.
#[derive(Clone, Copy, Debug, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn compress_into(&self, x: &[f32], _rng: &mut Xoshiro256, out: &mut CompressedVec) {
        out.dense.clear();
        out.dense.extend_from_slice(x);
        out.wire_bytes = self.encoded_bytes(x.len());
        out.repr = WireRepr::Dense;
    }

    fn encode_into(&self, c: &CompressedVec, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(4 * c.dense.len());
        for v in &c.dense {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) {
        let d = out.len();
        assert_eq!(bytes.len(), 4 * d, "identity payload: want {} bytes, got {}", 4 * d, bytes.len());
        for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
            *o = f32::from_le_bytes(c.try_into().unwrap());
        }
    }

    fn delta(&self, _d: usize) -> f64 {
        1.0
    }

    fn encoded_bytes(&self, d: usize) -> usize {
        4 * d
    }

    fn box_clone(&self) -> Box<dyn Compressor> {
        Box::new(*self)
    }
}

/// Parse "sign" | "top0.01" | "rand0.05" | "qsgd4" | "identity".
pub fn parse(spec: &str) -> Option<Box<dyn Compressor>> {
    if spec == "sign" {
        return Some(Box::new(Sign));
    }
    if spec == "identity" || spec == "none" {
        return Some(Box::new(Identity));
    }
    if let Some(r) = spec.strip_prefix("top") {
        return r.parse().ok().filter(|&r| r > 0.0 && r <= 1.0).map(|ratio| {
            Box::new(TopK { ratio }) as Box<dyn Compressor>
        });
    }
    if let Some(r) = spec.strip_prefix("rand") {
        return r.parse().ok().filter(|&r| r > 0.0 && r <= 1.0).map(|ratio| {
            Box::new(RandK { ratio }) as Box<dyn Compressor>
        });
    }
    if let Some(l) = spec.strip_prefix("qsgd") {
        return l.parse().ok().filter(|&l| l >= 1).map(|levels| {
            Box::new(Qsgd { levels }) as Box<dyn Compressor>
        });
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    fn operators() -> Vec<Box<dyn Compressor>> {
        vec![
            Box::new(Sign),
            Box::new(TopK { ratio: 0.1 }),
            Box::new(RandK { ratio: 0.1 }),
            Box::new(Qsgd { levels: 4 }),
            Box::new(Identity),
        ]
    }

    #[test]
    fn prop_delta_contraction_holds() {
        // Definition 1 (the paper's only requirement on Q): for every
        // operator and random x, ||x - Q(x)||² <= (1 - δ)||x||², i.e.
        // measured_delta >= advertised delta. Deterministic operators
        // must satisfy it per-sample; stochastic ones (RandK/QSGD) in
        // expectation over Q's randomness, so we average 200 draws.
        forall(0xC0FFEE, 25, |rng| {
            let d = 1 + rng.below(400);
            let sigma = [0.01f32, 1.0, 100.0][rng.below(3)];
            let x = rng.normal_vec(d, sigma);
            for c in operators() {
                let adv = c.delta(d);
                let meas = if c.is_stochastic() {
                    let n = 200;
                    (0..n).map(|_| measured_delta(c.as_ref(), &x, rng)).sum::<f64>() / n as f64
                } else {
                    measured_delta(c.as_ref(), &x, rng)
                };
                let tol = if c.is_stochastic() { 0.05 * (1.0 - adv).max(adv) } else { 1e-4 };
                assert!(
                    meas >= adv - tol,
                    "{}: measured {meas} < advertised {adv} (d={d})",
                    c.name()
                );
                assert!(meas <= 1.0 + 1e-6, "{}: {meas}", c.name());
            }
        });
    }

    #[test]
    fn prop_never_expands() {
        // Deterministic projections/sign never expand the error beyond
        // ||x||² per-sample; stochastic operators obey it in expectation.
        forall(7, 30, |rng| {
            let d = 1 + rng.below(300);
            let x = rng.normal_vec(d, 1.0);
            let nx: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
            for c in operators() {
                let err_of = |rng: &mut Xoshiro256| -> f64 {
                    let q = c.compress(&x, rng);
                    x.iter().zip(&q.dense).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum()
                };
                let err = if c.is_stochastic() {
                    let n = 100;
                    (0..n).map(|_| err_of(rng)).sum::<f64>() / n as f64
                } else {
                    err_of(rng)
                };
                assert!(err <= nx * 1.05 + 1e-9, "{} expanded error: {err} vs {nx}", c.name());
            }
        });
    }

    // NOTE: the bit-identical encode→decode round-trip property for every
    // operator lives in rust/tests/wire_roundtrip.rs (it also exercises
    // parse() and the network payload-length invariant).

    #[test]
    fn prop_zero_maps_to_zero() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let x = vec![0.0f32; 128];
        for c in operators() {
            let q = c.compress(&x, &mut rng);
            assert!(q.dense.iter().all(|&v| v == 0.0), "{}", c.name());
            // and the all-zero message still round-trips through its codec
            let back = c.decode(&c.encode(&q), 128);
            assert!(back.iter().all(|&v| v == 0.0), "{}", c.name());
        }
    }

    #[test]
    fn sign_wire_is_one_bit_per_coord() {
        assert_eq!(Sign.encoded_bytes(800), 4 + 100);
        // vs 3200 bytes dense: ~32x reduction, matching the paper's claim
        assert!(Identity.encoded_bytes(800) / Sign.encoded_bytes(800) >= 30);
    }

    #[test]
    fn sign_preserves_signs_and_scale() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let x = vec![3.0f32, -1.0, 2.0, -2.0];
        let q = Sign.compress(&x, &mut rng);
        let scale = (3.0 + 1.0 + 2.0 + 2.0) / 4.0;
        assert_eq!(q.dense, vec![scale, -scale, scale, -scale]);
    }

    #[test]
    fn sign_wire_layout_is_scale_then_bitmap() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let x = vec![1.0f32, -1.0, 1.0, 1.0, -1.0, -1.0, -1.0, 1.0, 1.0];
        let q = Sign.compress(&x, &mut rng);
        let bytes = Sign.encode(&q);
        assert_eq!(bytes.len(), 4 + 2); // f32 scale + 9 bits -> 2 bytes
        assert_eq!(f32::from_le_bytes(bytes[..4].try_into().unwrap()), 1.0);
        assert_eq!(bytes[4], 0b1000_1101); // LSB-first signs of coords 0..7
        assert_eq!(bytes[5], 0b0000_0001); // coord 8
    }

    #[test]
    fn topk_keeps_largest() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let x = vec![0.1f32, -5.0, 0.2, 4.0, -0.3];
        let q = TopK { ratio: 0.4 }.compress(&x, &mut rng); // k = 2
        assert_eq!(q.dense, vec![0.0, -5.0, 0.0, 4.0, 0.0]);
        assert_eq!(q.wire_bytes, 16);
    }

    #[test]
    fn topk_wire_layout_is_index_value_pairs() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let x = vec![0.1f32, -5.0, 0.2, 4.0, -0.3];
        let c = TopK { ratio: 0.4 };
        let bytes = c.encode(&c.compress(&x, &mut rng));
        assert_eq!(bytes.len(), 16);
        // canonical ascending index order: (1, -5.0) then (3, 4.0)
        assert_eq!(u32::from_le_bytes(bytes[0..4].try_into().unwrap()), 1);
        assert_eq!(f32::from_le_bytes(bytes[4..8].try_into().unwrap()), -5.0);
        assert_eq!(u32::from_le_bytes(bytes[8..12].try_into().unwrap()), 3);
        assert_eq!(f32::from_le_bytes(bytes[12..16].try_into().unwrap()), 4.0);
    }

    #[test]
    fn topk_nan_input_is_deterministic() {
        // Regression: partial_cmp(..).unwrap_or(Equal) let NaN gradients
        // silently reorder the selection. total_cmp gives a total order
        // (NaN sorts largest), so the poisoned coordinate is always kept
        // and repeated compressions agree bit-for-bit.
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut x = vec![0.5f32, 2.0, -1.0, 3.0, 0.25, -2.5];
        x[2] = f32::NAN;
        let c = TopK { ratio: 0.5 }; // k = 3
        let a = c.compress(&x, &mut rng);
        let b = c.compress(&x, &mut rng);
        let bits = |q: &CompressedVec| q.dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a), bits(&b), "NaN input must not destabilize selection");
        assert!(a.dense[2].is_nan(), "NaN sorts largest under total_cmp, so it is kept");
        // top-3 by |.|: NaN (idx 2), 3.0 (idx 3), -2.5 (idx 5)
        assert_eq!(a.dense[1], 0.0);
        assert_eq!(a.dense[3], 3.0);
        assert_eq!(a.dense[5], -2.5);
        // and the NaN payload survives the wire bit-exactly
        let back = c.decode(&c.encode(&a), x.len());
        assert_eq!(bits(&a), back.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn topk_delta_is_k_over_d() {
        let c = TopK { ratio: 0.25 };
        assert!((c.delta(100) - 0.25).abs() < 1e-12);
        assert_eq!(c.k_for(100), 25);
        assert_eq!(c.k_for(3), 1);
    }

    #[test]
    fn randk_keeps_exactly_k() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let x = vec![1.0f32; 50];
        let q = RandK { ratio: 0.2 }.compress(&x, &mut rng);
        let nz = q.dense.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, 10);
    }

    #[test]
    fn qsgd_mean_is_damped_input() {
        // The raw quantizer is unbiased; the contraction form divides by
        // (1+omega), so the sample mean must approach x/(1+omega).
        let mut rng = Xoshiro256::seed_from_u64(5);
        let x = vec![0.7f32, -0.3, 0.1, 0.9];
        let c = Qsgd { levels: 2 };
        let damp = 1.0 / (1.0 + c.omega(4));
        let mut acc = vec![0.0f64; 4];
        let n = 20_000;
        for _ in 0..n {
            let q = c.compress(&x, &mut rng);
            for (a, &v) in acc.iter_mut().zip(&q.dense) {
                *a += v as f64;
            }
        }
        for (a, &xi) in acc.iter().zip(&x) {
            assert!((a / n as f64 - damp * xi as f64).abs() < 0.02, "{a} vs {xi}");
        }
    }

    #[test]
    fn qsgd_wire_bits() {
        // levels=1 => 3 symbols => 2 bits/coord
        assert_eq!(Qsgd { levels: 1 }.encoded_bytes(16), 4 + 4);
    }

    #[test]
    fn qsgd_packs_norm_then_levels() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let x = vec![3.0f32, -4.0]; // L2 norm 5
        let c = Qsgd { levels: 1 }; // symbols in {-1, 0, 1}, 2 bits each
        let q = c.compress(&x, &mut rng);
        let bytes = c.encode(&q);
        assert_eq!(bytes.len(), 4 + 1);
        assert_eq!(f32::from_le_bytes(bytes[..4].try_into().unwrap()), 5.0);
        let back = c.decode(&bytes, 2);
        assert_eq!(back.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   q.dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn parse_specs() {
        for spec in ["sign", "top0.01", "rand0.5", "qsgd8", "identity"] {
            let c = parse(spec).unwrap_or_else(|| panic!("{spec}"));
            assert!(!c.name().is_empty());
        }
        assert!(parse("top0").is_none());
        assert!(parse("garbage").is_none());
        assert!(parse("qsgd0").is_none());
    }

    #[test]
    fn box_clone_preserves_operator_parameters() {
        // The old clone path re-parsed `name()` — lossy for any operator
        // whose Display rounds its parameters. box_clone must be exact.
        let mut rng = Xoshiro256::seed_from_u64(11);
        let x = rng.normal_vec(200, 1.0);
        for c in operators() {
            let cl = c.box_clone();
            assert_eq!(c.name(), cl.name());
            assert_eq!(c.encoded_bytes(1234), cl.encoded_bytes(1234));
            assert_eq!(c.delta(1234).to_bits(), cl.delta(1234).to_bits());
            if !c.is_stochastic() {
                let a = c.compress(&x, &mut rng.clone());
                let b = cl.compress(&x, &mut rng.clone());
                let bits = |q: &CompressedVec| q.dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&a), bits(&b), "{}", c.name());
            }
        }
        // a ratio that does not survive the %.3 name formatting
        let odd = TopK { ratio: 0.123456789 };
        let cl = odd.box_clone();
        assert_eq!(cl.encoded_bytes(10_000), odd.encoded_bytes(10_000));
        assert!(parse(&odd.name()).unwrap().encoded_bytes(10_000) != 0); // parse still works, but...
        assert_eq!(cl.delta(10_000).to_bits(), odd.delta(10_000).to_bits());
    }

    #[test]
    fn identity_roundtrips_exactly() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let x = rng.normal_vec(333, 2.0);
        let q = Identity.compress(&x, &mut rng);
        assert_eq!(q.dense, x);
        assert_eq!(q.wire_bytes, 4 * 333);
        assert_eq!(Identity.decode(&Identity.encode(&q), 333), x);
    }

    #[test]
    #[should_panic(expected = "payload")]
    fn decode_rejects_wrong_length() {
        Sign.decode(&[0u8; 3], 16);
    }

    #[test]
    fn prop_compress_into_reused_buffers_match_fresh_compress() {
        // The zero-allocation path must be oblivious to whatever the
        // CompressedVec held before — including a repr from a DIFFERENT
        // operator and a dense buffer of the wrong length.
        forall(0x1A70, 20, |rng| {
            let d = 1 + rng.below(300);
            let x = rng.normal_vec(d, 1.0);
            for c in operators() {
                let mut fresh_rng = rng.fork(1);
                let mut reuse_rng = rng.fork(1);
                let fresh = c.compress(&x, &mut fresh_rng);
                // Dirty target: stale Sparse repr + wrong-length dense.
                let mut reused = CompressedVec {
                    dense: vec![7.7; d / 2 + 3],
                    wire_bytes: 999,
                    repr: WireRepr::Sparse { indices: vec![0, 1, 2] },
                };
                c.compress_into(&x, &mut reuse_rng, &mut reused);
                // ... and then again, so the operator's OWN reclaimed
                // buffers (indices/symbols) are exercised too.
                let mut reuse_rng2 = rng.fork(1);
                c.compress_into(&x, &mut reuse_rng2, &mut reused);
                let bits = |q: &CompressedVec| {
                    q.dense.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                };
                assert_eq!(bits(&fresh), bits(&reused), "{}: dense drifted", c.name());
                assert_eq!(fresh.wire_bytes, reused.wire_bytes, "{}", c.name());
                assert_eq!(
                    c.encode(&fresh),
                    c.encode(&reused),
                    "{}: wire bytes drifted",
                    c.name()
                );
            }
        });
    }

    #[test]
    fn prop_encode_decode_into_reuse_matches_allocating_forms() {
        forall(0x0DEC, 20, |rng| {
            let d = 1 + rng.below(200);
            let x = rng.normal_vec(d, 1.0);
            for c in operators() {
                let q = c.compress(&x, rng);
                let mut wire = vec![0xEEu8; 5]; // dirty, wrong length
                c.encode_into(&q, &mut wire);
                assert_eq!(wire, c.encode(&q), "{}", c.name());
                assert_eq!(wire.len(), q.wire_bytes, "{}", c.name());
                let mut dense = vec![3.3f32; d]; // dirty: must be overwritten
                c.decode_into(&wire, &mut dense);
                let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&dense), bits(&q.dense), "{}", c.name());
            }
        });
    }

    use crate::testing::MisCosted;

    #[test]
    fn check_wire_size_is_a_release_mode_error_path() {
        // The invariant used to be a debug_assert — absent from exactly
        // the release binaries that produce Figure 2. It must now be a
        // real error path in every profile.
        let mut rng = Xoshiro256::seed_from_u64(3);
        let x = vec![1.0f32, -2.0, 3.0];
        for c in operators() {
            let q = c.compress(&x, &mut rng);
            let wire = c.encode(&q);
            check_wire_size(c.as_ref(), &q, wire.len())
                .unwrap_or_else(|e| panic!("honest codec flagged: {e}"));
        }
        let lying = MisCosted;
        let q = lying.compress(&x, &mut rng);
        let wire = lying.encode(&q);
        let err = check_wire_size(&lying, &q, wire.len()).unwrap_err();
        assert!(err.contains("wire-size invariant"), "{err}");
        assert!(err.contains("miscosted"), "{err}");
    }
}
