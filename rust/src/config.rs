//! Configuration system: a TOML-subset parser + the typed experiment
//! config the CLI, examples, and benches all consume.
//!
//! No `toml`/`serde` crates exist in this offline environment, so the
//! parser is in-crate. Supported grammar (everything the configs in
//! `configs/` use): `[section]` tables, `key = value` with string,
//! integer, float, boolean and homogeneous-array values, `#` comments.
//! Unknown keys are rejected (catches typos in experiment sweeps).

use std::collections::BTreeMap;

use crate::algorithms::Hyper;
use crate::comm::{CostModel, StragglerDist};
use crate::data::Sharding;
use crate::optim::LrSchedule;
use crate::topology::{Topology, Weighting};

// ---------------------------------------------------------------------------
// TOML subset
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key -> value` flat map.
pub type TomlDoc = BTreeMap<String, TomlValue>;

/// Parse the TOML subset; keys are returned as `section.key` (keys before
/// any `[section]` have no prefix).
pub fn parse_toml(src: &str) -> Result<TomlDoc, String> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            section = name.to_string();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        let value = parse_value(val.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        if doc.insert(full.clone(), value).is_some() {
            return Err(format!("line {}: duplicate key {full}", lineno + 1));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items: Result<Vec<_>, _> = split_top_level(inner).iter().map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        if !s.contains('.') && !s.contains('e') && !s.contains('E') {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

// ---------------------------------------------------------------------------
// Typed experiment config
// ---------------------------------------------------------------------------

/// Which gradient oracle an experiment uses.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadConfig {
    Quadratic { dim: usize, heterogeneity: f32, noise: f32 },
    Logistic { n: usize, dim: usize, classes: usize, batch: usize, l2: f32 },
    Mlp { n: usize, dim: usize, classes: usize, hidden: usize, batch: usize },
    /// The XLA transformer on the synthetic Markov corpus.
    Transformer { model: String, artifacts_dir: String },
}

/// Optional early-stop budgets (the `[stop]` config section). Each maps
/// onto a `coordinator::StopCondition` that `Session::run_to_stop`
/// composes with the step count, so configs can sweep
/// scenario-diverse budgets (wall-clock, traffic, quality) instead of
/// fixed step counts.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StopConfig {
    /// Stop once the evaluated global loss reaches this.
    pub target_loss: Option<f64>,
    /// Stop once cumulative communication reaches this many MiB.
    pub comm_budget_mb: Option<f64>,
    /// Stop once α–β simulated wall-clock reaches this many seconds.
    pub sim_seconds_budget: Option<f64>,
    /// Stop once *real* elapsed time reaches this many seconds — a host
    /// deadline, distinct from `sim_seconds_budget` (the simulated α–β
    /// clock). The timer starts when the session is built and restarts
    /// on resume; like every `[stop]` budget it is excluded from the
    /// resume fingerprint.
    pub wall_clock_seconds: Option<f64>,
}

/// One scheduled churn event: `worker` departs at the *start* of step
/// `leave_step` and rejoins at the start of step `rejoin_step`,
/// restoring its parameters from the versioned checkpoint the session
/// stashed at departure (see `coordinator`). Steps are 0-based.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnEvent {
    pub worker: usize,
    pub leave_step: u64,
    pub rejoin_step: u64,
}

impl ChurnEvent {
    /// Parse a schedule spec: `W@LEAVE:REJOIN[,W@LEAVE:REJOIN...]`,
    /// e.g. `1@60:120,3@200:260`.
    pub fn parse_list(spec: &str) -> Result<Vec<ChurnEvent>, String> {
        let bad = |part: &str, msg: &str| {
            Err(format!(
                "churn event {part:?}: {msg} (expected WORKER@LEAVE:REJOIN, e.g. 1@60:120)"
            ))
        };
        let mut out = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((w, steps)) = part.split_once('@') else {
                return bad(part, "missing '@'");
            };
            let Some((leave, rejoin)) = steps.split_once(':') else {
                return bad(part, "missing ':'");
            };
            let (Ok(worker), Ok(leave_step), Ok(rejoin_step)) =
                (w.trim().parse::<usize>(), leave.trim().parse::<u64>(), rejoin.trim().parse::<u64>())
            else {
                return bad(part, "fields must be non-negative integers");
            };
            out.push(ChurnEvent { worker, leave_step, rejoin_step });
        }
        Ok(out)
    }
}

/// The `[faults]` config section: the deterministic fault-injection and
/// heterogeneity layer (DESIGN.md §7). Everything defaults to off, and a
/// fully-off section does not install a `FaultPlan` at all — unless
/// `enabled = true` forces a (zero-rate) plan, which the bit-identity
/// property tests use to prove the plan itself is transparent.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsConfig {
    /// Force-install a fault plan even when every rate is zero.
    pub enabled: bool,
    /// Per-message probability a dense gossip message is lost in flight.
    pub drop_prob: f64,
    /// Per-message probability a dense gossip message is delayed.
    pub delay_prob: f64,
    /// Delay lag is uniform over {1, …, max_delay} comm rounds.
    pub max_delay: u64,
    /// Per-receiver probability an inbox is shuffled before draining.
    pub reorder_prob: f64,
    /// Seed of the fault RNG stream (independent of the data/model seed,
    /// so the same training run can be replayed under different fault
    /// realizations and vice versa).
    pub seed: u64,
    /// Per-worker latency multiplier distribution (stragglers).
    pub straggler: Option<StragglerDist>,
    /// Scheduled leave/rejoin events (worker churn).
    pub churn: Vec<ChurnEvent>,
    /// Extend drop/delay/reorder to the compressed (`Payload::Encoded`)
    /// gossip of the CHOCO-family algorithms, which then maintain
    /// per-receiver x̂ replicas (DESIGN.md §7). Deliberately excluded
    /// from `is_active()`: the flag only widens *which* payloads an
    /// otherwise-active plan touches, so `compressed = true` with no
    /// active plan is a config error, not a silent no-op.
    pub compressed: bool,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            drop_prob: 0.0,
            delay_prob: 0.0,
            max_delay: 1,
            reorder_prob: 0.0,
            seed: 0,
            straggler: None,
            churn: Vec::new(),
            compressed: false,
        }
    }
}

impl FaultsConfig {
    /// Whether the session should install a `FaultPlan` / straggler
    /// multipliers at all. False means the run takes the exact legacy
    /// code path, bit-identical to a build without this module.
    pub fn is_active(&self) -> bool {
        self.enabled
            || self.drop_prob > 0.0
            || self.delay_prob > 0.0
            || self.reorder_prob > 0.0
            || self.straggler.is_some()
            || !self.churn.is_empty()
    }

    fn validate(&self, workers: usize) -> Result<(), String> {
        for (key, p) in [
            ("faults.drop_prob", self.drop_prob),
            ("faults.delay_prob", self.delay_prob),
            ("faults.reorder_prob", self.reorder_prob),
        ] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(format!("{key} must be a probability in [0, 1], got {p}"));
            }
        }
        if self.max_delay == 0 {
            return Err("faults.max_delay must be >= 1 communication round".into());
        }
        if let Some(s) = &self.straggler {
            s.validate().map_err(|e| format!("faults.straggler: {e}"))?;
        }
        let mut sorted = self.churn.clone();
        sorted.sort_by_key(|e| (e.worker, e.leave_step));
        for (i, e) in sorted.iter().enumerate() {
            if e.worker >= workers {
                return Err(format!(
                    "faults.churn: worker {} does not exist (K = {workers})",
                    e.worker
                ));
            }
            if e.leave_step >= e.rejoin_step {
                return Err(format!(
                    "faults.churn: worker {} must leave before it rejoins (got {}:{})",
                    e.worker, e.leave_step, e.rejoin_step
                ));
            }
            if let Some(prev) = i.checked_sub(1).map(|j| &sorted[j]) {
                if prev.worker == e.worker && e.leave_step < prev.rejoin_step {
                    return Err(format!(
                        "faults.churn: worker {} has overlapping absence windows",
                        e.worker
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Which socket family the multi-process transport uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportBackend {
    /// Loopback (or LAN) TCP with `TCP_NODELAY`.
    Tcp,
    /// Unix-domain stream sockets in a per-run scratch directory.
    Unix,
}

/// The `[transport]` config section. Its *presence* switches `pdsgdm
/// train` from the in-memory simulator to real multi-process training:
/// a coordinator spawns one `pdsgdm worker` OS process per worker and
/// gossip moves over sockets as CRC32-checked frames (DESIGN.md §10).
/// All durations are milliseconds.
#[derive(Clone, Debug, PartialEq)]
pub struct TransportConfig {
    pub backend: TransportBackend,
    /// Bind/dial host for the TCP backend.
    pub host: String,
    /// Scratch directory for Unix sockets + the worker config file.
    /// `None` = the OS temp dir.
    pub socket_dir: Option<String>,
    /// Dial attempts per connect are `connect_retries + 1`.
    pub connect_retries: u32,
    /// First retry backoff; doubles per attempt (with jitter) ...
    pub retry_base_ms: u64,
    /// ... up to this cap.
    pub retry_max_ms: u64,
    /// Read/write deadline applied to every socket op.
    pub io_timeout_ms: u64,
    /// Keepalive cadence while blocked waiting on a peer.
    pub heartbeat_ms: u64,
    /// Silent heartbeat intervals before a peer is declared dead.
    pub heartbeat_misses: u32,
    /// Hard deadline on one gossip round / eval collect.
    pub round_timeout_ms: u64,
    /// Fault-injection hook: SIGKILL worker `.0` at the first eval step
    /// >= `.1` (config syntax `"W@STEP"`). Drives the peer-loss tests
    /// and the CI kill leg.
    pub kill_worker: Option<(usize, u64)>,
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self {
            backend: TransportBackend::Tcp,
            host: "127.0.0.1".into(),
            socket_dir: None,
            connect_retries: 8,
            retry_base_ms: 25,
            retry_max_ms: 1600,
            io_timeout_ms: 5_000,
            heartbeat_ms: 500,
            heartbeat_misses: 10,
            round_timeout_ms: 30_000,
            kill_worker: None,
        }
    }
}

impl TransportConfig {
    fn validate(&self) -> Result<(), String> {
        for (key, v) in [
            ("transport.retry_base_ms", self.retry_base_ms),
            ("transport.io_timeout_ms", self.io_timeout_ms),
            ("transport.heartbeat_ms", self.heartbeat_ms),
            ("transport.round_timeout_ms", self.round_timeout_ms),
        ] {
            if v == 0 {
                return Err(format!("{key} must be >= 1"));
            }
        }
        if self.retry_max_ms < self.retry_base_ms {
            return Err("transport.retry_max_ms must be >= transport.retry_base_ms".into());
        }
        if self.heartbeat_misses == 0 {
            return Err("transport.heartbeat_misses must be >= 1".into());
        }
        Ok(())
    }
}

/// Parse the `"W@STEP"` kill-hook syntax.
pub fn parse_kill_spec(s: &str) -> Result<(usize, u64), String> {
    let (w, step) = s
        .split_once('@')
        .ok_or_else(|| format!("kill spec {s:?} must be WORKER@STEP"))?;
    let w = w.trim().parse().map_err(|_| format!("bad worker in kill spec {s:?}"))?;
    let step = step.trim().parse().map_err(|_| format!("bad step in kill spec {s:?}"))?;
    Ok((w, step))
}

/// The full experiment description (one `configs/*.toml` file).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub algorithm: String,
    pub workers: usize,
    pub steps: u64,
    pub eval_every: u64,
    pub seed: u64,
    pub topology: Topology,
    pub weighting: Weighting,
    pub sharding: Sharding,
    pub hyper: Hyper,
    pub compressor: Option<String>,
    pub workload: WorkloadConfig,
    pub cost_model: CostModel,
    pub stop: StopConfig,
    pub faults: FaultsConfig,
    /// `Some` = real multi-process socket training; `None` = the
    /// in-memory simulator (the default, byte-for-byte the legacy path).
    pub transport: Option<TransportConfig>,
    pub out_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "experiment".into(),
            algorithm: "pd-sgdm".into(),
            workers: 8,
            steps: 1000,
            eval_every: 50,
            seed: 42,
            topology: Topology::Ring,
            weighting: Weighting::UniformDegree,
            sharding: Sharding::Iid,
            hyper: Hyper::default(),
            compressor: None,
            workload: WorkloadConfig::Mlp { n: 4000, dim: 32, classes: 10, hidden: 64, batch: 16 },
            cost_model: CostModel::default(),
            stop: StopConfig::default(),
            faults: FaultsConfig::default(),
            transport: None,
            out_dir: "bench_out".into(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_toml_str(src: &str) -> Result<Self, String> {
        let doc = parse_toml(src)?;
        Self::from_doc(&doc)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Self::from_toml_str(&src)
    }

    fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let mut cfg = ExperimentConfig::default();
        let mut seen = std::collections::BTreeSet::new();
        let known = [
            "name", "algorithm", "workers", "steps", "eval_every", "seed",
            "topology", "weighting", "sharding.kind", "sharding.alpha",
            "hyper.eta", "hyper.mu", "hyper.weight_decay", "hyper.period",
            "hyper.gamma", "hyper.lr_schedule", "hyper.lr_milestones",
            "compressor",
            "workload.kind", "workload.dim", "workload.heterogeneity",
            "workload.noise", "workload.n", "workload.classes",
            "workload.hidden", "workload.batch", "workload.l2",
            "workload.model", "workload.artifacts_dir",
            "cost.alpha", "cost.beta", "cost.step_seconds",
            "stop.target_loss", "stop.comm_budget_mb", "stop.sim_seconds_budget",
            "stop.wall_clock_seconds",
            // `[serve]` and `[job]` are consumed by `ServeConfig` and the
            // service job queue; they're listed here so one TOML file can
            // be both an experiment config and a daemon/job description.
            "serve.listen", "serve.max_concurrent", "serve.pool_threads",
            "serve.state_dir", "serve.spool_dir", "serve.poll_ms",
            "serve.exit_when_idle",
            "job.name", "job.priority",
            "faults.enabled", "faults.drop_prob", "faults.delay_prob",
            "faults.max_delay", "faults.reorder_prob", "faults.seed",
            "faults.straggler", "faults.churn", "faults.compressed",
            "transport.backend", "transport.host", "transport.socket_dir",
            "transport.connect_retries", "transport.retry_base_ms",
            "transport.retry_max_ms", "transport.io_timeout_ms",
            "transport.heartbeat_ms", "transport.heartbeat_misses",
            "transport.round_timeout_ms", "transport.kill_worker",
            "out_dir",
        ];
        for key in doc.keys() {
            if !known.contains(&key.as_str()) {
                return Err(format!("unknown config key: {key}"));
            }
            seen.insert(key.clone());
        }

        let get_str = |k: &str| doc.get(k).and_then(|v| v.as_str().map(str::to_string));
        let get_usize = |k: &str| -> Result<Option<usize>, String> {
            match doc.get(k) {
                None => Ok(None),
                Some(v) => v
                    .as_i64()
                    .filter(|&i| i >= 0)
                    .map(|i| Some(i as usize))
                    .ok_or_else(|| format!("{k} must be a non-negative integer")),
            }
        };
        let get_f32 = |k: &str| -> Result<Option<f32>, String> {
            match doc.get(k) {
                None => Ok(None),
                Some(v) => v.as_f64().map(|f| Some(f as f32)).ok_or_else(|| format!("{k} must be a number")),
            }
        };
        let get_f64 = |k: &str| -> Result<Option<f64>, String> {
            match doc.get(k) {
                None => Ok(None),
                Some(v) => v.as_f64().map(Some).ok_or_else(|| format!("{k} must be a number")),
            }
        };

        if let Some(v) = get_str("name") {
            cfg.name = v;
        }
        if let Some(v) = get_str("algorithm") {
            if !crate::algorithms::ALL_NAMES.contains(&v.as_str()) {
                return Err(format!("unknown algorithm {v}; options: {:?}", crate::algorithms::ALL_NAMES));
            }
            cfg.algorithm = v;
        }
        if let Some(v) = get_usize("workers")? {
            cfg.workers = v;
        }
        if let Some(v) = get_usize("steps")? {
            cfg.steps = v as u64;
        }
        if let Some(v) = get_usize("eval_every")? {
            cfg.eval_every = v as u64;
        }
        if let Some(v) = get_usize("seed")? {
            cfg.seed = v as u64;
        }
        if let Some(v) = get_str("topology") {
            cfg.topology = Topology::parse(&v).ok_or_else(|| format!("unknown topology {v}"))?;
        }
        if let Some(v) = get_str("weighting") {
            cfg.weighting = match v.as_str() {
                "uniform" => Weighting::UniformDegree,
                "metropolis" => Weighting::Metropolis,
                "lazy-metropolis" => Weighting::LazyMetropolis,
                _ => return Err(format!("unknown weighting {v}")),
            };
        }
        if let Some(kind) = get_str("sharding.kind") {
            cfg.sharding = match kind.as_str() {
                "iid" => Sharding::Iid,
                "dirichlet" => Sharding::Dirichlet {
                    alpha: get_f32("sharding.alpha")?.unwrap_or(0.5) as f64,
                },
                _ => return Err(format!("unknown sharding {kind}")),
            };
        }
        // hyper
        let eta = get_f32("hyper.eta")?.unwrap_or(0.1);
        cfg.hyper.lr = match get_str("hyper.lr_schedule").as_deref() {
            None | Some("constant") => LrSchedule::Constant { eta },
            Some("step-decay") => {
                let milestones = match doc.get("hyper.lr_milestones") {
                    Some(TomlValue::Arr(a)) => a
                        .iter()
                        .map(|v| v.as_f64().ok_or("milestones must be numbers".to_string()))
                        .collect::<Result<Vec<_>, _>>()?,
                    None => vec![0.5, 0.75],
                    _ => return Err("hyper.lr_milestones must be an array".into()),
                };
                LrSchedule::StepDecay { eta0: eta, factor: 0.1, milestones, total_steps: cfg.steps }
            }
            Some("corollary1") => LrSchedule::Corollary1 { eta0: eta, k: cfg.workers, total_steps: cfg.steps },
            Some(other) => return Err(format!("unknown lr_schedule {other}")),
        };
        if let Some(v) = get_f32("hyper.mu")? {
            cfg.hyper.mu = v;
        }
        if let Some(v) = get_f32("hyper.weight_decay")? {
            cfg.hyper.weight_decay = v;
        }
        if let Some(v) = get_usize("hyper.period")? {
            cfg.hyper.period = v.max(1) as u64;
        }
        if let Some(v) = get_f32("hyper.gamma")? {
            cfg.hyper.gamma = v;
        }
        if let Some(v) = get_str("compressor") {
            if crate::compress::parse(&v).is_none() {
                return Err(format!("unknown compressor spec {v}"));
            }
            cfg.compressor = Some(v);
        }
        // workload
        if let Some(kind) = get_str("workload.kind") {
            cfg.workload = match kind.as_str() {
                "quadratic" => WorkloadConfig::Quadratic {
                    dim: get_usize("workload.dim")?.unwrap_or(64),
                    heterogeneity: get_f32("workload.heterogeneity")?.unwrap_or(1.0),
                    noise: get_f32("workload.noise")?.unwrap_or(0.1),
                },
                "logistic" => WorkloadConfig::Logistic {
                    n: get_usize("workload.n")?.unwrap_or(4000),
                    dim: get_usize("workload.dim")?.unwrap_or(32),
                    classes: get_usize("workload.classes")?.unwrap_or(10),
                    batch: get_usize("workload.batch")?.unwrap_or(16),
                    l2: get_f32("workload.l2")?.unwrap_or(1e-4),
                },
                "mlp" => WorkloadConfig::Mlp {
                    n: get_usize("workload.n")?.unwrap_or(4000),
                    dim: get_usize("workload.dim")?.unwrap_or(32),
                    classes: get_usize("workload.classes")?.unwrap_or(10),
                    hidden: get_usize("workload.hidden")?.unwrap_or(64),
                    batch: get_usize("workload.batch")?.unwrap_or(16),
                },
                "transformer" => WorkloadConfig::Transformer {
                    model: get_str("workload.model").unwrap_or_else(|| "tiny".into()),
                    artifacts_dir: get_str("workload.artifacts_dir")
                        .unwrap_or_else(|| "artifacts".into()),
                },
                _ => return Err(format!("unknown workload {kind}")),
            };
        }
        // cost model
        if let Some(v) = get_f32("cost.alpha")? {
            cfg.cost_model.alpha = v as f64;
        }
        if let Some(v) = get_f32("cost.beta")? {
            cfg.cost_model.beta = v as f64;
        }
        if let Some(v) = get_f32("cost.step_seconds")? {
            cfg.cost_model.step_seconds = v as f64;
        }
        // stop budgets
        if let Some(v) = get_f32("stop.target_loss")? {
            cfg.stop.target_loss = Some(v as f64);
        }
        if let Some(v) = get_f32("stop.comm_budget_mb")? {
            cfg.stop.comm_budget_mb = Some(v as f64);
        }
        if let Some(v) = get_f32("stop.sim_seconds_budget")? {
            cfg.stop.sim_seconds_budget = Some(v as f64);
        }
        if let Some(v) = get_f64("stop.wall_clock_seconds")? {
            cfg.stop.wall_clock_seconds = Some(v);
        }
        // faults
        if let Some(v) = doc.get("faults.enabled") {
            cfg.faults.enabled = v
                .as_bool()
                .ok_or_else(|| "faults.enabled must be a boolean".to_string())?;
        }
        if let Some(v) = get_f64("faults.drop_prob")? {
            cfg.faults.drop_prob = v;
        }
        if let Some(v) = get_f64("faults.delay_prob")? {
            cfg.faults.delay_prob = v;
        }
        if let Some(v) = get_usize("faults.max_delay")? {
            cfg.faults.max_delay = v as u64;
        }
        if let Some(v) = get_f64("faults.reorder_prob")? {
            cfg.faults.reorder_prob = v;
        }
        if let Some(v) = get_usize("faults.seed")? {
            cfg.faults.seed = v as u64;
        }
        if let Some(v) = get_str("faults.straggler") {
            cfg.faults.straggler = Some(StragglerDist::parse(&v)?);
        }
        if let Some(v) = get_str("faults.churn") {
            cfg.faults.churn = ChurnEvent::parse_list(&v)?;
        }
        if let Some(v) = doc.get("faults.compressed") {
            cfg.faults.compressed = v
                .as_bool()
                .ok_or_else(|| "faults.compressed must be a boolean".to_string())?;
        }
        // transport: any `transport.*` key switches socket mode on.
        if doc.keys().any(|k| k.starts_with("transport.")) {
            let mut t = TransportConfig::default();
            if let Some(v) = get_str("transport.backend") {
                t.backend = match v.as_str() {
                    "tcp" => TransportBackend::Tcp,
                    "unix" => TransportBackend::Unix,
                    other => return Err(format!("unknown transport backend {other}; options: tcp, unix")),
                };
            }
            if let Some(v) = get_str("transport.host") {
                t.host = v;
            }
            if let Some(v) = get_str("transport.socket_dir") {
                t.socket_dir = Some(v);
            }
            if let Some(v) = get_usize("transport.connect_retries")? {
                t.connect_retries = v as u32;
            }
            if let Some(v) = get_usize("transport.retry_base_ms")? {
                t.retry_base_ms = v as u64;
            }
            if let Some(v) = get_usize("transport.retry_max_ms")? {
                t.retry_max_ms = v as u64;
            }
            if let Some(v) = get_usize("transport.io_timeout_ms")? {
                t.io_timeout_ms = v as u64;
            }
            if let Some(v) = get_usize("transport.heartbeat_ms")? {
                t.heartbeat_ms = v as u64;
            }
            if let Some(v) = get_usize("transport.heartbeat_misses")? {
                t.heartbeat_misses = v as u32;
            }
            if let Some(v) = get_usize("transport.round_timeout_ms")? {
                t.round_timeout_ms = v as u64;
            }
            if let Some(v) = get_str("transport.kill_worker") {
                t.kill_worker = Some(parse_kill_spec(&v)?);
            }
            cfg.transport = Some(t);
        }
        if let Some(v) = get_str("out_dir") {
            cfg.out_dir = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Stable description of everything that must match between the
    /// saving and resuming runs for a checkpoint resume to be
    /// bit-identical: the problem rebuild inputs (seed, workload,
    /// topology, sharding), the optimizer (hyper, compressor), the cost
    /// model, and the eval cadence. `steps`, the `[stop]` budgets,
    /// `name`, and `out_dir` are deliberately excluded — changing those
    /// is the *point* of resuming. Stored in the `PDSGDM02` header and
    /// checked by `Session::load_state`.
    pub fn resume_fingerprint(&self) -> String {
        format!(
            "algo={} k={} eval_every={} seed={} topo={:?} weighting={:?} sharding={:?} \
             hyper={:?} comp={:?} workload={:?} cost={:?} faults={:?}",
            self.algorithm,
            self.workers,
            self.eval_every,
            self.seed,
            self.topology,
            self.weighting,
            self.sharding,
            self.hyper,
            self.compressor,
            self.workload,
            self.cost_model,
            self.faults,
        )
    }

    /// Serialize back into the TOML subset `from_toml_str` reads, so
    /// the coordinator can hand worker processes the *exact* resolved
    /// experiment (`from_toml_str(cfg.to_toml()) == cfg` for every
    /// representable config — float fields print their shortest
    /// round-trip form). Errs on states `from_doc` cannot produce
    /// (warmup schedules, non-default decay factors, straggler/churn
    /// plans), none of which socket mode permits anyway.
    pub fn to_toml(&self) -> Result<String, String> {
        fn esc(s: &str) -> String {
            format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
        }
        let mut out = String::new();
        let mut line = |s: String| {
            out.push_str(&s);
            out.push('\n');
        };
        line(format!("name = {}", esc(&self.name)));
        line(format!("algorithm = {}", esc(&self.algorithm)));
        line(format!("workers = {}", self.workers));
        line(format!("steps = {}", self.steps));
        line(format!("eval_every = {}", self.eval_every));
        line(format!("seed = {}", self.seed));
        let topo = match self.topology {
            Topology::Ring => "ring".to_string(),
            Topology::Chain => "chain".to_string(),
            Topology::Complete => "complete".to_string(),
            Topology::Star => "star".to_string(),
            Topology::Torus2d => "torus".to_string(),
            Topology::Hypercube => "hypercube".to_string(),
            Topology::ExpGraph => "expgraph".to_string(),
            Topology::RandomRegular { degree } => format!("random-regular:{degree}"),
        };
        line(format!("topology = {}", esc(&topo)));
        let weighting = match self.weighting {
            Weighting::UniformDegree => "uniform",
            Weighting::Metropolis => "metropolis",
            Weighting::LazyMetropolis => "lazy-metropolis",
        };
        line(format!("weighting = {}", esc(weighting)));
        line(format!("out_dir = {}", esc(&self.out_dir)));
        line("".into());
        match self.sharding {
            Sharding::Iid => line("sharding.kind = \"iid\"".into()),
            Sharding::Dirichlet { alpha } => {
                line("sharding.kind = \"dirichlet\"".into());
                // `from_doc` reads alpha through f32; print the f32 form
                // so it re-parses to the identical value.
                line(format!("sharding.alpha = {:?}", alpha as f32));
            }
        }
        line("".into());
        let (eta, schedule) = match &self.hyper.lr {
            LrSchedule::Constant { eta } => (*eta, None),
            LrSchedule::StepDecay { eta0, factor, milestones, total_steps } => {
                if *factor != 0.1 {
                    return Err("to_toml: step-decay factor must be 0.1".into());
                }
                if *total_steps != self.steps {
                    return Err("to_toml: step-decay horizon differs from steps".into());
                }
                let ms = milestones
                    .iter()
                    .map(|m| format!("{m:?}"))
                    .collect::<Vec<_>>()
                    .join(", ");
                (*eta0, Some(("step-decay", Some(ms))))
            }
            LrSchedule::Corollary1 { eta0, k, total_steps } => {
                if *k != self.workers || *total_steps != self.steps {
                    return Err("to_toml: corollary1 horizon differs from (workers, steps)".into());
                }
                (*eta0, Some(("corollary1", None)))
            }
            LrSchedule::Warmup { .. } => {
                return Err("to_toml: warmup schedules have no config syntax".into())
            }
        };
        line(format!("hyper.eta = {eta:?}"));
        if let Some((name, milestones)) = schedule {
            line(format!("hyper.lr_schedule = {}", esc(name)));
            if let Some(ms) = milestones {
                line(format!("hyper.lr_milestones = [{ms}]"));
            }
        }
        line(format!("hyper.mu = {:?}", self.hyper.mu));
        line(format!("hyper.weight_decay = {:?}", self.hyper.weight_decay));
        line(format!("hyper.period = {}", self.hyper.period));
        line(format!("hyper.gamma = {:?}", self.hyper.gamma));
        if let Some(c) = &self.compressor {
            line(format!("compressor = {}", esc(c)));
        }
        line("".into());
        match &self.workload {
            WorkloadConfig::Quadratic { dim, heterogeneity, noise } => {
                line("workload.kind = \"quadratic\"".into());
                line(format!("workload.dim = {dim}"));
                line(format!("workload.heterogeneity = {heterogeneity:?}"));
                line(format!("workload.noise = {noise:?}"));
            }
            WorkloadConfig::Logistic { n, dim, classes, batch, l2 } => {
                line("workload.kind = \"logistic\"".into());
                line(format!("workload.n = {n}"));
                line(format!("workload.dim = {dim}"));
                line(format!("workload.classes = {classes}"));
                line(format!("workload.batch = {batch}"));
                line(format!("workload.l2 = {l2:?}"));
            }
            WorkloadConfig::Mlp { n, dim, classes, hidden, batch } => {
                line("workload.kind = \"mlp\"".into());
                line(format!("workload.n = {n}"));
                line(format!("workload.dim = {dim}"));
                line(format!("workload.classes = {classes}"));
                line(format!("workload.hidden = {hidden}"));
                line(format!("workload.batch = {batch}"));
            }
            WorkloadConfig::Transformer { model, artifacts_dir } => {
                line("workload.kind = \"transformer\"".into());
                line(format!("workload.model = {}", esc(model)));
                line(format!("workload.artifacts_dir = {}", esc(artifacts_dir)));
            }
        }
        line("".into());
        // `from_doc` reads the cost model through f32 — print f32 forms.
        line(format!("cost.alpha = {:?}", self.cost_model.alpha as f32));
        line(format!("cost.beta = {:?}", self.cost_model.beta as f32));
        line(format!("cost.step_seconds = {:?}", self.cost_model.step_seconds as f32));
        if let Some(v) = self.stop.target_loss {
            line(format!("stop.target_loss = {:?}", v as f32));
        }
        if let Some(v) = self.stop.comm_budget_mb {
            line(format!("stop.comm_budget_mb = {:?}", v as f32));
        }
        if let Some(v) = self.stop.sim_seconds_budget {
            line(format!("stop.sim_seconds_budget = {:?}", v as f32));
        }
        if let Some(v) = self.stop.wall_clock_seconds {
            line(format!("stop.wall_clock_seconds = {v:?}"));
        }
        if self.faults.straggler.is_some() || !self.faults.churn.is_empty() {
            return Err("to_toml: straggler/churn plans have no serializer".into());
        }
        if self.faults != FaultsConfig::default() {
            line(format!("faults.enabled = {}", self.faults.enabled));
            line(format!("faults.drop_prob = {:?}", self.faults.drop_prob));
            line(format!("faults.delay_prob = {:?}", self.faults.delay_prob));
            line(format!("faults.max_delay = {}", self.faults.max_delay));
            line(format!("faults.reorder_prob = {:?}", self.faults.reorder_prob));
            line(format!("faults.seed = {}", self.faults.seed));
            line(format!("faults.compressed = {}", self.faults.compressed));
        }
        if let Some(t) = &self.transport {
            line("".into());
            line(format!(
                "transport.backend = {}",
                esc(match t.backend {
                    TransportBackend::Tcp => "tcp",
                    TransportBackend::Unix => "unix",
                })
            ));
            line(format!("transport.host = {}", esc(&t.host)));
            if let Some(d) = &t.socket_dir {
                line(format!("transport.socket_dir = {}", esc(d)));
            }
            line(format!("transport.connect_retries = {}", t.connect_retries));
            line(format!("transport.retry_base_ms = {}", t.retry_base_ms));
            line(format!("transport.retry_max_ms = {}", t.retry_max_ms));
            line(format!("transport.io_timeout_ms = {}", t.io_timeout_ms));
            line(format!("transport.heartbeat_ms = {}", t.heartbeat_ms));
            line(format!("transport.heartbeat_misses = {}", t.heartbeat_misses));
            line(format!("transport.round_timeout_ms = {}", t.round_timeout_ms));
            if let Some((w, s)) = t.kill_worker {
                line(format!("transport.kill_worker = {}", esc(&format!("{w}@{s}"))));
            }
        }
        Ok(out)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.workers == 0 {
            return Err("workers must be >= 1".into());
        }
        if !(0.0..1.0).contains(&self.hyper.mu) {
            return Err(format!("mu must be in [0,1), got {}", self.hyper.mu));
        }
        if self.hyper.period == 0 {
            return Err("period must be >= 1".into());
        }
        if self.hyper.gamma <= 0.0 {
            return Err("gamma must be > 0".into());
        }
        if self.eval_every == 0 {
            // Regression guard: the old driver computed
            // `(t + 1) % eval_every` and panicked with a division by
            // zero. Configs must name a real cadence; `Session` itself
            // additionally treats a raw eval_every of 0 as
            // "endpoints-only" rather than dividing by it.
            return Err("eval_every must be >= 1".into());
        }
        for (key, v) in [
            ("stop.comm_budget_mb", self.stop.comm_budget_mb),
            ("stop.sim_seconds_budget", self.stop.sim_seconds_budget),
            ("stop.wall_clock_seconds", self.stop.wall_clock_seconds),
        ] {
            if let Some(v) = v {
                if !(v > 0.0) || !v.is_finite() {
                    return Err(format!("{key} must be a finite number > 0, got {v}"));
                }
            }
        }
        if let Some(l) = self.stop.target_loss {
            // Every workload in this repo has a non-negative loss, so a
            // zero/negative (or non-finite) target can never trigger —
            // reject it instead of silently running to the step ceiling.
            if !(l > 0.0) || !l.is_finite() {
                return Err(format!("stop.target_loss must be a finite number > 0, got {l}"));
            }
        }
        // Topology feasibility (torus factorization, hypercube power of
        // two, random-regular handshake lemma, ...) lives with the
        // topology definitions so the CLI and config surface one message.
        self.topology
            .validate(self.workers)
            .map_err(|e| format!("topology: {e}"))?;
        if let Sharding::Dirichlet { alpha } = self.sharding {
            // α ≤ 0 is outside the Dirichlet's domain; the gamma sampler
            // would silently hand back NaN/degenerate shards.
            if !(alpha > 0.0) || !alpha.is_finite() {
                return Err(format!(
                    "sharding.alpha must be a finite concentration > 0, got {alpha}"
                ));
            }
        }
        if self.faults.compressed {
            if !self.faults.is_active() {
                return Err(
                    "faults.compressed = true has no effect without an active fault plan; \
                     enable faults.enabled or a non-zero drop/delay/reorder rate"
                        .into(),
                );
            }
            const COMPRESSED_ALGOS: [&str; 3] = ["cpd-sgdm", "choco-sgd", "deepsqueeze"];
            if !COMPRESSED_ALGOS.contains(&self.algorithm.as_str()) {
                return Err(format!(
                    "faults.compressed only applies to the compressed-gossip algorithms \
                     (cpd-sgdm, choco-sgd, deepsqueeze); {} exchanges dense payloads, \
                     which the fault plan already covers",
                    self.algorithm
                ));
            }
        }
        self.faults.validate(self.workers)?;
        if let Some(t) = &self.transport {
            t.validate()?;
            // Socket mode replays the sequential pd-sgdm schedule one
            // row per OS process; anything that couples workers through
            // shared in-process state can't be split across processes
            // and is rejected up front (DESIGN.md §10).
            if self.algorithm != "pd-sgdm" {
                return Err(format!(
                    "[transport] supports algorithm = \"pd-sgdm\" only (got {}); \
                     compressed/tracking variants keep cross-worker state in-process",
                    self.algorithm
                ));
            }
            if self.compressor.is_some() {
                return Err("[transport] does not support compressed gossip yet".into());
            }
            if self.faults.is_active() || !self.faults.churn.is_empty() || self.faults.straggler.is_some() {
                return Err(
                    "[transport] provides real faults (peer loss, timeouts); remove the \
                     simulated [faults] section"
                        .into(),
                );
            }
            if matches!(self.workload, WorkloadConfig::Transformer { .. }) {
                return Err(
                    "[transport] does not support the transformer workload (XLA gradient \
                     state cannot be sharded per-process)"
                        .into(),
                );
            }
            if self.stop != StopConfig::default() {
                return Err(
                    "[transport] runs are step-bounded; [stop] budgets are not supported".into(),
                );
            }
            if let Some((w, _)) = t.kill_worker {
                if w >= self.workers {
                    return Err(format!(
                        "transport.kill_worker: worker {w} does not exist (K = {})",
                        self.workers
                    ));
                }
            }
        }
        Ok(())
    }
}

/// The `[serve]` config section: how the training service daemon
/// (`pdsgdm serve`) listens, schedules, and drains. Lives in the same
/// TOML file as an experiment config or on its own — `ServeConfig`
/// reads only `serve.*` keys and ignores the rest, so the daemon can be
/// pointed at any shipped config.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// `host:port` for the metrics/jobs HTTP listener. Port 0 asks the
    /// OS for an ephemeral port (the bound address is logged).
    pub listen: String,
    /// How many sessions run at once; queued jobs wait for a slot.
    pub max_concurrent: usize,
    /// Worker threads in the one shared `engine::WorkerPool` all
    /// concurrent sessions multiplex onto. `None` = available
    /// parallelism. With `max_concurrent` sessions in flight, total CPU
    /// demand is roughly `max_concurrent` step loops fanning onto these
    /// threads — size it to the host, not per job.
    pub pool_threads: Option<usize>,
    /// Daemon working directory: spooled job copies, per-job logs,
    /// drain checkpoints, the drain manifest, and result CSVs.
    pub state_dir: String,
    /// Optional hot-spool directory watched for `*.toml` job files
    /// (what `pdsgdm submit` writes into). `None` = only jobs named on
    /// the command line.
    pub spool_dir: Option<String>,
    /// Main-loop poll interval (drain flag, spool scan, idle check).
    pub poll_ms: u64,
    /// Exit once the queue is empty and no session is running — used by
    /// CI and batch runs; a long-lived daemon keeps waiting for work.
    pub exit_when_idle: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:9090".into(),
            max_concurrent: 2,
            pool_threads: None,
            state_dir: "serve_state".into(),
            spool_dir: None,
            poll_ms: 200,
            exit_when_idle: false,
        }
    }
}

impl ServeConfig {
    pub fn from_toml_str(src: &str) -> Result<Self, String> {
        let doc = parse_toml(src)?;
        Self::from_doc(&doc)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Self::from_toml_str(&src)
    }

    /// Read the `serve.*` keys out of any parsed document. Unknown keys
    /// are NOT rejected here — the same file usually holds a full
    /// experiment config, which does its own strict check.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let mut cfg = ServeConfig::default();
        if let Some(v) = doc.get("serve.listen") {
            cfg.listen = v
                .as_str()
                .ok_or_else(|| "serve.listen must be a string".to_string())?
                .to_string();
        }
        if let Some(v) = doc.get("serve.max_concurrent") {
            cfg.max_concurrent = v
                .as_i64()
                .filter(|&i| i >= 0)
                .ok_or_else(|| "serve.max_concurrent must be a non-negative integer".to_string())?
                as usize;
        }
        if let Some(v) = doc.get("serve.pool_threads") {
            cfg.pool_threads = Some(
                v.as_i64()
                    .filter(|&i| i >= 0)
                    .ok_or_else(|| "serve.pool_threads must be a non-negative integer".to_string())?
                    as usize,
            );
        }
        if let Some(v) = doc.get("serve.state_dir") {
            cfg.state_dir = v
                .as_str()
                .ok_or_else(|| "serve.state_dir must be a string".to_string())?
                .to_string();
        }
        if let Some(v) = doc.get("serve.spool_dir") {
            cfg.spool_dir = Some(
                v.as_str()
                    .ok_or_else(|| "serve.spool_dir must be a string".to_string())?
                    .to_string(),
            );
        }
        if let Some(v) = doc.get("serve.poll_ms") {
            cfg.poll_ms = v
                .as_i64()
                .filter(|&i| i >= 0)
                .ok_or_else(|| "serve.poll_ms must be a non-negative integer".to_string())?
                as u64;
        }
        if let Some(v) = doc.get("serve.exit_when_idle") {
            cfg.exit_when_idle = v
                .as_bool()
                .ok_or_else(|| "serve.exit_when_idle must be a boolean".to_string())?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.max_concurrent == 0 {
            return Err("serve.max_concurrent must be >= 1".into());
        }
        if self.pool_threads == Some(0) {
            return Err("serve.pool_threads must be >= 1".into());
        }
        if self.poll_ms == 0 {
            return Err("serve.poll_ms must be >= 1".into());
        }
        if self.listen.is_empty() {
            return Err("serve.listen must be host:port".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Paper §5.1 CIFAR-10-style setup, MLP proxy workload.
name = "fig1a"
algorithm = "pd-sgdm"
workers = 8
steps = 3000
eval_every = 100
seed = 7
topology = "ring"
weighting = "uniform"

[sharding]
kind = "dirichlet"
alpha = 0.5

[hyper]
eta = 0.1
mu = 0.9
weight_decay = 1e-4
period = 4
lr_schedule = "step-decay"
lr_milestones = [0.5, 0.75]

[workload]
kind = "mlp"
n = 4000
dim = 32
classes = 10
hidden = 64
batch = 16

[cost]
alpha = 5e-5
beta = 1.25e9
step_seconds = 0.05
"#;

    #[test]
    fn parses_sample_config() {
        let cfg = ExperimentConfig::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.name, "fig1a");
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.hyper.period, 4);
        assert_eq!(cfg.hyper.mu, 0.9);
        assert_eq!(cfg.topology, Topology::Ring);
        assert_eq!(cfg.sharding, Sharding::Dirichlet { alpha: 0.5 });
        match cfg.workload {
            WorkloadConfig::Mlp { hidden: 64, batch: 16, .. } => {}
            other => panic!("{other:?}"),
        }
        assert!((cfg.hyper.lr.eta(0) - 0.1).abs() < 1e-6);
        assert!((cfg.hyper.lr.eta(2999) - 0.001).abs() < 1e-6);
    }

    #[test]
    fn rejects_unknown_keys() {
        let err = ExperimentConfig::from_toml_str("typo_key = 3").unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
    }

    #[test]
    fn rejects_unknown_algorithm() {
        let err = ExperimentConfig::from_toml_str(r#"algorithm = "sgd9000""#).unwrap_err();
        assert!(err.contains("unknown algorithm"), "{err}");
    }

    #[test]
    fn rejects_bad_mu() {
        let err = ExperimentConfig::from_toml_str("[hyper]\nmu = 1.5").unwrap_err();
        assert!(err.contains("mu"), "{err}");
    }

    #[test]
    fn rejects_hypercube_with_non_power_of_two() {
        let err =
            ExperimentConfig::from_toml_str("workers = 6\ntopology = \"hypercube\"").unwrap_err();
        assert!(err.contains("hypercube requires K = 2^n"), "{err}");
    }

    #[test]
    fn rejects_infeasible_topology_combos() {
        // The config layer surfaces Topology::validate errors verbatim.
        let err = ExperimentConfig::from_toml_str("workers = 7\ntopology = \"torus\"")
            .unwrap_err();
        assert!(err.contains("no such factorization"), "{err}");
        let err =
            ExperimentConfig::from_toml_str("workers = 8\ntopology = \"random-regular:9\"")
                .unwrap_err();
        assert!(err.contains("must be < K"), "{err}");
        assert!(
            ExperimentConfig::from_toml_str("workers = 256\ntopology = \"expgraph\"").is_ok()
        );
    }

    #[test]
    fn toml_scalars() {
        let doc = parse_toml("a = 1\nb = 2.5\nc = \"x\"\nd = true\ne = [1, 2]").unwrap();
        assert_eq!(doc["a"], TomlValue::Int(1));
        assert_eq!(doc["b"], TomlValue::Float(2.5));
        assert_eq!(doc["c"], TomlValue::Str("x".into()));
        assert_eq!(doc["d"], TomlValue::Bool(true));
        assert_eq!(doc["e"], TomlValue::Arr(vec![TomlValue::Int(1), TomlValue::Int(2)]));
    }

    #[test]
    fn toml_sections_and_comments() {
        let doc = parse_toml("# top\nx = 1 # inline\n[s]\ny = \"a # not comment\"").unwrap();
        assert_eq!(doc["x"], TomlValue::Int(1));
        assert_eq!(doc["s.y"], TomlValue::Str("a # not comment".into()));
    }

    #[test]
    fn toml_rejects_duplicates_and_garbage() {
        assert!(parse_toml("a = 1\na = 2").is_err());
        assert!(parse_toml("[unclosed").is_err());
        assert!(parse_toml("novalue =").is_err());
        assert!(parse_toml("= 3").is_err());
        assert!(parse_toml("x = [1, ").is_err());
    }

    #[test]
    fn rejects_zero_eval_every() {
        // Regression: eval_every = 0 used to reach the driver's modulo
        // and panic; now it is a config error with a clear message.
        let err = ExperimentConfig::from_toml_str("eval_every = 0").unwrap_err();
        assert!(err.contains("eval_every"), "{err}");
    }

    #[test]
    fn stop_section_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml_str(
            "[stop]\ntarget_loss = 0.25\ncomm_budget_mb = 64.0\nsim_seconds_budget = 120",
        )
        .unwrap();
        assert_eq!(cfg.stop.target_loss, Some(0.25));
        assert_eq!(cfg.stop.comm_budget_mb, Some(64.0));
        assert_eq!(cfg.stop.sim_seconds_budget, Some(120.0));
        assert!(ExperimentConfig::from_toml_str("[stop]\ncomm_budget_mb = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("[stop]\nsim_seconds_budget = -1").is_err());
        // an unreachable target (losses here are non-negative) is a
        // config error, not a silently inert budget
        assert!(ExperimentConfig::from_toml_str("[stop]\ntarget_loss = -1.0").is_err());
        assert!(ExperimentConfig::from_toml_str("[stop]\ntarget_loss = 0").is_err());
    }

    #[test]
    fn compressor_spec_validated() {
        let cfg = ExperimentConfig::from_toml_str(r#"compressor = "sign""#).unwrap();
        assert_eq!(cfg.compressor.as_deref(), Some("sign"));
        assert!(ExperimentConfig::from_toml_str(r#"compressor = "zip99""#).is_err());
    }

    #[test]
    fn corollary1_schedule_from_config() {
        let cfg = ExperimentConfig::from_toml_str(
            "workers = 4\nsteps = 10000\n[hyper]\neta = 1.0\nlr_schedule = \"corollary1\"",
        )
        .unwrap();
        let expect = (4.0f64 / 10000.0).sqrt() as f32;
        assert!((cfg.hyper.lr.eta(0) - expect).abs() < 1e-7);
    }

    #[test]
    fn faults_section_parses() {
        let cfg = ExperimentConfig::from_toml_str(
            "[faults]\nenabled = true\ndrop_prob = 0.1\ndelay_prob = 0.05\nmax_delay = 3\n\
             reorder_prob = 0.2\nseed = 9\nstraggler = \"lognormal:0,0.5\"\nchurn = \"1@60:120,3@10:30\"",
        )
        .unwrap();
        assert!(cfg.faults.enabled);
        assert!(cfg.faults.is_active());
        assert_eq!(cfg.faults.drop_prob, 0.1);
        assert_eq!(cfg.faults.max_delay, 3);
        assert_eq!(cfg.faults.seed, 9);
        assert_eq!(
            cfg.faults.straggler,
            Some(crate::comm::StragglerDist::LogNormal { mu: 0.0, sigma: 0.5 })
        );
        assert_eq!(
            cfg.faults.churn,
            vec![
                ChurnEvent { worker: 1, leave_step: 60, rejoin_step: 120 },
                ChurnEvent { worker: 3, leave_step: 10, rejoin_step: 30 },
            ]
        );
        // Off by default, and an absent section is inactive.
        let plain = ExperimentConfig::default();
        assert!(!plain.faults.is_active());
    }

    #[test]
    fn rejects_out_of_range_fault_probabilities() {
        for (src, what) in [
            ("[faults]\ndrop_prob = 1.5", "drop_prob"),
            ("[faults]\ndrop_prob = -0.1", "drop_prob"),
            ("[faults]\ndelay_prob = 2", "delay_prob"),
            ("[faults]\nreorder_prob = -1", "reorder_prob"),
            ("[faults]\nmax_delay = 0", "max_delay"),
        ] {
            let err = ExperimentConfig::from_toml_str(src).unwrap_err();
            assert!(err.contains(what), "{src}: {err}");
        }
    }

    #[test]
    fn rejects_bad_straggler_and_churn_specs() {
        let err = ExperimentConfig::from_toml_str("[faults]\nstraggler = \"constant:-2\"")
            .unwrap_err();
        assert!(err.contains("positive"), "{err}");
        let err = ExperimentConfig::from_toml_str("workers = 4\n[faults]\nchurn = \"9@10:20\"")
            .unwrap_err();
        assert!(err.contains("does not exist"), "{err}");
        let err = ExperimentConfig::from_toml_str("[faults]\nchurn = \"1@20:10\"").unwrap_err();
        assert!(err.contains("leave before"), "{err}");
        let err = ExperimentConfig::from_toml_str("[faults]\nchurn = \"1@10:30,1@20:40\"")
            .unwrap_err();
        assert!(err.contains("overlapping"), "{err}");
        let err = ExperimentConfig::from_toml_str("[faults]\nchurn = \"1-10-20\"").unwrap_err();
        assert!(err.contains("churn event"), "{err}");
    }

    #[test]
    fn rejects_non_positive_dirichlet_alpha() {
        for alpha in ["0", "-0.5", "nan"] {
            let src = format!("[sharding]\nkind = \"dirichlet\"\nalpha = {alpha}");
            match ExperimentConfig::from_toml_str(&src) {
                Err(err) => assert!(err.contains("alpha") || err.contains("number"), "{err}"),
                Ok(_) => panic!("alpha = {alpha} should be rejected"),
            }
        }
        // a legitimate concentration still parses
        let cfg =
            ExperimentConfig::from_toml_str("[sharding]\nkind = \"dirichlet\"\nalpha = 0.3")
                .unwrap();
        assert_eq!(cfg.sharding, Sharding::Dirichlet { alpha: 0.30000001192092896 });
    }

    #[test]
    fn compressed_faults_parse_and_validate() {
        // Accepted: a compressed-gossip algorithm under an active plan.
        let cfg = ExperimentConfig::from_toml_str(
            "algorithm = \"cpd-sgdm\"\ncompressor = \"sign\"\n[faults]\ndrop_prob = 0.3\ncompressed = true",
        )
        .unwrap();
        assert!(cfg.faults.compressed);
        assert!(cfg.faults.is_active());
        // `compressed` alone must NOT activate a plan — and is therefore
        // rejected rather than silently inert.
        let err = ExperimentConfig::from_toml_str(
            "algorithm = \"cpd-sgdm\"\n[faults]\ncompressed = true",
        )
        .unwrap_err();
        assert!(err.contains("without an active fault plan"), "{err}");
        // Dense-only algorithms have no encoded payloads to fault.
        let err = ExperimentConfig::from_toml_str(
            "algorithm = \"pd-sgdm\"\n[faults]\ndrop_prob = 0.3\ncompressed = true",
        )
        .unwrap_err();
        assert!(err.contains("cpd-sgdm, choco-sgd, deepsqueeze"), "{err}");
        assert!(err.contains("pd-sgdm"), "{err}");
        // Type error keeps the established message shape.
        let err = ExperimentConfig::from_toml_str("[faults]\ncompressed = 1").unwrap_err();
        assert!(err.contains("faults.compressed must be a boolean"), "{err}");
        // `enabled = true` (zero-rate plan) counts as active: that is the
        // configuration the bit-identity property tests run under.
        assert!(ExperimentConfig::from_toml_str(
            "algorithm = \"choco-sgd\"\ncompressor = \"sign\"\n[faults]\nenabled = true\ncompressed = true",
        )
        .is_ok());
    }

    #[test]
    fn fingerprint_tracks_fault_config() {
        let mut a = ExperimentConfig::default();
        let b = ExperimentConfig::default();
        assert_eq!(a.resume_fingerprint(), b.resume_fingerprint());
        a.faults.drop_prob = 0.25;
        assert_ne!(
            a.resume_fingerprint(),
            b.resume_fingerprint(),
            "fault rates must invalidate cross-plan resumes"
        );
    }

    #[test]
    fn defaults_are_paper_settings() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.workers, 8); // paper: 8 workers
        assert_eq!(cfg.topology, Topology::Ring); // paper: ring
        assert_eq!(cfg.hyper.mu, 0.9); // paper: 0.9
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn wall_clock_budget_parses_and_validates() {
        let cfg =
            ExperimentConfig::from_toml_str("[stop]\nwall_clock_seconds = 2.5").unwrap();
        assert_eq!(cfg.stop.wall_clock_seconds, Some(2.5));
        assert!(ExperimentConfig::from_toml_str("[stop]\nwall_clock_seconds = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("[stop]\nwall_clock_seconds = -3").is_err());
        // Like every [stop] budget, it is not part of the resume identity.
        let mut a = ExperimentConfig::default();
        a.stop.wall_clock_seconds = Some(10.0);
        assert_eq!(
            a.resume_fingerprint(),
            ExperimentConfig::default().resume_fingerprint()
        );
    }

    #[test]
    fn serve_section_parses_with_defaults_and_overrides() {
        let src = "\
algorithm = \"pd-sgdm\"

[serve]
listen = \"127.0.0.1:0\"
max_concurrent = 3
pool_threads = 4
state_dir = \"/tmp/pdsgdm_serve\"
poll_ms = 50
exit_when_idle = true
";
        // The same file parses as both an experiment and a serve config.
        assert!(ExperimentConfig::from_toml_str(src).is_ok());
        let s = ServeConfig::from_toml_str(src).unwrap();
        assert_eq!(s.listen, "127.0.0.1:0");
        assert_eq!(s.max_concurrent, 3);
        assert_eq!(s.pool_threads, Some(4));
        assert_eq!(s.state_dir, "/tmp/pdsgdm_serve");
        assert_eq!(s.spool_dir, None);
        assert_eq!(s.poll_ms, 50);
        assert!(s.exit_when_idle);
        // No [serve] section at all → defaults.
        let d = ServeConfig::from_toml_str("algorithm = \"d-sgd\"").unwrap();
        assert_eq!(d, ServeConfig::default());
    }

    #[test]
    fn serve_section_rejects_degenerate_values() {
        assert!(ServeConfig::from_toml_str("[serve]\nmax_concurrent = 0").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\npool_threads = 0").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\npoll_ms = 0").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nlisten = 9090").is_err());
    }

    #[test]
    fn job_keys_are_accepted_by_the_experiment_parser() {
        // `pdsgdm submit` appends a [job] section to the spooled copy;
        // the strict experiment parser must keep accepting the file.
        let cfg = ExperimentConfig::from_toml_str(
            "algorithm = \"pd-sgdm\"\n[job]\nname = \"run-a\"\npriority = 5",
        )
        .unwrap();
        assert_eq!(cfg.algorithm, "pd-sgdm");
    }

    /// `to_toml` must be a fixed point of the parser: every field a
    /// worker process consumes survives serialize → parse bit-exactly,
    /// including awkward f32 values (0.3) and exponent forms (1e-4).
    #[test]
    fn to_toml_round_trips() {
        let src = r#"
            name = "rt"
            algorithm = "pd-sgdm"
            workers = 8
            steps = 120
            eval_every = 10
            seed = 7
            topology = "random-regular:3"
            weighting = "metropolis"
            sharding.kind = "dirichlet"
            sharding.alpha = 0.3
            hyper.eta = 0.05
            hyper.lr_schedule = "step-decay"
            hyper.lr_milestones = [0.5, 0.75]
            hyper.mu = 0.9
            hyper.weight_decay = 1e-4
            hyper.period = 4
            hyper.gamma = 0.4
            workload.kind = "quadratic"
            workload.dim = 16
            workload.heterogeneity = 0.3
            workload.noise = 0.01
            cost.alpha = 0.0005
            cost.beta = 0.0000000125
            cost.step_seconds = 0.002
            transport.backend = "tcp"
            transport.host = "127.0.0.1"
            transport.connect_retries = 5
            transport.retry_base_ms = 10
            transport.retry_max_ms = 400
            transport.io_timeout_ms = 2000
            transport.heartbeat_ms = 250
            transport.heartbeat_misses = 4
            transport.round_timeout_ms = 9000
            transport.kill_worker = "3@40"
        "#;
        let cfg = ExperimentConfig::from_toml_str(src).unwrap();
        let toml = cfg.to_toml().unwrap();
        let back = ExperimentConfig::from_toml_str(&toml)
            .unwrap_or_else(|e| panic!("re-parse failed: {e}\n--- emitted ---\n{toml}"));
        assert_eq!(format!("{cfg:?}"), format!("{back:?}"), "--- emitted ---\n{toml}");
        // And again through the emitted form: to_toml is a fixed point.
        assert_eq!(toml, back.to_toml().unwrap());
    }

    #[test]
    fn to_toml_round_trips_other_workloads() {
        for workload in [
            "workload.kind = \"logistic\"\nworkload.n = 64\nworkload.dim = 5\n\
             workload.classes = 3\nworkload.batch = 8\nworkload.l2 = 0.001",
            "workload.kind = \"mlp\"\nworkload.n = 64\nworkload.dim = 5\n\
             workload.classes = 3\nworkload.hidden = 7\nworkload.batch = 8",
        ] {
            let src = format!(
                "algorithm = \"pd-sgdm\"\nworkers = 4\nsteps = 20\n\
                 hyper.lr_schedule = \"corollary1\"\nstop.target_loss = 0.3\n{workload}\n"
            );
            let cfg = ExperimentConfig::from_toml_str(&src).unwrap();
            let back = ExperimentConfig::from_toml_str(&cfg.to_toml().unwrap()).unwrap();
            assert_eq!(format!("{cfg:?}"), format!("{back:?}"));
        }
    }

    #[test]
    fn to_toml_rejects_unrepresentable_schedules() {
        let mut cfg = ExperimentConfig::default();
        cfg.hyper.lr = crate::optim::LrSchedule::Warmup { eta: 0.1, warmup_steps: 5 };
        assert!(cfg.to_toml().is_err());
        cfg.hyper.lr = crate::optim::LrSchedule::StepDecay {
            eta0: 0.1,
            factor: 0.5,
            milestones: vec![0.5],
            total_steps: cfg.steps,
        };
        assert!(cfg.to_toml().is_err());
    }

    #[test]
    fn transport_section_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml_str(
            "algorithm = \"pd-sgdm\"\nworkers = 4\nsteps = 20\n\
             workload.kind = \"quadratic\"\nworkload.dim = 4\n\
             transport.backend = \"unix\"\ntransport.kill_worker = \"1@8\"\n",
        )
        .unwrap();
        let t = cfg.transport.as_ref().unwrap();
        assert_eq!(t.backend, TransportBackend::Unix);
        assert_eq!(t.kill_worker, Some((1, 8)));

        // Simulated faults and real transport are mutually exclusive
        // (validate runs inside from_doc, so the parse itself fails).
        let err = ExperimentConfig::from_toml_str(
            "algorithm = \"pd-sgdm\"\nworkers = 4\nsteps = 20\n\
             workload.kind = \"quadratic\"\nworkload.dim = 4\n\
             faults.drop_prob = 0.1\ntransport.backend = \"tcp\"\n",
        )
        .unwrap_err();
        assert!(err.contains("transport"), "{err}");

        // kill_worker index must be a real worker.
        assert!(ExperimentConfig::from_toml_str(
            "algorithm = \"pd-sgdm\"\nworkers = 4\nsteps = 20\n\
             workload.kind = \"quadratic\"\nworkload.dim = 4\n\
             transport.backend = \"tcp\"\ntransport.kill_worker = \"9@5\"\n",
        )
        .is_err());
    }
}
