//! The training driver, redesigned around a resumable, step-wise
//! [`Session`]:
//!
//! * [`Session::build`] materializes a full experiment from a
//!   [`SessionSpec`] (config → topology, algorithm, oracle, network) —
//!   optionally resuming from a versioned `PDSGDM02` checkpoint that
//!   restores *every* mutable bit of the run (worker iterates, momentum
//!   and error-feedback buffers, RNG streams, batch cursors, byte
//!   counters, the trace so far), so a resumed run reproduces the
//!   uninterrupted trace bit-identically (rust/tests/session_resume.rs).
//! * [`Session::step`] advances one synchronous global iteration;
//!   [`Session::eval_now`] records a pull-based [`TracePoint`];
//!   [`Session::run_until`] drives to a [`StopCondition`] — step count,
//!   target loss, communication budget, or simulated-wall-clock budget —
//!   evaluating on the configured cadence.
//! * [`Observer`]s receive `on_step` / `on_comm_round` / `on_eval`
//!   callbacks, replacing the old hardcoded verbose printing
//!   ([`VerboseObserver`] reproduces it).
//! * [`run`] remains as a thin shim over `Session` for the legacy
//!   `(algo, source, net, RunOpts)` call shape;
//!   [`Session::from_parts`] serves callers that own the pieces.
//!
//! Checkpoint formats: `PDSGDM02` is the full-session format written by
//! [`Session::save`]; the legacy x̄-only `PDSGDM01` files still load
//! through [`load_checkpoint`] (which also extracts x̄ from a v2 file).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::algorithms::{Algorithm, AlgorithmSpec, StepStats};
use crate::comm::{CostModel, FaultCounters, FaultPlan, Network};
use crate::config::{ChurnEvent, ExperimentConfig, WorkloadConfig};
use crate::data::Blobs;
use crate::grad::{GradientSource, Logistic, Mlp, Quadratic};
use crate::metrics::{Trace, TracePoint};
use crate::rng::Xoshiro256;
use crate::state::{StateReader, StateWriter};
use crate::topology;

/// Magic prefix of the full-session checkpoint format.
pub const CKPT_MAGIC_V2: &[u8; 8] = b"PDSGDM02";
/// Magic prefix of the legacy x̄-only checkpoint format.
pub const CKPT_MAGIC_V1: &[u8; 8] = b"PDSGDM01";

/// Options for the legacy [`run`] shim.
#[derive(Clone, Copy, Debug)]
pub struct RunOpts {
    pub steps: u64,
    /// Evaluation cadence; `0` means "endpoints only" (the t=0 point and
    /// the final step) — no longer a division-by-zero panic.
    pub eval_every: u64,
    pub cost_model: CostModel,
    /// Print progress lines to stderr (attaches a [`VerboseObserver`]).
    pub verbose: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        Self {
            steps: 1000,
            eval_every: 50,
            cost_model: CostModel::default(),
            verbose: false,
        }
    }
}

/// When [`Session::run_until`] should stop driving the loop. Budget
/// conditions are checked after every step, so the session halts within
/// one communication round of the budget; `TargetLoss` is judged on the
/// most recent evaluation point.
#[derive(Clone, Debug)]
pub enum StopCondition {
    /// Stop once the session's *total* step count reaches this value
    /// (absolute, so a resumed session continues to the same target).
    Steps(u64),
    /// Stop once the latest evaluated global loss is at or below this.
    /// Combine with a `Steps` bound inside [`StopCondition::Any`] unless
    /// the target is provably reachable.
    TargetLoss(f64),
    /// Stop once cumulative communication reaches this many MiB.
    CommBudgetMb(f64),
    /// Stop once the α–β simulated wall-clock reaches this many seconds.
    SimSecondsBudget(f64),
    /// Stop once *real* elapsed time since the session was assembled
    /// reaches this many seconds — a deadline for service jobs, distinct
    /// from [`StopCondition::SimSecondsBudget`] (which tracks the
    /// simulated α–β clock, not the host's). The anchor instant is
    /// deliberately not checkpointed: a resumed job gets a fresh
    /// deadline window.
    WallClockSeconds(f64),
    /// Stop when any member condition holds (budget sweeps compose:
    /// `Any(vec![Steps(10_000), CommBudgetMb(64.0)])`).
    Any(Vec<StopCondition>),
}

/// *Why* [`Session::run_until`] returned, queryable via
/// [`Session::last_stop_reason`]. Distinguishes a target loss genuinely
/// reached from a run whose evaluated loss went NaN/±inf: a non-finite
/// loss compares false against every target forever, so before this
/// existed a `TargetLoss` condition on a diverging run simply never
/// fired and the loop ran away to its step bound (or, with a bare
/// `TargetLoss`, forever).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The configured step count was reached.
    StepLimit,
    /// The latest evaluated loss hit the target.
    TargetReached,
    /// The latest evaluated loss is NaN/±inf — the run diverged and no
    /// loss target can ever fire, so the session stops instead of
    /// looping.
    Diverged,
    /// The cumulative communication budget was exhausted.
    CommBudget,
    /// The simulated wall-clock budget was exhausted.
    SimSecondsBudget,
    /// The real elapsed-time deadline passed.
    WallClock,
}

/// How [`Session::run_until_interruptible`] ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunOutcome {
    /// The stop condition fired — the normal end of a run.
    Stopped(StopReason),
    /// The interrupt callback returned true mid-run (e.g. the service
    /// daemon draining on SIGTERM). The session is left at a clean step
    /// boundary with a final evaluation recorded, ready for
    /// [`Session::save`]; resuming that checkpoint drops the off-cadence
    /// point and reproduces the uninterrupted trace bit-identically.
    Interrupted,
}

/// Mid-run instrumentation hooks. All methods default to no-ops; attach
/// implementations with [`Session::observe`]. Streaming metrics, early
/// stopping dashboards, and the CLI's `--verbose` all live here instead
/// of inside the driver loop.
pub trait Observer {
    /// After global iteration `t` completed (`t` is the 0-based index of
    /// the executed step; the session's step count is now `t + 1`).
    fn on_step(&mut self, t: u64, stats: &StepStats) {
        let _ = (t, stats);
    }

    /// After a step whose communication round moved `bytes` over the
    /// wire, costing `round_seconds` of simulated time.
    fn on_comm_round(&mut self, t: u64, bytes: u64, round_seconds: f64) {
        let _ = (t, bytes, round_seconds);
    }

    /// After an evaluation point was recorded. Only called on sessions
    /// with an installed fault plan: the plan's cumulative drop/delay
    /// counters at that step (encoded = compressed-gossip subset).
    fn on_fault_counters(&mut self, step: u64, counters: &FaultCounters) {
        let _ = (step, counters);
    }

    /// After an evaluation point was recorded.
    fn on_eval(&mut self, label: &str, point: &TracePoint) {
        let _ = (label, point);
    }

    /// After an evaluation point was recorded. Only called on sessions
    /// driving a real socket transport (`[transport]` runs): the
    /// fleet-aggregated wire counters at that step — retries,
    /// reconnects, timeouts, heartbeat misses, dead peers, frames and
    /// bytes actually moved (see `comm::transport`).
    fn on_transport_counters(
        &mut self,
        step: u64,
        counters: &crate::comm::transport::TransportCounters,
    ) {
        let _ = (step, counters);
    }
}

/// Reproduces the driver's old `verbose: true` stderr lines as an
/// [`Observer`]. Lines go through a pluggable [`std::io::Write`] sink:
/// the default (`VerboseObserver::default()` / [`VerboseObserver::stderr`])
/// writes to the process stderr exactly as before, while the service
/// daemon points each job at its own log file so concurrent sessions
/// never interleave on one stream.
#[derive(Default)]
pub struct VerboseObserver {
    /// `None` = process stderr (the CLI default); `Some` = captured sink.
    sink: Option<Box<dyn std::io::Write + Send>>,
}

impl VerboseObserver {
    /// The classic stderr observer (same as `default()`).
    pub fn stderr() -> Self {
        Self::default()
    }

    /// Route every progress line into `sink` instead of stderr. Write
    /// errors are swallowed — observability must never kill a run.
    pub fn to_sink(sink: Box<dyn std::io::Write + Send>) -> Self {
        Self { sink: Some(sink) }
    }

    fn emit(&mut self, line: std::fmt::Arguments<'_>) {
        use std::io::Write as _;
        match &mut self.sink {
            Some(s) => {
                let _ = writeln!(s, "{line}");
            }
            None => eprintln!("{line}"),
        }
    }
}

impl Observer for VerboseObserver {
    fn on_eval(&mut self, label: &str, p: &TracePoint) {
        self.emit(format_args!(
            "[{}] step {:>6}  loss {:.4}  acc {:.3}  comm {:.2} MB  consensus {:.3e}",
            label, p.step, p.loss, p.accuracy, p.comm_mb, p.consensus
        ));
    }

    fn on_fault_counters(&mut self, step: u64, c: &FaultCounters) {
        self.emit(format_args!(
            "[faults] step {:>6}  dropped {} ({} encoded)  delayed {} ({} encoded)",
            step, c.dropped, c.dropped_encoded, c.delayed_total, c.delayed_encoded
        ));
    }
}

/// How a [`Session`] holds each component: owned (built from a config)
/// or borrowed (wrapped around caller-owned parts, e.g. the [`run`]
/// shim and the e2e example).
enum Slot<'a, T: ?Sized> {
    Owned(Box<T>),
    Borrowed(&'a mut T),
}

impl<'a, T: ?Sized> Slot<'a, T> {
    fn get(&self) -> &T {
        match self {
            Slot::Owned(b) => b,
            Slot::Borrowed(r) => r,
        }
    }

    fn get_mut(&mut self) -> &mut T {
        match self {
            Slot::Owned(b) => b,
            Slot::Borrowed(r) => r,
        }
    }
}

/// Build instructions for [`Session::build`].
pub struct SessionSpec {
    pub config: ExperimentConfig,
    /// Resume from a `PDSGDM02` checkpoint written by [`Session::save`].
    /// The config must describe the same experiment (algorithm, K, d);
    /// mismatches are rejected at load time.
    pub resume_from: Option<PathBuf>,
}

impl SessionSpec {
    pub fn new(config: ExperimentConfig) -> Self {
        Self { config, resume_from: None }
    }

    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume_from = Some(path.into());
        self
    }
}

/// A resumable, step-wise training session: algorithm + oracle + network
/// + driver state (step counter, cost accounting, trace) + observers.
pub struct Session<'a> {
    algo: Slot<'a, dyn Algorithm + 'a>,
    source: Slot<'a, dyn GradientSource + 'a>,
    net: Slot<'a, Network>,
    cost_model: CostModel,
    /// Evaluation cadence; 0 = endpoints only.
    eval_every: u64,
    observers: Vec<Box<dyn Observer + 'a>>,
    /// Global iteration count completed so far.
    t: u64,
    sim_seconds: f64,
    cum_bytes: u64,
    links_per_worker: usize,
    prev_sent: Vec<u64>,
    trace: Trace,
    last_eval: Option<u64>,
    /// True when the latest trace point exists only because
    /// [`Session::run_until`] stopped there (off the eval cadence) — a
    /// point an uninterrupted run would never record. Stored in the
    /// checkpoint so a resume can drop exactly that point and nothing
    /// else (a user-pulled [`Session::eval_now`] at the same step is
    /// kept).
    forced_final: bool,
    /// Persistent x̄ scratch — evaluation never re-allocates K×d.
    xbar: Vec<f32>,
    /// Per-worker compute/latency multipliers from `[faults].straggler`
    /// (empty = homogeneous fleet, exact legacy cost arithmetic).
    straggler_mults: Vec<f64>,
    /// Cached `max(straggler_mults)` — synchronous rounds are priced at
    /// the slowest worker.
    straggler_slowest: f64,
    /// Scheduled leave/rejoin windows from `[faults].churn`.
    churn: Vec<ChurnEvent>,
    /// Departure-time checkpoints of currently-absent workers, keyed by
    /// worker index; a rejoining worker restores its parameters from the
    /// stashed `PDSGDM02` bytes (the x̄ the fleet had when it left).
    churn_stash: BTreeMap<usize, Vec<u8>>,
    /// Why the last [`Session::run_until`] call returned.
    last_stop_reason: Option<StopReason>,
    /// Real-time anchor for [`StopCondition::WallClockSeconds`], taken
    /// when the session is assembled. Deliberately not checkpointed: a
    /// resumed job measures its deadline from its own start.
    wall_start: std::time::Instant,
    /// Spectral gap of the built mixing matrix (0 for borrowed parts).
    pub rho: f64,
    /// The originating config, when built from one.
    pub config: Option<ExperimentConfig>,
    /// Live fleet-aggregated wire counters, set by the socket-transport
    /// coordinator (`comm::transport::run_coordinator`). When present,
    /// every eval point also fires `Observer::on_transport_counters`
    /// with a snapshot.
    transport_counters:
        Option<std::sync::Arc<std::sync::Mutex<crate::comm::transport::TransportCounters>>>,
}

/// Construct the gradient oracle a config describes. Shared between
/// [`Session::build`] and the socket-transport worker processes
/// (`comm::transport::run_worker`), which must rebuild the *identical*
/// oracle from the same seed to reproduce the in-memory run bit-exactly.
pub fn build_source(config: &ExperimentConfig) -> Result<Box<dyn GradientSource>> {
    let k = config.workers;
    Ok(match &config.workload {
        WorkloadConfig::Quadratic { dim, heterogeneity, noise } => {
            Box::new(Quadratic::new(k, *dim, *heterogeneity, *noise, config.seed))
        }
        WorkloadConfig::Logistic { n, dim, classes, batch, l2 } => {
            let data = Blobs { n: *n, dim: *dim, classes: *classes, spread: 3.0 }
                .generate(config.seed);
            Box::new(Logistic::new(data, k, config.sharding, *batch, *l2, config.seed))
        }
        WorkloadConfig::Mlp { n, dim, classes, hidden, batch } => {
            let data = Blobs { n: *n, dim: *dim, classes: *classes, spread: 3.0 }
                .generate(config.seed);
            Box::new(Mlp::new(data, k, config.sharding, *hidden, *batch, 0.2, config.seed))
        }
        WorkloadConfig::Transformer { model, artifacts_dir } => {
            let rt = crate::runtime::Runtime::new(artifacts_dir.clone())?;
            let step = rt.train_step(model)?;
            // ~64 windows per worker is plenty for a few hundred steps
            let corpus = (step.manifest.seq_len + 1) * 64 * k + (step.manifest.seq_len + 1) * 8;
            Box::new(crate::runtime::XlaGradSource::new(step, k, corpus, config.seed)?)
        }
    })
}

impl Session<'static> {
    /// Materialize a session from a config (and optionally a checkpoint).
    /// Transformer workloads require the artifacts directory (see
    /// `make artifacts`).
    pub fn build(spec: SessionSpec) -> Result<Self> {
        let SessionSpec { config, resume_from } = spec;
        config.validate().map_err(|e| anyhow!(e))?;
        let k = config.workers;
        // Sparse path: the driver never materializes a dense K×K matrix,
        // so K=1024 fleets build in O(K·deg) instead of O(K²).
        let (graph, w, rho) =
            topology::build_sparse(config.topology, k, config.weighting, config.seed);
        let net = Network::new(&graph);

        let source = build_source(&config)?;

        let x0 = source.init(config.seed);
        let compressor = config
            .compressor
            .as_deref()
            .map(|s| crate::compress::parse(s).expect("validated by config"));
        let algo = AlgorithmSpec::new(&config.algorithm, k, x0)
            .mixing(w)
            .hyper(config.hyper.clone())
            .compressor_opt(compressor)
            .seed(config.seed)
            .build()
            .map_err(|e| anyhow!(e))?;

        let mut session = Session::assemble(
            Slot::Owned(algo),
            Slot::Owned(source),
            Slot::Owned(Box::new(net)),
            config.eval_every,
            config.cost_model,
        );
        session.rho = rho;
        // Fault layer: only installed when the `[faults]` section is
        // active, so the default path runs byte-for-byte the same code
        // as before this layer existed (property-tested in
        // rust/tests/fault_injection.rs).
        let faults = &config.faults;
        if faults.is_active() {
            let mut plan = FaultPlan::new(
                k,
                faults.drop_prob,
                faults.delay_prob,
                faults.max_delay,
                faults.reorder_prob,
                faults.seed,
            );
            // Opt the compressed (Payload::Encoded) gossip into the same
            // drop/delay/reorder model; config::validate already rejected
            // the flag for dense-only algorithms.
            plan.compressed = faults.compressed;
            session.net.get_mut().set_fault_plan(plan);
            if let Some(dist) = &faults.straggler {
                // Own forked stream: multipliers are a pure function of
                // (fault seed, K), independent of every other RNG in the
                // run and redrawn identically on resume — they are
                // deliberately NOT checkpointed.
                let mut rng = Xoshiro256::seed_from_u64(faults.seed).fork(0x57A6);
                session.straggler_mults = dist.sample_all(k, &mut rng);
                session.straggler_slowest = session
                    .straggler_mults
                    .iter()
                    .copied()
                    .fold(f64::NEG_INFINITY, f64::max);
            }
            session.churn = faults.churn.clone();
        }
        session.config = Some(config);
        if let Some(path) = resume_from {
            session.load(&path)?;
        }
        Ok(session)
    }
}

impl<'a> Session<'a> {
    /// Wrap caller-owned parts in a session (the [`run`] shim and bench
    /// sweeps that pre-build `(algo, source, net)` themselves).
    /// `eval_every == 0` means endpoints-only evaluation.
    pub fn from_parts(
        algo: &'a mut dyn Algorithm,
        source: &'a mut dyn GradientSource,
        net: &'a mut Network,
        eval_every: u64,
        cost_model: CostModel,
    ) -> Self {
        Session::assemble(
            Slot::Borrowed(algo),
            Slot::Borrowed(source),
            Slot::Borrowed(net),
            eval_every,
            cost_model,
        )
    }

    fn assemble(
        algo: Slot<'a, dyn Algorithm + 'a>,
        source: Slot<'a, dyn GradientSource + 'a>,
        net: Slot<'a, Network>,
        eval_every: u64,
        cost_model: CostModel,
    ) -> Self {
        let label = algo.get().name();
        let n = net.get();
        // The α–β model prices the round at the busiest worker: its
        // degree is the link count (NOT worker 0's — on a star, node 0
        // is the hub but on other irregular graphs index 0 can be a
        // leaf) and its measured per-round bytes are the bandwidth term.
        let links_per_worker = if n.k() > 1 { n.max_degree().max(1) } else { 0 };
        let prev_sent = n.bytes_sent.clone();
        Self {
            algo,
            source,
            net,
            cost_model,
            eval_every,
            observers: Vec::new(),
            t: 0,
            sim_seconds: 0.0,
            cum_bytes: 0,
            links_per_worker,
            prev_sent,
            trace: Trace::new(label),
            last_eval: None,
            forced_final: false,
            xbar: Vec::new(),
            straggler_mults: Vec::new(),
            straggler_slowest: 1.0,
            churn: Vec::new(),
            churn_stash: BTreeMap::new(),
            last_stop_reason: None,
            wall_start: std::time::Instant::now(),
            rho: 0.0,
            config: None,
            transport_counters: None,
        }
    }

    /// Attach the shared wire-counter cell a socket-transport run keeps
    /// current; eval points then notify observers via
    /// [`Observer::on_transport_counters`].
    pub fn set_transport_counters(
        &mut self,
        counters: std::sync::Arc<std::sync::Mutex<crate::comm::transport::TransportCounters>>,
    ) {
        self.transport_counters = Some(counters);
    }

    /// Attach an observer; all attached observers receive every
    /// subsequent callback in attachment order.
    pub fn observe(&mut self, obs: Box<dyn Observer + 'a>) {
        self.observers.push(obs);
    }

    /// Run this session's engine fan-outs on a shared worker pool (see
    /// [`crate::engine::LocalStepEngine::install_shared_pool`]). The
    /// service daemon calls this so N concurrent sessions multiplex
    /// onto one thread budget instead of N pools oversubscribing the
    /// host. No-op for algorithms that own no engine.
    pub fn install_shared_pool(&mut self, pool: std::sync::Arc<crate::engine::WorkerPool>) {
        self.algo.get_mut().install_shared_pool(pool);
    }

    /// Global iterations completed so far.
    pub fn steps_done(&self) -> u64 {
        self.t
    }

    /// Cumulative wire bytes (all algorithms, including the centralized
    /// baseline's parameter-server traffic).
    pub fn comm_bytes(&self) -> u64 {
        self.cum_bytes
    }

    pub fn sim_seconds(&self) -> f64 {
        self.sim_seconds
    }

    /// Per-worker straggler latency multipliers (empty when no straggler
    /// model is configured).
    pub fn straggler_multipliers(&self) -> &[f64] {
        &self.straggler_mults
    }

    /// Snapshot of the installed fault plan's cumulative drop/delay
    /// counters; `None` when the session runs fault-free.
    pub fn fault_counters(&self) -> Option<FaultCounters> {
        self.net.get().fault_plan().map(|p| p.counters())
    }

    /// Why the last [`Session::run_until`] call returned; `None` before
    /// the first call.
    pub fn last_stop_reason(&self) -> Option<StopReason> {
        self.last_stop_reason
    }

    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    pub fn into_trace(self) -> Trace {
        self.trace
    }

    pub fn algo(&self) -> &dyn Algorithm {
        self.algo.get()
    }

    /// The averaged iterate x̄ at the current step.
    pub fn avg_params(&self) -> Vec<f32> {
        self.algo.get().avg_params()
    }

    /// Advance one synchronous global iteration, updating the α–β cost
    /// accounting and notifying observers. Does **not** evaluate — call
    /// [`Session::eval_now`] (pull-based) or use [`Session::run_until`]
    /// for cadence-driven evaluation.
    pub fn step(&mut self) -> StepStats {
        self.process_churn();
        let t = self.t;
        let stats = {
            let Self { algo, source, net, .. } = &mut *self;
            algo.get_mut().step(t, source.get_mut(), net.get_mut())
        };
        if self.straggler_mults.is_empty() {
            self.sim_seconds += self.cost_model.step_seconds;
        } else {
            // Synchronous BSP: every iteration waits for the slowest
            // worker's compute.
            self.sim_seconds += self.cost_model.step_seconds * self.straggler_slowest;
        }
        self.cum_bytes += stats.bytes;
        let mut round_seconds = 0.0;
        if stats.communicated && stats.bytes > 0 && self.links_per_worker > 0 {
            // Busiest-worker bytes this round, measured from the
            // network's per-worker counters in f64 (integer division
            // truncated small compressed payloads — e.g. Sign at small d
            // — to a zero bandwidth term). Centralized baselines
            // (C-SGDM) never touch the gossip network, so their counters
            // don't move: fall back to an even per-worker split of the
            // reported bytes.
            let measured = {
                let net = self.net.get();
                net.bytes_sent
                    .iter()
                    .zip(&self.prev_sent)
                    .map(|(now, before)| now - before)
                    .max()
                    .unwrap_or(0)
            };
            let busiest_bytes = if measured > 0 {
                measured as f64
            } else {
                stats.bytes as f64 / self.algo.get().k().max(1) as f64
            };
            round_seconds = if self.straggler_mults.is_empty() {
                self.cost_model.round_seconds(self.links_per_worker, busiest_bytes)
            } else {
                self.cost_model.straggled_round_seconds(
                    self.links_per_worker,
                    busiest_bytes,
                    self.straggler_slowest,
                )
            };
            self.sim_seconds += round_seconds;
        }
        if stats.communicated {
            let Self { net, prev_sent, .. } = &mut *self;
            prev_sent.copy_from_slice(&net.get().bytes_sent);
        }
        self.t = t + 1;
        for obs in self.observers.iter_mut() {
            obs.on_step(t, &stats);
            if stats.communicated {
                obs.on_comm_round(t, stats.bytes, round_seconds);
            }
        }
        stats
    }

    /// Apply the churn schedule at the current step, before the
    /// iteration runs: a worker whose `leave_step` is now gets a
    /// departure checkpoint stashed and its fabric links cut (every
    /// message from/to it drops, uncharged); a worker whose
    /// `rejoin_step` is now gets its links restored and its parameters
    /// reset from the stashed checkpoint's x̄ — the crash-and-restart
    /// protocol: local progress made while partitioned is discarded in
    /// favor of the consensus state the fleet had when it left (PR 4's
    /// versioned checkpoints are the transport). Rejoin before leave so
    /// a step that is one worker's rejoin and another's leave stashes
    /// the post-rejoin state.
    fn process_churn(&mut self) {
        if self.churn.is_empty() {
            return;
        }
        let t = self.t;
        let rejoins: Vec<usize> = self
            .churn
            .iter()
            .filter(|e| e.rejoin_step == t)
            .map(|e| e.worker)
            .collect();
        let leaves: Vec<usize> = self
            .churn
            .iter()
            .filter(|e| e.leave_step == t)
            .map(|e| e.worker)
            .collect();
        for w in rejoins {
            if let Some(stash) = self.churn_stash.remove(&w) {
                assert!(
                    stash.len() > 8 && &stash[..8] == CKPT_MAGIC_V2,
                    "churn stash is not a PDSGDM02 checkpoint"
                );
                let header = read_v2_header(&mut StateReader::new(&stash[8..]))
                    .expect("churn stash header is valid");
                self.algo.get_mut().set_worker_params(w, &header.xbar);
            }
            if let Some(plan) = self.net.get_mut().fault_plan_mut() {
                plan.set_absent(w, false);
            }
        }
        for w in leaves {
            let stash = self.save_state();
            self.churn_stash.insert(w, stash);
            if let Some(plan) = self.net.get_mut().fault_plan_mut() {
                plan.set_absent(w, true);
            }
        }
    }

    /// Record a [`TracePoint`] at the current step: global loss/accuracy
    /// at x̄_t, cumulative comm-MB, consensus error, and the simulated
    /// wall-clock. Pull-based — call whenever a fresh point is wanted.
    pub fn eval_now(&mut self) -> TracePoint {
        let point = {
            let Self { algo, source, xbar, t, cum_bytes, sim_seconds, .. } = &mut *self;
            let a = algo.get();
            a.avg_params_into(xbar);
            let m = source.get_mut().eval(xbar);
            TracePoint {
                step: *t,
                loss: m.loss,
                accuracy: m.accuracy,
                comm_mb: *cum_bytes as f64 / (1024.0 * 1024.0),
                consensus: a.consensus_error_about(xbar),
                grad_norm_sq: m.grad_norm_sq,
                sim_seconds: *sim_seconds,
            }
        };
        self.trace.push(point);
        self.last_eval = Some(point.step);
        self.forced_final = false; // direct pulls are deliberate; run_until overrides
        let counters = self.fault_counters();
        // Snapshot before the observer loop: observers must never block
        // on the transport's live mutex mid-callback.
        let wire = self
            .transport_counters
            .as_ref()
            .map(|c| c.lock().expect("transport counter mutex poisoned").clone());
        for obs in self.observers.iter_mut() {
            obs.on_eval(&self.trace.label, &point);
            if let Some(c) = &counters {
                obs.on_fault_counters(point.step, c);
            }
            if let Some(w) = &wire {
                obs.on_transport_counters(point.step, w);
            }
        }
        point
    }

    /// Whether `stop` holds for the current session state.
    pub fn stopped(&self, stop: &StopCondition) -> bool {
        self.reason_for(stop).is_some()
    }

    /// The [`StopReason`] `stop` yields right now, or `None` if the
    /// session should keep running. Single source of truth for
    /// [`Session::stopped`] and [`Session::last_stop_reason`].
    ///
    /// `TargetLoss` treats a non-finite evaluated loss as
    /// [`StopReason::Diverged`]: NaN/±inf compares false against every
    /// target, so without this a diverging run under a bare `TargetLoss`
    /// would loop forever (regression-tested below).
    fn reason_for(&self, stop: &StopCondition) -> Option<StopReason> {
        match stop {
            StopCondition::Steps(n) => (self.t >= *n).then_some(StopReason::StepLimit),
            StopCondition::TargetLoss(target) => {
                self.trace.points.last().and_then(|p| {
                    if !p.loss.is_finite() {
                        Some(StopReason::Diverged)
                    } else if p.loss <= *target {
                        Some(StopReason::TargetReached)
                    } else {
                        None
                    }
                })
            }
            StopCondition::CommBudgetMb(mb) => {
                (self.cum_bytes as f64 / (1024.0 * 1024.0) >= *mb)
                    .then_some(StopReason::CommBudget)
            }
            StopCondition::SimSecondsBudget(s) => {
                (self.sim_seconds >= *s).then_some(StopReason::SimSecondsBudget)
            }
            StopCondition::WallClockSeconds(s) => {
                (self.wall_start.elapsed().as_secs_f64() >= *s)
                    .then_some(StopReason::WallClock)
            }
            StopCondition::Any(conds) => conds.iter().find_map(|c| self.reason_for(c)),
        }
    }

    /// Drive the loop until `stop` holds, evaluating at the configured
    /// cadence, at the initial step of a fresh session, and at the final
    /// step. Returns the trace so far (which, for a resumed session,
    /// includes every point from before the checkpoint).
    ///
    /// Panics if `stop` involves [`StopCondition::TargetLoss`] while the
    /// session evaluates endpoints-only (`eval_every == 0`): the loss is
    /// only observed at evaluation points, so the target could never
    /// fire — a bare `TargetLoss` would loop forever and one inside
    /// `Any` would be silently inert. (Config-built sessions can't get
    /// here: `validate` rejects `eval_every == 0`.)
    pub fn run_until(&mut self, stop: StopCondition) -> &Trace {
        self.run_until_interruptible(stop, &mut || false);
        &self.trace
    }

    /// [`Session::run_until`] with a cooperative interrupt: `interrupt`
    /// is polled before every step, and when it returns true the loop
    /// exits at the current (clean) step boundary with
    /// [`RunOutcome::Interrupted`]. The session records a final
    /// evaluation exactly as an off-cadence stop would, so a checkpoint
    /// written right after can be resumed bit-identically — this is how
    /// the service daemon drains running jobs on SIGTERM.
    ///
    /// Same `TargetLoss`/`eval_every` panic contract as
    /// [`Session::run_until`].
    pub fn run_until_interruptible(
        &mut self,
        stop: StopCondition,
        interrupt: &mut dyn FnMut() -> bool,
    ) -> RunOutcome {
        fn wants_loss(stop: &StopCondition) -> bool {
            match stop {
                StopCondition::TargetLoss(_) => true,
                StopCondition::Any(cs) => cs.iter().any(wants_loss),
                _ => false,
            }
        }
        assert!(
            self.eval_every > 0 || !wants_loss(&stop),
            "StopCondition::TargetLoss needs an eval cadence (eval_every >= 1): \
             with endpoints-only evaluation the loss is never re-observed"
        );
        if self.trace.points.is_empty() {
            self.eval_now();
        }
        while !self.stopped(&stop) {
            if interrupt() {
                // Drain: leave the session exactly as an off-cadence
                // stop would — the final point marked forced, so a
                // resume drops it and replays the uninterrupted trace.
                if self.last_eval != Some(self.t) {
                    self.eval_now();
                    self.forced_final =
                        self.eval_every == 0 || self.t % self.eval_every != 0;
                }
                self.last_stop_reason = None;
                return RunOutcome::Interrupted;
            }
            self.step();
            let on_cadence = self.eval_every > 0 && self.t % self.eval_every == 0;
            if on_cadence || self.stopped(&stop) {
                self.eval_now();
                self.forced_final = !on_cadence;
            }
        }
        if self.last_eval != Some(self.t) {
            self.eval_now();
            self.forced_final = self.eval_every == 0 || self.t % self.eval_every != 0;
        }
        self.last_stop_reason = self.reason_for(&stop);
        RunOutcome::Stopped(
            self.last_stop_reason
                .expect("loop exited because the stop condition held"),
        )
    }

    /// The stop condition implied by the config: its step count plus any
    /// `[stop]` budgets. Sessions assembled from borrowed parts have no
    /// config and stop immediately — pass an explicit condition to
    /// [`Session::run_until`] instead.
    pub fn stop_condition(&self) -> StopCondition {
        let Some(cfg) = &self.config else {
            return StopCondition::Steps(self.t);
        };
        let mut conds = vec![StopCondition::Steps(cfg.steps)];
        if let Some(l) = cfg.stop.target_loss {
            conds.push(StopCondition::TargetLoss(l));
        }
        if let Some(mb) = cfg.stop.comm_budget_mb {
            conds.push(StopCondition::CommBudgetMb(mb));
        }
        if let Some(s) = cfg.stop.sim_seconds_budget {
            conds.push(StopCondition::SimSecondsBudget(s));
        }
        if let Some(s) = cfg.stop.wall_clock_seconds {
            conds.push(StopCondition::WallClockSeconds(s));
        }
        if conds.len() == 1 {
            conds.pop().unwrap()
        } else {
            StopCondition::Any(conds)
        }
    }

    /// Drive to the config-implied stop condition (see
    /// [`Session::stop_condition`]).
    pub fn run_to_stop(&mut self) -> &Trace {
        let stop = self.stop_condition();
        self.run_until(stop)
    }

    // -- full-state checkpointing (PDSGDM02) --------------------------------

    /// Serialize the session to the `PDSGDM02` checkpoint format:
    /// magic, session header (algorithm name, K, d, step, cost
    /// accounting), x̄ (so x̄-only consumers can read v2 files too), the
    /// trace so far, the network counters, and the nested full state of
    /// the algorithm and the gradient source.
    pub fn save_state(&self) -> Vec<u8> {
        let algo = self.algo.get();
        let mut w = StateWriter::new();
        w.tag("session");
        w.put_str(&algo.name());
        w.put_u64(algo.k() as u64);
        let xbar = algo.avg_params();
        w.put_u64(xbar.len() as u64);
        w.put_u64(self.t);
        w.put_f64(self.sim_seconds);
        w.put_u64(self.cum_bytes);
        // Resume-compatibility fingerprint (empty for sessions wrapped
        // around caller-owned parts) + whether the trace's last point is
        // a forced end-of-run eval (see the `forced_final` field).
        w.put_str(
            &self
                .config
                .as_ref()
                .map(|c| c.resume_fingerprint())
                .unwrap_or_default(),
        );
        w.put_u64(self.forced_final as u64);
        w.tag("xbar");
        w.put_f32s(&xbar);
        self.trace.state_save(&mut w);
        w.tag("net");
        let net = self.net.get();
        w.put_u64(net.total_bytes);
        w.put_u64(net.rounds);
        w.put_u64(net.messages);
        w.put_u64s(&net.bytes_sent);
        w.put_u64s(&self.prev_sent);
        w.tag("algo");
        let mut aw = StateWriter::new();
        algo.state_save(&mut aw);
        w.put_bytes(&aw.into_bytes());
        w.tag("source");
        let mut sw = StateWriter::new();
        self.source.get().state_save(&mut sw);
        w.put_bytes(&sw.into_bytes());
        // Trailing, optional section: present exactly when a fault plan
        // is installed, so faultless checkpoints keep the pre-fault
        // layout and older readers (which stop at "source") stay valid.
        if let Some(plan) = self.net.get().fault_plan() {
            w.tag("faults");
            w.put_bytes(&plan.state_save());
            w.put_u64(self.churn_stash.len() as u64);
            for (worker, stash) in &self.churn_stash {
                w.put_u64(*worker as u64);
                w.put_bytes(stash);
            }
        }

        let mut out = CKPT_MAGIC_V2.to_vec();
        out.extend_from_slice(&w.into_bytes());
        out
    }

    /// Write [`Session::save_state`] to `path` (creating parent dirs).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.save_state())?;
        Ok(())
    }

    /// Restore a `PDSGDM02` checkpoint into this (identically
    /// configured) session. Rejects v1 files, foreign algorithms, and
    /// shape mismatches with descriptive errors — all header/shape
    /// validation runs before any session state is touched. Errors from
    /// the nested algorithm/source blocks (corrupt interior bytes) can
    /// leave those components partially restored: on `Err`, discard the
    /// session rather than continuing to drive it.
    pub fn load_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        if bytes.len() >= 8 && &bytes[..8] == CKPT_MAGIC_V1 {
            return Err(format!(
                "not a resumable checkpoint: {} files keep only x̄ (use load_checkpoint)",
                String::from_utf8_lossy(CKPT_MAGIC_V1)
            ));
        }
        if bytes.len() < 8 || &bytes[..8] != CKPT_MAGIC_V2 {
            return Err("not a pdsgdm checkpoint (bad magic)".into());
        }
        let mut r = StateReader::new(&bytes[8..]);
        let header = read_v2_header(&mut r)?;
        let live_name = self.algo.get().name();
        if header.name != live_name {
            return Err(format!(
                "checkpoint is for algorithm {:?}, session runs {live_name:?}",
                header.name
            ));
        }
        if header.k != self.algo.get().k() {
            return Err(format!(
                "checkpoint K {} != session K {}",
                header.k,
                self.algo.get().k()
            ));
        }
        let live_d = self.source.get().dim();
        if header.d != live_d {
            return Err(format!("checkpoint d {} != session d {live_d}", header.d));
        }
        // Same algorithm/K/d is necessary but not sufficient: the
        // problem data, RNG seeding, topology, hyper-parameters, cost
        // model, and eval cadence are all rebuilt from the config, so a
        // resume under a different config (a typo'd --seed, a changed
        // --eta) would load cleanly and then silently diverge. Compare
        // the full fingerprint whenever both sides have one.
        if let Some(cfg) = &self.config {
            let live_fp = cfg.resume_fingerprint();
            if !header.fingerprint.is_empty() && header.fingerprint != live_fp {
                return Err(format!(
                    "checkpoint config does not match this session's config \
                     (resume needs identical flags except --steps / stop budgets)\n  \
                     checkpoint: {}\n  session:    {live_fp}",
                    header.fingerprint
                ));
            }
        }
        let t = header.t;
        let trace = Trace::state_load(&mut r)?;
        r.expect_tag("net")?;
        let total_bytes = r.take_u64()?;
        let rounds = r.take_u64()?;
        let messages = r.take_u64()?;
        let bytes_sent = r.take_u64s()?;
        let prev_sent = r.take_u64s()?;
        if bytes_sent.len() != self.net.get().bytes_sent.len() {
            return Err(format!(
                "checkpoint network K {} != session K {}",
                bytes_sent.len(),
                self.net.get().bytes_sent.len()
            ));
        }
        if prev_sent.len() != self.prev_sent.len() {
            return Err("checkpoint prev_sent length mismatch".into());
        }
        r.expect_tag("algo")?;
        let ablk = r.take_bytes()?;
        r.expect_tag("source")?;
        let sblk = r.take_bytes()?;
        // Optional trailing "faults" section (only written when the
        // saving session had a fault plan installed). Parsed — and its
        // presence checked against this session's own plan — before any
        // state is mutated.
        let faults_blk = if r.is_done() {
            None
        } else {
            r.expect_tag("faults")?;
            let plan_bytes = r.take_bytes()?;
            let n = r.take_u64()? as usize;
            let mut stashes = BTreeMap::new();
            for _ in 0..n {
                let worker = r.take_u64()? as usize;
                let stash = r.take_bytes()?.to_vec();
                stashes.insert(worker, stash);
            }
            Some((plan_bytes, stashes))
        };
        if faults_blk.is_some() != self.net.get().faults_active() {
            return Err(if faults_blk.is_some() {
                "checkpoint carries fault-injection state but this session has no \
                 [faults] section configured"
                    .into()
            } else {
                "this session has a [faults] section configured but the checkpoint \
                 carries no fault-injection state"
                    .into()
            });
        }
        // Everything above was parse + validate only — no session state
        // has been touched yet, so header/shape/truncation errors leave
        // the session exactly as it was. The nested loads below mutate
        // the algorithm/source in place; if one of them errs midway
        // (corrupt interior bytes), the session is partially restored
        // and MUST be discarded — `Session::build` does exactly that on
        // the resume path.
        self.algo.get_mut().state_load(&mut StateReader::new(ablk))?;
        self.source.get_mut().state_load(&mut StateReader::new(sblk))?;
        {
            let net = self.net.get_mut();
            net.total_bytes = total_bytes;
            net.rounds = rounds;
            net.messages = messages;
            net.bytes_sent.copy_from_slice(&bytes_sent);
        }
        if let Some((plan_bytes, stashes)) = faults_blk {
            self.net
                .get_mut()
                .fault_plan_mut()
                .expect("presence checked against faults_active above")
                .state_load(plan_bytes)?;
            self.churn_stash = stashes;
        }

        self.t = t;
        self.sim_seconds = header.sim_seconds;
        self.cum_bytes = header.cum_bytes;
        self.prev_sent = prev_sent;
        let mut trace = trace;
        // `run_until` force-evaluates at the step it stops on; when that
        // step is off the eval cadence, the point exists only because
        // the interrupted run *ended* there — an uninterrupted run would
        // never record it. The saved `forced_final` marker identifies
        // exactly that point (a user-pulled eval_now at the same step is
        // kept), so dropping it keeps the resumed trace bit-identical to
        // the uninterrupted one; if the resumed run stops at this same
        // step again, the point is recomputed identically (evaluation
        // consumes no randomness).
        if header.forced_final {
            if let Some(p) = trace.points.last() {
                let off_cadence = self.eval_every == 0 || p.step % self.eval_every != 0;
                if p.step == t && p.step != 0 && off_cadence {
                    trace.points.pop();
                }
            }
        }
        self.forced_final = false;
        self.last_eval = trace.points.last().map(|p| p.step);
        self.trace = trace;
        Ok(())
    }

    /// Read and [`Session::load_state`] a checkpoint file.
    pub fn load(&mut self, path: &Path) -> Result<()> {
        let bytes = std::fs::read(path)?;
        self.load_state(&bytes).map_err(|e| anyhow!("{path:?}: {e}"))
    }
}

/// Legacy one-shot driver, now a thin shim over [`Session::from_parts`]:
/// drive `algo` on `source` over `net` for `opts.steps` iterations,
/// recording the paper's observables on the `opts.eval_every` cadence.
pub fn run(
    algo: &mut dyn Algorithm,
    source: &mut dyn GradientSource,
    net: &mut Network,
    opts: RunOpts,
) -> Trace {
    let mut session = Session::from_parts(algo, source, net, opts.eval_every, opts.cost_model);
    if opts.verbose {
        session.observe(Box::new(VerboseObserver::default()));
    }
    session.run_until(StopCondition::Steps(opts.steps));
    session.into_trace()
}

// ---------------------------------------------------------------------------
// PDSGDM02 header (single definition shared by every v2 reader)
// ---------------------------------------------------------------------------

/// The fixed `"session"` + `"xbar"` header every `PDSGDM02` file opens
/// with. `Session::save_state` writes it; `Session::load_state` and
/// [`load_checkpoint`] both parse it through [`read_v2_header`], so the
/// layout lives in exactly one writer/reader pair — extending the
/// header means touching `save_state` and this struct, nothing else.
struct V2Header {
    name: String,
    k: usize,
    d: usize,
    t: u64,
    sim_seconds: f64,
    cum_bytes: u64,
    /// `ExperimentConfig::resume_fingerprint` of the saving run; empty
    /// for sessions wrapped around caller-owned parts.
    fingerprint: String,
    /// Whether the trace's last point is a forced end-of-run eval.
    forced_final: bool,
    /// The averaged iterate x̄ (the v1-compatible payload).
    xbar: Vec<f32>,
}

fn read_v2_header(r: &mut StateReader) -> Result<V2Header, String> {
    r.expect_tag("session")?;
    let name = r.take_str()?.to_string();
    let k = r.take_u64()? as usize;
    let d = r.take_u64()? as usize;
    let t = r.take_u64()?;
    let sim_seconds = r.take_f64()?;
    let cum_bytes = r.take_u64()?;
    let fingerprint = r.take_str()?.to_string();
    let forced_final = r.take_u64()? != 0;
    r.expect_tag("xbar")?;
    let xbar = r.take_f32s()?;
    Ok(V2Header { name, k, d, t, sim_seconds, cum_bytes, fingerprint, forced_final, xbar })
}

// ---------------------------------------------------------------------------
// x̄-only checkpoint helpers (v1 format + v2 extraction)
// ---------------------------------------------------------------------------

/// Binary checkpoint of the averaged iterate only (legacy `PDSGDM01`
/// layout: magic, d, then f32 LE data). Full-state checkpoints are
/// written by [`Session::save`] instead.
pub fn save_checkpoint(path: &Path, x: &[f32]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut buf = Vec::with_capacity(8 + 8 + 4 * x.len());
    buf.extend_from_slice(CKPT_MAGIC_V1);
    buf.extend_from_slice(&(x.len() as u64).to_le_bytes());
    for v in x {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, buf)?;
    Ok(())
}

/// Load the averaged iterate from *either* checkpoint generation:
/// `PDSGDM01` files are x̄-only by construction; `PDSGDM02` files carry
/// x̄ in their header, so old tooling keeps working against new
/// checkpoints.
pub fn load_checkpoint(path: &Path) -> Result<Vec<f32>> {
    let buf = std::fs::read(path)?;
    if buf.len() >= 8 && &buf[..8] == CKPT_MAGIC_V2 {
        return read_v2_header(&mut StateReader::new(&buf[8..]))
            .map(|h| h.xbar)
            .map_err(|e| anyhow!("{path:?}: {e}"));
    }
    if buf.len() < 16 || &buf[..8] != CKPT_MAGIC_V1 {
        anyhow::bail!("{path:?}: not a pdsgdm checkpoint");
    }
    let d = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    if buf.len() != 16 + 4 * d {
        anyhow::bail!("{path:?}: truncated checkpoint (d={d}, len={})", buf.len());
    }
    Ok(buf[16..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn quick_config(algorithm: &str) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.algorithm = algorithm.into();
        c.workers = 4;
        c.steps = 60;
        c.eval_every = 20;
        c.workload = WorkloadConfig::Quadratic { dim: 16, heterogeneity: 1.0, noise: 0.05 };
        c.hyper.lr = crate::optim::LrSchedule::Constant { eta: 0.05 };
        c
    }

    fn run_session(cfg: ExperimentConfig) -> Trace {
        let mut s = Session::build(SessionSpec::new(cfg)).unwrap();
        s.run_to_stop();
        s.into_trace()
    }

    #[test]
    fn session_builds_and_runs_every_algorithm() {
        for name in crate::algorithms::ALL_NAMES {
            let trace = run_session(quick_config(name));
            // t=0 point + 3 eval points
            assert_eq!(trace.points.len(), 4, "{name}");
            assert!(trace.final_loss().is_finite(), "{name}");
            assert!(
                trace.final_loss() < trace.points[0].loss,
                "{name}: no progress"
            );
        }
    }

    #[test]
    fn trace_comm_mb_is_monotone() {
        let trace = run_session(quick_config("pd-sgdm"));
        for w in trace.points.windows(2) {
            assert!(w[1].comm_mb >= w[0].comm_mb);
            assert!(w[1].sim_seconds >= w[0].sim_seconds);
        }
    }

    #[test]
    fn rho_matches_topology() {
        let mut c = quick_config("pd-sgdm");
        c.topology = crate::topology::Topology::Complete;
        let s = Session::build(SessionSpec::new(c)).unwrap();
        assert!((s.rho - 1.0).abs() < 1e-6);
    }

    #[test]
    fn eval_cadence_includes_final_partial_window() {
        let mut c = quick_config("pd-sgdm");
        c.steps = 50;
        c.eval_every = 20; // evals at 20, 40 and the final 50
        let trace = run_session(c);
        let steps: Vec<u64> = trace.points.iter().map(|p| p.step).collect();
        assert_eq!(steps, vec![0, 20, 40, 50]);
    }

    #[test]
    fn run_shim_matches_session_loop() {
        // The legacy entry point is a shim over Session — identical trace.
        let mut c = quick_config("pd-sgdm");
        c.steps = 40;
        let via_session = run_session(c.clone());
        let mut s = Session::build(SessionSpec::new(c)).unwrap();
        let via_shim = {
            let Session { algo, source, net, .. } = &mut s;
            run(
                algo.get_mut(),
                source.get_mut(),
                net.get_mut(),
                RunOpts { steps: 40, eval_every: 20, verbose: false, ..Default::default() },
            )
        };
        assert_eq!(via_session.points.len(), via_shim.points.len());
        for (a, b) in via_session.points.iter().zip(&via_shim.points) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
            assert_eq!(a.sim_seconds.to_bits(), b.sim_seconds.to_bits());
        }
    }

    #[test]
    fn eval_every_zero_is_endpoints_only_not_a_panic() {
        // Regression: the old driver computed `(t + 1) % opts.eval_every`
        // and panicked with a division by zero.
        let mut c = quick_config("pd-sgdm");
        c.steps = 30;
        let mut s = Session::build(SessionSpec::new(c)).unwrap();
        s.eval_every = 0;
        s.run_until(StopCondition::Steps(30));
        let steps: Vec<u64> = s.trace().points.iter().map(|p| p.step).collect();
        assert_eq!(steps, vec![0, 30]);
    }

    #[test]
    fn observers_see_steps_comm_rounds_and_evals() {
        #[derive(Default)]
        struct Counter {
            steps: u64,
            rounds: u64,
            evals: u64,
            comm_bytes: u64,
        }
        impl Observer for Counter {
            fn on_step(&mut self, _t: u64, _s: &StepStats) {
                self.steps += 1;
            }
            fn on_comm_round(&mut self, _t: u64, bytes: u64, secs: f64) {
                self.rounds += 1;
                self.comm_bytes += bytes;
                assert!(secs > 0.0);
            }
            fn on_eval(&mut self, label: &str, _p: &TracePoint) {
                assert!(label.contains("pd-sgdm"));
                self.evals += 1;
            }
        }
        // Observers are boxed into the session, so count through a cell.
        use std::cell::RefCell;
        use std::rc::Rc;
        struct Shared(Rc<RefCell<Counter>>);
        impl Observer for Shared {
            fn on_step(&mut self, t: u64, s: &StepStats) {
                self.0.borrow_mut().on_step(t, s);
            }
            fn on_comm_round(&mut self, t: u64, b: u64, s: f64) {
                self.0.borrow_mut().on_comm_round(t, b, s);
            }
            fn on_eval(&mut self, l: &str, p: &TracePoint) {
                self.0.borrow_mut().on_eval(l, p);
            }
        }
        let counter = Rc::new(RefCell::new(Counter::default()));
        let mut s = Session::build(SessionSpec::new(quick_config("pd-sgdm"))).unwrap();
        s.observe(Box::new(Shared(Rc::clone(&counter))));
        s.run_to_stop();
        let c = counter.borrow();
        assert_eq!(c.steps, 60);
        assert_eq!(c.rounds, 60 / 4); // period 4
        assert_eq!(c.evals, 4); // 0, 20, 40, 60
        assert_eq!(c.comm_bytes, s.comm_bytes());
    }

    #[test]
    fn stop_condition_comm_budget_halts_within_one_round() {
        let mut c = quick_config("pd-sgdm");
        c.steps = 10_000; // budget must bite long before this
        let mut s = Session::build(SessionSpec::new(c)).unwrap();
        // One round: K=4 ring, degree 2, d=16 => 4 * 2 * 64 = 512 bytes.
        let round_bytes = 512.0;
        let budget_mb = (3.5 * round_bytes) / (1024.0 * 1024.0);
        s.run_until(StopCondition::Any(vec![
            StopCondition::Steps(10_000),
            StopCondition::CommBudgetMb(budget_mb),
        ]));
        let got = s.comm_bytes() as f64;
        assert!(got >= budget_mb * 1024.0 * 1024.0, "stopped under budget");
        assert!(
            got <= budget_mb * 1024.0 * 1024.0 + round_bytes,
            "overshot by more than one round: {got}"
        );
        assert!(s.steps_done() < 10_000);
    }

    #[test]
    fn stop_condition_target_loss_and_sim_budget() {
        let mut c = quick_config("pd-sgdm");
        c.steps = 5_000;
        let mut s = Session::build(SessionSpec::new(c.clone())).unwrap();
        let start_loss = s.eval_now().loss;
        s.run_until(StopCondition::Any(vec![
            StopCondition::Steps(5_000),
            StopCondition::TargetLoss(start_loss * 0.5),
        ]));
        assert!(s.trace().final_loss() <= start_loss * 0.5);
        assert!(s.steps_done() < 5_000, "target should hit early");

        let mut s2 = Session::build(SessionSpec::new(c)).unwrap();
        s2.run_until(StopCondition::Any(vec![
            StopCondition::Steps(5_000),
            StopCondition::SimSecondsBudget(1.0),
        ]));
        assert!(s2.sim_seconds() >= 1.0);
        assert!(s2.steps_done() < 5_000);
    }

    #[test]
    fn target_loss_on_diverging_run_stops_with_diverged_reason() {
        // Regression: a non-finite evaluated loss compares false against
        // every target, so TargetLoss never fired on a diverging run and
        // the loop ran away to its step bound. eta = 50 on the quadratic
        // overflows f32 within a few dozen steps.
        let mut c = quick_config("d-sgd");
        c.steps = 5_000;
        c.eval_every = 5;
        c.hyper.lr = crate::optim::LrSchedule::Constant { eta: 50.0 };
        let mut s = Session::build(SessionSpec::new(c)).unwrap();
        s.run_until(StopCondition::Any(vec![
            StopCondition::Steps(5_000),
            StopCondition::TargetLoss(1e-12),
        ]));
        assert_eq!(s.last_stop_reason(), Some(StopReason::Diverged));
        assert!(s.steps_done() < 5_000, "diverged run must stop early");
        assert!(!s.trace().final_loss().is_finite());

        // A healthy run that hits its target reports TargetReached.
        let mut s2 = Session::build(SessionSpec::new(quick_config("pd-sgdm"))).unwrap();
        let start = s2.eval_now().loss;
        s2.run_until(StopCondition::Any(vec![
            StopCondition::Steps(5_000),
            StopCondition::TargetLoss(start * 0.5),
        ]));
        assert_eq!(s2.last_stop_reason(), Some(StopReason::TargetReached));
    }

    #[test]
    fn straggler_multipliers_scale_the_simulated_clock() {
        let base = run_session(quick_config("pd-sgdm"));
        let mut c = quick_config("pd-sgdm");
        c.faults.straggler =
            Some(crate::comm::StragglerDist::Constant { factor: 2.0 });
        let mut s = Session::build(SessionSpec::new(c)).unwrap();
        assert_eq!(s.straggler_multipliers(), &[2.0; 4]);
        s.run_to_stop();
        // Every step and round is priced at exactly 2x the slowest
        // worker, and the fault plan is a zero-rate transparent one, so
        // the clock doubles while the trajectory is untouched.
        let t0 = base.points.last().unwrap();
        let t1 = s.trace().points.last().unwrap();
        assert_eq!(t0.loss.to_bits(), t1.loss.to_bits());
        assert!((t1.sim_seconds - 2.0 * t0.sim_seconds).abs() < 1e-9 * t0.sim_seconds.abs());
    }

    #[test]
    fn compressed_fault_session_runs_and_reports_counters() {
        use std::cell::Cell;
        use std::rc::Rc;
        let mut c = quick_config("cpd-sgdm");
        c.compressor = Some("sign".into());
        c.faults.drop_prob = 0.5;
        c.faults.compressed = true;
        let mut s = Session::build(SessionSpec::new(c)).unwrap();
        // The counter hook fires on every eval of a faulted session.
        struct Probe(Rc<Cell<u64>>);
        impl Observer for Probe {
            fn on_fault_counters(&mut self, _step: u64, c: &FaultCounters) {
                self.0.set(c.dropped_encoded);
            }
        }
        let seen = Rc::new(Cell::new(0));
        s.observe(Box::new(Probe(Rc::clone(&seen))));
        s.run_to_stop();
        let counters = s.fault_counters().expect("fault plan installed");
        assert!(counters.dropped_encoded > 0, "a 50% plan must drop encoded payloads");
        assert!(counters.dropped >= counters.dropped_encoded);
        assert_eq!(seen.get(), counters.dropped_encoded, "observer saw the final snapshot");
        assert!(s.trace().final_loss().is_finite());
    }

    #[test]
    fn churn_leave_and_rejoin_completes_with_finite_loss() {
        let mut c = quick_config("pd-sgdm");
        c.faults.churn = vec![ChurnEvent { worker: 1, leave_step: 8, rejoin_step: 24 }];
        let mut s = Session::build(SessionSpec::new(c)).unwrap();
        s.run_until(StopCondition::Steps(10));
        // Mid-absence: links down, departure checkpoint stashed.
        assert!(s.net.get().is_absent(1));
        assert_eq!(s.churn_stash.len(), 1);
        s.run_to_stop();
        assert!(!s.net.get().is_absent(1), "worker 1 rejoined at step 24");
        assert!(s.churn_stash.is_empty());
        assert!(s.trace().final_loss().is_finite());
        assert!(s.trace().final_loss() < s.trace().points[0].loss);
    }

    #[test]
    fn config_stop_section_feeds_run_to_stop() {
        let mut c = quick_config("pd-sgdm");
        c.steps = 10_000;
        c.stop.sim_seconds_budget = Some(1.0);
        let mut s = Session::build(SessionSpec::new(c)).unwrap();
        s.run_to_stop();
        assert!(s.sim_seconds() >= 1.0);
        assert!(s.steps_done() < 10_000);
    }

    #[test]
    fn v2_checkpoint_roundtrips_through_load_checkpoint_as_xbar() {
        let dir = std::env::temp_dir().join(format!("pdsgdm_v2x_{}", std::process::id()));
        let path = dir.join("v2.ckpt");
        let mut s = Session::build(SessionSpec::new(quick_config("pd-sgdm"))).unwrap();
        s.run_until(StopCondition::Steps(20));
        s.save(&path).unwrap();
        let xbar = load_checkpoint(&path).unwrap();
        assert_eq!(xbar, s.avg_params());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_state_rejects_foreign_algorithm_and_v1() {
        let dir = std::env::temp_dir().join(format!("pdsgdm_rej_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut a = Session::build(SessionSpec::new(quick_config("pd-sgdm"))).unwrap();
        a.run_until(StopCondition::Steps(8));
        let bytes = a.save_state();
        let mut b = Session::build(SessionSpec::new(quick_config("d-sgd"))).unwrap();
        let err = b.load_state(&bytes).unwrap_err();
        assert!(err.contains("algorithm"), "{err}");
        // v1 files cannot resume a session
        let v1 = dir.join("v1.ckpt");
        save_checkpoint(&v1, &[1.0; 16]).unwrap();
        let err = a.load(&v1).unwrap_err().to_string();
        assert!(err.contains("x̄"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join(format!("pdsgdm_ckpt_{}", std::process::id()));
        let path = dir.join("x.ckpt");
        let x: Vec<f32> = (0..100).map(|i| i as f32 * 0.25 - 7.0).collect();
        save_checkpoint(&path, &x).unwrap();
        let y = load_checkpoint(&path).unwrap();
        assert_eq!(x, y);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_rejects_corruption() {
        let dir = std::env::temp_dir().join(format!("pdsgdm_ckpt2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxx").unwrap();
        assert!(load_checkpoint(&path).is_err());
        // truncated
        let x = vec![1.0f32; 10];
        save_checkpoint(&path, &x).unwrap();
        let mut buf = std::fs::read(&path).unwrap();
        buf.truncate(buf.len() - 3);
        std::fs::write(&path, buf).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn transformer_workload_errors_cleanly_without_artifacts() {
        let mut c = quick_config("pd-sgdm");
        c.workload = WorkloadConfig::Transformer {
            model: "tiny".into(),
            artifacts_dir: "/definitely/not/here".into(),
        };
        let err = match Session::build(SessionSpec::new(c)) {
            Ok(_) => panic!("should fail without artifacts"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn wall_clock_stop_fires_and_reports_reason() {
        let mut s = Session::build(SessionSpec::new(quick_config("pd-sgdm"))).unwrap();
        // A zero deadline is already past; a huge one is not.
        assert!(s.stopped(&StopCondition::WallClockSeconds(0.0)));
        assert!(!s.stopped(&StopCondition::WallClockSeconds(1e9)));
        s.run_until(StopCondition::Any(vec![
            StopCondition::Steps(1_000_000),
            StopCondition::WallClockSeconds(0.0),
        ]));
        assert_eq!(s.steps_done(), 0, "expired deadline must not step");
        assert_eq!(s.last_stop_reason(), Some(StopReason::WallClock));
    }

    #[test]
    fn wall_clock_stop_wires_through_config() {
        let mut c = quick_config("pd-sgdm");
        c.steps = 100_000_000; // far beyond what 50 ms of quadratic steps reach
        c.stop.wall_clock_seconds = Some(0.05);
        let mut s = Session::build(SessionSpec::new(c)).unwrap();
        s.run_to_stop();
        assert_eq!(s.last_stop_reason(), Some(StopReason::WallClock));
        assert!(s.steps_done() > 0, "a 50 ms budget allows at least one step");
        assert!(s.steps_done() < 100_000_000);
    }

    #[test]
    fn interrupted_run_resumes_bit_identically() {
        // Reference: an uninterrupted run to the step limit.
        let straight = run_session(quick_config("pd-sgdm"));

        // Interrupted run: drain after 7 steps (off the eval cadence of
        // 20), checkpoint, resume in a fresh session, finish.
        let mut s = Session::build(SessionSpec::new(quick_config("pd-sgdm"))).unwrap();
        let mut budget = 7u64;
        let outcome = s.run_until_interruptible(StopCondition::Steps(60), &mut || {
            if budget == 0 {
                true
            } else {
                budget -= 1;
                false
            }
        });
        assert_eq!(outcome, RunOutcome::Interrupted);
        assert_eq!(s.steps_done(), 7);
        assert_eq!(s.last_stop_reason(), None, "an interrupt is not a stop");
        // The drain recorded a forced off-cadence point at t=7.
        assert_eq!(s.trace().points.last().unwrap().step, 7);
        let bytes = s.save_state();

        let mut r = Session::build(SessionSpec::new(quick_config("pd-sgdm"))).unwrap();
        r.load_state(&bytes).unwrap();
        let outcome = r.run_until_interruptible(StopCondition::Steps(60), &mut || false);
        assert_eq!(outcome, RunOutcome::Stopped(StopReason::StepLimit));

        // Resume dropped the forced t=7 point: the trace matches the
        // uninterrupted run bit for bit.
        let resumed = r.trace();
        let steps: Vec<u64> = resumed.points.iter().map(|p| p.step).collect();
        assert_eq!(steps, vec![0, 20, 40, 60]);
        assert_eq!(straight.points.len(), resumed.points.len());
        for (a, b) in straight.points.iter().zip(&resumed.points) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
            assert_eq!(a.consensus.to_bits(), b.consensus.to_bits());
            assert_eq!(a.comm_mb.to_bits(), b.comm_mb.to_bits());
            assert_eq!(a.sim_seconds.to_bits(), b.sim_seconds.to_bits());
        }
    }

    #[test]
    fn interrupt_on_cadence_does_not_duplicate_the_eval_point() {
        let mut s = Session::build(SessionSpec::new(quick_config("pd-sgdm"))).unwrap();
        // Stop the interrupted loop exactly at the cadence step 20.
        let mut budget = 20u64;
        let outcome = s.run_until_interruptible(StopCondition::Steps(60), &mut || {
            if budget == 0 {
                true
            } else {
                budget -= 1;
                false
            }
        });
        assert_eq!(outcome, RunOutcome::Interrupted);
        let steps: Vec<u64> = s.trace().points.iter().map(|p| p.step).collect();
        assert_eq!(steps, vec![0, 20], "cadence point recorded once, not twice");
    }

    #[derive(Clone, Default)]
    struct SharedBuf(std::sync::Arc<std::sync::Mutex<Vec<u8>>>);

    impl std::io::Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn verbose_observer_routes_lines_to_the_sink() {
        let buf = SharedBuf::default();
        let mut s = Session::build(SessionSpec::new(quick_config("pd-sgdm"))).unwrap();
        s.observe(Box::new(VerboseObserver::to_sink(Box::new(buf.clone()))));
        s.run_until(StopCondition::Steps(20));
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        // Same line format the stderr default prints (regression: CLI
        // output is unchanged, only the destination is pluggable).
        assert!(text.contains("[pd-sgdm"), "{text}");
        assert!(text.contains("loss"), "{text}");
        assert!(text.contains("step      0"), "t=0 eval line present: {text}");
        assert!(text.lines().count() >= 2, "initial + final eval: {text}");
    }
}
