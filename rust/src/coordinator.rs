//! The training driver: wires config → (topology, algorithm, oracle,
//! network) and runs the synchronous decentralized loop, recording the
//! paper's observables at every eval point.
//!
//! Two entry points:
//!
//! * [`run`] — drive any prepared `(Algorithm, GradientSource, Network)`
//!   triple for `steps` iterations (what the figure benches call in
//!   sweeps).
//! * [`Experiment`] — build all of the above from an
//!   [`ExperimentConfig`] (what the CLI and examples use); supports all
//!   pure-Rust workloads and, when `workload.kind = "transformer"`, the
//!   XLA runtime path.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::algorithms::{self, Algorithm};
use crate::comm::{CostModel, Network};
use crate::config::{ExperimentConfig, WorkloadConfig};
use crate::data::Blobs;
use crate::grad::{GradientSource, Logistic, Mlp, Quadratic};
use crate::metrics::{Trace, TracePoint};
use crate::topology;

/// Options for the driver loop.
#[derive(Clone, Copy, Debug)]
pub struct RunOpts {
    pub steps: u64,
    pub eval_every: u64,
    pub cost_model: CostModel,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        Self {
            steps: 1000,
            eval_every: 50,
            cost_model: CostModel::default(),
            verbose: false,
        }
    }
}

/// Drive `algo` on `source` over `net` for `opts.steps` iterations.
///
/// At every `eval_every` boundary (and at the final step) records a
/// [`TracePoint`] with the paper's y-axes: global loss/accuracy at the
/// averaged iterate x̄_t, cumulative comm-MB, consensus error, and the
/// α–β simulated wall-clock.
pub fn run(
    algo: &mut dyn Algorithm,
    source: &mut dyn GradientSource,
    net: &mut Network,
    opts: RunOpts,
) -> Trace {
    let mut trace = Trace::new(algo.name());
    let mut sim_seconds = 0.0f64;
    // Cumulative wire bytes from StepStats: equals net.total_bytes for
    // decentralized algorithms (they meter through the Network) and also
    // covers centralized baselines (C-SGDM's parameter-server up+down
    // traffic never crosses the gossip topology).
    let mut cum_bytes = 0u64;
    // The α–β model prices the round at the busiest worker: its degree is
    // the link count (NOT worker 0's — on a star, node 0 is the hub but
    // on other irregular graphs index 0 can be a leaf) and its measured
    // per-round bytes are the bandwidth term.
    let links_per_worker = if net.k() > 1 { net.max_degree().max(1) } else { 0 };
    let mut prev_sent = net.bytes_sent.clone();

    let mut eval_and_push = |t: u64,
                             algo: &dyn Algorithm,
                             source: &mut dyn GradientSource,
                             cum_bytes: u64,
                             sim_seconds: f64,
                             trace: &mut Trace| {
        let xbar = algo.avg_params();
        let m = source.eval(&xbar);
        trace.push(TracePoint {
            step: t,
            loss: m.loss,
            accuracy: m.accuracy,
            comm_mb: cum_bytes as f64 / (1024.0 * 1024.0),
            consensus: algo.consensus_error(),
            grad_norm_sq: m.grad_norm_sq,
            sim_seconds,
        });
    };

    eval_and_push(0, algo, source, cum_bytes, sim_seconds, &mut trace);
    for t in 0..opts.steps {
        let stats = algo.step(t, source, net);
        sim_seconds += opts.cost_model.step_seconds;
        cum_bytes += stats.bytes;
        if stats.communicated && stats.bytes > 0 && links_per_worker > 0 {
            // Busiest-worker bytes this round, measured from the network's
            // per-worker counters in f64 (integer division truncated small
            // compressed payloads — e.g. Sign at small d — to a zero
            // bandwidth term). Centralized baselines (C-SGDM) never touch
            // the gossip network, so their counters don't move: fall back
            // to an even per-worker split of the reported bytes.
            let measured = net
                .bytes_sent
                .iter()
                .zip(&prev_sent)
                .map(|(now, before)| now - before)
                .max()
                .unwrap_or(0);
            let busiest_bytes = if measured > 0 {
                measured as f64
            } else {
                stats.bytes as f64 / algo.k().max(1) as f64
            };
            sim_seconds += opts.cost_model.round_seconds(links_per_worker, busiest_bytes);
        }
        if stats.communicated {
            prev_sent.copy_from_slice(&net.bytes_sent);
        }
        if (t + 1) % opts.eval_every == 0 || t + 1 == opts.steps {
            eval_and_push(t + 1, algo, source, cum_bytes, sim_seconds, &mut trace);
            if opts.verbose {
                let last = trace.points.last().unwrap();
                eprintln!(
                    "[{}] step {:>6}  loss {:.4}  acc {:.3}  comm {:.2} MB  consensus {:.3e}",
                    trace.label, last.step, last.loss, last.accuracy, last.comm_mb, last.consensus
                );
            }
        }
    }
    trace
}

/// A fully-materialized experiment: algorithm + oracle + network.
pub struct Experiment {
    pub config: ExperimentConfig,
    pub algo: Box<dyn Algorithm>,
    pub source: Box<dyn GradientSource>,
    pub net: Network,
    /// Spectral gap of the built mixing matrix (logged with results).
    pub rho: f64,
}

impl Experiment {
    /// Build everything from a config. Transformer workloads require the
    /// artifacts directory (see `make artifacts`).
    pub fn build(config: ExperimentConfig) -> Result<Self> {
        config.validate().map_err(|e| anyhow!(e))?;
        let k = config.workers;
        let (graph, w, rho) =
            topology::build(config.topology, k, config.weighting, config.seed);
        let net = Network::new(&graph);

        let source: Box<dyn GradientSource> = match &config.workload {
            WorkloadConfig::Quadratic { dim, heterogeneity, noise } => Box::new(
                Quadratic::new(k, *dim, *heterogeneity, *noise, config.seed),
            ),
            WorkloadConfig::Logistic { n, dim, classes, batch, l2 } => {
                let data = Blobs { n: *n, dim: *dim, classes: *classes, spread: 3.0 }
                    .generate(config.seed);
                Box::new(Logistic::new(data, k, config.sharding, *batch, *l2, config.seed))
            }
            WorkloadConfig::Mlp { n, dim, classes, hidden, batch } => {
                let data = Blobs { n: *n, dim: *dim, classes: *classes, spread: 3.0 }
                    .generate(config.seed);
                Box::new(Mlp::new(
                    data,
                    k,
                    config.sharding,
                    *hidden,
                    *batch,
                    0.2,
                    config.seed,
                ))
            }
            WorkloadConfig::Transformer { model, artifacts_dir } => {
                let rt = crate::runtime::Runtime::new(artifacts_dir.clone())?;
                let step = rt.train_step(model)?;
                // ~64 windows per worker is plenty for a few hundred steps
                let corpus = (step.manifest.seq_len + 1) * 64 * k + (step.manifest.seq_len + 1) * 8;
                Box::new(crate::runtime::XlaGradSource::new(step, k, corpus, config.seed)?)
            }
        };

        let x0 = source.init(config.seed);
        let compressor = config
            .compressor
            .as_deref()
            .map(|s| crate::compress::parse(s).expect("validated by config"));
        let algo = algorithms::by_name(
            &config.algorithm,
            k,
            x0,
            w,
            config.hyper.clone(),
            compressor,
            config.seed,
        )
        .ok_or_else(|| anyhow!("unknown algorithm {}", config.algorithm))?;

        Ok(Self { config, algo, source, net, rho })
    }

    /// Run to completion and return the trace.
    pub fn run(&mut self, verbose: bool) -> Trace {
        let opts = RunOpts {
            steps: self.config.steps,
            eval_every: self.config.eval_every,
            cost_model: self.config.cost_model,
            verbose,
        };
        run(self.algo.as_mut(), self.source.as_mut(), &mut self.net, opts)
    }
}

/// Binary checkpoint of the averaged iterate: magic, d, then f32 LE data.
/// (Own format — no serde in this environment; round-trip tested below.)
pub fn save_checkpoint(path: &Path, x: &[f32]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut buf = Vec::with_capacity(8 + 8 + 4 * x.len());
    buf.extend_from_slice(b"PDSGDM01");
    buf.extend_from_slice(&(x.len() as u64).to_le_bytes());
    for v in x {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, buf)?;
    Ok(())
}

pub fn load_checkpoint(path: &Path) -> Result<Vec<f32>> {
    let buf = std::fs::read(path)?;
    if buf.len() < 16 || &buf[..8] != b"PDSGDM01" {
        anyhow::bail!("{path:?}: not a pdsgdm checkpoint");
    }
    let d = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    if buf.len() != 16 + 4 * d {
        anyhow::bail!("{path:?}: truncated checkpoint (d={d}, len={})", buf.len());
    }
    Ok(buf[16..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn quick_config(algorithm: &str) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.algorithm = algorithm.into();
        c.workers = 4;
        c.steps = 60;
        c.eval_every = 20;
        c.workload = WorkloadConfig::Quadratic { dim: 16, heterogeneity: 1.0, noise: 0.05 };
        c.hyper.lr = crate::optim::LrSchedule::Constant { eta: 0.05 };
        c
    }

    #[test]
    fn experiment_builds_and_runs_every_algorithm() {
        for name in crate::algorithms::ALL_NAMES {
            let mut exp = Experiment::build(quick_config(name)).unwrap();
            let trace = exp.run(false);
            // t=0 point + 3 eval points
            assert_eq!(trace.points.len(), 4, "{name}");
            assert!(trace.final_loss().is_finite(), "{name}");
            assert!(
                trace.final_loss() < trace.points[0].loss,
                "{name}: no progress"
            );
        }
    }

    #[test]
    fn trace_comm_mb_is_monotone() {
        let mut exp = Experiment::build(quick_config("pd-sgdm")).unwrap();
        let trace = exp.run(false);
        for w in trace.points.windows(2) {
            assert!(w[1].comm_mb >= w[0].comm_mb);
            assert!(w[1].sim_seconds >= w[0].sim_seconds);
        }
    }

    #[test]
    fn rho_matches_topology() {
        let mut c = quick_config("pd-sgdm");
        c.topology = crate::topology::Topology::Complete;
        let exp = Experiment::build(c).unwrap();
        assert!((exp.rho - 1.0).abs() < 1e-6);
    }

    #[test]
    fn eval_cadence_includes_final_partial_window() {
        let mut c = quick_config("pd-sgdm");
        c.steps = 50;
        c.eval_every = 20; // evals at 20, 40 and the final 50
        let mut exp = Experiment::build(c).unwrap();
        let trace = exp.run(false);
        let steps: Vec<u64> = trace.points.iter().map(|p| p.step).collect();
        assert_eq!(steps, vec![0, 20, 40, 50]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join(format!("pdsgdm_ckpt_{}", std::process::id()));
        let path = dir.join("x.ckpt");
        let x: Vec<f32> = (0..100).map(|i| i as f32 * 0.25 - 7.0).collect();
        save_checkpoint(&path, &x).unwrap();
        let y = load_checkpoint(&path).unwrap();
        assert_eq!(x, y);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_rejects_corruption() {
        let dir = std::env::temp_dir().join(format!("pdsgdm_ckpt2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxx").unwrap();
        assert!(load_checkpoint(&path).is_err());
        // truncated
        let x = vec![1.0f32; 10];
        save_checkpoint(&path, &x).unwrap();
        let mut buf = std::fs::read(&path).unwrap();
        buf.truncate(buf.len() - 3);
        std::fs::write(&path, buf).unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn transformer_workload_errors_cleanly_without_artifacts() {
        let mut c = quick_config("pd-sgdm");
        c.workload = WorkloadConfig::Transformer {
            model: "tiny".into(),
            artifacts_dir: "/definitely/not/here".into(),
        };
        let err = match Experiment::build(c) {
            Ok(_) => panic!("should fail without artifacts"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }
}
