//! Synthetic datasets + decentralized sharding.
//!
//! The paper trains on CIFAR-10/ImageNet; the repro band gates those, so
//! per DESIGN.md §2 we substitute synthetic workloads that exercise the
//! same optimizer behaviour:
//!
//! * [`Blobs`] — a K-class Gaussian-mixture classification set (the
//!   "CIFAR-10 proxy" for the Figure 1/2/3 benches, consumed by the MLP
//!   and logistic gradient sources).
//! * [`MarkovCorpus`] — a token stream from a random sparse Markov chain
//!   (learnable structure; the transformer's e2e workload).
//! * [`Sharding`] — iid and Dirichlet non-iid partitions across workers,
//!   the standard way to control inter-worker heterogeneity (the paper's
//!   D^(k) distributions).

use crate::rng::Xoshiro256;

/// Dense classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub features: Vec<Vec<f32>>,
    pub labels: Vec<usize>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.features.len()
    }

    pub fn is_empty(&self) -> bool {
        self.features.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.features.first().map(|f| f.len()).unwrap_or(0)
    }
}

/// Gaussian blobs: `n` points, `classes` isotropic clusters in `dim`-D
/// with inter-center distance controlled by `spread` (larger = easier).
pub struct Blobs {
    pub n: usize,
    pub dim: usize,
    pub classes: usize,
    pub spread: f32,
}

impl Blobs {
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let centers: Vec<Vec<f32>> = (0..self.classes)
            .map(|_| rng.normal_vec(self.dim, self.spread))
            .collect();
        let mut features = Vec::with_capacity(self.n);
        let mut labels = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let c = i % self.classes; // balanced classes
            let mut x = rng.normal_vec(self.dim, 1.0);
            for (xi, ci) in x.iter_mut().zip(&centers[c]) {
                *xi += ci;
            }
            features.push(x);
            labels.push(c);
        }
        // Shuffle so shards don't stripe by class.
        let mut idx: Vec<usize> = (0..self.n).collect();
        rng.shuffle(&mut idx);
        Dataset {
            features: idx.iter().map(|&i| features[i].clone()).collect(),
            labels: idx.iter().map(|&i| labels[i]).collect(),
            n_classes: self.classes,
        }
    }
}

/// Token corpus from a random sparse first-order Markov chain over a
/// `vocab`-symbol alphabet. Each state transitions to `branching`
/// successors with Zipf-ish probabilities, so next-token entropy is far
/// below log(vocab) — a transformer that learns the chain drops its loss
/// well under ln(V), which is what the e2e driver's loss curve shows.
pub struct MarkovCorpus {
    pub vocab: usize,
    pub branching: usize,
    pub tokens: usize,
}

impl MarkovCorpus {
    pub fn generate(&self, seed: u64) -> Vec<u32> {
        assert!(self.branching >= 1 && self.branching <= self.vocab);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        // successor table + unnormalized Zipf weights
        let succ: Vec<Vec<usize>> = (0..self.vocab)
            .map(|_| rng.sample_indices(self.vocab, self.branching))
            .collect();
        let weights: Vec<f64> = (1..=self.branching).map(|r| 1.0 / r as f64).collect();
        let wsum: f64 = weights.iter().sum();

        let mut out = Vec::with_capacity(self.tokens);
        let mut state = rng.below(self.vocab);
        for _ in 0..self.tokens {
            out.push(state as u32);
            let mut u = rng.next_f64() * wsum;
            let mut next = succ[state][self.branching - 1];
            for (j, w) in weights.iter().enumerate() {
                if u < *w {
                    next = succ[state][j];
                    break;
                }
                u -= w;
            }
            state = next;
        }
        out
    }

    /// Per-token entropy of the chain (nats) — lower bound on achievable
    /// next-token loss (reported next to the e2e loss curve).
    pub fn entropy_nats(&self) -> f64 {
        let weights: Vec<f64> = (1..=self.branching).map(|r| 1.0 / r as f64).collect();
        let wsum: f64 = weights.iter().sum();
        -weights.iter().map(|w| (w / wsum) * (w / wsum).ln()).sum::<f64>()
    }
}

/// How to split a dataset across K workers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sharding {
    /// Round-robin (iid shards) — the paper's homogeneous-data setting.
    Iid,
    /// Dirichlet(alpha) label-skew: each class's examples are divided
    /// among workers by a Dirichlet draw. Small alpha => heterogeneous
    /// D^(k) (large inter-worker gradient variance).
    Dirichlet { alpha: f64 },
}

/// Partition `data` into K index shards.
pub fn shard_indices(data: &Dataset, k: usize, sharding: Sharding, seed: u64) -> Vec<Vec<usize>> {
    assert!(k >= 1);
    let mut shards = vec![Vec::new(); k];
    match sharding {
        Sharding::Iid => {
            for i in 0..data.len() {
                shards[i % k].push(i);
            }
        }
        Sharding::Dirichlet { alpha } => {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            for c in 0..data.n_classes {
                let members: Vec<usize> =
                    (0..data.len()).filter(|&i| data.labels[i] == c).collect();
                let probs = rng.dirichlet(alpha, k);
                // proportional assignment with largest-remainder rounding
                let mut cuts: Vec<usize> = probs
                    .iter()
                    .map(|p| (p * members.len() as f64).floor() as usize)
                    .collect();
                let mut assigned: usize = cuts.iter().sum();
                while assigned < members.len() {
                    let j = rng.below(k);
                    cuts[j] += 1;
                    assigned += 1;
                }
                let mut it = members.into_iter();
                for (w, &cut) in cuts.iter().enumerate() {
                    for _ in 0..cut {
                        if let Some(i) = it.next() {
                            shards[w].push(i);
                        }
                    }
                }
            }
            // Guarantee no empty shard (steal from the largest).
            for w in 0..k {
                if shards[w].is_empty() {
                    let biggest = (0..k).max_by_key(|&j| shards[j].len()).unwrap();
                    let donated = shards[biggest].pop().expect("dataset too small to shard");
                    shards[w].push(donated);
                }
            }
        }
    }
    shards
}

/// Cyclic minibatch sampler over one worker's shard.
#[derive(Clone, Debug)]
pub struct BatchIter {
    indices: Vec<usize>,
    cursor: usize,
    rng: Xoshiro256,
}

impl BatchIter {
    pub fn new(indices: Vec<usize>, seed: u64) -> Self {
        assert!(!indices.is_empty(), "empty shard");
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut idx = indices;
        rng.shuffle(&mut idx);
        Self { indices: idx, cursor: 0, rng }
    }

    /// Checkpoint this sampler's full mutable state: the (shuffled)
    /// index order, the epoch cursor, and the RNG stream. All three are
    /// needed for a resumed run to draw the exact batches the
    /// uninterrupted run would have.
    pub fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("batch-iter");
        w.put_u64s(&self.indices.iter().map(|&i| i as u64).collect::<Vec<_>>());
        w.put_u64(self.cursor as u64);
        w.put_u64s(&self.rng.state());
    }

    /// Restore state written by [`BatchIter::state_save`]. The saved
    /// order must be a *permutation of the live shard's index set* (same
    /// dataset/sharding config) — a corrupt or foreign checkpoint whose
    /// indices point outside this worker's shard (or outside the dataset
    /// entirely, which would panic deep in the gradient code) is
    /// rejected here with an `Err`, never a panic.
    pub fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("batch-iter")?;
        let indices = r.take_u64s()?;
        if indices.len() != self.indices.len() {
            return Err(format!(
                "batch-iter: saved shard size {} != live {}",
                indices.len(),
                self.indices.len()
            ));
        }
        let cursor = r.take_u64()? as usize;
        if cursor > indices.len() {
            return Err(format!("batch-iter: cursor {cursor} out of range"));
        }
        let s = r.take_u64s()?;
        let s: [u64; 4] =
            s.try_into().map_err(|_| "batch-iter: bad rng state".to_string())?;
        let indices: Vec<usize> = indices.into_iter().map(|i| i as usize).collect();
        let mut saved_sorted = indices.clone();
        saved_sorted.sort_unstable();
        let mut live_sorted = self.indices.clone();
        live_sorted.sort_unstable();
        if saved_sorted != live_sorted {
            return Err("batch-iter: saved order is not a permutation of this shard".into());
        }
        self.indices = indices;
        self.cursor = cursor;
        self.rng = Xoshiro256::from_state(s);
        Ok(())
    }

    /// Next minibatch of (up to) `b` indices; reshuffles each epoch.
    pub fn next_batch(&mut self, b: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(b);
        for _ in 0..b {
            if self.cursor == self.indices.len() {
                self.rng.shuffle(&mut self.indices);
                self.cursor = 0;
            }
            out.push(self.indices[self.cursor]);
            self.cursor += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn blobs_shapes_and_balance() {
        let ds = Blobs { n: 200, dim: 10, classes: 4, spread: 3.0 }.generate(1);
        assert_eq!(ds.len(), 200);
        assert_eq!(ds.dim(), 10);
        for c in 0..4 {
            let count = ds.labels.iter().filter(|&&l| l == c).count();
            assert_eq!(count, 50);
        }
    }

    #[test]
    fn blobs_are_separable_when_spread_large() {
        // nearest-center classification should beat chance easily
        let ds = Blobs { n: 400, dim: 8, classes: 4, spread: 8.0 }.generate(2);
        // recompute centers from the labeled data, then check 1-NN-center acc
        let mut centers = vec![vec![0.0f64; 8]; 4];
        let mut counts = [0usize; 4];
        for (x, &l) in ds.features.iter().zip(&ds.labels) {
            counts[l] += 1;
            for (c, &xi) in centers[l].iter_mut().zip(x) {
                *c += xi as f64;
            }
        }
        for (c, n) in centers.iter_mut().zip(counts) {
            c.iter_mut().for_each(|v| *v /= n as f64);
        }
        let correct = ds
            .features
            .iter()
            .zip(&ds.labels)
            .filter(|(x, &l)| {
                let d = |c: &Vec<f64>| -> f64 {
                    x.iter().zip(c).map(|(&a, b)| (a as f64 - b).powi(2)).sum()
                };
                (0..4).min_by(|&a, &b| d(&centers[a]).total_cmp(&d(&centers[b]))).unwrap() == l
            })
            .count();
        assert!(correct as f64 / ds.len() as f64 > 0.9);
    }

    #[test]
    fn markov_corpus_in_vocab_and_deterministic() {
        let gen = MarkovCorpus { vocab: 64, branching: 4, tokens: 5000 };
        let a = gen.generate(7);
        let b = gen.generate(7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (t as usize) < 64));
        assert_eq!(a.len(), 5000);
    }

    #[test]
    fn markov_entropy_below_log_vocab() {
        let gen = MarkovCorpus { vocab: 1024, branching: 4, tokens: 0 };
        assert!(gen.entropy_nats() < (1024f64).ln());
        assert!(gen.entropy_nats() > 0.0);
        // branching=1 chain is deterministic
        let det = MarkovCorpus { vocab: 8, branching: 1, tokens: 0 };
        assert!(det.entropy_nats().abs() < 1e-12);
    }

    #[test]
    fn markov_bigram_structure_exists() {
        // each state should have at most `branching` distinct successors
        let gen = MarkovCorpus { vocab: 32, branching: 3, tokens: 20_000 };
        let toks = gen.generate(9);
        let mut succ = vec![std::collections::BTreeSet::new(); 32];
        for w in toks.windows(2) {
            succ[w[0] as usize].insert(w[1]);
        }
        assert!(succ.iter().all(|s| s.len() <= 3));
    }

    #[test]
    fn prop_shards_partition_dataset() {
        // Both sharders produce an exact partition: disjoint, covering.
        forall(21, 20, |rng| {
            let k = 1 + rng.below(8);
            let n = k * (5 + rng.below(40));
            let ds = Blobs { n, dim: 4, classes: 5, spread: 2.0 }.generate(rng.next_u64());
            for sharding in [Sharding::Iid, Sharding::Dirichlet { alpha: 0.5 }] {
                let shards = shard_indices(&ds, k, sharding, rng.next_u64());
                let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
                all.sort_unstable();
                assert_eq!(all, (0..n).collect::<Vec<_>>(), "{sharding:?}");
                assert!(shards.iter().all(|s| !s.is_empty()), "{sharding:?} empty shard");
            }
        });
    }

    #[test]
    fn dirichlet_small_alpha_skews_labels() {
        let ds = Blobs { n: 4000, dim: 2, classes: 10, spread: 1.0 }.generate(3);
        let iid = shard_indices(&ds, 8, Sharding::Iid, 0);
        let skew = shard_indices(&ds, 8, Sharding::Dirichlet { alpha: 0.1 }, 0);
        // label-distribution total variation from uniform, averaged over workers
        let tv = |shards: &Vec<Vec<usize>>| -> f64 {
            shards
                .iter()
                .map(|s| {
                    let mut hist = vec![0.0f64; 10];
                    for &i in s {
                        hist[ds.labels[i]] += 1.0;
                    }
                    let n: f64 = hist.iter().sum();
                    hist.iter().map(|h| (h / n - 0.1).abs()).sum::<f64>() / 2.0
                })
                .sum::<f64>()
                / shards.len() as f64
        };
        assert!(tv(&skew) > 3.0 * tv(&iid), "skew {} iid {}", tv(&skew), tv(&iid));
    }

    #[test]
    fn batch_iter_cycles_with_reshuffle() {
        let mut it = BatchIter::new(vec![10, 11, 12], 1);
        let mut seen = Vec::new();
        for _ in 0..4 {
            seen.extend(it.next_batch(2));
        }
        assert_eq!(seen.len(), 8);
        assert!(seen.iter().all(|i| (10..13).contains(i)));
        // each element appears >= 2 times across ~2.67 epochs
        for v in 10..13 {
            assert!(seen.iter().filter(|&&x| x == v).count() >= 2);
        }
    }

    #[test]
    fn batch_iter_state_roundtrip_resumes_exact_stream() {
        let mut a = BatchIter::new((0..17).collect(), 9);
        a.next_batch(5); // advance into the epoch
        let mut w = crate::state::StateWriter::new();
        a.state_save(&mut w);
        let bytes = w.into_bytes();
        // restore into a differently-advanced sampler over the same shard
        let mut b = BatchIter::new((0..17).collect(), 1234);
        b.next_batch(11);
        b.state_load(&mut crate::state::StateReader::new(&bytes)).unwrap();
        for _ in 0..10 {
            assert_eq!(a.next_batch(4), b.next_batch(4));
        }
        // shard-size mismatch must be rejected
        let mut c = BatchIter::new((0..5).collect(), 2);
        assert!(c.state_load(&mut crate::state::StateReader::new(&bytes)).is_err());
        // same size but a different index set (another worker's shard /
        // corrupt indices) must be rejected too — those indices would
        // otherwise read foreign samples or panic out-of-bounds later.
        let mut d = BatchIter::new((100..117).collect(), 2);
        assert!(d.state_load(&mut crate::state::StateReader::new(&bytes)).is_err());
    }
}
