//! The parallel step-loop engine: a persistent [`WorkerPool`] plus one
//! implementation of Alg. 1/2 lines 2–4 (per-worker gradient + local
//! update) shared by every algorithm in [`crate::algorithms`].
//!
//! The paper's headline claim is linear speedup in the number of workers
//! K, which only materializes if *both* halves of the step loop actually
//! run concurrently (Lian et al. 2017; Wang et al. 2024). PR 1
//! parallelized the local-step half over `std::thread::scope`, paying a
//! spawn+join (tens of µs per worker) on **every step** — which both
//! forced a high sequential-fallback threshold and made the
//! communication half (gossip mixing, compressed exchange) not worth
//! threading at all. This revision replaces the per-step spawn with a
//! **persistent pool**: K parked threads created once per engine (hence
//! once per `coordinator::Session`), woken by channel sends, executing
//! borrowed-closure tasks and reporting results in deterministic task
//! order. The same pool serves the local-step fan-out *and* the
//! communication round (see [`crate::algorithms::GossipState::mix`] and
//! [`crate::algorithms::CompressedExchange`]), amortizing thread startup
//! to zero and cutting per-task dispatch to a channel send/recv pair
//! (order ~1–2 µs; see the [`PARALLEL_MIN_DIM`] note on how that
//! estimate set the 4×-lower threshold and how the benches check it).
//!
//! **Determinism contract:** the pooled and sequential paths produce
//! bit-identical iterates and losses. Each worker's randomness lives in
//! its own stream, every buffer is per-worker, and every reduction
//! (mean loss, gradient averaging, gossip weighted sums) happens on the
//! caller's thread in worker order after a deterministic K-way join —
//! the thread schedule has nothing to perturb. The contract is enforced
//! by rust/tests/engine_determinism.rs across all of
//! [`crate::algorithms::ALL_NAMES`] and all comm phases.
//!
//! Sources that cannot split (e.g. [`crate::runtime::XlaGradSource`]'s
//! single shared PJRT executable) fall back to the sequential
//! allocation-free path transparently.

use std::sync::{mpsc, Arc, Mutex};

use crate::arena::ParamArena;
use crate::grad::{GradientSource, WorkerGrad};
use crate::linalg;
use crate::optim::{self, MomentumBank};

/// A borrowed-closure task for [`WorkerPool::run_scoped`]: the closure
/// may borrow caller state (`run_scoped` blocks until every task has
/// finished, so the borrows outlive the execution).
pub type ScopedTask<'a, R> = Box<dyn FnOnce() -> R + Send + 'a>;

/// A lifetime-erased job queued to a pool thread.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Persistent worker pool: parked threads + per-thread channel dispatch,
/// deterministic K-way join order, joined threads on drop. Std-only (no
/// rayon/crossbeam in this offline build).
///
/// Tasks are distributed round-robin (`task i` → `thread i % n`), each
/// thread drains its queue in FIFO order, and results are collected into
/// index-ordered slots before [`WorkerPool::run_scoped`] returns — so
/// the *completion* schedule never influences the order any caller
/// observes results in. That, plus per-task-disjoint data, is the whole
/// determinism argument.
///
/// The pool is `Sync` (senders sit behind mutexes), so ONE pool can be
/// shared — via `Arc` — by several sessions running on different
/// threads, the way the service daemon multiplexes concurrent jobs onto
/// a fixed thread budget. Concurrent `run_scoped` calls are safe: each
/// call has a private result channel and per-task-disjoint borrows, and
/// each pool thread just interleaves the two callers' FIFO jobs.
pub struct WorkerPool {
    senders: Vec<Mutex<mpsc::Sender<Job>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` parked workers (clamped to at least one). Threads
    /// live until the pool is dropped; an idle pool costs nothing but
    /// the blocked `recv`.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let mut senders = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let (tx, rx) = mpsc::channel::<Job>();
            senders.push(Mutex::new(tx));
            let handle = std::thread::Builder::new()
                .name(format!("pdsgdm-pool-{i}"))
                .spawn(move || {
                    // Parked on recv between dispatches; exits when the
                    // pool drops its sender.
                    while let Ok(job) = rx.recv() {
                        job();
                    }
                })
                .expect("failed to spawn worker-pool thread");
            handles.push(handle);
        }
        Self { senders, handles }
    }

    pub fn threads(&self) -> usize {
        self.senders.len()
    }

    /// Execute `tasks` on the pool and return their results **in task
    /// order** (never completion order). Blocks until every task has
    /// finished; if any task panicked, the panic is re-raised on the
    /// caller's thread — lowest task index first — after all tasks have
    /// completed, so no borrow ever outlives this call.
    pub fn run_scoped<'a, R: Send + 'a>(&self, tasks: Vec<ScopedTask<'a, R>>) -> Vec<R> {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        // SAFETY ARGUMENT for the lifetime erasure below: jobs borrow
        // data living on the caller's stack (lifetime 'a), so control
        // must NEVER leave this function — by return OR unwind — while a
        // dispatched job might still run. The function upholds that by
        // construction:
        //  * the only fallible step between dispatching job 0 and the
        //    join loop is `Sender::send`; on failure the un-sent job is
        //    returned inside the error and dropped HERE (consuming the
        //    closure without running it), dispatch stops, and we fall
        //    through to the join loop before reporting the dead thread;
        //  * the join loop blocks until one result per *dispatched* job
        //    has arrived, and a result is only sent after the task
        //    closure has been consumed, so every borrow ends first;
        //  * `rx.recv()` can only fail once every dispatched job's
        //    sender clone is dropped — i.e. after all their closures
        //    were consumed — so even that panic path escapes with no
        //    borrow outstanding.
        let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<R>)>();
        let mut dispatched = 0usize;
        let mut dead_thread = false;
        for (i, task) in tasks.into_iter().enumerate() {
            let tx = tx.clone();
            let job: Box<dyn FnOnce() + Send + 'a> = Box::new(move || {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
                // The receiver outlives every dispatched job; if it is
                // somehow gone there is nobody left to inform.
                let _ = tx.send((i, result));
            });
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Job>(job)
            };
            let send_result = self.senders[i % self.senders.len()]
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .send(job);
            if let Err(mpsc::SendError(job)) = send_result {
                drop(job); // consume the closure on the caller's thread
                dead_thread = true;
                break;
            }
            dispatched += 1;
        }
        drop(tx);
        let mut slots: Vec<Option<std::thread::Result<R>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for _ in 0..dispatched {
            let (i, result) = rx
                .recv()
                .expect("worker-pool task vanished without reporting a result");
            slots[i] = Some(result);
        }
        assert!(!dead_thread, "worker-pool thread died");
        let mut out = Vec::with_capacity(n);
        for slot in slots {
            match slot.expect("worker-pool result slot never filled") {
                Ok(v) => out.push(v),
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
        out
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channels wakes every parked thread with RecvError;
        // joining makes shutdown observable (no detached threads linger
        // past the owning Session).
        self.senders.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// What each worker does with its freshly drawn gradient.
pub enum LocalUpdate<'a> {
    /// Heavy-ball Eq. (8): `m = mu*m + (g + wd*x); x -= eta*m`, with the
    /// K momentum rows living in one flat [`MomentumBank`].
    Momentum { moms: &'a mut MomentumBank, eta: f32 },
    /// Plain SGD: `x -= eta * g` (the no-momentum baselines).
    Sgd { eta: f32 },
}

/// Per-worker slice of a [`LocalUpdate`], movable onto a pool thread.
enum WorkerUpdate<'a> {
    Momentum { m: &'a mut [f32], mu: f32, wd: f32, eta: f32 },
    Sgd(f32),
}

impl WorkerUpdate<'_> {
    fn apply(&mut self, x: &mut [f32], g: &[f32]) {
        match self {
            WorkerUpdate::Momentum { m, mu, wd, eta } => {
                optim::momentum_step(m, x, g, *mu, *wd, *eta)
            }
            WorkerUpdate::Sgd(eta) => linalg::axpy(-*eta, g, x),
        }
    }
}

/// Below this dimension, even pool dispatch (one channel send + recv
/// per worker — order ~1–2 µs on typical hardware, versus tens of µs
/// for the PR 1 scoped-thread spawn it replaces) costs more than the
/// gradient it parallelizes, so the engine defaults to the sequential
/// path. The 4× drop from the spawn-era 4096 follows that cost ratio;
/// it is an ESTIMATE until the `algo_step`/`mix_round` records in
/// BENCH_hotpath.json confirm it on a real machine (the committed
/// baseline is flagged `estimated` — revisit this constant with the
/// first real bench run; flipping it never changes results, only
/// wall-clock). Explicit [`LocalStepEngine::set_parallel`]`(true)`
/// overrides — the determinism tests force the pooled path at tiny d
/// on purpose.
const PARALLEL_MIN_DIM: usize = 1024;

/// Owns the per-worker gradient buffers, the persistent [`WorkerPool`],
/// and the threading policy.
///
/// Buffers are **lazy**: the K per-worker buffers materialize only when
/// a path that truly needs K gradients alive at once runs (the pooled
/// parallel fan-out). Sequential paths consume each worker's gradient
/// immediately after drawing it, so they reuse ONE scratch buffer — a
/// non-splittable source like the XLA transformer (d in the millions)
/// never pays K×d resident memory.
pub struct LocalStepEngine {
    /// Dimension d every buffer is sized to on first use.
    d: usize,
    /// Per-worker gradient buffers (parallel paths only); empty until
    /// first needed, then written in place every step.
    bufs: Vec<Vec<f32>>,
    /// Single reusable gradient buffer for the sequential path.
    scratch: Vec<f32>,
    parallel: bool,
    /// The persistent pool shared by the local-step fan-out and the
    /// communication round; `None` until a parallel mode ever engages.
    /// Behind `Arc` so the service daemon can hand several engines (one
    /// per concurrent session) the SAME pool instead of K threads each.
    pool: Option<Arc<WorkerPool>>,
}

impl LocalStepEngine {
    /// Engine for K workers in dimension d. Parallelism defaults on when
    /// the host has more than one core AND the per-worker work is large
    /// enough to amortize pool dispatch (d >= [`PARALLEL_MIN_DIM`]);
    /// flipping it never changes results, only wall-clock.
    pub fn new(k: usize, d: usize) -> Self {
        let cores = Self::cores();
        let parallel = d >= PARALLEL_MIN_DIM && cores > 1 && k > 1;
        let pool = if parallel { Some(Arc::new(WorkerPool::new(k.min(cores)))) } else { None };
        Self { d, bufs: vec![Vec::new(); k], scratch: Vec::new(), parallel, pool }
    }

    /// Sequential-only engine (profiling / determinism baselines).
    pub fn sequential(k: usize, d: usize) -> Self {
        Self { d, bufs: vec![Vec::new(); k], scratch: Vec::new(), parallel: false, pool: None }
    }

    fn cores() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Toggle the pooled path. Turning it on lazily spins the pool up if
    /// this engine never had one (e.g. tiny-d engines force-enabled by
    /// the determinism tests); turning it off parks the pool but keeps
    /// it for a later re-enable.
    pub fn set_parallel(&mut self, on: bool) {
        let k = self.bufs.len();
        if on && self.pool.is_none() && k > 1 {
            self.pool = Some(Arc::new(WorkerPool::new(k.min(Self::cores()))));
        }
        self.parallel = on;
    }

    /// Adopt an externally owned pool (and engage the pooled path).
    /// This is how the service daemon multiplexes N concurrent sessions
    /// onto one thread budget: every session's engine dispatches into
    /// the same `Arc<WorkerPool>` instead of spinning up K threads each.
    /// Determinism is unaffected — results are joined in task order per
    /// call, regardless of which pool executes them.
    pub fn install_shared_pool(&mut self, pool: Arc<WorkerPool>) {
        self.pool = Some(pool);
        self.parallel = true;
    }

    pub fn is_parallel(&self) -> bool {
        self.parallel
    }

    /// The shared pool for the communication phase, or `None` when the
    /// engine is running sequentially. Algorithms pass this into
    /// [`crate::algorithms::GossipState::mix`] and
    /// [`crate::algorithms::CompressedExchange::round`], so ONE pool
    /// (created once per engine, hence once per `Session`) serves both
    /// halves of the step loop.
    pub fn comm_pool(&self) -> Option<&WorkerPool> {
        if self.parallel { self.pool.as_deref() } else { None }
    }

    fn ensure_bufs(bufs: &mut [Vec<f32>], d: usize) {
        for b in bufs.iter_mut() {
            if b.len() != d {
                b.resize(d, 0.0);
            }
        }
    }

    /// Alg. 1/2 lines 2–4: every worker draws a stochastic gradient at
    /// its own iterate (row `w` of the flat `xs` arena) and applies
    /// `update`. Returns the mean minibatch loss across workers.
    pub fn local_step(
        &mut self,
        source: &mut dyn GradientSource,
        xs: &mut ParamArena,
        update: LocalUpdate<'_>,
    ) -> f64 {
        let k = xs.k();
        assert_eq!(self.bufs.len(), k, "engine sized for a different K");
        assert_eq!(xs.d(), self.d, "engine sized for a different d");
        let mut ups: Vec<WorkerUpdate<'_>> = match update {
            LocalUpdate::Momentum { moms, eta } => {
                assert_eq!(moms.k(), k);
                let (mu, wd) = (moms.mu(), moms.weight_decay());
                moms.rows_mut().map(|m| WorkerUpdate::Momentum { m, mu, wd, eta }).collect()
            }
            LocalUpdate::Sgd { eta } => (0..k).map(|_| WorkerUpdate::Sgd(eta)).collect(),
        };
        let losses = match &self.pool {
            Some(pool) if self.parallel && k > 1 => {
                Self::try_parallel(source, xs, &mut self.bufs, self.d, &mut ups, pool)
            }
            _ => None,
        };
        let losses = match losses {
            Some(l) => l,
            None => {
                if self.scratch.len() != self.d {
                    self.scratch.resize(self.d, 0.0);
                }
                Self::run_sequential(source, xs, &mut self.scratch, &mut ups)
            }
        };
        losses.iter().sum::<f64>() / k as f64
    }

    /// Centralized-baseline variant: every worker draws its gradient at
    /// the SAME shared iterate `x`, and their average `(1/K) Σ_w g_w`
    /// (accumulated in worker order) is written into `mean_out`.
    /// Returns the mean minibatch loss.
    ///
    /// The sequential path accumulates through the single scratch buffer
    /// — one gradient alive at a time, exactly the pre-engine memory
    /// profile — while the pooled path (split sources only) fans out
    /// into the per-worker buffers first. Both reduce in worker order on
    /// the caller's thread, so the result is bit-identical either way.
    pub fn grad_at_shared_mean_into(
        &mut self,
        source: &mut dyn GradientSource,
        x: &[f32],
        mean_out: &mut [f32],
    ) -> f64 {
        let k = self.bufs.len();
        assert_eq!(mean_out.len(), self.d);
        assert!(k >= 1);
        if let Some(pool) = &self.pool {
            if self.parallel && k > 1 {
                if let Some(l) =
                    Self::try_parallel_shared(source, x, &mut self.bufs, self.d, pool)
                {
                    mean_out.copy_from_slice(&self.bufs[0]);
                    for g in &self.bufs[1..] {
                        linalg::axpy(1.0, g, mean_out);
                    }
                    linalg::scale(1.0 / k as f32, mean_out);
                    return l.iter().sum::<f64>() / k as f64;
                }
            }
        }
        if self.scratch.len() != self.d {
            self.scratch.resize(self.d, 0.0);
        }
        let losses: Vec<f64> = (0..k)
            .map(|w| {
                let loss = source.grad_into(w, x, &mut self.scratch);
                if w == 0 {
                    mean_out.copy_from_slice(&self.scratch);
                } else {
                    linalg::axpy(1.0, &self.scratch, mean_out);
                }
                loss
            })
            .collect();
        linalg::scale(1.0 / k as f32, mean_out);
        losses.iter().sum::<f64>() / k as f64
    }

    fn run_sequential(
        source: &mut dyn GradientSource,
        xs: &mut ParamArena,
        scratch: &mut [f32],
        ups: &mut [WorkerUpdate<'_>],
    ) -> Vec<f64> {
        xs.rows_mut()
            .zip(ups.iter_mut())
            .enumerate()
            .map(|(w, (x, up))| {
                let loss = source.grad_into(w, x, scratch);
                up.apply(x, scratch);
                loss
            })
            .collect()
    }

    /// `None` if the source does not split; otherwise one pool task per
    /// worker, each owning (shard, x_k, buf_k, update_k). Buffers are
    /// materialized only after the split succeeds, so non-splittable
    /// sources never allocate them.
    fn try_parallel(
        source: &mut dyn GradientSource,
        xs: &mut ParamArena,
        bufs: &mut [Vec<f32>],
        d: usize,
        ups: &mut [WorkerUpdate<'_>],
        pool: &WorkerPool,
    ) -> Option<Vec<f64>> {
        let workers = source.split_workers()?;
        assert_eq!(workers.len(), xs.k(), "split_workers() must yield K shards");
        Self::ensure_bufs(bufs, d);
        let tasks: Vec<ScopedTask<'_, f64>> = workers
            .into_iter()
            .zip(xs.rows_mut())
            .zip(bufs.iter_mut())
            .zip(ups.iter_mut())
            .map(|(((mut shard, x), buf), up)| {
                Box::new(move || {
                    let loss = shard.grad_into(x, buf);
                    up.apply(x, buf);
                    loss
                }) as ScopedTask<'_, f64>
            })
            .collect();
        Some(pool.run_scoped(tasks))
    }

    fn try_parallel_shared(
        source: &mut dyn GradientSource,
        x: &[f32],
        bufs: &mut [Vec<f32>],
        d: usize,
        pool: &WorkerPool,
    ) -> Option<Vec<f64>> {
        let workers = source.split_workers()?;
        assert_eq!(workers.len(), bufs.len(), "split_workers() must yield K shards");
        Self::ensure_bufs(bufs, d);
        let tasks: Vec<ScopedTask<'_, f64>> = workers
            .into_iter()
            .zip(bufs.iter_mut())
            .map(|(mut shard, buf)| {
                Box::new(move || shard.grad_into(x, buf)) as ScopedTask<'_, f64>
            })
            .collect();
        Some(pool.run_scoped(tasks))
    }
}

/// One worker's Alg. 1 lines 2–4 — gradient at `x`, heavy-ball update —
/// exactly as the in-process engine executes them ([`WorkerUpdate`]'s
/// momentum arm), exposed for the socket-transport worker processes
/// (`comm::transport::run_worker`): a process replaying only its own row
/// must perform bit-identical float ops to the simulator's per-worker
/// slice to keep loopback runs reproducible. Returns the sampled loss.
pub fn momentum_row_step(
    source: &mut dyn GradientSource,
    worker: usize,
    x: &mut [f32],
    m: &mut [f32],
    scratch: &mut [f32],
    mu: f32,
    wd: f32,
    eta: f32,
) -> f64 {
    let loss = source.grad_into(worker, x, scratch);
    optim::momentum_step(m, x, scratch, mu, wd, eta);
    loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::Quadratic;

    fn setup(k: usize, d: usize, noise: f32, seed: u64) -> (Quadratic, ParamArena) {
        let src = Quadratic::new(k, d, 1.0, noise, seed);
        let rows: Vec<Vec<f32>> = (0..k).map(|i| src.init(seed ^ i as u64)).collect();
        (src, ParamArena::from_rows(&rows))
    }

    fn run_mode(parallel: bool, momentum: bool) -> (ParamArena, Vec<f64>) {
        let (k, d) = (4, 33);
        let (mut src, mut xs) = setup(k, d, 0.1, 77);
        let mut engine = if parallel {
            let mut e = LocalStepEngine::new(k, d);
            e.set_parallel(true);
            e
        } else {
            LocalStepEngine::sequential(k, d)
        };
        let mut moms = MomentumBank::new(k, d, 0.9, 0.0);
        let mut losses = Vec::new();
        for _ in 0..7 {
            let update = if momentum {
                LocalUpdate::Momentum { moms: &mut moms, eta: 0.05 }
            } else {
                LocalUpdate::Sgd { eta: 0.05 }
            };
            losses.push(engine.local_step(&mut src, &mut xs, update));
        }
        (xs, losses)
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        for momentum in [false, true] {
            let (xs_seq, l_seq) = run_mode(false, momentum);
            let (xs_par, l_par) = run_mode(true, momentum);
            let bitwise = xs_seq
                .as_slice()
                .iter()
                .zip(xs_par.as_slice())
                .all(|(p, q)| p.to_bits() == q.to_bits());
            assert!(bitwise, "momentum={momentum}: iterates diverged");
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&l_seq), bits(&l_par), "momentum={momentum}: losses diverged");
        }
    }

    #[test]
    fn sgd_update_matches_manual_axpy() {
        let (k, d) = (3, 10);
        let (mut src, mut xs) = setup(k, d, 0.0, 5);
        let (mut src2, xs2) = setup(k, d, 0.0, 5);
        let mut engine = LocalStepEngine::sequential(k, d);
        engine.local_step(&mut src, &mut xs, LocalUpdate::Sgd { eta: 0.1 });
        for w in 0..k {
            let x0 = xs2.row(w);
            let (_, g) = src2.grad(w, x0);
            let mut want = x0.to_vec();
            linalg::axpy(-0.1, &g, &mut want);
            assert_eq!(xs.row(w), &want[..]);
        }
    }

    #[test]
    fn grad_at_shared_mean_matches_manual_average() {
        let (k, d) = (3, 10);
        let (mut src, _) = setup(k, d, 0.0, 6);
        let (mut src2, _) = setup(k, d, 0.0, 6);
        let x = src.init(2);
        let mut engine = LocalStepEngine::sequential(k, d);
        let mut mean = vec![9.9f32; d]; // dirty: must be overwritten
        let loss = engine.grad_at_shared_mean_into(&mut src, &x, &mut mean);
        assert!(loss.is_finite());
        // manual reference: sum in worker order, then scale by 1/k
        let mut want = src2.grad(0, &x).1;
        for w in 1..k {
            let (_, g) = src2.grad(w, &x);
            linalg::axpy(1.0, &g, &mut want);
        }
        linalg::scale(1.0 / k as f32, &mut want);
        assert_eq!(mean, want);
    }

    #[test]
    fn small_dims_default_to_sequential_but_override_works() {
        let e = LocalStepEngine::new(4, 8);
        assert!(!e.is_parallel(), "tiny d must not pay pool dispatch by default");
        assert!(e.comm_pool().is_none());
        let mut e = LocalStepEngine::new(4, 8);
        e.set_parallel(true);
        assert!(e.is_parallel());
        if std::thread::available_parallelism().map(|n| n.get() > 1).unwrap_or(false) {
            assert!(e.comm_pool().is_some(), "forcing parallel must spin the pool up");
        }
        e.set_parallel(false);
        assert!(e.comm_pool().is_none(), "sequential mode exposes no comm pool");
    }

    #[test]
    fn grad_at_shared_mean_parallel_matches_sequential_bitwise() {
        let (k, d) = (4, 12);
        let (mut src, _) = setup(k, d, 0.1, 8);
        let (mut src2, _) = setup(k, d, 0.1, 8);
        let x = src.init(1);
        let mut par = LocalStepEngine::new(k, d);
        par.set_parallel(true);
        let mut mean_par = vec![0.0f32; d];
        let loss_par = par.grad_at_shared_mean_into(&mut src, &x, &mut mean_par);
        let mut seq = LocalStepEngine::sequential(k, d);
        let mut mean_seq = vec![0.0f32; d];
        let loss_seq = seq.grad_at_shared_mean_into(&mut src2, &x, &mut mean_seq);
        assert_eq!(loss_par.to_bits(), loss_seq.to_bits());
        assert_eq!(mean_par, mean_seq);
    }

    #[test]
    #[should_panic(expected = "different K")]
    fn engine_rejects_mismatched_k() {
        let (mut src, mut xs) = setup(3, 4, 0.0, 9);
        let mut engine = LocalStepEngine::new(2, 4);
        engine.local_step(&mut src, &mut xs, LocalUpdate::Sgd { eta: 0.1 });
    }

    #[test]
    fn pool_returns_results_in_task_order() {
        let pool = WorkerPool::new(4);
        for round in 0..20u64 {
            let tasks: Vec<ScopedTask<'_, u64>> = (0..13u64)
                .map(|i| {
                    Box::new(move || {
                        // Skew completion order: early tasks finish last.
                        if (i + round) % 3 == 0 {
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        i * 10
                    }) as ScopedTask<'_, u64>
                })
                .collect();
            let got = pool.run_scoped(tasks);
            assert_eq!(got, (0..13).map(|i| i * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn pool_tasks_may_borrow_caller_state() {
        let pool = WorkerPool::new(3);
        let mut rows = vec![vec![0.0f32; 16]; 5];
        let tasks: Vec<ScopedTask<'_, ()>> = rows
            .iter_mut()
            .enumerate()
            .map(|(i, row)| {
                Box::new(move || {
                    for v in row.iter_mut() {
                        *v = i as f32;
                    }
                }) as ScopedTask<'_, ()>
            })
            .collect();
        pool.run_scoped(tasks);
        for (i, row) in rows.iter().enumerate() {
            assert!(row.iter().all(|&v| v == i as f32));
        }
    }

    #[test]
    fn pool_handles_more_tasks_than_threads() {
        let pool = WorkerPool::new(2);
        let tasks: Vec<ScopedTask<'_, usize>> =
            (0..64).map(|i| Box::new(move || i) as ScopedTask<'_, usize>).collect();
        assert_eq!(pool.run_scoped(tasks), (0..64).collect::<Vec<_>>());
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    #[should_panic(expected = "task 2 exploded")]
    fn pool_propagates_task_panics() {
        let pool = WorkerPool::new(2);
        let tasks: Vec<ScopedTask<'_, usize>> = (0..4)
            .map(|i| {
                Box::new(move || {
                    if i == 2 {
                        panic!("task 2 exploded");
                    }
                    i
                }) as ScopedTask<'_, usize>
            })
            .collect();
        pool.run_scoped(tasks);
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        // Two caller threads drive the SAME pool concurrently (the
        // daemon's concurrent-session shape). Each caller must still see
        // its own results in its own task order.
        let pool = Arc::new(WorkerPool::new(3));
        let mut joins = Vec::new();
        for caller in 0..2u64 {
            let pool = Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let tasks: Vec<ScopedTask<'_, u64>> = (0..9u64)
                        .map(|i| Box::new(move || caller * 1000 + i) as ScopedTask<'_, u64>)
                        .collect();
                    let got = pool.run_scoped(tasks);
                    assert_eq!(got, (0..9).map(|i| caller * 1000 + i).collect::<Vec<_>>());
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn install_shared_pool_matches_sequential_bitwise() {
        // Engines driven by one shared external pool must reproduce the
        // sequential trajectory exactly, like every other pooled mode.
        let (k, d) = (4, 33);
        let shared = Arc::new(WorkerPool::new(2));
        let (mut src_a, mut xs_a) = setup(k, d, 0.1, 99);
        let mut eng_a = LocalStepEngine::sequential(k, d);
        eng_a.install_shared_pool(Arc::clone(&shared));
        assert!(eng_a.is_parallel());
        assert!(eng_a.comm_pool().is_some());
        let (mut src_b, mut xs_b) = setup(k, d, 0.1, 99);
        let mut eng_b = LocalStepEngine::sequential(k, d);
        for _ in 0..7 {
            let la = eng_a.local_step(&mut src_a, &mut xs_a, LocalUpdate::Sgd { eta: 0.05 });
            let lb = eng_b.local_step(&mut src_b, &mut xs_b, LocalUpdate::Sgd { eta: 0.05 });
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        let bitwise = xs_a
            .as_slice()
            .iter()
            .zip(xs_b.as_slice())
            .all(|(p, q)| p.to_bits() == q.to_bits());
        assert!(bitwise, "shared-pool iterates diverged from sequential");
    }

    #[test]
    fn pool_survives_a_caught_panic_round() {
        // A panicking task must not poison the pool: threads stay alive
        // and later rounds still run (the catch_unwind wrapper keeps the
        // worker loop going).
        let pool = WorkerPool::new(2);
        let boom: Vec<ScopedTask<'_, usize>> =
            vec![Box::new(|| panic!("boom")) as ScopedTask<'_, usize>];
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_scoped(boom);
        }))
        .is_err());
        let tasks: Vec<ScopedTask<'_, usize>> =
            (0..6).map(|i| Box::new(move || i + 1) as ScopedTask<'_, usize>).collect();
        assert_eq!(pool.run_scoped(tasks), vec![1, 2, 3, 4, 5, 6]);
    }
}
