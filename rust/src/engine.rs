//! The parallel local-step engine: one implementation of Alg. 1/2
//! lines 2–4 (per-worker gradient + local update) shared by every
//! algorithm in [`crate::algorithms`].
//!
//! The paper's headline claim is linear speedup in the number of workers
//! K, which only materializes if the K local steps actually run
//! concurrently (Lian et al. 2017; Wang et al. 2024). The engine owns
//! one preallocated `d`-length gradient buffer per worker and, when the
//! oracle can split into per-worker shards
//! ([`GradientSource::split_workers`]), fans the gradient + momentum
//! phase out over `std::thread::scope` — no extra dependencies, no
//! locks: worker `k` touches only `xs[k]`, `bufs[k]`, `moms[k]`, and its
//! own RNG/sampler shard, so there are no data races *by construction*.
//!
//! **Determinism contract:** the parallel and sequential paths produce
//! bit-identical iterates and losses. Each worker's randomness lives in
//! its own stream, every buffer is per-worker, and the mean loss is
//! reduced in worker order in both paths. The contract is enforced by
//! rust/tests/engine_determinism.rs across all of
//! [`crate::algorithms::ALL_NAMES`].
//!
//! Sources that cannot split (e.g. [`crate::runtime::XlaGradSource`]'s
//! single shared PJRT executable) fall back to the sequential
//! allocation-free path transparently.

use crate::grad::{GradientSource, WorkerGrad};
use crate::linalg;
use crate::optim::MomentumState;

/// What each worker does with its freshly drawn gradient.
pub enum LocalUpdate<'a> {
    /// Heavy-ball Eq. (8): `m = mu*m + (g + wd*x); x -= eta*m`.
    Momentum { moms: &'a mut [MomentumState], eta: f32 },
    /// Plain SGD: `x -= eta * g` (the no-momentum baselines).
    Sgd { eta: f32 },
}

/// Per-worker slice of a [`LocalUpdate`], movable onto a worker thread.
enum WorkerUpdate<'a> {
    Momentum(&'a mut MomentumState, f32),
    Sgd(f32),
}

impl WorkerUpdate<'_> {
    fn apply(&mut self, x: &mut [f32], g: &[f32]) {
        match self {
            WorkerUpdate::Momentum(mom, eta) => mom.step(x, g, *eta),
            WorkerUpdate::Sgd(eta) => linalg::axpy(-*eta, g, x),
        }
    }
}

/// Below this dimension, scoped-thread spawn+join (tens of µs per
/// worker) costs more than the gradient it parallelizes, so the engine
/// defaults to the sequential path. Explicit [`LocalStepEngine::
/// set_parallel`]`(true)` overrides — the determinism tests force the
/// threaded path at tiny d on purpose.
const PARALLEL_MIN_DIM: usize = 4096;

/// Owns the per-worker gradient buffers and the threading policy.
///
/// Buffers are **lazy**: the K per-worker buffers materialize only when
/// a path that truly needs K gradients alive at once runs (the
/// scoped-thread parallel fan-out). Sequential paths consume each
/// worker's gradient immediately after drawing it, so they reuse ONE
/// scratch buffer — a non-splittable source like the XLA transformer
/// (d in the millions) never pays K×d resident memory.
pub struct LocalStepEngine {
    /// Dimension d every buffer is sized to on first use.
    d: usize,
    /// Per-worker gradient buffers (parallel paths only); empty until
    /// first needed, then written in place every step.
    bufs: Vec<Vec<f32>>,
    /// Single reusable gradient buffer for the sequential path.
    scratch: Vec<f32>,
    parallel: bool,
}

impl LocalStepEngine {
    /// Engine for K workers in dimension d. Parallelism defaults on when
    /// the host has more than one core AND the per-worker work is large
    /// enough to amortize thread spawns (d >= [`PARALLEL_MIN_DIM`]);
    /// flipping it never changes results, only wall-clock.
    pub fn new(k: usize, d: usize) -> Self {
        let parallel = d >= PARALLEL_MIN_DIM
            && std::thread::available_parallelism()
                .map(|n| n.get() > 1)
                .unwrap_or(false);
        Self { d, bufs: vec![Vec::new(); k], scratch: Vec::new(), parallel }
    }

    /// Sequential-only engine (profiling / determinism baselines).
    pub fn sequential(k: usize, d: usize) -> Self {
        Self { d, bufs: vec![Vec::new(); k], scratch: Vec::new(), parallel: false }
    }

    pub fn set_parallel(&mut self, on: bool) {
        self.parallel = on;
    }

    pub fn is_parallel(&self) -> bool {
        self.parallel
    }

    fn ensure_bufs(bufs: &mut [Vec<f32>], d: usize) {
        for b in bufs.iter_mut() {
            if b.len() != d {
                b.resize(d, 0.0);
            }
        }
    }

    /// Alg. 1/2 lines 2–4: every worker draws a stochastic gradient at
    /// its own iterate `xs[k]` and applies `update`. Returns the mean
    /// minibatch loss across workers.
    pub fn local_step(
        &mut self,
        source: &mut dyn GradientSource,
        xs: &mut [Vec<f32>],
        update: LocalUpdate<'_>,
    ) -> f64 {
        let k = xs.len();
        assert_eq!(self.bufs.len(), k, "engine sized for a different K");
        let mut ups: Vec<WorkerUpdate<'_>> = match update {
            LocalUpdate::Momentum { moms, eta } => {
                assert_eq!(moms.len(), k);
                moms.iter_mut().map(|m| WorkerUpdate::Momentum(m, eta)).collect()
            }
            LocalUpdate::Sgd { eta } => (0..k).map(|_| WorkerUpdate::Sgd(eta)).collect(),
        };
        let losses = if self.parallel && k > 1 {
            Self::try_parallel(source, xs, &mut self.bufs, self.d, &mut ups)
        } else {
            None
        };
        let losses = match losses {
            Some(l) => l,
            None => {
                if self.scratch.len() != self.d {
                    self.scratch.resize(self.d, 0.0);
                }
                Self::run_sequential(source, xs, &mut self.scratch, &mut ups)
            }
        };
        losses.iter().sum::<f64>() / k as f64
    }

    /// Centralized-baseline variant: every worker draws its gradient at
    /// the SAME shared iterate `x`, and their average `(1/K) Σ_w g_w`
    /// (accumulated in worker order) is written into `mean_out`.
    /// Returns the mean minibatch loss.
    ///
    /// The sequential path accumulates through the single scratch buffer
    /// — one gradient alive at a time, exactly the pre-engine memory
    /// profile — while the parallel path (split sources only) fans out
    /// into the per-worker buffers first. Both reduce in worker order,
    /// so the result is bit-identical either way.
    pub fn grad_at_shared_mean_into(
        &mut self,
        source: &mut dyn GradientSource,
        x: &[f32],
        mean_out: &mut [f32],
    ) -> f64 {
        let k = self.bufs.len();
        assert_eq!(mean_out.len(), self.d);
        assert!(k >= 1);
        let losses: Vec<f64>;
        if self.parallel && k > 1 {
            if let Some(l) = Self::try_parallel_shared(source, x, &mut self.bufs, self.d) {
                mean_out.copy_from_slice(&self.bufs[0]);
                for g in &self.bufs[1..] {
                    linalg::axpy(1.0, g, mean_out);
                }
                linalg::scale(1.0 / k as f32, mean_out);
                return l.iter().sum::<f64>() / k as f64;
            }
        }
        if self.scratch.len() != self.d {
            self.scratch.resize(self.d, 0.0);
        }
        losses = (0..k)
            .map(|w| {
                let loss = source.grad_into(w, x, &mut self.scratch);
                if w == 0 {
                    mean_out.copy_from_slice(&self.scratch);
                } else {
                    linalg::axpy(1.0, &self.scratch, mean_out);
                }
                loss
            })
            .collect();
        linalg::scale(1.0 / k as f32, mean_out);
        losses.iter().sum::<f64>() / k as f64
    }

    fn run_sequential(
        source: &mut dyn GradientSource,
        xs: &mut [Vec<f32>],
        scratch: &mut [f32],
        ups: &mut [WorkerUpdate<'_>],
    ) -> Vec<f64> {
        xs.iter_mut()
            .zip(ups.iter_mut())
            .enumerate()
            .map(|(w, (x, up))| {
                let loss = source.grad_into(w, x, scratch);
                up.apply(x, scratch);
                loss
            })
            .collect()
    }

    /// `None` if the source does not split; otherwise one scoped thread
    /// per worker, each owning (shard, x_k, buf_k, update_k). Buffers
    /// are materialized only after the split succeeds, so non-splittable
    /// sources never allocate them.
    fn try_parallel(
        source: &mut dyn GradientSource,
        xs: &mut [Vec<f32>],
        bufs: &mut [Vec<f32>],
        d: usize,
        ups: &mut [WorkerUpdate<'_>],
    ) -> Option<Vec<f64>> {
        let workers = source.split_workers()?;
        assert_eq!(workers.len(), xs.len(), "split_workers() must yield K shards");
        Self::ensure_bufs(bufs, d);
        Some(std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .into_iter()
                .zip(xs.iter_mut())
                .zip(bufs.iter_mut())
                .zip(ups.iter_mut())
                .map(|(((mut shard, x), buf), up)| {
                    s.spawn(move || {
                        let loss = shard.grad_into(x, buf);
                        up.apply(x, buf);
                        loss
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("worker thread panicked"))
                .collect()
        }))
    }

    fn try_parallel_shared(
        source: &mut dyn GradientSource,
        x: &[f32],
        bufs: &mut [Vec<f32>],
        d: usize,
    ) -> Option<Vec<f64>> {
        let workers = source.split_workers()?;
        assert_eq!(workers.len(), bufs.len(), "split_workers() must yield K shards");
        Self::ensure_bufs(bufs, d);
        Some(std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .into_iter()
                .zip(bufs.iter_mut())
                .map(|(mut shard, buf)| s.spawn(move || shard.grad_into(x, buf)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("worker thread panicked"))
                .collect()
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::Quadratic;

    fn setup(k: usize, d: usize, noise: f32, seed: u64) -> (Quadratic, Vec<Vec<f32>>) {
        let src = Quadratic::new(k, d, 1.0, noise, seed);
        let xs: Vec<Vec<f32>> = (0..k).map(|i| src.init(seed ^ i as u64)).collect();
        (src, xs)
    }

    fn run_mode(parallel: bool, momentum: bool) -> (Vec<Vec<f32>>, Vec<f64>) {
        let (k, d) = (4, 33);
        let (mut src, mut xs) = setup(k, d, 0.1, 77);
        let mut engine = if parallel {
            let mut e = LocalStepEngine::new(k, d);
            e.set_parallel(true);
            e
        } else {
            LocalStepEngine::sequential(k, d)
        };
        let mut moms: Vec<MomentumState> =
            (0..k).map(|_| MomentumState::new(d, 0.9, 0.0)).collect();
        let mut losses = Vec::new();
        for _ in 0..7 {
            let update = if momentum {
                LocalUpdate::Momentum { moms: &mut moms, eta: 0.05 }
            } else {
                LocalUpdate::Sgd { eta: 0.05 }
            };
            losses.push(engine.local_step(&mut src, &mut xs, update));
        }
        (xs, losses)
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        for momentum in [false, true] {
            let (xs_seq, l_seq) = run_mode(false, momentum);
            let (xs_par, l_par) = run_mode(true, momentum);
            let bitwise = xs_seq.iter().zip(&xs_par).all(|(a, b)| {
                a.iter().zip(b).all(|(p, q)| p.to_bits() == q.to_bits())
            });
            assert!(bitwise, "momentum={momentum}: iterates diverged");
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&l_seq), bits(&l_par), "momentum={momentum}: losses diverged");
        }
    }

    #[test]
    fn sgd_update_matches_manual_axpy() {
        let (k, d) = (3, 10);
        let (mut src, mut xs) = setup(k, d, 0.0, 5);
        let (mut src2, xs2) = setup(k, d, 0.0, 5);
        let mut engine = LocalStepEngine::sequential(k, d);
        engine.local_step(&mut src, &mut xs, LocalUpdate::Sgd { eta: 0.1 });
        for (w, x0) in xs2.iter().enumerate() {
            let (_, g) = src2.grad(w, x0);
            let mut want = x0.clone();
            linalg::axpy(-0.1, &g, &mut want);
            assert_eq!(xs[w], want);
        }
    }

    #[test]
    fn grad_at_shared_mean_matches_manual_average() {
        let (k, d) = (3, 10);
        let (mut src, _) = setup(k, d, 0.0, 6);
        let (mut src2, _) = setup(k, d, 0.0, 6);
        let x = src.init(2);
        let mut engine = LocalStepEngine::sequential(k, d);
        let mut mean = vec![9.9f32; d]; // dirty: must be overwritten
        let loss = engine.grad_at_shared_mean_into(&mut src, &x, &mut mean);
        assert!(loss.is_finite());
        // manual reference: sum in worker order, then scale by 1/k
        let mut want = src2.grad(0, &x).1;
        for w in 1..k {
            let (_, g) = src2.grad(w, &x);
            linalg::axpy(1.0, &g, &mut want);
        }
        linalg::scale(1.0 / k as f32, &mut want);
        assert_eq!(mean, want);
    }

    #[test]
    fn small_dims_default_to_sequential_but_override_works() {
        let e = LocalStepEngine::new(4, 8);
        assert!(!e.is_parallel(), "tiny d must not pay thread spawns by default");
        let mut e = LocalStepEngine::new(4, 8);
        e.set_parallel(true);
        assert!(e.is_parallel());
    }

    #[test]
    fn grad_at_shared_mean_parallel_matches_sequential_bitwise() {
        let (k, d) = (4, 12);
        let (mut src, _) = setup(k, d, 0.1, 8);
        let (mut src2, _) = setup(k, d, 0.1, 8);
        let x = src.init(1);
        let mut par = LocalStepEngine::new(k, d);
        par.set_parallel(true);
        let mut mean_par = vec![0.0f32; d];
        let loss_par = par.grad_at_shared_mean_into(&mut src, &x, &mut mean_par);
        let mut seq = LocalStepEngine::sequential(k, d);
        let mut mean_seq = vec![0.0f32; d];
        let loss_seq = seq.grad_at_shared_mean_into(&mut src2, &x, &mut mean_seq);
        assert_eq!(loss_par.to_bits(), loss_seq.to_bits());
        assert_eq!(mean_par, mean_seq);
    }

    #[test]
    #[should_panic(expected = "different K")]
    fn engine_rejects_mismatched_k() {
        let (mut src, mut xs) = setup(3, 4, 0.0, 9);
        let mut engine = LocalStepEngine::new(2, 4);
        engine.local_step(&mut src, &mut xs, LocalUpdate::Sgd { eta: 0.1 });
    }
}
