//! Gradient sources: the `f^(k)` family each worker optimizes.
//!
//! The paper's Eq. (1) is `min_x (1/K) sum_k f^(k)(x)` with stochastic
//! first-order oracles per worker. This module provides three pure-Rust
//! oracles used by the figure benches and the algorithm tests (no XLA
//! needed, millisecond steps), plus the trait the XLA transformer
//! (`runtime::XlaGradSource`) also implements so the coordinator is
//! generic over all of them:
//!
//! * [`Quadratic`] — per-worker quadratic `0.5 (x-b_k)^T A_k (x-b_k)` with
//!   a closed-form global optimum: the sharpest tool for checking
//!   convergence *rates* and consensus bounds (Lemma 5/6).
//! * [`Logistic`] — multinomial logistic regression on [`crate::data::Blobs`]
//!   shards (convex, non-quadratic).
//! * [`Mlp`] — 1-hidden-layer tanh MLP with manual backprop on blobs
//!   (non-convex — the paper's setting; stands in for ResNet20/CIFAR-10
//!   in the Figure 1–3 benches per DESIGN.md §2).
//!
//! ## The hot path (DESIGN.md §4, EXPERIMENTS.md §Perf)
//!
//! Two properties make the K-worker inner loop fast:
//!
//! 1. **Zero-allocation gradients** — [`GradientSource::grad_into`]
//!    overwrites a caller-owned `d`-length buffer instead of returning a
//!    fresh `Vec<f32>` per call (d is in the millions for the e2e
//!    workloads; the old allocate-per-grad path was one malloc + page
//!    fault sweep per worker per step).
//! 2. **Splittable worker state** — [`GradientSource::split_workers`]
//!    fractures the oracle into per-worker [`WorkerGrad`] handles that
//!    borrow the shared read-only problem data and *disjoint* mutable
//!    state (each worker's RNG stream / batch sampler), so
//!    [`crate::engine::LocalStepEngine`] can run them on scoped threads
//!    with no locks and no data races *by construction*. Sources that
//!    cannot split (the single shared PJRT executable) return `None` and
//!    the engine falls back to the sequential path.
//!
//! Determinism: each worker owns an independent, explicitly seeded RNG
//! stream, so the parallel and sequential schedules consume identical
//! randomness and produce bit-identical iterates (asserted by
//! rust/tests/engine_determinism.rs).

use crate::data::{shard_indices, BatchIter, Dataset, Sharding};
use crate::rng::Xoshiro256;

/// Global evaluation snapshot at a parameter vector.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalMetrics {
    /// Full-data global loss f(x).
    pub loss: f64,
    /// Classification accuracy in [0,1] (NaN-free; 0 for regression).
    pub accuracy: f64,
    /// ||∇f(x)||² — the quantity the paper's theorems bound.
    pub grad_norm_sq: f64,
}

/// One worker's handle into a split oracle: shared problem data +
/// exclusively-owned worker-local state (RNG stream, batch sampler).
/// `Send` so the engine can move each handle onto its own scoped thread.
pub trait WorkerGrad: Send {
    /// Overwrite `out` with this worker's stochastic gradient at `x`;
    /// returns the minibatch loss. Must be allocation-free in `d` and
    /// must consume exactly the same per-worker randomness as the
    /// sequential [`GradientSource::grad_into`] path.
    fn grad_into(&mut self, x: &[f32], out: &mut [f32]) -> f64;
}

/// A stochastic first-order oracle over K workers.
pub trait GradientSource {
    /// Dimension d of the flat parameter vector.
    fn dim(&self) -> usize;

    /// Number of workers K this source shards across.
    fn workers(&self) -> usize;

    /// Stochastic (minibatch) gradient of `f^(worker)` at `x`, written
    /// into `out` (fully overwritten; `out.len() == dim()`). Returns the
    /// minibatch loss. This is the allocation-free hot path.
    fn grad_into(&mut self, worker: usize, x: &[f32], out: &mut [f32]) -> f64;

    /// Allocating convenience form of [`GradientSource::grad_into`].
    /// Returns (minibatch loss, gradient).
    fn grad(&mut self, worker: usize, x: &[f32]) -> (f64, Vec<f32>) {
        let mut g = vec![0.0f32; self.dim()];
        let loss = self.grad_into(worker, x, &mut g);
        (loss, g)
    }

    /// Full-data global metrics at `x` (used for the figure y-axes).
    fn eval(&mut self, x: &[f32]) -> EvalMetrics;

    /// Initial parameter vector (same x_0 on every worker, per Alg. 1).
    fn init(&self, seed: u64) -> Vec<f32>;

    /// Split into per-worker oracles with disjoint mutable state for the
    /// parallel engine. `None` (the default) means the source cannot
    /// split — e.g. [`crate::runtime::XlaGradSource`]'s single shared
    /// PJRT executable — and the engine runs the sequential adapter.
    fn split_workers(&mut self) -> Option<Vec<Box<dyn WorkerGrad + '_>>> {
        None
    }

    /// Serialize the oracle's mutable state — per-worker noise/sampler
    /// RNG streams and epoch cursors. Problem data (curvatures, datasets)
    /// is rebuilt deterministically from the config seed, so only the
    /// *consumed-randomness position* needs to survive a checkpoint for
    /// a resumed run to draw the exact gradient stream the uninterrupted
    /// run would. The default (for genuinely stateless oracles) writes a
    /// marker tag so load stays shape-checked.
    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("stateless-source");
    }

    /// Restore state written by [`GradientSource::state_save`].
    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("stateless-source")
    }
}

// ---------------------------------------------------------------------------
// Quadratic consensus problem
// ---------------------------------------------------------------------------

/// Per-worker diagonal quadratic: `f^(k)(x) = 0.5 (x-b_k)^T diag(a_k) (x-b_k)`,
/// stochastic gradient = exact gradient + N(0, noise² I).
///
/// The global optimum is closed-form: `x* = (Σ diag(a_k))^{-1} Σ a_k ⊙ b_k`,
/// so `f(x) - f*` and `||x - x*||` are exactly measurable — this is the
/// workload for the speedup/topology ablations.
pub struct Quadratic {
    k: usize,
    d: usize,
    /// Diagonal curvatures a_k (all in [l_min, l_max] => L-smooth with L = l_max).
    a: Vec<Vec<f32>>,
    /// Per-worker optima b_k (heterogeneity = inter-worker spread of b_k).
    b: Vec<Vec<f32>>,
    pub noise: f32,
    /// One independent noise stream per worker, so the parallel engine's
    /// schedule cannot perturb the randomness any worker sees.
    rngs: Vec<Xoshiro256>,
}

/// Shared gradient kernel for the sequential path and the split workers:
/// writes `a ⊙ (x − b) + noise` into `out`, returns the minibatch loss.
fn quad_grad_into(
    a: &[f32],
    b: &[f32],
    noise: f32,
    rng: &mut Xoshiro256,
    x: &[f32],
    out: &mut [f32],
) -> f64 {
    debug_assert_eq!(x.len(), out.len());
    let mut loss = 0.0f64;
    for (((o, &xi), &ai), &bi) in out.iter_mut().zip(x).zip(a).zip(b) {
        let e = xi - bi;
        let mut g = ai * e;
        if noise > 0.0 {
            g += rng.normal_f32() * noise;
        }
        *o = g;
        loss += 0.5 * ai as f64 * (e as f64) * (e as f64);
    }
    loss
}

struct QuadraticWorker<'a> {
    a: &'a [f32],
    b: &'a [f32],
    noise: f32,
    rng: &'a mut Xoshiro256,
}

impl WorkerGrad for QuadraticWorker<'_> {
    fn grad_into(&mut self, x: &[f32], out: &mut [f32]) -> f64 {
        quad_grad_into(self.a, self.b, self.noise, self.rng, x, out)
    }
}

impl Quadratic {
    /// `heterogeneity` scales how far apart the workers' optima are — the
    /// analogue of non-iid data.
    pub fn new(k: usize, d: usize, heterogeneity: f32, noise: f32, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let a = (0..k)
            .map(|_| (0..d).map(|_| 0.5 + rng.next_f32()).collect()) // [0.5, 1.5]
            .collect();
        let b = (0..k).map(|_| rng.normal_vec(d, heterogeneity)).collect();
        let rngs = (0..k).map(|i| rng.fork(1 + i as u64)).collect();
        Self { k, d, a, b, noise, rngs }
    }

    /// Closed-form global minimizer of (1/K) Σ f^(k).
    pub fn optimum(&self) -> Vec<f32> {
        (0..self.d)
            .map(|j| {
                let num: f64 = (0..self.k)
                    .map(|k| self.a[k][j] as f64 * self.b[k][j] as f64)
                    .sum();
                let den: f64 = (0..self.k).map(|k| self.a[k][j] as f64).sum();
                (num / den) as f32
            })
            .collect()
    }

    /// Global loss at the optimum (for gap-to-optimal curves).
    pub fn f_star(&mut self) -> f64 {
        let xs = self.optimum();
        self.eval(&xs).loss
    }

    /// Smoothness constant L = max curvature (for the Theorem 1 eta bound).
    pub fn l_smooth(&self) -> f32 {
        self.a
            .iter()
            .flat_map(|row| row.iter())
            .fold(0.0f32, |acc, &v| acc.max(v))
    }
}

impl GradientSource for Quadratic {
    fn dim(&self) -> usize {
        self.d
    }

    fn workers(&self) -> usize {
        self.k
    }

    fn grad_into(&mut self, worker: usize, x: &[f32], out: &mut [f32]) -> f64 {
        quad_grad_into(
            &self.a[worker],
            &self.b[worker],
            self.noise,
            &mut self.rngs[worker],
            x,
            out,
        )
    }

    fn eval(&mut self, x: &[f32]) -> EvalMetrics {
        let mut loss = 0.0;
        let mut grad = vec![0.0f64; self.d];
        for k in 0..self.k {
            for j in 0..self.d {
                let (a, b) = (self.a[k][j] as f64, self.b[k][j] as f64);
                let e = x[j] as f64 - b;
                loss += 0.5 * a * e * e;
                grad[j] += a * e;
            }
        }
        let kf = self.k as f64;
        EvalMetrics {
            loss: loss / kf,
            accuracy: 0.0,
            grad_norm_sq: grad.iter().map(|g| (g / kf).powi(2)).sum(),
        }
    }

    fn init(&self, seed: u64) -> Vec<f32> {
        Xoshiro256::seed_from_u64(seed).normal_vec(self.d, 1.0)
    }

    fn split_workers(&mut self) -> Option<Vec<Box<dyn WorkerGrad + '_>>> {
        let noise = self.noise;
        let Self { a, b, rngs, .. } = self;
        let mut v: Vec<Box<dyn WorkerGrad + '_>> = Vec::with_capacity(rngs.len());
        for ((a, b), rng) in a.iter().zip(b.iter()).zip(rngs.iter_mut()) {
            v.push(Box::new(QuadraticWorker { a: a.as_slice(), b: b.as_slice(), noise, rng }));
        }
        Some(v)
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("quadratic");
        w.put_u64(self.rngs.len() as u64);
        for rng in &self.rngs {
            w.put_u64s(&rng.state());
        }
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("quadratic")?;
        let k = r.take_u64()? as usize;
        if k != self.rngs.len() {
            return Err(format!("quadratic: saved K {k} != live K {}", self.rngs.len()));
        }
        for rng in self.rngs.iter_mut() {
            let s = r.take_u64s()?;
            let s: [u64; 4] = s.try_into().map_err(|_| "quadratic: bad rng state".to_string())?;
            *rng = Xoshiro256::from_state(s);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shared softmax utilities
// ---------------------------------------------------------------------------

fn softmax_xent(logits: &mut [f64], label: usize) -> f64 {
    // in-place softmax; returns -log p[label]
    let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut z = 0.0;
    for l in logits.iter_mut() {
        *l = (*l - max).exp();
        z += *l;
    }
    for l in logits.iter_mut() {
        *l /= z;
    }
    -(logits[label].max(1e-300)).ln()
}

// ---------------------------------------------------------------------------
// Logistic regression
// ---------------------------------------------------------------------------

/// Multinomial logistic regression on a sharded classification dataset.
/// Parameters: row-major `W (classes x dim)` then bias `(classes)`.
pub struct Logistic {
    data: Dataset,
    shards: Vec<BatchIter>,
    k: usize,
    pub batch: usize,
    pub l2: f32,
}

/// Gradient/loss over explicit indices, written into `out` (overwritten).
/// Shared by the sequential path, the split workers, and `eval`.
fn logistic_loss_grad_into(
    data: &Dataset,
    l2: f32,
    x: &[f32],
    indices: &[usize],
    out: &mut [f32],
) -> f64 {
    let (din, c) = (data.dim(), data.n_classes);
    debug_assert_eq!(out.len(), c * din + c);
    out.iter_mut().for_each(|g| *g = 0.0);
    let mut loss = 0.0;
    let mut logits = vec![0.0f64; c]; // per-call scratch, reused per sample
    for &i in indices {
        let feat = &data.features[i];
        let label = data.labels[i];
        for (j, l) in logits.iter_mut().enumerate() {
            let row = &x[j * din..(j + 1) * din];
            *l = crate::linalg::dot(row, feat) + x[c * din + j] as f64;
        }
        loss += softmax_xent(&mut logits, label);
        for j in 0..c {
            let coef = (logits[j] - if j == label { 1.0 } else { 0.0 }) as f32;
            let grow = &mut out[j * din..(j + 1) * din];
            crate::linalg::axpy(coef, feat, grow);
            out[c * din + j] += coef;
        }
    }
    let n = indices.len().max(1) as f32;
    out.iter_mut().for_each(|g| *g /= n);
    if l2 > 0.0 {
        crate::linalg::axpy(l2, x, out);
    }
    loss / n as f64
}

struct LogisticWorker<'a> {
    data: &'a Dataset,
    batch: usize,
    l2: f32,
    sampler: &'a mut BatchIter,
}

impl WorkerGrad for LogisticWorker<'_> {
    fn grad_into(&mut self, x: &[f32], out: &mut [f32]) -> f64 {
        let idx = self.sampler.next_batch(self.batch);
        logistic_loss_grad_into(self.data, self.l2, x, &idx, out)
    }
}

impl Logistic {
    pub fn new(data: Dataset, k: usize, sharding: Sharding, batch: usize, l2: f32, seed: u64) -> Self {
        let idx = shard_indices(&data, k, sharding, seed);
        let shards = idx
            .into_iter()
            .enumerate()
            .map(|(i, s)| BatchIter::new(s, seed ^ (i as u64 + 1)))
            .collect();
        Self { data, shards, k, batch, l2 }
    }

    fn dim_in(&self) -> usize {
        self.data.dim()
    }

    fn classes(&self) -> usize {
        self.data.n_classes
    }

    /// loss + grad over an explicit index set (allocating form).
    fn loss_grad_at(&self, x: &[f32], indices: &[usize]) -> (f64, Vec<f32>) {
        let mut g = vec![0.0f32; self.dim_total()];
        let loss = logistic_loss_grad_into(&self.data, self.l2, x, indices, &mut g);
        (loss, g)
    }

    fn dim_total(&self) -> usize {
        self.classes() * self.dim_in() + self.classes()
    }

    pub fn accuracy_on(&self, x: &[f32], indices: &[usize]) -> f64 {
        let (din, c) = (self.dim_in(), self.classes());
        let correct = indices
            .iter()
            .filter(|&&i| {
                let feat = &self.data.features[i];
                let pred = (0..c)
                    .max_by(|&a, &b| {
                        let la = crate::linalg::dot(&x[a * din..(a + 1) * din], feat)
                            + x[c * din + a] as f64;
                        let lb = crate::linalg::dot(&x[b * din..(b + 1) * din], feat)
                            + x[c * din + b] as f64;
                        la.total_cmp(&lb)
                    })
                    .unwrap();
                pred == self.data.labels[i]
            })
            .count();
        correct as f64 / indices.len().max(1) as f64
    }
}

impl GradientSource for Logistic {
    fn dim(&self) -> usize {
        self.dim_total()
    }

    fn workers(&self) -> usize {
        self.k
    }

    fn grad_into(&mut self, worker: usize, x: &[f32], out: &mut [f32]) -> f64 {
        let batch = self.shards[worker].next_batch(self.batch);
        logistic_loss_grad_into(&self.data, self.l2, x, &batch, out)
    }

    fn eval(&mut self, x: &[f32]) -> EvalMetrics {
        let all: Vec<usize> = (0..self.data.len()).collect();
        let (loss, grad) = self.loss_grad_at(x, &all);
        EvalMetrics {
            loss,
            accuracy: self.accuracy_on(x, &all),
            grad_norm_sq: crate::linalg::dot(&grad, &grad),
        }
    }

    fn init(&self, _seed: u64) -> Vec<f32> {
        vec![0.0; self.dim_total()] // convex: zero init is standard
    }

    fn split_workers(&mut self) -> Option<Vec<Box<dyn WorkerGrad + '_>>> {
        let (batch, l2) = (self.batch, self.l2);
        let Self { data, shards, .. } = self;
        let data: &Dataset = data;
        let mut v: Vec<Box<dyn WorkerGrad + '_>> = Vec::with_capacity(shards.len());
        for sampler in shards.iter_mut() {
            v.push(Box::new(LogisticWorker { data, batch, l2, sampler }));
        }
        Some(v)
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("logistic");
        save_samplers(&self.shards, w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("logistic")?;
        load_samplers(&mut self.shards, r)
    }
}

// ---------------------------------------------------------------------------
// One-hidden-layer MLP (manual backprop)
// ---------------------------------------------------------------------------

/// Non-convex classifier: `logits = W2 tanh(W1 x + b1) + b2`.
/// Layout: W1 (h x din) | b1 (h) | W2 (c x h) | b2 (c).
pub struct Mlp {
    data: Dataset,
    holdout: Vec<usize>,
    shards: Vec<BatchIter>,
    k: usize,
    pub hidden: usize,
    pub batch: usize,
}

/// fwd+bwd over explicit indices, written into `out` (overwritten);
/// `indices` map into `data` offset by the holdout size. Shared by the
/// sequential path, the split workers, and `eval`. Per-sample scratch
/// (activations, logit deltas) is hoisted out of the sample loop, so the
/// only allocations are O(hidden + classes) per *call*, never O(d).
fn mlp_loss_grad_into(
    data: &Dataset,
    hidden_units: usize,
    x: &[f32],
    indices: &[usize],
    offset: usize,
    out: &mut [f32],
) -> f64 {
    let (din, h, c) = (data.dim(), hidden_units, data.n_classes);
    debug_assert_eq!(out.len(), h * din + h + c * h + c);
    let (w1, rest) = x.split_at(h * din);
    let (b1, rest) = rest.split_at(h);
    let (w2, b2) = rest.split_at(c * h);
    debug_assert_eq!(b2.len(), c);
    out.iter_mut().for_each(|g| *g = 0.0);
    let mut loss = 0.0;
    let mut hidden = vec![0.0f64; h];
    let mut logits = vec![0.0f64; c];
    let mut dlogits = vec![0.0f64; c];
    let mut dhidden = vec![0.0f64; h];
    for &i0 in indices {
        let i = i0 + offset;
        let feat = &data.features[i];
        let label = data.labels[i];
        // fwd
        for (j, a) in hidden.iter_mut().enumerate() {
            *a = (crate::linalg::dot(&w1[j * din..(j + 1) * din], feat) + b1[j] as f64).tanh();
        }
        for (j, l) in logits.iter_mut().enumerate() {
            *l = w2[j * h..(j + 1) * h]
                .iter()
                .zip(&hidden)
                .map(|(&w, &a)| w as f64 * a)
                .sum::<f64>()
                + b2[j] as f64;
        }
        loss += softmax_xent(&mut logits, label);
        // bwd: dlogits = p - onehot
        for (j, dl) in dlogits.iter_mut().enumerate() {
            *dl = logits[j] - if j == label { 1.0 } else { 0.0 };
        }
        // grads of W2, b2; accumulate dhidden
        dhidden.iter_mut().for_each(|v| *v = 0.0);
        {
            let (gw1, rest) = out.split_at_mut(h * din);
            let (_gb1, rest) = rest.split_at_mut(h);
            let (gw2, gb2) = rest.split_at_mut(c * h);
            let _ = gw1;
            for j in 0..c {
                let dj = dlogits[j];
                gb2[j] += dj as f32;
                for (l, (&a, dh)) in hidden.iter().zip(dhidden.iter_mut()).enumerate() {
                    gw2[j * h + l] += (dj * a) as f32;
                    *dh += dj * w2[j * h + l] as f64;
                }
            }
        }
        // tanh' = 1 - a^2
        for (dh, a) in dhidden.iter_mut().zip(hidden.iter()) {
            *dh *= 1.0 - *a * *a;
        }
        {
            let (gw1, rest) = out.split_at_mut(h * din);
            let (gb1, _rest) = rest.split_at_mut(h);
            for j in 0..h {
                gb1[j] += dhidden[j] as f32;
                let row = &mut gw1[j * din..(j + 1) * din];
                crate::linalg::axpy(dhidden[j] as f32, feat, row);
            }
        }
    }
    let n = indices.len().max(1) as f32;
    out.iter_mut().for_each(|g| *g /= n);
    loss / n as f64
}

struct MlpWorker<'a> {
    data: &'a Dataset,
    hidden: usize,
    batch: usize,
    offset: usize,
    sampler: &'a mut BatchIter,
}

impl WorkerGrad for MlpWorker<'_> {
    fn grad_into(&mut self, x: &[f32], out: &mut [f32]) -> f64 {
        let idx = self.sampler.next_batch(self.batch);
        mlp_loss_grad_into(self.data, self.hidden, x, &idx, self.offset, out)
    }
}

impl Mlp {
    /// `holdout_frac` of the data is reserved for the "test accuracy"
    /// curves of Figure 1(c,d)/2.
    pub fn new(
        data: Dataset,
        k: usize,
        sharding: Sharding,
        hidden: usize,
        batch: usize,
        holdout_frac: f64,
        seed: u64,
    ) -> Self {
        let n = data.len();
        let n_hold = ((n as f64 * holdout_frac) as usize).min(n / 2);
        let holdout: Vec<usize> = (0..n_hold).collect();
        let train = Dataset {
            features: data.features[n_hold..].to_vec(),
            labels: data.labels[n_hold..].to_vec(),
            n_classes: data.n_classes,
        };
        let idx = shard_indices(&train, k, sharding, seed);
        let shards = idx
            .into_iter()
            .enumerate()
            .map(|(i, s)| BatchIter::new(s, seed ^ (0x100 + i as u64)))
            .collect();
        Self { data, holdout, shards, k, hidden, batch }
    }

    fn din(&self) -> usize {
        self.data.dim()
    }

    fn classes(&self) -> usize {
        self.data.n_classes
    }

    fn dim_total(&self) -> usize {
        let (din, h, c) = (self.din(), self.hidden, self.classes());
        h * din + h + c * h + c
    }

    fn split<'a>(&self, x: &'a [f32]) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        let (din, h, c) = (self.din(), self.hidden, self.classes());
        let (w1, rest) = x.split_at(h * din);
        let (b1, rest) = rest.split_at(h);
        let (w2, b2) = rest.split_at(c * h);
        debug_assert_eq!(b2.len(), c);
        (w1, b1, w2, b2)
    }

    /// Allocating form of the fwd+bwd over explicit indices.
    fn loss_grad_at(&self, x: &[f32], indices: &[usize], offset: usize) -> (f64, Vec<f32>) {
        let mut g = vec![0.0f32; self.dim_total()];
        let loss = mlp_loss_grad_into(&self.data, self.hidden, x, indices, offset, &mut g);
        (loss, g)
    }

    pub fn accuracy_on(&self, x: &[f32], indices: &[usize]) -> f64 {
        let (din, h, c) = (self.din(), self.hidden, self.classes());
        let (w1, b1, w2, b2) = self.split(x);
        let correct = indices
            .iter()
            .filter(|&&i| {
                let feat = &self.data.features[i];
                let hidden: Vec<f64> = (0..h)
                    .map(|j| {
                        (crate::linalg::dot(&w1[j * din..(j + 1) * din], feat) + b1[j] as f64)
                            .tanh()
                    })
                    .collect();
                let pred = (0..c)
                    .max_by(|&a, &b| {
                        let la: f64 = w2[a * h..(a + 1) * h]
                            .iter()
                            .zip(&hidden)
                            .map(|(&w, &v)| w as f64 * v)
                            .sum::<f64>()
                            + b2[a] as f64;
                        let lb: f64 = w2[b * h..(b + 1) * h]
                            .iter()
                            .zip(&hidden)
                            .map(|(&w, &v)| w as f64 * v)
                            .sum::<f64>()
                            + b2[b] as f64;
                        la.total_cmp(&lb)
                    })
                    .unwrap();
                pred == self.data.labels[i]
            })
            .count();
        correct as f64 / indices.len().max(1) as f64
    }

    /// Held-out accuracy — the y-axis of Figure 1(c,d) and Figure 2.
    pub fn test_accuracy(&self, x: &[f32]) -> f64 {
        self.accuracy_on(x, &self.holdout)
    }
}

impl GradientSource for Mlp {
    fn dim(&self) -> usize {
        self.dim_total()
    }

    fn workers(&self) -> usize {
        self.k
    }

    fn grad_into(&mut self, worker: usize, x: &[f32], out: &mut [f32]) -> f64 {
        let batch = self.shards[worker].next_batch(self.batch);
        mlp_loss_grad_into(&self.data, self.hidden, x, &batch, self.holdout.len(), out)
    }

    fn eval(&mut self, x: &[f32]) -> EvalMetrics {
        let train: Vec<usize> = (0..self.data.len() - self.holdout.len()).collect();
        let (loss, grad) = self.loss_grad_at(x, &train, self.holdout.len());
        EvalMetrics {
            loss,
            accuracy: self.test_accuracy(x),
            grad_norm_sq: crate::linalg::dot(&grad, &grad),
        }
    }

    fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let (din, h, c) = (self.din(), self.hidden, self.classes());
        let mut x = Vec::with_capacity(self.dim_total());
        let s1 = (1.0 / din as f64).sqrt() as f32;
        x.extend((0..h * din).map(|_| rng.normal_f32() * s1));
        x.extend(std::iter::repeat(0.0f32).take(h));
        let s2 = (1.0 / h as f64).sqrt() as f32;
        x.extend((0..c * h).map(|_| rng.normal_f32() * s2));
        x.extend(std::iter::repeat(0.0f32).take(c));
        x
    }

    fn split_workers(&mut self) -> Option<Vec<Box<dyn WorkerGrad + '_>>> {
        let (hidden, batch, offset) = (self.hidden, self.batch, self.holdout.len());
        let Self { data, shards, .. } = self;
        let data: &Dataset = data;
        let mut v: Vec<Box<dyn WorkerGrad + '_>> = Vec::with_capacity(shards.len());
        for sampler in shards.iter_mut() {
            v.push(Box::new(MlpWorker { data, hidden, batch, offset, sampler }));
        }
        Some(v)
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("mlp");
        save_samplers(&self.shards, w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("mlp")?;
        load_samplers(&mut self.shards, r)
    }
}

/// Checkpoint helpers for a per-worker bank of batch samplers, shared by
/// [`Logistic`], [`Mlp`], and [`crate::runtime::XlaGradSource`].
pub(crate) fn save_samplers(shards: &[BatchIter], w: &mut crate::state::StateWriter) {
    w.put_u64(shards.len() as u64);
    for s in shards {
        s.state_save(w);
    }
}

pub(crate) fn load_samplers(
    shards: &mut [BatchIter],
    r: &mut crate::state::StateReader,
) -> Result<(), String> {
    let k = r.take_u64()? as usize;
    if k != shards.len() {
        return Err(format!("samplers: saved K {k} != live K {}", shards.len()));
    }
    for s in shards.iter_mut() {
        s.state_load(r)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Blobs;
    use crate::testing::forall;

    fn blobs(n: usize) -> Dataset {
        Blobs { n, dim: 6, classes: 3, spread: 4.0 }.generate(42)
    }

    // --- quadratic ---

    #[test]
    fn quadratic_optimum_has_zero_gradient() {
        let mut q = Quadratic::new(4, 20, 2.0, 0.0, 1);
        let xs = q.optimum();
        let m = q.eval(&xs);
        assert!(m.grad_norm_sq < 1e-10, "{}", m.grad_norm_sq);
    }

    #[test]
    fn quadratic_gd_converges_to_optimum() {
        let mut q = Quadratic::new(3, 10, 1.0, 0.0, 2);
        let xs = q.optimum();
        let mut x = q.init(0);
        for _ in 0..500 {
            // full gradient = average of worker exact grads
            let g: Vec<f32> = {
                let grads: Vec<Vec<f32>> = (0..3).map(|k| q.grad(k, &x).1).collect();
                crate::linalg::mean_of(&grads)
            };
            crate::linalg::axpy(-0.5, &g, &mut x);
        }
        assert!(crate::linalg::dist(&x, &xs) < 1e-3);
    }

    #[test]
    fn quadratic_noise_perturbs_gradient() {
        let mut q = Quadratic::new(2, 5, 1.0, 0.5, 3);
        let x = vec![0.0f32; 5];
        let (_l1, g1) = q.grad(0, &x);
        let (_l2, g2) = q.grad(0, &x);
        assert_ne!(g1, g2, "stochastic gradients should differ");
    }

    #[test]
    fn quadratic_noise_streams_are_per_worker() {
        // Worker 1's draws must not depend on how often worker 0 drew —
        // the invariant the parallel engine relies on.
        let x = vec![0.0f32; 6];
        let mut a = Quadratic::new(2, 6, 1.0, 0.3, 9);
        let (_, _) = a.grad(0, &x); // interleaved extra draw on worker 0
        let (_, g1_a) = a.grad(1, &x);
        let mut b = Quadratic::new(2, 6, 1.0, 0.3, 9);
        let (_, g1_b) = b.grad(1, &x);
        assert_eq!(g1_a, g1_b, "worker 1 stream perturbed by worker 0 draws");
    }

    #[test]
    fn quadratic_l_smooth_bounds_curvature() {
        let q = Quadratic::new(4, 16, 1.0, 0.0, 4);
        let l = q.l_smooth();
        assert!((0.5..=1.5).contains(&l));
    }

    #[test]
    fn prop_quadratic_fstar_is_minimum() {
        forall(31, 15, |rng| {
            let mut q = Quadratic::new(1 + rng.below(6), 1 + rng.below(20), 2.0, 0.0, rng.next_u64());
            let fstar = q.f_star();
            for _ in 0..5 {
                let x = rng.normal_vec(q.dim(), 2.0);
                assert!(q.eval(&x).loss >= fstar - 1e-9);
            }
        });
    }

    // --- the grad_into / split_workers contract ---

    #[test]
    fn grad_into_matches_allocating_grad() {
        // Two identically-seeded sources, one driven through grad(), one
        // through grad_into(): bit-identical output.
        let x = Xoshiro256::seed_from_u64(5).normal_vec(12, 1.0);
        let mut a = Quadratic::new(3, 12, 1.0, 0.2, 11);
        let mut b = Quadratic::new(3, 12, 1.0, 0.2, 11);
        for w in 0..3 {
            let (la, ga) = a.grad(w, &x);
            let mut gb = vec![9.9f32; 12]; // dirty buffer: must be overwritten
            let lb = b.grad_into(w, &x, &mut gb);
            assert_eq!(la.to_bits(), lb.to_bits(), "worker {w} loss");
            assert_eq!(ga, gb, "worker {w} grad");
        }
    }

    #[test]
    fn split_workers_match_sequential_streams() {
        // For every pure-Rust oracle: a split worker draws exactly the
        // stream the sequential grad_into path would.
        fn check(mut seq: Box<dyn GradientSource>, mut par: Box<dyn GradientSource>, x: &[f32]) {
            let d = seq.dim();
            let k = seq.workers();
            let mut seq_out = vec![0.0f32; d];
            let seq_losses: Vec<f64> = (0..k)
                .map(|w| seq.grad_into(w, x, &mut seq_out))
                .collect();
            // (keep only the last worker's grad for the bit check below)
            let workers = par.split_workers().expect("pure-Rust oracles split");
            assert_eq!(workers.len(), k);
            let mut par_out = vec![0.0f32; d];
            let mut par_losses = Vec::new();
            for mut w in workers {
                par_losses.push(w.grad_into(x, &mut par_out));
            }
            for (a, b) in seq_losses.iter().zip(&par_losses) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(seq_out, par_out, "last worker's gradient differs");
        }
        let xq = Xoshiro256::seed_from_u64(6).normal_vec(10, 1.0);
        check(
            Box::new(Quadratic::new(4, 10, 1.0, 0.1, 21)),
            Box::new(Quadratic::new(4, 10, 1.0, 0.1, 21)),
            &xq,
        );
        let lg = |s| Box::new(Logistic::new(blobs(90), 3, Sharding::Iid, 16, 0.01, s));
        let xl = Xoshiro256::seed_from_u64(7).normal_vec(lg(22).dim(), 0.5);
        check(lg(22), lg(22), &xl);
        let mk = |s| Box::new(Mlp::new(blobs(90), 3, Sharding::Iid, 8, 16, 0.1, s));
        let xm = Xoshiro256::seed_from_u64(8).normal_vec(mk(23).dim(), 0.5);
        check(mk(23), mk(23), &xm);
    }

    // --- logistic ---

    #[test]
    fn logistic_grad_matches_numerical() {
        let lg = Logistic::new(blobs(60), 2, Sharding::Iid, 60, 0.01, 5);
        let mut rng = Xoshiro256::seed_from_u64(6);
        let x = rng.normal_vec(lg.dim(), 0.5);
        let all: Vec<usize> = (0..lg.data.len()).collect();
        let (_, g) = lg.loss_grad_at(&x, &all);
        let eps = 1e-3f32;
        for &i in &[0usize, 7, lg.dim() - 1] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let (lp, _) = lg.loss_grad_at(&xp, &all);
            let (lm, _) = lg.loss_grad_at(&xm, &all);
            // numerical grad of loss term; add l2 term analytically
            let num = (lp - lm) / (2.0 * eps as f64)
                + 0.0; // l2 is included in loss_grad_at's grad but not loss; compare loosely
            let l2_term = lg.l2 as f64 * x[i] as f64;
            assert!(
                ((num + l2_term) - g[i] as f64).abs() < 5e-3,
                "coord {i}: num {} vs analytic {}",
                num + l2_term,
                g[i]
            );
        }
    }

    #[test]
    fn logistic_training_improves_accuracy() {
        let mut lg = Logistic::new(blobs(300), 4, Sharding::Iid, 32, 0.0, 7);
        let mut x = lg.init(0);
        let acc0 = lg.eval(&x).accuracy;
        for t in 0..200 {
            let k = t % 4;
            let (_, g) = lg.grad(k, &x);
            crate::linalg::axpy(-0.5, &g, &mut x);
        }
        let acc1 = lg.eval(&x).accuracy;
        assert!(acc1 > 0.9, "acc {acc1} (from {acc0})");
        assert!(acc1 > acc0);
    }

    // --- mlp ---

    #[test]
    fn mlp_grad_matches_numerical() {
        let mlp = Mlp::new(blobs(40), 2, Sharding::Iid, 8, 16, 0.0, 8);
        let mut rng = Xoshiro256::seed_from_u64(9);
        let x = rng.normal_vec(mlp.dim(), 0.5);
        let idx: Vec<usize> = (0..20).collect();
        let (_, g) = mlp.loss_grad_at(&x, &idx, 0);
        let eps = 1e-3f32;
        let probe: Vec<usize> = vec![0, mlp.hidden * mlp.din() + 1, mlp.dim() - 1];
        for &i in &probe {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let (lp, _) = mlp.loss_grad_at(&xp, &idx, 0);
            let (lm, _) = mlp.loss_grad_at(&xm, &idx, 0);
            let num = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (num - g[i] as f64).abs() < 5e-3,
                "coord {i}: num {num} vs analytic {}",
                g[i]
            );
        }
    }

    #[test]
    fn mlp_training_beats_chance() {
        let mut mlp = Mlp::new(blobs(400), 4, Sharding::Iid, 16, 32, 0.2, 10);
        let mut x = mlp.init(1);
        for t in 0..400 {
            let (_, g) = mlp.grad(t % 4, &x);
            crate::linalg::axpy(-0.3, &g, &mut x);
        }
        let m = mlp.eval(&x);
        assert!(m.accuracy > 0.8, "test acc {}", m.accuracy);
    }

    #[test]
    fn mlp_holdout_is_excluded_from_training_shards() {
        let mlp = Mlp::new(blobs(100), 4, Sharding::Iid, 4, 8, 0.2, 11);
        assert_eq!(mlp.holdout.len(), 20);
        // dim sanity: W1 + b1 + W2 + b2
        assert_eq!(mlp.dim(), 4 * 6 + 4 + 3 * 4 + 3);
    }

    #[test]
    fn mlp_eval_loss_decreases_under_gd() {
        let mut mlp = Mlp::new(blobs(120), 1, Sharding::Iid, 8, 120, 0.0, 12);
        let mut x = mlp.init(2);
        let l0 = mlp.eval(&x).loss;
        for _ in 0..50 {
            let (_, g) = mlp.grad(0, &x);
            crate::linalg::axpy(-0.3, &g, &mut x);
        }
        let l1 = mlp.eval(&x).loss;
        assert!(l1 < l0, "{l1} !< {l0}");
    }
}
