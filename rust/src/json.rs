//! Minimal JSON parser/printer (no serde in this offline environment).
//!
//! Consumes the artifact manifests (`artifacts/<cfg>.meta.json`) written
//! by `python/compile/aot.py` and emits metrics JSONL. Supports the full
//! JSON grammar except `\u` surrogate pairs beyond the BMP (the manifests
//! are ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Compact single-line rendering (used for metrics JSONL).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for metric records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(cp).ok_or("bad codepoint")?);
                            self.i += 4;
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"name": "tiny", "d": 19712, "layout": [{"name": "embed", "offset": 0, "shape": [64, 32]}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("d").unwrap().as_usize(), Some(19712));
        let l0 = &v.get("layout").unwrap().as_arr().unwrap()[0];
        assert_eq!(l0.get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(64));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn roundtrips_compact() {
        let src = r#"{"a":[1,2.5,"x"],"b":true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn escapes_on_output() {
        let v = Json::Str("a\"b\\c\nd".into());
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn obj_builder() {
        let v = obj(vec![("x", Json::Num(1.0)), ("y", Json::Str("z".into()))]);
        assert_eq!(v.to_string_compact(), r#"{"x":1,"y":"z"}"#);
    }
}
