//! # pdsgdm — Periodic Decentralized Momentum SGD
//!
//! Reproduction of Gao & Huang (2020), *"Periodic Stochastic Gradient
//! Descent with Momentum for Decentralized Training"*, as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the decentralized-training coordinator:
//!   topologies & mixing matrices ([`topology`]), δ-contraction
//!   compression ([`compress`]), the simulated byte-metered network
//!   ([`comm`]), the paper's two algorithms plus six baselines
//!   ([`algorithms`]) all driven through the parallel local-step engine
//!   ([`engine`]), gradient oracles ([`grad`]), the PJRT runtime that
//!   executes the AOT-compiled JAX/Pallas artifacts ([`runtime`],
//!   feature-gated behind `pjrt`), and the training driver
//!   ([`coordinator`]).
//! * **L2 (python/compile/model.py)** — a flat-parameter-vector decoder
//!   transformer whose fused fwd+bwd is AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots (tiled matmul, fused momentum, gossip mixing).
//!
//! Python runs only at `make artifacts`; the binary is self-contained.
//!
//! See DESIGN.md for the paper -> module map and EXPERIMENTS.md for
//! reproduced figures.

pub mod algorithms;
pub mod analysis;
pub mod arena;
pub mod benchlib;
pub mod comm;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod grad;
pub mod json;
pub mod linalg;
pub mod metrics;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod state;
pub mod testing;
pub mod topology;
