//! Dense vector/matrix primitives used by every layer of the coordinator.
//!
//! The paper's state objects are flat vectors `x in R^d` (one per worker)
//! and small `K x K` mixing matrices, so this module provides exactly
//! that: cache-friendly `f32` slice kernels (the L3 hot path — see
//! EXPERIMENTS.md §Perf) plus a small row-major [`Mat`] with the
//! spectral machinery (power iteration on `W - 11^T/K`) needed to compute
//! the paper's spectral gap `rho = 1 - |lambda_2|`.

/// y += a * x (the classic axpy). Hot path: momentum + consensus updates.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // 4-way unrolled so LLVM reliably autovectorizes without a SIMD crate.
    let n = x.len();
    let chunks = n / 4;
    let (x4, xr) = x.split_at(chunks * 4);
    let (y4, yr) = y.split_at_mut(chunks * 4);
    for (xc, yc) in x4.chunks_exact(4).zip(y4.chunks_exact_mut(4)) {
        yc[0] += a * xc[0];
        yc[1] += a * xc[1];
        yc[2] += a * xc[2];
        yc[3] += a * xc[3];
    }
    for (xi, yi) in xr.iter().zip(yr.iter_mut()) {
        *yi += a * xi;
    }
}

/// y = a * x + b * y (scaled blend). This is the standalone form of the
/// momentum recurrence `m = mu*m + g` — [`crate::optim::MomentumState::step`]
/// fuses that recurrence with the weight-decay and iterate updates in
/// one pass, so this kernel serves optimizer variants and analysis code.
/// 4-way unrolled exactly like [`axpy`] so LLVM reliably autovectorizes
/// without a SIMD crate.
#[inline]
pub fn axpby(a: f32, x: &[f32], b: f32, y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (x4, xr) = x.split_at(chunks * 4);
    let (y4, yr) = y.split_at_mut(chunks * 4);
    for (xc, yc) in x4.chunks_exact(4).zip(y4.chunks_exact_mut(4)) {
        yc[0] = a * xc[0] + b * yc[0];
        yc[1] = a * xc[1] + b * yc[1];
        yc[2] = a * xc[2] + b * yc[2];
        yc[3] = a * xc[3] + b * yc[3];
    }
    for (xi, yi) in xr.iter().zip(yr.iter_mut()) {
        *yi = a * xi + b * *yi;
    }
}

/// dst = Σ_i terms[i].0 · terms[i].1 in ONE pass over memory — the fused
/// gossip accumulator (§Perf: one write pass instead of scale + per-term
/// axpy read-modify-writes).
pub fn weighted_sum_into(dst: &mut [f32], terms: &[(f32, &[f32])]) {
    for (_, x) in terms {
        debug_assert_eq!(x.len(), dst.len());
    }
    match terms {
        [] => dst.iter_mut().for_each(|v| *v = 0.0),
        [(a, x)] => {
            for (d, xi) in dst.iter_mut().zip(*x) {
                *d = a * xi;
            }
        }
        [(a, x), (b, y)] => {
            for ((d, xi), yi) in dst.iter_mut().zip(*x).zip(*y) {
                *d = a * xi + b * yi;
            }
        }
        [(a, x), (b, y), (c, z)] => {
            // ring topology fast path: self + two neighbors
            for (((d, xi), yi), zi) in dst.iter_mut().zip(*x).zip(*y).zip(*z) {
                *d = a * xi + b * yi + c * zi;
            }
        }
        [first @ (a, x), rest @ ..] => {
            let _ = first;
            for (d, xi) in dst.iter_mut().zip(*x) {
                *d = a * xi;
            }
            for (w, y) in rest {
                axpy(*w, y, dst);
            }
        }
    }
}

/// Allocating form of [`weighted_sum_into`] that skips the zero-fill a
/// `vec![0.0; d]` destination would pay (collect from an exact-size
/// iterator writes each element exactly once).
pub fn weighted_sum(terms: &[(f32, &[f32])], d: usize) -> Vec<f32> {
    match terms {
        [(a, x), (b, y), (c, z)] => {
            // ring fast path: self + two neighbors, single fused pass
            debug_assert!(x.len() == d && y.len() == d && z.len() == d);
            x.iter()
                .zip(*y)
                .zip(*z)
                .map(|((xi, yi), zi)| a * xi + b * yi + c * zi)
                .collect()
        }
        [(a, x), (b, y)] => {
            debug_assert!(x.len() == d && y.len() == d);
            x.iter().zip(*y).map(|(xi, yi)| a * xi + b * yi).collect()
        }
        _ => {
            let mut out = vec![0.0f32; d];
            weighted_sum_into(&mut out, terms);
            out
        }
    }
}

/// x *= a.
#[inline]
pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// Dot product with f64 accumulation (d is in the millions; f32
/// accumulation loses ~3 digits there).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

/// Euclidean norm (f64 accumulation).
#[inline]
pub fn norm(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// ||x - y||_2.
pub fn dist(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Mean of equal-length row views — the PRIMARY averaging API: it
/// consumes any row iterator (arena rows, slices-of-vecs, filtered
/// subsets) without collecting or cloning. `d` is the row length.
pub fn mean_of_rows<'a>(rows: impl IntoIterator<Item = &'a [f32]>, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; d];
    let mut n = 0usize;
    for x in rows {
        axpy(1.0, x, &mut out);
        n += 1;
    }
    assert!(n > 0, "mean of zero rows");
    scale(1.0 / n as f32, &mut out);
    out
}

/// Consensus error `sum_k ||x_k - x_bar||^2` over any row iterator —
/// the quantity bounded by the paper's Lemma 5 / Lemma 6, and the
/// PRIMARY consensus API (arena rows feed it directly). The iterator is
/// walked twice (mean, then deviations), hence `Clone`.
pub fn consensus_error_rows<'a, I>(rows: I, d: usize) -> f64
where
    I: IntoIterator<Item = &'a [f32]> + Clone,
{
    let xbar = mean_of_rows(rows.clone(), d);
    rows.into_iter()
        .map(|x| {
            let e = dist(x, &xbar);
            e * e
        })
        .sum()
}

/// out = mean of the rows (each `xs[k]` is a worker's x_k). Thin
/// wrapper over [`mean_of_rows`] for per-worker-Vec callers.
pub fn mean_of(xs: &[Vec<f32>]) -> Vec<f32> {
    assert!(!xs.is_empty());
    mean_of_rows(xs.iter().map(Vec::as_slice), xs[0].len())
}

/// Per-worker-Vec wrapper over [`consensus_error_rows`].
pub fn consensus_error(xs: &[Vec<f32>]) -> f64 {
    assert!(!xs.is_empty());
    consensus_error_rows(xs.iter().map(Vec::as_slice), xs[0].len())
}

/// Borrowed-view wrapper over [`consensus_error_rows`]. (The driver's
/// eval path goes further still — `Algorithm::consensus_error_about`
/// reuses the x̄ it already computed instead of re-averaging here.)
pub fn consensus_error_slices(xs: &[&[f32]]) -> f64 {
    assert!(!xs.is_empty());
    consensus_error_rows(xs.iter().copied(), xs[0].len())
}

/// Small dense row-major matrix (K x K mixing matrices, covariances).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// y = A x into a caller-provided buffer (the power-iteration path).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for (i, yi) in y.iter_mut().enumerate() {
            *yi = self.row(i).iter().zip(x).map(|(a, b)| a * b).sum();
        }
    }

    /// C = A B.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Row-stochastic check: W 1 = 1.
    pub fn rows_sum_to_one(&self, tol: f64) -> bool {
        (0..self.rows).all(|i| (self.row(i).iter().sum::<f64>() - 1.0).abs() <= tol)
    }

    /// Column-stochastic check: 1^T W = 1^T.
    pub fn cols_sum_to_one(&self, tol: f64) -> bool {
        (0..self.cols).all(|j| {
            ((0..self.rows).map(|i| self[(i, j)]).sum::<f64>() - 1.0).abs() <= tol
        })
    }

    /// Doubly-stochastic per the paper's Assumption 1 (plus symmetry and
    /// entries in [0,1]).
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        self.is_symmetric(tol)
            && self.rows_sum_to_one(tol)
            && self.cols_sum_to_one(tol)
            && self.data.iter().all(|&w| (-tol..=1.0 + tol).contains(&w))
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// |lambda_2| via power iteration on the deflated operator
/// `W - (1/K) 1 1^T`, generic over HOW `y = W x` is applied — the dense
/// [`Mat`] and the sparse `topology::MixWeights` both feed this one
/// implementation, so the K=1024 spectral gap never materializes a
/// dense K×K matrix.
pub fn second_eigenvalue_magnitude_op(
    n: usize,
    mut matvec: impl FnMut(&[f64], &mut [f64]),
    iters: usize,
    seed: u64,
) -> f64 {
    if n == 1 {
        return 0.0;
    }
    let mut rng = crate::rng::Xoshiro256::seed_from_u64(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    // Deflate the all-ones eigenvector and normalize.
    let deflate = |v: &mut [f64]| {
        let mean = v.iter().sum::<f64>() / n as f64;
        for vi in v.iter_mut() {
            *vi -= mean;
        }
        let nrm = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
        for vi in v.iter_mut() {
            *vi /= nrm;
        }
    };
    deflate(&mut v);
    let mut wv = vec![0.0f64; n];
    let mut wv2 = vec![0.0f64; n];
    let mut lambda = 0.0;
    for _ in 0..iters {
        matvec(&v, &mut wv);
        deflate(&mut wv);
        // Rayleigh quotient |v^T W v| on the deflated subspace.
        matvec(&wv, &mut wv2);
        lambda = wv.iter().zip(&wv2).map(|(a, b)| a * b).sum::<f64>().abs();
        std::mem::swap(&mut v, &mut wv);
    }
    lambda.min(1.0)
}

/// |lambda_2(W)| for a symmetric doubly-stochastic dense W (the paper's
/// Lemma 1 deflation).
pub fn second_eigenvalue_magnitude(w: &Mat, iters: usize, seed: u64) -> f64 {
    assert_eq!(w.rows, w.cols);
    second_eigenvalue_magnitude_op(w.rows, |x, y| w.matvec_into(x, y), iters, seed)
}

/// Spectral gap rho = 1 - |lambda_2(W)| (paper §3.2).
pub fn spectral_gap(w: &Mat, seed: u64) -> f64 {
    1.0 - second_eigenvalue_magnitude(w, 400, seed)
}

/// Spectral gap through the generic matvec (sparse mixing weights).
pub fn spectral_gap_op(n: usize, matvec: impl FnMut(&[f64], &mut [f64]), seed: u64) -> f64 {
    1.0 - second_eigenvalue_magnitude_op(n, matvec, 400, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn axpy_matches_scalar() {
        let x: Vec<f32> = (0..103).map(|i| i as f32 * 0.5).collect();
        let mut y: Vec<f32> = (0..103).map(|i| -(i as f32)).collect();
        let want: Vec<f32> = x.iter().zip(&y).map(|(a, b)| b + 2.5 * a).collect();
        axpy(2.5, &x, &mut y);
        assert_eq!(y, want);
    }

    #[test]
    fn axpby_momentum_form() {
        // m = mu*m + g  is  axpby(1.0, g, mu, m)
        let g = vec![1.0f32, 2.0, 3.0];
        let mut m = vec![10.0f32, 20.0, 30.0];
        axpby(1.0, &g, 0.9, &mut m);
        assert_eq!(m, vec![10.0, 20.0, 30.0].iter().map(|v| v * 0.9).zip(&g).map(|(a, b)| a + b).collect::<Vec<f32>>());
    }

    #[test]
    fn axpby_matches_scalar_across_remainder_lengths() {
        // Cover the unrolled body plus every 0..3 remainder arm.
        for n in [0usize, 1, 3, 4, 7, 8, 103] {
            let x: Vec<f32> = (0..n).map(|i| i as f32 * 0.25 - 3.0).collect();
            let y0: Vec<f32> = (0..n).map(|i| -(i as f32) * 0.5 + 1.0).collect();
            let want: Vec<f32> = x.iter().zip(&y0).map(|(xi, yi)| 1.7 * xi + -0.3 * yi).collect();
            let mut y = y0.clone();
            axpby(1.7, &x, -0.3, &mut y);
            assert_eq!(y, want, "n={n}");
        }
    }

    #[test]
    fn dot_and_norm() {
        let x = vec![3.0f32, 4.0];
        assert!((norm(&x) - 5.0).abs() < 1e-12);
        assert!((dot(&x, &x) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn mean_and_consensus() {
        let xs = vec![vec![0.0f32, 2.0], vec![2.0, 0.0]];
        assert_eq!(mean_of(&xs), vec![1.0, 1.0]);
        // each worker deviates by sqrt(2) => total 2 + 2 = 4
        assert!((consensus_error(&xs) - 4.0).abs() < 1e-6);
    }

    #[test]
    fn consensus_error_zero_at_consensus() {
        let xs = vec![vec![1.5f32; 7]; 4];
        assert!(consensus_error(&xs) < 1e-12);
    }

    #[test]
    fn mat_matvec_and_matmul() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        let b = a.matmul(&Mat::eye(2));
        assert_eq!(b, a);
        let t = a.transpose();
        assert_eq!(t[(0, 1)], 3.0);
    }

    #[test]
    fn stochastic_checks() {
        let w = Mat::from_rows(&[
            vec![0.5, 0.5, 0.0],
            vec![0.5, 0.0, 0.5],
            vec![0.0, 0.5, 0.5],
        ]);
        assert!(w.is_doubly_stochastic(1e-12));
        let bad = Mat::from_rows(&[vec![0.9, 0.0], vec![0.1, 1.0]]);
        assert!(!bad.is_doubly_stochastic(1e-12));
    }

    #[test]
    fn second_eigenvalue_of_complete_graph() {
        // W = (1/K) 1 1^T has lambda_2 = 0 => rho = 1.
        let k = 6;
        let w = Mat::from_rows(&vec![vec![1.0 / k as f64; k]; k]);
        let l2 = second_eigenvalue_magnitude(&w, 200, 1);
        assert!(l2 < 1e-8, "l2={l2}");
        assert!((spectral_gap(&w, 1) - 1.0).abs() < 1e-8);
    }

    #[test]
    fn second_eigenvalue_of_identity() {
        // W = I is disconnected: lambda_2 = 1 => rho = 0.
        let w = Mat::eye(5);
        let l2 = second_eigenvalue_magnitude(&w, 200, 2);
        assert!((l2 - 1.0).abs() < 1e-9, "l2={l2}");
    }

    #[test]
    fn second_eigenvalue_matches_known_ring() {
        // Ring with (1/3,1/3,1/3) weights: lambda_j = (1+2cos(2 pi j/K))/3.
        let k = 8usize;
        let mut w = Mat::zeros(k, k);
        for i in 0..k {
            w[(i, i)] = 1.0 / 3.0;
            w[(i, (i + 1) % k)] += 1.0 / 3.0;
            w[(i, (i + k - 1) % k)] += 1.0 / 3.0;
        }
        let expect = (0..k)
            .map(|j| ((1.0 + 2.0 * (2.0 * std::f64::consts::PI * j as f64 / k as f64).cos()) / 3.0).abs())
            .filter(|_| true)
            .fold(0.0f64, |acc, v| if (v - 1.0).abs() < 1e-12 { acc } else { acc.max(v) });
        let got = second_eigenvalue_magnitude(&w, 500, 3);
        assert!((got - expect).abs() < 1e-6, "got {got} want {expect}");
    }

    #[test]
    fn power_iteration_seed_invariance() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        // random symmetric doubly-stochastic-ish: lazy metropolis of a random graph
        let k = 10;
        let mut w = Mat::eye(k);
        for _ in 0..15 {
            let i = rng.below(k);
            let j = rng.below(k);
            if i == j {
                continue;
            }
            let eps = 0.02;
            w[(i, i)] -= eps;
            w[(j, j)] -= eps;
            w[(i, j)] += eps;
            w[(j, i)] += eps;
        }
        let a = second_eigenvalue_magnitude(&w, 2000, 1);
        let b = second_eigenvalue_magnitude(&w, 2000, 99);
        // near-degenerate spectra converge slowly; 1e-4 is ample for the
        // rho values the experiments consume.
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[cfg(test)]
mod weighted_sum_tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn prop_weighted_sum_matches_naive() {
        forall(0x5E5, 30, |rng| {
            let d = 1 + rng.below(200);
            let n_terms = rng.below(5);
            let vecs: Vec<Vec<f32>> = (0..n_terms).map(|_| rng.normal_vec(d, 1.0)).collect();
            let weights: Vec<f32> = (0..n_terms).map(|_| rng.normal_f32()).collect();
            let terms: Vec<(f32, &[f32])> =
                weights.iter().zip(&vecs).map(|(&w, v)| (w, v.as_slice())).collect();
            let naive: Vec<f32> = (0..d)
                .map(|i| terms.iter().map(|(w, v)| w * v[i]).sum())
                .collect();
            let got = weighted_sum(&terms, d);
            crate::testing::assert_allclose(&got, &naive, 1e-5, 1e-6);
            let mut into = vec![9.9f32; d];
            weighted_sum_into(&mut into, &terms);
            crate::testing::assert_allclose(&into, &naive, 1e-5, 1e-6);
        });
    }

    #[test]
    fn empty_terms_zero_out() {
        let mut dst = vec![1.0f32; 4];
        weighted_sum_into(&mut dst, &[]);
        assert_eq!(dst, vec![0.0; 4]);
        assert_eq!(weighted_sum(&[], 3), vec![0.0; 3]);
    }

    #[test]
    fn four_and_more_terms_hit_the_fallback_arm() {
        // The >= 4-term arm (first-term overwrite + axpy per rest) is what
        // dense mixing rows (complete/star topologies in gossip) execute;
        // check it against the naive formula at exactly 4 terms, beyond 4,
        // and across the axpy remainder lengths.
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(0xF4);
        for n_terms in [4usize, 5, 9] {
            for d in [1usize, 4, 7, 33] {
                let vecs: Vec<Vec<f32>> = (0..n_terms).map(|_| rng.normal_vec(d, 1.0)).collect();
                let weights: Vec<f32> = (0..n_terms).map(|_| rng.normal_f32()).collect();
                let terms: Vec<(f32, &[f32])> =
                    weights.iter().zip(&vecs).map(|(&w, v)| (w, v.as_slice())).collect();
                let naive: Vec<f32> = (0..d)
                    .map(|i| {
                        // same association order as the implementation:
                        // ((w0*v0 + w1*v1) + w2*v2) + ...
                        let mut acc = weights[0] * vecs[0][i];
                        for t in 1..n_terms {
                            acc += weights[t] * vecs[t][i];
                        }
                        acc
                    })
                    .collect();
                let mut dst = vec![5.5f32; d]; // dirty: must be overwritten
                weighted_sum_into(&mut dst, &terms);
                assert_eq!(dst, naive, "n_terms={n_terms} d={d}");
            }
        }
    }
}
