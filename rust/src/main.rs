//! `pdsgdm` — CLI launcher for the decentralized-training coordinator.
//!
//! Subcommands:
//!
//! * `train --config <file.toml> [--verbose] [--out <csv>]`
//!   run one experiment from a config file, print the summary row, dump
//!   the trace CSV and a full-state checkpoint.
//! * `train [--algo A] [--workers K] [--steps T] [--period P] ...`
//!   the same without a file, using flag overrides on the defaults.
//! * `train --resume <ckpt> --steps T` — resume a `PDSGDM02` checkpoint
//!   (written by `--ckpt`) and continue to the new total step count; the
//!   resumed trace is bit-identical to an uninterrupted run.
//! * `train --target-loss F | --comm-budget-mb F | --sim-seconds F` —
//!   budget-based stop conditions instead of (or combined with) a fixed
//!   step count.
//! * `topology --kind ring --workers 8` — print W and its spectral gap.
//! * `inspect --artifacts DIR --model NAME` — validate artifacts and show
//!   the model manifest (d, layout, mix Ks).
//! * `algorithms` — list implemented algorithms with summaries.
//! * `serve [--config FILE] [JOB.toml ...]` — run the training service
//!   daemon: a job queue, N concurrent sessions on one shared worker
//!   pool, `/metrics` + `/jobs` over HTTP, graceful drain on SIGTERM.
//! * `submit --spool DIR JOB.toml ...` — drop job files into a running
//!   daemon's spool directory.
//!
//! (Arg parsing is in-crate: no clap in this offline build environment.)

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};
use pdsgdm::config::ExperimentConfig;
use pdsgdm::coordinator::{Session, SessionSpec, VerboseObserver};
use pdsgdm::metrics;
use pdsgdm::topology::{mixing_matrix, MixWeights, Topology, Weighting};

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        print_help();
        return Ok(());
    };
    let flags = Flags::parse(rest)?;
    match cmd.as_str() {
        "train" => cmd_train(flags),
        "worker" => cmd_worker(flags),
        "topology" => cmd_topology(flags),
        "inspect" => cmd_inspect(flags),
        "serve" => cmd_serve(flags),
        "submit" => cmd_submit(flags),
        "algorithms" => {
            for b in pdsgdm::algorithms::REGISTRY {
                println!("{:<12} {}", b.name, b.summary);
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other}; try `pdsgdm help`"),
    }
}

fn print_help() {
    println!(
        "pdsgdm — Periodic Decentralized Momentum SGD (Gao & Huang 2020)\n\
         \n\
         USAGE:\n\
           pdsgdm train   [--config FILE] [--algo NAME] [--workers K] [--steps T]\n\
                          [--eval-every N] [--period P] [--eta F] [--mu F] [--gamma F]\n\
                          [--topology T] [--compressor SPEC] [--workload W] [--seed N]\n\
                          [--target-loss F] [--comm-budget-mb F] [--sim-seconds F]\n\
                          [--wall-clock-seconds F] [--threads N]\n\
                          [--dirichlet-alpha F] [--drop-prob F] [--delay-prob F]\n\
                          [--max-delay N] [--reorder-prob F] [--straggler SPEC]\n\
                          [--churn W@LEAVE:REJOIN,..] [--fault-seed N]\n\
                          [--fault-compressed]\n\
                          [--transport none|tcp|unix] [--transport-kill W@STEP]\n\
                          [--resume CKPT] [--out CSV] [--ckpt FILE] [--verbose]\n\
           pdsgdm topology --kind ring|chain|complete|star|torus|hypercube|expgraph\n\
                          |random-regular:D  [--workers K] [--seed N]\n\
                          [--weighting uniform|metropolis|lazy-metropolis]\n\
           pdsgdm inspect  [--artifacts DIR] [--model NAME]\n\
           pdsgdm algorithms\n\
           pdsgdm serve    [--config FILE] [--listen HOST:PORT] [--threads N]\n\
                          [--max-concurrent N] [--state-dir DIR] [--spool DIR]\n\
                          [--poll-ms MS] [--exit-when-idle] [JOB.toml ...]\n\
           pdsgdm submit   --spool DIR [--name NAME] [--priority P] JOB.toml ...\n\
         \n\
         Topologies: ring | chain | complete | star | torus | hypercube | expgraph\n\
         | random-regular:D — expgraph (hops i±2^s) and random-regular scale to\n\
         K=1024 fleets with O(K log K) edges; infeasible (topology, K) pairs are\n\
         rejected with the reason (torus factorization, 2^n, handshake lemma).\n\
         Workloads: quadratic | logistic | mlp | transformer (needs `make artifacts`).\n\
         Compressors: sign | topR | randR | qsgdL | identity (R ratio, L levels).\n\
         Faults: --straggler constant:F | uniform:LO,HI | lognormal:MU,SIGMA;\n\
         --churn 1@60:120 (worker 1 leaves at step 60, rejoins at 120);\n\
         --dirichlet-alpha sets non-IID label skew (small alpha = more skew);\n\
         --fault-compressed extends drop/delay/reorder to the compressed gossip\n\
         of cpd-sgdm | choco-sgd | deepsqueeze (needs an active fault plan).\n\
         Checkpoints: --ckpt writes a full-state PDSGDM02 file; --resume continues\n\
         it bit-identically (give the same config plus the new --steps total).\n\
         Transport: --transport tcp|unix (or a [transport] config section) runs\n\
         K real OS worker processes over loopback sockets — bit-identical trace\n\
         to the in-memory run on the same seed, measured wall-clock, retries/\n\
         heartbeats/peer-loss degradation built in; --transport none strips the\n\
         section; --transport-kill 3@40 kills worker 3 at step 40 (fault drill).\n\
         Serve: jobs are experiment TOMLs (+ optional [job] name/priority); the\n\
         daemon multiplexes --max-concurrent sessions onto one --threads pool,\n\
         exports Prometheus text at /metrics and JSON at /jobs, and on SIGTERM\n\
         drains running jobs to PDSGDM02 checkpoints — restarting with the same\n\
         --state-dir resumes them bit-identically (see DESIGN.md section 9)."
    );
}

/// `--key value` / `--flag` parser. Bare arguments are collected as
/// positionals (job files for `serve`/`submit`); commands that take
/// none call [`Flags::no_positionals`] to keep the legacy error.
struct Flags {
    map: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut positionals = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(key) = a.strip_prefix("--") else {
                positionals.push(a.clone());
                i += 1;
                continue;
            };
            let boolean = ["verbose", "fault-compressed", "exit-when-idle"].contains(&key);
            if boolean {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--{key} needs a value"))?;
                map.insert(key.to_string(), val.clone());
                i += 2;
            }
        }
        Ok(Self { map, positionals })
    }

    fn no_positionals(&self) -> Result<()> {
        match self.positionals.first() {
            Some(a) => bail!("expected --flag, got {a}"),
            None => Ok(()),
        }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow!("--{key}: cannot parse {v}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }
}

fn cmd_train(flags: Flags) -> Result<()> {
    flags.no_positionals()?;
    let mut cfg = match flags.get("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path)).map_err(|e| anyhow!(e))?,
        None => ExperimentConfig::default(),
    };
    // Flag overrides.
    if let Some(a) = flags.get("algo") {
        if !pdsgdm::algorithms::ALL_NAMES.contains(&a) {
            bail!("unknown algorithm {a}; see `pdsgdm algorithms`");
        }
        cfg.algorithm = a.to_string();
    }
    if let Some(k) = flags.get_parse("workers")? {
        cfg.workers = k;
    }
    if let Some(t) = flags.get_parse("steps")? {
        cfg.steps = t;
    }
    if let Some(e) = flags.get_parse("eval-every")? {
        cfg.eval_every = e;
    }
    if let Some(p) = flags.get_parse("period")? {
        cfg.hyper.period = p;
    }
    if let Some(e) = flags.get_parse::<f32>("eta")? {
        cfg.hyper.lr = pdsgdm::optim::LrSchedule::Constant { eta: e };
    }
    if let Some(m) = flags.get_parse("mu")? {
        cfg.hyper.mu = m;
    }
    if let Some(g) = flags.get_parse("gamma")? {
        cfg.hyper.gamma = g;
    }
    if let Some(s) = flags.get_parse("seed")? {
        cfg.seed = s;
    }
    if let Some(t) = flags.get("topology") {
        cfg.topology = Topology::parse(t).ok_or_else(|| anyhow!("unknown topology {t}"))?;
    }
    if let Some(c) = flags.get("compressor") {
        if pdsgdm::compress::parse(c).is_none() {
            bail!("unknown compressor {c}");
        }
        cfg.compressor = Some(c.to_string());
    }
    if let Some(w) = flags.get("workload") {
        cfg.workload = match w {
            "quadratic" => pdsgdm::config::WorkloadConfig::Quadratic {
                dim: 64,
                heterogeneity: 1.0,
                noise: 0.1,
            },
            "logistic" => pdsgdm::config::WorkloadConfig::Logistic {
                n: 4000,
                dim: 32,
                classes: 10,
                batch: 16,
                l2: 1e-4,
            },
            "mlp" => pdsgdm::config::WorkloadConfig::Mlp {
                n: 4000,
                dim: 32,
                classes: 10,
                hidden: 64,
                batch: 16,
            },
            "transformer" => pdsgdm::config::WorkloadConfig::Transformer {
                model: flags.get("model").unwrap_or("tiny").to_string(),
                artifacts_dir: flags.get("artifacts").unwrap_or("artifacts").to_string(),
            },
            other => bail!("unknown workload {other}"),
        };
    }
    if let Some(l) = flags.get_parse::<f64>("target-loss")? {
        cfg.stop.target_loss = Some(l);
    }
    if let Some(mb) = flags.get_parse::<f64>("comm-budget-mb")? {
        cfg.stop.comm_budget_mb = Some(mb);
    }
    if let Some(s) = flags.get_parse::<f64>("sim-seconds")? {
        cfg.stop.sim_seconds_budget = Some(s);
    }
    if let Some(s) = flags.get_parse::<f64>("wall-clock-seconds")? {
        cfg.stop.wall_clock_seconds = Some(s);
    }
    // Fault-injection & heterogeneity overrides (see configs/faults.toml).
    if let Some(a) = flags.get_parse::<f64>("dirichlet-alpha")? {
        cfg.sharding = pdsgdm::data::Sharding::Dirichlet { alpha: a };
    }
    if let Some(p) = flags.get_parse::<f64>("drop-prob")? {
        cfg.faults.drop_prob = p;
    }
    if let Some(p) = flags.get_parse::<f64>("delay-prob")? {
        cfg.faults.delay_prob = p;
    }
    if let Some(n) = flags.get_parse::<u64>("max-delay")? {
        cfg.faults.max_delay = n;
    }
    if let Some(p) = flags.get_parse::<f64>("reorder-prob")? {
        cfg.faults.reorder_prob = p;
    }
    if let Some(s) = flags.get("straggler") {
        cfg.faults.straggler =
            Some(pdsgdm::comm::StragglerDist::parse(s).map_err(|e| anyhow!(e))?);
    }
    if let Some(c) = flags.get("churn") {
        cfg.faults.churn =
            pdsgdm::config::ChurnEvent::parse_list(c).map_err(|e| anyhow!(e))?;
    }
    if let Some(s) = flags.get_parse::<u64>("fault-seed")? {
        cfg.faults.seed = s;
    }
    if flags.has("fault-compressed") {
        cfg.faults.compressed = true;
    }
    // Real-socket transport overrides (`[transport]` in the config, or
    // `--transport tcp|unix` from a plain config; `none` strips the
    // section so the same file can drive both legs of a bit-identity
    // comparison).
    match flags.get("transport") {
        Some("none") => cfg.transport = None,
        Some(backend @ ("tcp" | "unix")) => {
            let mut t = cfg.transport.take().unwrap_or_default();
            t.backend = match backend {
                "tcp" => pdsgdm::config::TransportBackend::Tcp,
                _ => pdsgdm::config::TransportBackend::Unix,
            };
            cfg.transport = Some(t);
        }
        Some(other) => bail!("--transport must be none|tcp|unix, got {other}"),
        None => {}
    }
    if let Some(spec) = flags.get("transport-kill") {
        let kill = pdsgdm::config::parse_kill_spec(spec).map_err(|e| anyhow!(e))?;
        let t = cfg
            .transport
            .as_mut()
            .ok_or_else(|| anyhow!("--transport-kill needs socket mode (--transport tcp|unix)"))?;
        t.kill_worker = Some(kill);
    }
    cfg.validate().map_err(|e| anyhow!(e))?;

    if cfg.transport.is_some() {
        return cmd_train_transport(cfg, &flags);
    }

    eprintln!(
        "building: {} | K={} {:?} | p={} mu={} | workload={:?}",
        cfg.algorithm, cfg.workers, cfg.topology, cfg.hyper.period, cfg.hyper.mu, cfg.workload
    );
    let mut spec = SessionSpec::new(cfg);
    if let Some(ckpt) = flags.get("resume") {
        spec = spec.resume_from(ckpt);
    }
    let mut session = Session::build(spec)?;
    if let Some(n) = flags.get_parse::<usize>("threads")? {
        if n == 0 {
            bail!("--threads must be >= 1");
        }
        session.install_shared_pool(std::sync::Arc::new(pdsgdm::engine::WorkerPool::new(n)));
    }
    eprintln!("spectral gap rho = {:.4}", session.rho);
    if session.steps_done() > 0 {
        eprintln!(
            "resumed at step {} ({:.2} MB communicated so far)",
            session.steps_done(),
            session.comm_bytes() as f64 / (1024.0 * 1024.0)
        );
    }
    if flags.has("verbose") {
        session.observe(Box::new(VerboseObserver::default()));
    }
    session.run_to_stop();
    print!("{}", metrics::summary_table(std::slice::from_ref(session.trace())));
    if let Some(c) = session.fault_counters() {
        eprintln!(
            "faults: dropped {} messages ({} encoded), delayed {} ({} encoded)",
            c.dropped, c.dropped_encoded, c.delayed_total, c.delayed_encoded
        );
    }

    if let Some(out) = flags.get("out") {
        metrics::write_csv(Path::new(out), std::slice::from_ref(session.trace()))?;
        eprintln!("trace -> {out}");
    }
    if let Some(ckpt) = flags.get("ckpt") {
        session.save(Path::new(ckpt))?;
        eprintln!("checkpoint (PDSGDM02 full state) -> {ckpt}");
    }
    Ok(())
}

/// Socket-mode `train`: spawn K `pdsgdm worker` OS processes and drive
/// the run over real loopback TCP / Unix sockets. Bit-identical to the
/// in-memory run on the same seed; wall-clock is *measured*, not the
/// α–β simulation.
fn cmd_train_transport(cfg: ExperimentConfig, flags: &Flags) -> Result<()> {
    for unsupported in ["resume", "ckpt", "threads"] {
        if flags.has(unsupported) {
            bail!("--{unsupported} is not supported in socket-transport mode (--transport none to disable)");
        }
    }
    let t = cfg.transport.as_ref().expect("caller checked");
    eprintln!(
        "transport: {} | K={} {:?} OS processes | p={} | workload={:?}",
        match t.backend {
            pdsgdm::config::TransportBackend::Tcp => "loopback tcp",
            pdsgdm::config::TransportBackend::Unix => "unix sockets",
        },
        cfg.workers,
        cfg.topology,
        cfg.hyper.period,
        cfg.workload
    );
    let exe = std::env::current_exe()?;
    let outcome = pdsgdm::comm::transport::run_coordinator(&cfg, &exe, flags.has("verbose"))
        .map_err(|e| anyhow!(e))?;
    eprintln!("spectral gap rho = {:.4}", outcome.rho);
    print!("{}", metrics::summary_table(std::slice::from_ref(&outcome.trace)));
    eprintln!("measured wall-clock: {:.3}s", outcome.wall_seconds);
    if outcome.peers_lost > 0 {
        eprintln!(
            "degraded: lost {} worker process(es) mid-run; mixing renormalized over survivors",
            outcome.peers_lost
        );
    }
    let wire: Vec<String> = outcome
        .counters
        .named()
        .iter()
        .filter(|(_, v)| *v > 0)
        .map(|(n, v)| format!("{n}={v}"))
        .collect();
    eprintln!("wire: {}", if wire.is_empty() { "quiet".into() } else { wire.join(" ") });
    if let Some(out) = flags.get("out") {
        metrics::write_csv(Path::new(out), std::slice::from_ref(&outcome.trace))?;
        eprintln!("trace -> {out}");
    }
    Ok(())
}

/// One worker OS process (spawned by the socket-mode coordinator — not
/// intended for interactive use). Replays its worker's exact slice of
/// the simulated schedule against the real socket fabric.
fn cmd_worker(flags: Flags) -> Result<()> {
    flags.no_positionals()?;
    let cfg_path = flags
        .get("config")
        .ok_or_else(|| anyhow!("worker: --config FILE required"))?;
    let me: usize = flags
        .get_parse("worker")?
        .ok_or_else(|| anyhow!("worker: --worker INDEX required"))?;
    let coordinator = flags
        .get("coordinator")
        .ok_or_else(|| anyhow!("worker: --coordinator ADDR required"))?;
    let cfg = ExperimentConfig::from_file(Path::new(cfg_path)).map_err(|e| anyhow!(e))?;
    pdsgdm::comm::transport::run_worker(&cfg, me, coordinator)
        .map_err(|e| anyhow!("worker {me}: {e}"))
}

fn cmd_serve(flags: Flags) -> Result<()> {
    let mut serve = match flags.get("config") {
        Some(p) => pdsgdm::config::ServeConfig::from_file(Path::new(p)).map_err(|e| anyhow!(e))?,
        None => pdsgdm::config::ServeConfig::default(),
    };
    if let Some(l) = flags.get("listen") {
        serve.listen = l.to_string();
    }
    if let Some(n) = flags.get_parse("max-concurrent")? {
        serve.max_concurrent = n;
    }
    if let Some(n) = flags.get_parse("threads")? {
        serve.pool_threads = Some(n);
    }
    if let Some(d) = flags.get("state-dir") {
        serve.state_dir = d.to_string();
    }
    if let Some(d) = flags.get("spool") {
        serve.spool_dir = Some(d.to_string());
    }
    if let Some(ms) = flags.get_parse("poll-ms")? {
        serve.poll_ms = ms;
    }
    if flags.has("exit-when-idle") {
        serve.exit_when_idle = true;
    }
    serve.validate().map_err(|e| anyhow!(e))?;
    let daemon = pdsgdm::service::Daemon::new(serve).map_err(|e| anyhow!(e))?;
    for job in &flags.positionals {
        let id = daemon.submit_file(Path::new(job)).map_err(|e| anyhow!(e))?;
        eprintln!("[serve] queued {job} as job {id}");
    }
    daemon.run().map_err(|e| anyhow!(e))
}

fn cmd_submit(flags: Flags) -> Result<()> {
    let spool = flags
        .get("spool")
        .ok_or_else(|| anyhow!("--spool DIR required (the daemon's serve.spool_dir)"))?;
    if flags.positionals.is_empty() {
        bail!("submit needs at least one JOB.toml");
    }
    std::fs::create_dir_all(spool)?;
    let name = flags.get("name");
    let priority = flags.get_parse::<i64>("priority")?;
    if name.is_some() && flags.positionals.len() > 1 {
        bail!("--name applies to a single job; submit the files one at a time");
    }
    for job in &flags.positionals {
        let mut src =
            std::fs::read_to_string(job).map_err(|e| anyhow!("{job}: {e}"))?;
        if name.is_some() || priority.is_some() {
            if src.contains("[job]") {
                bail!(
                    "{job} already has a [job] section; edit the file instead of \
                     passing --name/--priority"
                );
            }
            src.push_str("\n[job]\n");
            if let Some(n) = name {
                src.push_str(&format!("name = \"{n}\"\n"));
            }
            if let Some(p) = priority {
                src.push_str(&format!("priority = {p}\n"));
            }
        }
        // Validate before spooling so a typo is rejected here, with the
        // file name, instead of asynchronously by the daemon.
        pdsgdm::service::queue::parse_job_toml(&src).map_err(|e| anyhow!("{job}: {e}"))?;
        // Collision-proof sortable spool name (epoch + pid + sequence):
        // see `queue::spool_job` — two submissions in the same epoch
        // second used to overwrite each other.
        let dest = pdsgdm::service::queue::spool_job(Path::new(spool), &src)?;
        eprintln!("submitted {job} -> {}", dest.display());
    }
    Ok(())
}

fn cmd_topology(flags: Flags) -> Result<()> {
    flags.no_positionals()?;
    let kind = flags.get("kind").unwrap_or("ring");
    let k: usize = flags.get_parse("workers")?.unwrap_or(8);
    let topo = Topology::parse(kind).ok_or_else(|| anyhow!("unknown topology {kind}"))?;
    // Surface infeasible (topology, K) combos as CLI errors instead of
    // letting `build` panic (e.g. torus with prime K).
    topo.validate(k).map_err(|e| anyhow!(e))?;
    let weighting = match flags.get("weighting").unwrap_or("uniform") {
        "uniform" => Weighting::UniformDegree,
        "metropolis" => Weighting::Metropolis,
        "lazy-metropolis" => Weighting::LazyMetropolis,
        other => bail!("unknown weighting {other}"),
    };
    let g = topo.build(k, flags.get_parse("seed")?.unwrap_or(0));
    // Sparse weights even for display: rho via the CSR operator, so
    // `topology --workers 1024` never builds a K×K matrix.
    let mw = MixWeights::from_graph(&g, weighting);
    let rho = mw.spectral_gap(1);
    println!("topology: {kind}  K={k}  edges={}  rho={rho:.6}", g.edge_count());
    println!("Theorem 1 consensus amplification (1 + 4/rho^2) = {:.2}", 1.0 + 4.0 / (rho * rho));
    if k <= 32 {
        let w = mixing_matrix(&g, weighting);
        println!("W =");
        for i in 0..k {
            let row: Vec<String> = (0..k).map(|j| format!("{:.3}", w[(i, j)])).collect();
            println!("  [{}]", row.join(" "));
        }
    } else {
        println!(
            "(K > 32: dense W print suppressed; avg degree {:.1})",
            2.0 * g.edge_count() as f64 / k as f64
        );
    }
    Ok(())
}

fn cmd_inspect(flags: Flags) -> Result<()> {
    flags.no_positionals()?;
    let dir = PathBuf::from(flags.get("artifacts").unwrap_or("artifacts"));
    let model = flags.get("model").unwrap_or("tiny");
    let rt = pdsgdm::runtime::Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let m = rt.manifest(model)?;
    println!(
        "model {}: d={} vocab={} seq={} batch={} layers={} mix_ks={:?}",
        m.name, m.d, m.vocab, m.seq_len, m.batch, m.n_layers, m.mix_ks
    );
    println!("layout ({} tensors):", m.layout.len());
    for e in &m.layout {
        println!("  {:<18} offset {:>9}  shape {:?}", e.name, e.offset, e.shape);
    }
    // compile-check all three artifact kinds (pjrt builds only — the
    // stub runtime can read metadata but cannot compile HLO)
    if pdsgdm::runtime::HAS_PJRT {
        let _ = rt.train_step(model)?;
        println!("train_step_{model}.hlo.txt: compiles OK");
        let _ = rt.momentum_step(model)?;
        println!("momentum_{model}.hlo.txt: compiles OK");
        for k in &m.mix_ks {
            let _ = rt.mix_step(model, *k)?;
            println!("mix_k{k}_{model}.hlo.txt: compiles OK");
        }
    } else {
        println!("(compile checks skipped: built without the `pjrt` feature)");
    }
    Ok(())
}
