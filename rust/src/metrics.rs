//! Experiment observability: training traces, CSV/JSONL sinks.
//!
//! Every figure bench and example records a [`Trace`] — the series of
//! (iteration, loss, accuracy, comm-MB, consensus error, simulated
//! seconds) points that map one-to-one onto the paper's plot axes —
//! and dumps it as CSV (for plotting) and/or JSONL (for tooling).

use std::io::Write;
use std::path::Path;

use crate::json::{obj, Json};

/// One evaluation point along a training run.
#[derive(Clone, Copy, Debug, Default)]
pub struct TracePoint {
    /// Global iteration t.
    pub step: u64,
    /// Full-data global loss f(x̄_t).
    pub loss: f64,
    /// Held-out accuracy (0 for regression problems).
    pub accuracy: f64,
    /// Cumulative communication, MiB (Figure 2 x-axis).
    pub comm_mb: f64,
    /// Σ_k ||x_k − x̄||² (Lemma 5/6 diagnostics).
    pub consensus: f64,
    /// ||∇f(x̄)||² (the theorems' left-hand side).
    pub grad_norm_sq: f64,
    /// Simulated wall-clock under the α–β cost model.
    pub sim_seconds: f64,
}

/// A labeled training run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub label: String,
    pub points: Vec<TracePoint>,
}

impl Trace {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    pub fn final_loss(&self) -> f64 {
        self.points.last().map(|p| p.loss).unwrap_or(f64::NAN)
    }

    pub fn final_accuracy(&self) -> f64 {
        self.points.last().map(|p| p.accuracy).unwrap_or(f64::NAN)
    }

    pub fn total_comm_mb(&self) -> f64 {
        self.points.last().map(|p| p.comm_mb).unwrap_or(0.0)
    }

    /// Final α–β simulated wall-clock (the Figure 2 time axis).
    pub fn final_sim_seconds(&self) -> f64 {
        self.points.last().map(|p| p.sim_seconds).unwrap_or(0.0)
    }

    /// First step at which loss drops below `target` (linear-speedup
    /// ablation metric); None if never reached.
    pub fn steps_to_loss(&self, target: f64) -> Option<u64> {
        self.points.iter().find(|p| p.loss <= target).map(|p| p.step)
    }

    /// Best (minimum) loss along the run — robust to end-of-run noise.
    pub fn best_loss(&self) -> f64 {
        self.points.iter().map(|p| p.loss).fold(f64::INFINITY, f64::min)
    }

    /// Checkpoint this trace (label + every point, floats bit-exact) —
    /// a resumed session continues the *same* trace, so the final CSV of
    /// an interrupted-and-resumed run is byte-identical to an
    /// uninterrupted one.
    pub fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("trace");
        w.put_str(&self.label);
        w.put_u64(self.points.len() as u64);
        for p in &self.points {
            w.put_u64(p.step);
            w.put_f64(p.loss);
            w.put_f64(p.accuracy);
            w.put_f64(p.comm_mb);
            w.put_f64(p.consensus);
            w.put_f64(p.grad_norm_sq);
            w.put_f64(p.sim_seconds);
        }
    }

    pub fn state_load(r: &mut crate::state::StateReader) -> Result<Self, String> {
        r.expect_tag("trace")?;
        let label = r.take_str()?.to_string();
        let n = r.take_u64()? as usize;
        let mut points = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            points.push(TracePoint {
                step: r.take_u64()?,
                loss: r.take_f64()?,
                accuracy: r.take_f64()?,
                comm_mb: r.take_f64()?,
                consensus: r.take_f64()?,
                grad_norm_sq: r.take_f64()?,
                sim_seconds: r.take_f64()?,
            });
        }
        Ok(Self { label, points })
    }

    pub fn csv_header() -> &'static str {
        "label,step,loss,accuracy,comm_mb,consensus,grad_norm_sq,sim_seconds"
    }

    pub fn to_csv_rows(&self) -> String {
        let mut s = String::new();
        for p in &self.points {
            s.push_str(&format!(
                "{},{},{:.6e},{:.4},{:.4},{:.6e},{:.6e},{:.3}\n",
                self.label, p.step, p.loss, p.accuracy, p.comm_mb, p.consensus,
                p.grad_norm_sq, p.sim_seconds
            ));
        }
        s
    }

    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for p in &self.points {
            let rec = obj(vec![
                ("label", Json::Str(self.label.clone())),
                ("step", Json::Num(p.step as f64)),
                ("loss", Json::Num(p.loss)),
                ("accuracy", Json::Num(p.accuracy)),
                ("comm_mb", Json::Num(p.comm_mb)),
                ("consensus", Json::Num(p.consensus)),
                ("grad_norm_sq", Json::Num(p.grad_norm_sq)),
                ("sim_seconds", Json::Num(p.sim_seconds)),
            ]);
            s.push_str(&rec.to_string_compact());
            s.push('\n');
        }
        s
    }
}

/// Write a set of traces as one CSV file (header + all rows).
pub fn write_csv(path: &Path, traces: &[Trace]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", Trace::csv_header())?;
    for t in traces {
        f.write_all(t.to_csv_rows().as_bytes())?;
    }
    Ok(())
}

/// Console table: one row per trace with the headline numbers — this is
/// the "same rows the paper reports" output of each figure bench.
pub fn summary_table(traces: &[Trace]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<34} {:>12} {:>10} {:>12} {:>14} {:>10}\n",
        "run", "final_loss", "final_acc", "comm_MB", "consensus", "sim_s"
    ));
    for t in traces {
        let last = t.points.last().copied().unwrap_or_default();
        s.push_str(&format!(
            "{:<34} {:>12.4} {:>10.4} {:>12.2} {:>14.4e} {:>10.2}\n",
            t.label, last.loss, last.accuracy, last.comm_mb, last.consensus, last.sim_seconds
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::new("pd-sgdm(p=4)");
        for i in 0..5 {
            t.push(TracePoint {
                step: i * 10,
                loss: 2.0 / (i + 1) as f64,
                accuracy: 0.2 * i as f64,
                comm_mb: i as f64,
                consensus: 1e-3,
                grad_norm_sq: 0.5,
                sim_seconds: i as f64 * 0.1,
            });
        }
        t
    }

    #[test]
    fn accessors() {
        let t = sample();
        assert_eq!(t.final_loss(), 0.4);
        assert_eq!(t.final_accuracy(), 0.8);
        assert_eq!(t.total_comm_mb(), 4.0);
        assert!((t.final_sim_seconds() - 0.4).abs() < 1e-12);
        assert_eq!(t.best_loss(), 0.4);
        assert_eq!(t.steps_to_loss(1.0), Some(10));
        assert_eq!(t.steps_to_loss(0.01), None);
    }

    #[test]
    fn csv_roundtrip_field_count() {
        let t = sample();
        let rows = t.to_csv_rows();
        for line in rows.lines() {
            assert_eq!(line.split(',').count(), Trace::csv_header().split(',').count());
        }
    }

    #[test]
    fn jsonl_parses_back() {
        let t = sample();
        for line in t.to_jsonl().lines() {
            let v = crate::json::Json::parse(line).unwrap();
            assert_eq!(v.get("label").unwrap().as_str(), Some("pd-sgdm(p=4)"));
            assert!(v.get("loss").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn write_csv_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("pdsgdm_test_{}", std::process::id()));
        let path = dir.join("deep/nested/out.csv");
        write_csv(&path, &[sample()]).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.starts_with("label,step"));
        assert_eq!(content.lines().count(), 6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn summary_has_one_row_per_trace() {
        let s = summary_table(&[sample(), sample()]);
        assert_eq!(s.lines().count(), 3);
        assert!(s.contains("pd-sgdm(p=4)"));
    }

    #[test]
    fn trace_state_roundtrip_is_bit_exact() {
        let t = sample();
        let mut w = crate::state::StateWriter::new();
        t.state_save(&mut w);
        let bytes = w.into_bytes();
        let got = Trace::state_load(&mut crate::state::StateReader::new(&bytes)).unwrap();
        assert_eq!(got.label, t.label);
        assert_eq!(got.points.len(), t.points.len());
        for (a, b) in t.points.iter().zip(&got.points) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.sim_seconds.to_bits(), b.sim_seconds.to_bits());
        }
        // truncation is an error, not a panic
        assert!(Trace::state_load(&mut crate::state::StateReader::new(&bytes[..9])).is_err());
    }

    #[test]
    fn empty_trace_is_safe() {
        let t = Trace::new("empty");
        assert!(t.final_loss().is_nan());
        assert_eq!(t.total_comm_mb(), 0.0);
        assert_eq!(summary_table(&[t]).lines().count(), 2);
    }
}
