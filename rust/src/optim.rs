//! Local-optimizer substrate: momentum buffers, LR schedules, weight decay.
//!
//! The inner loop of both paper algorithms (Alg. 1/2 lines 2–4) is the
//! heavy-ball update Eq. (8):
//!
//! ```text
//! m_t       = mu * m_{t-1} + g_t
//! x_{t+1/2} = x_t - eta_t * m_t
//! ```
//!
//! [`MomentumState::step`] is the fused in-process version of the L1
//! Pallas kernel (`python/compile/kernels/momentum.py`); the XLA path in
//! `runtime::MomentumStep` executes the compiled artifact instead. Both
//! compute identical math — cross-checked by rust/tests/runtime_integration.rs.

use crate::linalg;

/// Per-worker momentum buffer + hyper-parameters.
#[derive(Clone, Debug)]
pub struct MomentumState {
    pub mu: f32,
    pub weight_decay: f32,
    pub m: Vec<f32>,
}

impl MomentumState {
    pub fn new(d: usize, mu: f32, weight_decay: f32) -> Self {
        assert!((0.0..1.0).contains(&mu), "paper requires 0 <= mu < 1");
        assert!(weight_decay >= 0.0);
        Self { mu, weight_decay, m: vec![0.0; d] }
    }

    /// Fused Eq. (8) update of `x` in place given gradient `g`.
    /// Weight decay enters the gradient (g + wd * x), matching the
    /// PyTorch SGD the paper's experiments used.
    pub fn step(&mut self, x: &mut [f32], g: &[f32], eta: f32) {
        momentum_step(&mut self.m, x, g, self.mu, self.weight_decay, eta);
    }

    /// ||m||^2 — Lemma 3 bounds this by G^2/(1-mu)^2.
    pub fn momentum_norm_sq(&self) -> f64 {
        linalg::dot(&self.m, &self.m)
    }

    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Checkpoint the momentum buffer (mu/weight_decay are config, not
    /// state — they come back from the rebuilt Hyper).
    pub fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.put_f32s(&self.m);
    }

    pub fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.take_f32s_into(&mut self.m, "momentum")
    }
}

/// The fused Eq. (8) kernel shared by [`MomentumState::step`] and
/// [`MomentumBank::step_row`] — ONE loop so the flat-arena bank is
/// bit-identical to the per-worker state it replaced.
#[inline]
pub fn momentum_step(m: &mut [f32], x: &mut [f32], g: &[f32], mu: f32, wd: f32, eta: f32) {
    debug_assert_eq!(x.len(), m.len());
    debug_assert_eq!(g.len(), m.len());
    for ((xi, mi), gi) in x.iter_mut().zip(m.iter_mut()).zip(g) {
        let grad = gi + wd * *xi;
        let m_new = mu * *mi + grad;
        *mi = m_new;
        *xi -= eta * m_new;
    }
}

/// All K workers' momentum buffers in ONE flat K×d arena
/// (ROADMAP item 1 / DESIGN.md §8): the heavy-ball state analogue of
/// [`crate::arena::ParamArena`], sharing its contiguous layout,
/// checkpoint section format, and v2 per-worker loading shim.
#[derive(Clone, Debug)]
pub struct MomentumBank {
    mu: f32,
    weight_decay: f32,
    bank: crate::arena::ParamArena,
}

impl MomentumBank {
    pub fn new(k: usize, d: usize, mu: f32, weight_decay: f32) -> Self {
        assert!((0.0..1.0).contains(&mu), "paper requires 0 <= mu < 1");
        assert!(weight_decay >= 0.0);
        Self { mu, weight_decay, bank: crate::arena::ParamArena::zeros(k, d) }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.bank.k()
    }

    #[inline]
    pub fn mu(&self) -> f32 {
        self.mu
    }

    #[inline]
    pub fn weight_decay(&self) -> f32 {
        self.weight_decay
    }

    /// Worker i's fused Eq. (8) update (same kernel as
    /// [`MomentumState::step`]).
    pub fn step_row(&mut self, i: usize, x: &mut [f32], g: &[f32], eta: f32) {
        let (mu, wd) = (self.mu, self.weight_decay);
        momentum_step(self.bank.row_mut(i), x, g, mu, wd, eta);
    }

    /// Per-worker momentum rows in worker order — what the engine fans
    /// across the pool alongside the iterate rows.
    pub fn rows_mut(&mut self) -> std::slice::ChunksExactMut<'_, f32> {
        self.bank.rows_mut()
    }

    /// The underlying arena (gossiped directly by d-sgdm-pm).
    pub fn arena_mut(&mut self) -> &mut crate::arena::ParamArena {
        &mut self.bank
    }

    pub fn row(&self, i: usize) -> &[f32] {
        self.bank.row(i)
    }

    /// ||m_i||^2 — Lemma 3 bounds this by G^2/(1-mu)^2.
    pub fn momentum_norm_sq(&self, i: usize) -> f64 {
        linalg::dot(self.bank.row(i), self.bank.row(i))
    }

    /// Zero worker i's buffer (churn rejoin hook).
    pub fn reset_row(&mut self, i: usize) {
        self.bank.row_mut(i).iter_mut().for_each(|v| *v = 0.0);
    }

    /// One contiguous checkpoint section; loads the v2 per-worker
    /// momentum layout (u64 K then K length-prefixed rows) via the
    /// state.rs shim.
    pub fn state_save(&self, w: &mut crate::state::StateWriter) {
        self.bank.state_save(w);
    }

    pub fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        self.bank.state_load(r, "momentum-bank")
    }
}

/// Learning-rate schedules. The paper uses step decay (x0.1 at epoch
/// 150/225 of 300 for CIFAR-10); `Corollary1` implements the theoretical
/// eta = eta0 * sqrt(K/T) constant rate used in the speedup ablation.
#[derive(Clone, Debug)]
pub enum LrSchedule {
    Constant { eta: f32 },
    /// eta0 decayed by `factor` at each fraction of total_steps in
    /// `milestones` (paper: factor=0.1, milestones=[0.5, 0.75]).
    StepDecay { eta0: f32, factor: f32, milestones: Vec<f64>, total_steps: u64 },
    /// eta = eta0 * sqrt(K / T): the Corollary 1/2 rate.
    Corollary1 { eta0: f32, k: usize, total_steps: u64 },
    /// Linear warmup into a constant rate.
    Warmup { eta: f32, warmup_steps: u64 },
}

impl LrSchedule {
    pub fn eta(&self, t: u64) -> f32 {
        match self {
            LrSchedule::Constant { eta } => *eta,
            LrSchedule::StepDecay { eta0, factor, milestones, total_steps } => {
                let frac = t as f64 / (*total_steps).max(1) as f64;
                let decays = milestones.iter().filter(|&&m| frac >= m).count() as i32;
                eta0 * factor.powi(decays)
            }
            LrSchedule::Corollary1 { eta0, k, total_steps } => {
                eta0 * ((*k as f64 / (*total_steps).max(1) as f64).sqrt() as f32)
            }
            LrSchedule::Warmup { eta, warmup_steps } => {
                if t < *warmup_steps {
                    eta * (t + 1) as f32 / *warmup_steps as f32
                } else {
                    *eta
                }
            }
        }
    }

    /// The paper's CIFAR-10 schedule scaled to `total_steps`.
    pub fn paper_cifar(eta0: f32, total_steps: u64) -> Self {
        LrSchedule::StepDecay { eta0, factor: 0.1, milestones: vec![0.5, 0.75], total_steps }
    }
}

/// Theorem 1/2 step-size condition: eta < (1-mu)^2 / (2L).
pub fn theorem_eta_bound(mu: f32, l_smooth: f32) -> f32 {
    (1.0 - mu).powi(2) / (2.0 * l_smooth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, forall};

    #[test]
    fn step_matches_reference_formula() {
        let mut st = MomentumState::new(3, 0.9, 0.0);
        let mut x = vec![1.0f32, 2.0, 3.0];
        let g = vec![0.5f32, -0.5, 1.0];
        st.step(&mut x, &g, 0.1);
        // m = g, x = x0 - 0.1 g
        assert_allclose(&st.m, &g, 1e-6, 0.0);
        assert_allclose(&x, &[0.95, 2.05, 2.9], 1e-6, 0.0);
        st.step(&mut x, &g, 0.1);
        // m = 0.9 g + g = 1.9 g
        assert_allclose(&st.m, &[0.95, -0.95, 1.9], 1e-6, 0.0);
    }

    #[test]
    fn weight_decay_enters_gradient() {
        let mut st = MomentumState::new(1, 0.0, 0.1);
        let mut x = vec![10.0f32];
        st.step(&mut x, &[0.0], 1.0);
        // g_eff = 0 + 0.1 * 10 = 1 => x = 9
        assert_allclose(&x, &[9.0], 1e-6, 0.0);
    }

    #[test]
    fn prop_momentum_norm_bounded_lemma3() {
        // Lemma 3: with ||g||^2 <= G^2, ||m_t||^2 <= G^2/(1-mu)^2.
        forall(11, 30, |rng| {
            let d = 1 + rng.below(64);
            let mu = 0.5 + 0.4 * rng.next_f32();
            let g_bound = 1.0f64;
            let mut st = MomentumState::new(d, mu, 0.0);
            let mut x = vec![0.0f32; d];
            for _ in 0..200 {
                // gradient with ||g|| <= 1
                let mut g = rng.normal_vec(d, 1.0);
                let n = crate::linalg::norm(&g).max(1e-9);
                g.iter_mut().for_each(|v| *v /= n as f32);
                st.step(&mut x, &g, 0.01);
            }
            let bound = g_bound / (1.0 - mu as f64).powi(2);
            assert!(
                st.momentum_norm_sq() <= bound * 1.0001,
                "||m||^2 = {} > {}",
                st.momentum_norm_sq(),
                bound
            );
        });
    }

    #[test]
    fn mu_zero_is_plain_sgd() {
        let mut st = MomentumState::new(2, 0.0, 0.0);
        let mut x = vec![1.0f32, 1.0];
        st.step(&mut x, &[2.0, 4.0], 0.5);
        assert_allclose(&x, &[0.0, -1.0], 1e-6, 0.0);
    }

    #[test]
    #[should_panic(expected = "mu")]
    fn mu_one_rejected() {
        MomentumState::new(1, 1.0, 0.0);
    }

    #[test]
    fn step_decay_schedule_matches_paper_shape() {
        let s = LrSchedule::paper_cifar(0.1, 300);
        assert!((s.eta(0) - 0.1).abs() < 1e-9);
        assert!((s.eta(149) - 0.1).abs() < 1e-9);
        assert!((s.eta(150) - 0.01).abs() < 1e-9);
        assert!((s.eta(225) - 0.001).abs() < 1e-9);
        assert!((s.eta(299) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn corollary1_rate_scales_with_k() {
        let t = 10_000;
        let e1 = LrSchedule::Corollary1 { eta0: 1.0, k: 1, total_steps: t }.eta(0);
        let e4 = LrSchedule::Corollary1 { eta0: 1.0, k: 4, total_steps: t }.eta(0);
        assert!((e4 / e1 - 2.0).abs() < 1e-5, "sqrt(K) scaling");
    }

    #[test]
    fn warmup_ramps() {
        let s = LrSchedule::Warmup { eta: 1.0, warmup_steps: 10 };
        assert!(s.eta(0) < s.eta(5));
        assert!((s.eta(10) - 1.0).abs() < 1e-9);
        assert!((s.eta(100) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn theorem_bound_shrinks_with_momentum() {
        assert!(theorem_eta_bound(0.9, 1.0) < theorem_eta_bound(0.5, 1.0));
        assert!((theorem_eta_bound(0.0, 1.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn bank_rows_are_bit_identical_to_per_worker_states() {
        forall(0xBA, 20, |rng| {
            let k = 1 + rng.below(6);
            let d = 1 + rng.below(40);
            let mut bank = MomentumBank::new(k, d, 0.9, 1e-4);
            let mut states: Vec<MomentumState> =
                (0..k).map(|_| MomentumState::new(d, 0.9, 1e-4)).collect();
            let mut xs_bank: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
            let mut xs_ref = xs_bank.clone();
            for _ in 0..5 {
                for i in 0..k {
                    let g = rng.normal_vec(d, 1.0);
                    bank.step_row(i, &mut xs_bank[i], &g, 0.05);
                    states[i].step(&mut xs_ref[i], &g, 0.05);
                }
            }
            for i in 0..k {
                let a: Vec<u32> = xs_bank[i].iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> = xs_ref[i].iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "worker {i} iterate diverged");
                let a: Vec<u32> = bank.row(i).iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> = states[i].m.iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "worker {i} momentum diverged");
            }
        });
    }

    #[test]
    fn bank_loads_legacy_per_worker_momentum_sections() {
        // The pre-arena checkpoint wrote u64 K then one put_f32s row per
        // worker; the bank must load that byte stream unchanged.
        let (k, d) = (3, 5);
        let mut w = crate::state::StateWriter::new();
        w.put_u64(k as u64);
        let rows: Vec<Vec<f32>> =
            (0..k).map(|i| (0..d).map(|j| (i * d + j) as f32).collect()).collect();
        for r in &rows {
            w.put_f32s(r);
        }
        let bytes = w.into_bytes();
        let mut bank = MomentumBank::new(k, d, 0.5, 0.0);
        bank.state_load(&mut crate::state::StateReader::new(&bytes)).unwrap();
        for i in 0..k {
            assert_eq!(bank.row(i), rows[i].as_slice());
        }
    }

    #[test]
    fn reset_zeroes_momentum() {
        let mut st = MomentumState::new(4, 0.9, 0.0);
        let mut x = vec![0.0f32; 4];
        st.step(&mut x, &[1.0; 4], 0.1);
        assert!(st.momentum_norm_sq() > 0.0);
        st.reset();
        assert_eq!(st.momentum_norm_sq(), 0.0);
    }
}
