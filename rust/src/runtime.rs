//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The request path is pure Rust: `make artifacts` ran python once to
//! lower L2 (transformer fwd+bwd, which embeds the L1 Pallas kernels) to
//! HLO **text**; this module parses that text
//! (`HloModuleProto::from_text_file` — the text parser reassigns the
//! 64-bit instruction ids jax ≥ 0.5 emits that xla_extension 0.5.1's
//! proto path rejects), compiles it on the PJRT CPU client once at
//! startup, and then executes it from the training loop with zero python.
//!
//! Exposed executables (signatures fixed by `python/compile/aot.py`):
//!
//! * [`TrainStep`]    — (params f32[d], tokens i32[B,S+1]) → (loss, grad)
//! * [`MomentumStep`] — (x, m, g f32[d], eta, mu f32[1]) → (x', m')
//! * [`MixStep`]      — (w f32[K,K], xs f32[K,d]) → xs'
//!
//! plus [`XlaGradSource`], which adapts `TrainStep` + the Markov corpus
//! to the [`crate::grad::GradientSource`] trait so the coordinator and
//! all algorithms run unchanged on the real model.
//!
//! ## The `pjrt` feature
//!
//! The `xla` bindings crate is unavailable in the offline build
//! environment, so everything that touches PJRT is gated behind the
//! `pjrt` cargo feature. Without it this module compiles a stub with the
//! same API whose constructors return descriptive errors — the pure-Rust
//! workloads, tests, and benches build and run everywhere, and code that
//! is generic over [`TrainStep`] type-checks identically in both modes.
//! [`Manifest`] parsing/validation is feature-independent (no XLA
//! needed), so `pdsgdm inspect` can still read artifact metadata.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::{BatchIter, MarkovCorpus};
use crate::grad::{EvalMetrics, GradientSource};
use crate::json::Json;
use crate::rng::Xoshiro256;

/// Whether this build carries the real PJRT runtime (`--features pjrt`).
pub const HAS_PJRT: bool = cfg!(feature = "pjrt");

/// One entry of the flat-parameter layout (mirrors model.param_layout).
#[derive(Clone, Debug, PartialEq)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `artifacts/<cfg>.meta.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub d: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_layers: usize,
    pub mix_ks: Vec<usize>,
    pub layout: Vec<LayoutEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let src = std::fs::read_to_string(path).with_context(|| format!("{path:?}"))?;
        let v = Json::parse(&src).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let need_usize = |k: &str| -> Result<usize> {
            v.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let layout = v
            .get("layout")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing layout"))?
            .iter()
            .map(|e| -> Result<LayoutEntry> {
                Ok(LayoutEntry {
                    name: e
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("layout entry missing name"))?
                        .to_string(),
                    offset: e
                        .get("offset")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("layout entry missing offset"))?,
                    shape: e
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("layout entry missing shape"))?
                        .iter()
                        .map(|s| s.as_usize().ok_or_else(|| anyhow!("bad shape")))
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let m = Self {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest missing name"))?
                .to_string(),
            d: need_usize("d")?,
            vocab: need_usize("vocab")?,
            seq_len: need_usize("seq_len")?,
            batch: need_usize("batch")?,
            n_layers: need_usize("n_layers")?,
            mix_ks: v
                .get("mix_ks")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            layout,
        };
        m.validate()?;
        Ok(m)
    }

    /// Cross-check the layout covers [0, d) contiguously — the same
    /// invariant python/tests/test_model.py asserts on the python side.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0usize;
        for e in &self.layout {
            if e.offset != off {
                bail!("layout entry {} at offset {} expected {off}", e.name, e.offset);
            }
            off += e.numel();
        }
        if off != self.d {
            bail!("layout covers {off} of d={}", self.d);
        }
        Ok(())
    }

    /// GPT-2-style init from the layout (statistically matches
    /// model.init_params; exact values differ — the RNGs differ — which
    /// is fine: workers only need *a* common x_0).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut out = vec![0.0f32; self.d];
        for e in &self.layout {
            let dst = &mut out[e.offset..e.offset + e.numel()];
            let name = e.name.as_str();
            if name.ends_with(".bias") || name.ends_with(".bqkv") || name.ends_with(".bo")
                || name.ends_with(".b1") || name.ends_with(".b2")
            {
                // zeros
            } else if name.ends_with(".scale") {
                dst.iter_mut().for_each(|v| *v = 1.0);
            } else if name == "embed" || name == "pos" {
                dst.iter_mut().for_each(|v| *v = rng.normal_f32() * 0.02);
            } else {
                let fan_in = e.shape[0] as f64;
                let mut s = (1.0 / fan_in).sqrt() as f32;
                if name.ends_with(".wo") || name.ends_with(".w2") {
                    s /= (2.0 * self.n_layers as f64).sqrt() as f32;
                }
                dst.iter_mut().for_each(|v| *v = rng.normal_f32() * s);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Real PJRT runtime (--features pjrt, needs the `xla` dependency)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod backend {
    use std::path::PathBuf;

    use anyhow::{anyhow, bail, Context, Result};

    use super::Manifest;

    /// A compiled HLO artifact on the PJRT CPU client.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT client + artifact directory.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
    }

    impl Runtime {
        pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            let dir = artifacts_dir.into();
            if !dir.is_dir() {
                bail!(
                    "artifacts directory {dir:?} not found — run `make artifacts` first"
                );
            }
            Ok(Self { client: xla::PjRtClient::cpu()?, dir })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn manifest(&self, model: &str) -> Result<Manifest> {
            Manifest::load(&self.dir.join(format!("{model}.meta.json")))
        }

        fn compile(&self, file: &str) -> Result<Executable> {
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(Executable { exe: self.client.compile(&comp)? })
        }

        pub fn train_step(&self, model: &str) -> Result<TrainStep> {
            let manifest = self.manifest(model)?;
            let exe = self.compile(&format!("train_step_{model}.hlo.txt"))?;
            Ok(TrainStep { exe, manifest })
        }

        pub fn momentum_step(&self, model: &str) -> Result<MomentumStep> {
            let manifest = self.manifest(model)?;
            let exe = self.compile(&format!("momentum_{model}.hlo.txt"))?;
            Ok(MomentumStep { exe, d: manifest.d })
        }

        pub fn mix_step(&self, model: &str, k: usize) -> Result<MixStep> {
            let manifest = self.manifest(model)?;
            if !manifest.mix_ks.contains(&k) {
                bail!(
                    "no mix artifact for K={k} (available: {:?}); re-run `make artifacts` with --ks",
                    manifest.mix_ks
                );
            }
            let exe = self.compile(&format!("mix_k{k}_{model}.hlo.txt"))?;
            Ok(MixStep { exe, k, d: manifest.d })
        }
    }

    fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// The fused fwd+bwd of the L2 transformer: (params, tokens) → (loss, grad).
    pub struct TrainStep {
        exe: Executable,
        pub manifest: Manifest,
    }

    impl TrainStep {
        /// Execute one training step. `tokens` is row-major [batch, seq_len+1].
        pub fn run(&self, params: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
            let m = &self.manifest;
            if params.len() != m.d {
                bail!("params len {} != d {}", params.len(), m.d);
            }
            let expect_tokens = m.batch * (m.seq_len + 1);
            if tokens.len() != expect_tokens {
                bail!("tokens len {} != B*(S+1) = {expect_tokens}", tokens.len());
            }
            let p = literal_f32(params, &[m.d as i64])?;
            let t = xla::Literal::vec1(tokens).reshape(&[m.batch as i64, (m.seq_len + 1) as i64])?;
            let result = self.exe.exe.execute::<xla::Literal>(&[p, t])?[0][0].to_literal_sync()?;
            let (loss_lit, grad_lit) = result.to_tuple2()?;
            let loss = loss_lit.to_vec::<f32>()?[0];
            let grad = grad_lit.to_vec::<f32>()?;
            Ok((loss, grad))
        }
    }

    /// The fused L1 momentum kernel artifact: (x, m, g, eta, mu) → (x', m').
    pub struct MomentumStep {
        exe: Executable,
        pub d: usize,
    }

    impl MomentumStep {
        pub fn run(
            &self,
            x: &[f32],
            m: &[f32],
            g: &[f32],
            eta: f32,
            mu: f32,
        ) -> Result<(Vec<f32>, Vec<f32>)> {
            if x.len() != self.d || m.len() != self.d || g.len() != self.d {
                bail!("momentum operand length mismatch (d={})", self.d);
            }
            let args = [
                literal_f32(x, &[self.d as i64])?,
                literal_f32(m, &[self.d as i64])?,
                literal_f32(g, &[self.d as i64])?,
                literal_f32(&[eta], &[1])?,
                literal_f32(&[mu], &[1])?,
            ];
            let result = self.exe.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let (x_new, m_new) = result.to_tuple2()?;
            Ok((x_new.to_vec::<f32>()?, m_new.to_vec::<f32>()?))
        }
    }

    /// The L1 gossip-mix kernel artifact: (w, xs) → W·X over stacked iterates.
    pub struct MixStep {
        exe: Executable,
        pub k: usize,
        pub d: usize,
    }

    impl MixStep {
        /// `w` is row-major [K,K]; `xs` row-major [K,d]. Returns mixed [K,d].
        pub fn run(&self, w: &[f32], xs: &[f32]) -> Result<Vec<f32>> {
            if w.len() != self.k * self.k {
                bail!("w len {} != K*K", w.len());
            }
            if xs.len() != self.k * self.d {
                bail!("xs len {} != K*d", xs.len());
            }
            let wl = literal_f32(w, &[self.k as i64, self.k as i64])?;
            let xl = literal_f32(xs, &[self.k as i64, self.d as i64])?;
            let result = self.exe.exe.execute::<xla::Literal>(&[wl, xl])?[0][0].to_literal_sync()?;
            Ok(result.to_tuple1()?.to_vec::<f32>()?)
        }
    }
}

// ---------------------------------------------------------------------------
// Stub runtime (default build: no `xla` crate available)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
mod backend {
    use std::path::PathBuf;

    use anyhow::{bail, Result};

    use super::Manifest;

    const NO_PJRT: &str = "pdsgdm was built without the `pjrt` feature, so the \
        XLA/PJRT runtime is unavailable; provide the `xla` dependency in \
        Cargo.toml and rebuild with `--features pjrt` (after `make artifacts`)";

    /// Stub: artifact-directory handle that can read manifests but not
    /// compile or execute.
    pub struct Runtime {
        dir: PathBuf,
    }

    impl Runtime {
        pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
            let dir = artifacts_dir.into();
            if !dir.is_dir() {
                bail!(
                    "artifacts directory {dir:?} not found — run `make artifacts` first"
                );
            }
            Ok(Self { dir })
        }

        pub fn platform(&self) -> String {
            "unavailable (built without the pjrt feature)".into()
        }

        pub fn manifest(&self, model: &str) -> Result<Manifest> {
            Manifest::load(&self.dir.join(format!("{model}.meta.json")))
        }

        pub fn train_step(&self, _model: &str) -> Result<TrainStep> {
            bail!(NO_PJRT)
        }

        pub fn momentum_step(&self, _model: &str) -> Result<MomentumStep> {
            bail!(NO_PJRT)
        }

        pub fn mix_step(&self, _model: &str, _k: usize) -> Result<MixStep> {
            bail!(NO_PJRT)
        }
    }

    /// Stub `TrainStep` — never constructible (only [`Runtime::train_step`]
    /// could mint one and it always errors), but the type exists so code
    /// generic over the runtime compiles unchanged.
    pub struct TrainStep {
        pub manifest: Manifest,
    }

    impl TrainStep {
        pub fn run(&self, _params: &[f32], _tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
            bail!(NO_PJRT)
        }
    }

    pub struct MomentumStep {
        pub d: usize,
    }

    impl MomentumStep {
        pub fn run(
            &self,
            _x: &[f32],
            _m: &[f32],
            _g: &[f32],
            _eta: f32,
            _mu: f32,
        ) -> Result<(Vec<f32>, Vec<f32>)> {
            bail!(NO_PJRT)
        }
    }

    pub struct MixStep {
        pub k: usize,
        pub d: usize,
    }

    impl MixStep {
        pub fn run(&self, _w: &[f32], _xs: &[f32]) -> Result<Vec<f32>> {
            bail!(NO_PJRT)
        }
    }
}

pub use backend::{MixStep, MomentumStep, Runtime, TrainStep};
#[cfg(feature = "pjrt")]
pub use backend::Executable;

// ---------------------------------------------------------------------------
// GradientSource adapter (works against either backend's TrainStep)
// ---------------------------------------------------------------------------

/// Adapts the XLA transformer to [`GradientSource`]: K workers sharing
/// one compiled `TrainStep`, each with its own contiguous shard of a
/// Markov-corpus token stream and its own batch sampler.
///
/// One shared PJRT executable cannot split into `Sync` per-worker
/// shards, so this source keeps the default `split_workers() == None`
/// and the [`crate::engine::LocalStepEngine`] drives it through the
/// sequential path: one shared scratch buffer (never K×d resident
/// memory), at the cost of copying the executable's output into it —
/// an O(d) memcpy that is negligible next to the train-step execution.
pub struct XlaGradSource {
    step: TrainStep,
    tokens: Vec<u32>,
    /// Per-worker [start, end) shard bounds into `tokens`.
    shards: Vec<(usize, usize)>,
    samplers: Vec<BatchIter>,
    /// Held-out window (tail of the corpus) for eval.
    eval_windows: Vec<usize>,
    k: usize,
}

impl XlaGradSource {
    pub fn new(step: TrainStep, k: usize, corpus_tokens: usize, seed: u64) -> Result<Self> {
        let m = &step.manifest;
        let window = m.seq_len + 1;
        let gen = MarkovCorpus { vocab: m.vocab, branching: 4, tokens: corpus_tokens };
        let tokens = gen.generate(seed);
        let n_eval = (corpus_tokens / 10).max(window * 4);
        let train_len = corpus_tokens - n_eval;
        if train_len / k < window * 4 {
            bail!("corpus too small: {corpus_tokens} tokens over {k} workers");
        }
        let per = train_len / k;
        let shards: Vec<(usize, usize)> = (0..k).map(|i| (i * per, (i + 1) * per)).collect();
        let samplers = shards
            .iter()
            .enumerate()
            .map(|(i, &(lo, hi))| {
                BatchIter::new((lo..hi - window).collect(), seed ^ (0x77 + i as u64))
            })
            .collect();
        let eval_windows = (train_len..corpus_tokens - window).step_by(window).collect();
        Ok(Self { step, tokens, shards, samplers, eval_windows, k })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.step.manifest
    }

    fn batch_tokens(&mut self, worker: usize) -> Vec<i32> {
        let m = &self.step.manifest;
        let window = m.seq_len + 1;
        let starts = self.samplers[worker].next_batch(m.batch);
        let mut out = Vec::with_capacity(m.batch * window);
        for s in starts {
            out.extend(self.tokens[s..s + window].iter().map(|&t| t as i32));
        }
        out
    }
}

impl GradientSource for XlaGradSource {
    fn dim(&self) -> usize {
        self.step.manifest.d
    }

    fn workers(&self) -> usize {
        self.k
    }

    fn grad_into(&mut self, worker: usize, x: &[f32], out: &mut [f32]) -> f64 {
        let toks = self.batch_tokens(worker);
        let (loss, grad) = self
            .step
            .run(x, &toks)
            .expect("train_step execution failed");
        out.copy_from_slice(&grad);
        loss as f64
    }

    fn eval(&mut self, x: &[f32]) -> EvalMetrics {
        // Average loss over a few held-out windows (batched).
        let m = &self.step.manifest;
        let window = m.seq_len + 1;
        let mut losses = Vec::new();
        for chunk in self.eval_windows.chunks(m.batch).take(4) {
            if chunk.len() < m.batch {
                break;
            }
            let mut toks = Vec::with_capacity(m.batch * window);
            for &s in chunk {
                toks.extend(self.tokens[s..s + window].iter().map(|&t| t as i32));
            }
            if let Ok((loss, _)) = self.step.run(x, &toks) {
                losses.push(loss as f64);
            }
        }
        let loss = if losses.is_empty() {
            f64::NAN
        } else {
            losses.iter().sum::<f64>() / losses.len() as f64
        };
        EvalMetrics { loss, accuracy: 0.0, grad_norm_sq: 0.0 }
    }

    fn init(&self, seed: u64) -> Vec<f32> {
        self.step.manifest.init_params(seed)
    }

    fn state_save(&self, w: &mut crate::state::StateWriter) {
        w.tag("xla");
        crate::grad::save_samplers(&self.samplers, w);
    }

    fn state_load(&mut self, r: &mut crate::state::StateReader) -> Result<(), String> {
        r.expect_tag("xla")?;
        crate::grad::load_samplers(&mut self.samplers, r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Manifest logic is testable without artifacts; the load-and-execute
    // path is covered by rust/tests/runtime_integration.rs (gated on the
    // artifacts directory existing AND the pjrt feature being enabled).

    fn manifest_json() -> String {
        r#"{
          "name": "t", "d": 10, "vocab": 8, "d_model": 2, "n_layers": 1,
          "n_heads": 1, "d_ff": 4, "seq_len": 4, "batch": 2, "mix_ks": [4],
          "layout": [
            {"name": "embed", "offset": 0, "shape": [4, 2]},
            {"name": "l0.ln1.scale", "offset": 8, "shape": [1]},
            {"name": "l0.ln1.bias", "offset": 9, "shape": [1]}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn manifest_parses_and_validates() {
        let dir = std::env::temp_dir().join(format!("pdsgdm_mani_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.meta.json");
        std::fs::write(&p, manifest_json()).unwrap();
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.d, 10);
        assert_eq!(m.layout.len(), 3);
        assert_eq!(m.layout[0].numel(), 8);
        assert_eq!(m.mix_ks, vec![4]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_rejects_gapped_layout() {
        let dir = std::env::temp_dir().join(format!("pdsgdm_mani2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.meta.json");
        std::fs::write(
            &p,
            r#"{"name":"b","d":5,"vocab":2,"seq_len":1,"batch":1,"n_layers":1,
               "layout":[{"name":"a","offset":0,"shape":[2]},
                          {"name":"c","offset":3,"shape":[2]}]}"#,
        )
        .unwrap();
        let err = Manifest::load(&p).unwrap_err().to_string();
        assert!(err.contains("expected 2"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn init_params_follows_layout_rules() {
        let m = Manifest {
            name: "t".into(),
            d: 12,
            vocab: 4,
            seq_len: 4,
            batch: 1,
            n_layers: 2,
            mix_ks: vec![],
            layout: vec![
                LayoutEntry { name: "embed".into(), offset: 0, shape: vec![4, 2] },
                LayoutEntry { name: "l0.ln1.scale".into(), offset: 8, shape: vec![2] },
                LayoutEntry { name: "l0.ln1.bias".into(), offset: 10, shape: vec![2] },
            ],
        };
        let x = m.init_params(3);
        assert_eq!(x.len(), 12);
        // embeddings small-normal
        assert!(x[..8].iter().any(|&v| v != 0.0));
        assert!(x[..8].iter().all(|&v| v.abs() < 0.2));
        // scale ones, bias zeros
        assert_eq!(&x[8..10], &[1.0, 1.0]);
        assert_eq!(&x[10..12], &[0.0, 0.0]);
        // deterministic
        assert_eq!(m.init_params(3), x);
        assert_ne!(m.init_params(4)[..8], x[..8]);
    }

    #[test]
    fn runtime_requires_artifact_dir() {
        let err = match Runtime::new("/nonexistent/path") {
            Ok(_) => panic!("should fail"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn stub_runtime_reads_manifests_but_cannot_execute() {
        let dir = std::env::temp_dir().join(format!("pdsgdm_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.meta.json"), manifest_json()).unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert_eq!(rt.manifest("t").unwrap().d, 10);
        assert!(rt.platform().contains("pjrt"));
        let err = rt.train_step("t").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
        assert!(rt.momentum_step("t").is_err());
        assert!(rt.mix_step("t", 4).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
