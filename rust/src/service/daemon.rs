//! The serve loop: N runner threads multiplex queued jobs onto ONE
//! shared [`WorkerPool`], an HTTP listener exports `/metrics` and
//! `/jobs`, and SIGTERM/SIGINT triggers a graceful drain — running
//! sessions stop at a clean step boundary, checkpoint to `PDSGDM02`,
//! and a `drain.json` manifest lets the next `pdsgdm serve` resume
//! every interrupted job bit-identically.
//!
//! Filesystem layout under `serve.state_dir`:
//!
//! ```text
//! jobs/job-<id>.toml   canonical copy of every submitted job
//! logs/job-<id>.log    per-job VerboseObserver lines
//! ckpt/job-<id>.ckpt   drain checkpoints (PDSGDM02)
//! out/<name>.csv       result traces of completed jobs
//! drain.json           manifest of interrupted + still-queued jobs
//! drain.last.json      the consumed manifest from the previous run
//! ```

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::ServeConfig;
use crate::coordinator::{RunOutcome, Session, SessionSpec, VerboseObserver};
use crate::engine::WorkerPool;
use crate::json::{obj, Json};
use crate::metrics::write_csv;
use crate::service::http::{self, Handler, HttpServer, Response};
use crate::service::metrics_export::{MetricsObserver, MetricsRegistry};
use crate::service::queue::{parse_job_toml, JobQueue, JobState};

/// Process-wide drain flag flipped by the SIGTERM/SIGINT handler.
/// Async-signal-safe: the handler does one atomic store.
static SIGNAL_DRAIN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    // No libc crate in this offline build; `signal(2)` is declared
    // directly. Registering an atomic-store-only handler is the
    // canonical async-signal-safe pattern.
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    extern "C" fn on_signal(_sig: i32) {
        SIGNAL_DRAIN.store(true, Ordering::SeqCst);
    }
    let h: extern "C" fn(i32) = on_signal;
    unsafe {
        signal(15, h as usize); // SIGTERM
        signal(2, h as usize); // SIGINT
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// The training service. Construct with [`Daemon::new`], enqueue work
/// with [`Daemon::submit_file`]/[`Daemon::submit_toml`] (or a spool
/// directory), then [`Daemon::run`] until drained or idle.
pub struct Daemon {
    cfg: ServeConfig,
    queue: Arc<JobQueue>,
    registry: Arc<MetricsRegistry>,
    pool: Arc<WorkerPool>,
    /// In-process drain request ([`Daemon::request_drain`], tests).
    drain: Arc<AtomicBool>,
    /// Bound HTTP address once [`Daemon::run`] is up (port 0 resolves
    /// here); lets tests scrape an ephemeral port.
    bound: Arc<Mutex<Option<std::net::SocketAddr>>>,
}

impl Daemon {
    pub fn new(cfg: ServeConfig) -> Result<Self, String> {
        cfg.validate()?;
        let state = PathBuf::from(&cfg.state_dir);
        for sub in ["jobs", "logs", "ckpt", "out"] {
            std::fs::create_dir_all(state.join(sub))
                .map_err(|e| format!("create {}/{sub}: {e}", cfg.state_dir))?;
        }
        let threads = cfg.pool_threads.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
        Ok(Self {
            cfg,
            queue: Arc::new(JobQueue::new()),
            registry: Arc::new(MetricsRegistry::new()),
            pool: Arc::new(WorkerPool::new(threads)),
            drain: Arc::new(AtomicBool::new(false)),
            bound: Arc::new(Mutex::new(None)),
        })
    }

    pub fn queue(&self) -> &Arc<JobQueue> {
        &self.queue
    }

    pub fn registry(&self) -> &Arc<MetricsRegistry> {
        &self.registry
    }

    /// Ask the daemon to drain (same path as SIGTERM, minus the
    /// signal). Used by tests and embedders.
    pub fn request_drain(&self) {
        self.drain.store(true, Ordering::SeqCst);
    }

    fn draining(&self) -> bool {
        self.drain.load(Ordering::SeqCst) || SIGNAL_DRAIN.load(Ordering::SeqCst)
    }

    /// The HTTP listener's bound address once [`Daemon::run`] is up.
    pub fn http_addr(&self) -> Option<std::net::SocketAddr> {
        *self.bound.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn state_dir(&self) -> PathBuf {
        PathBuf::from(&self.cfg.state_dir)
    }

    /// Submit a job TOML by path. The file is copied into
    /// `state_dir/jobs/` so the daemon owns a canonical version.
    pub fn submit_file(&self, path: &Path) -> Result<u64, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        self.submit_toml(&src)
    }

    /// Submit a job from TOML source (an experiment config plus an
    /// optional `[job]` section); returns the job id.
    pub fn submit_toml(&self, src: &str) -> Result<u64, String> {
        self.submit_spec(src, None, None)
    }

    fn submit_spec(
        &self,
        src: &str,
        name_override: Option<String>,
        resume_from: Option<PathBuf>,
    ) -> Result<u64, String> {
        let mut spec = parse_job_toml(src)?;
        if name_override.is_some() {
            spec.name = name_override;
        }
        let id = self.queue.submit(spec, resume_from, None);
        let copy = self.state_dir().join("jobs").join(format!("job-{id}.toml"));
        std::fs::write(&copy, src).map_err(|e| format!("spool {copy:?}: {e}"))?;
        self.queue.set_source_path(id, copy);
        Ok(id)
    }

    /// Re-submit everything a previous run's `drain.json` recorded:
    /// drained jobs resume from their checkpoints (keeping their names,
    /// so metrics and result files line up), still-queued jobs start
    /// fresh. The manifest is renamed once consumed so a later restart
    /// doesn't double-submit.
    fn recover(&self) -> Result<(), String> {
        let manifest = self.state_dir().join("drain.json");
        let Ok(src) = std::fs::read_to_string(&manifest) else {
            return Ok(());
        };
        let doc = Json::parse(&src).map_err(|e| format!("drain.json: {e}"))?;
        let jobs_of = |key: &str| -> Vec<Json> {
            doc.get(key).and_then(|v| v.as_arr()).map(<[Json]>::to_vec).unwrap_or_default()
        };
        for entry in jobs_of("drained").iter().chain(jobs_of("queued").iter()) {
            let Some(job_file) = entry.get("job_file").and_then(Json::as_str) else {
                return Err("drain.json entry missing job_file".into());
            };
            let name = entry.get("name").and_then(Json::as_str).map(str::to_string);
            let ckpt = entry.get("checkpoint").and_then(Json::as_str).map(PathBuf::from);
            let src = std::fs::read_to_string(job_file)
                .map_err(|e| format!("drain.json job {job_file}: {e}"))?;
            self.submit_spec(&src, name, ckpt)?;
        }
        let consumed = self.state_dir().join("drain.last.json");
        std::fs::rename(&manifest, &consumed)
            .map_err(|e| format!("consume drain.json: {e}"))?;
        Ok(())
    }

    /// Scan the spool directory: every `*.toml` (lexicographic order —
    /// `pdsgdm submit` writes sortable names) is submitted and renamed
    /// `*.toml.submitted`, or `*.toml.rejected` if it doesn't parse.
    fn scan_spool(&self) {
        let Some(dir) = &self.cfg.spool_dir else { return };
        let Ok(entries) = std::fs::read_dir(dir) else { return };
        let mut files: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "toml"))
            .collect();
        files.sort();
        for path in files {
            let verdict = match self.submit_file(&path) {
                Ok(id) => {
                    eprintln!("[serve] spool {path:?} -> job {id}");
                    "submitted"
                }
                Err(e) => {
                    eprintln!("[serve] spool {path:?} rejected: {e}");
                    "rejected"
                }
            };
            let mut renamed = path.clone().into_os_string();
            renamed.push(format!(".{verdict}"));
            let _ = std::fs::rename(&path, renamed);
        }
    }

    fn routes(&self) -> Handler {
        let registry = Arc::clone(&self.registry);
        let queue = Arc::clone(&self.queue);
        Arc::new(move |path| match path {
            "/metrics" => Some(Response::metrics(registry.render())),
            "/jobs" => Some(Response::json(jobs_json(&queue))),
            "/healthz" => Some(Response::text(200, "ok\n")),
            _ => None,
        })
    }

    fn publish_state_counts(&self) {
        let snap = self.queue.snapshot();
        let counts: Vec<(&'static str, usize)> = JobState::ALL
            .iter()
            .map(|s| (s.as_str(), snap.iter().filter(|j| j.state == *s).count()))
            .collect();
        self.registry.set_state_counts(&counts);
    }

    /// Serve until drained (SIGTERM/SIGINT/[`Daemon::request_drain`])
    /// or — with `serve.exit_when_idle` — until the queue empties.
    pub fn run(&self) -> Result<(), String> {
        // A daemon restarted in-process (tests) must not inherit the
        // previous run's signal; a real signal landing here re-sets it.
        SIGNAL_DRAIN.store(false, Ordering::SeqCst);
        install_signal_handlers();
        self.recover()?;

        let mut server =
            HttpServer::spawn(&self.cfg.listen, self.routes()).map_err(|e| {
                format!("bind {}: {e}", self.cfg.listen)
            })?;
        *self.bound.lock().unwrap_or_else(|p| p.into_inner()) = Some(server.addr());
        eprintln!("[serve] listening on http://{}", server.addr());

        let runners: Vec<_> = (0..self.cfg.max_concurrent)
            .map(|i| {
                let queue = Arc::clone(&self.queue);
                let registry = Arc::clone(&self.registry);
                let pool = Arc::clone(&self.pool);
                let drain = Arc::clone(&self.drain);
                let state = self.state_dir();
                std::thread::Builder::new()
                    .name(format!("pdsgdm-runner-{i}"))
                    .spawn(move || runner_loop(&queue, &registry, &pool, &drain, &state))
                    .expect("spawn runner thread")
            })
            .collect();

        loop {
            if self.draining() {
                break;
            }
            self.scan_spool();
            self.publish_state_counts();
            if self.cfg.exit_when_idle && self.queue.active_counts() == (0, 0) {
                break;
            }
            std::thread::sleep(Duration::from_millis(self.cfg.poll_ms));
        }

        let drained = self.draining();
        // No more claims; runners finish (or checkpoint) their current
        // job and exit.
        self.queue.close();
        for r in runners {
            let _ = r.join();
        }
        self.publish_state_counts();
        if drained {
            self.write_drain_manifest()?;
            eprintln!("[serve] drained; manifest at {:?}", self.state_dir().join("drain.json"));
        }
        server.shutdown();
        Ok(())
    }

    /// Atomically write `drain.json`: which jobs were interrupted (and
    /// where their checkpoints are) and which never started.
    fn write_drain_manifest(&self) -> Result<(), String> {
        let snap = self.queue.snapshot();
        let entry = |j: &crate::service::queue::Job| {
            let mut pairs = vec![
                ("id", Json::Num(j.id as f64)),
                ("name", Json::Str(j.name.clone())),
                (
                    "job_file",
                    Json::Str(
                        j.source_path.as_ref().map(|p| p.display().to_string()).unwrap_or_default(),
                    ),
                ),
            ];
            if let Some(ck) = &j.checkpoint {
                pairs.push(("checkpoint", Json::Str(ck.display().to_string())));
                pairs.push(("steps", Json::Num(j.steps_done as f64)));
            }
            obj(pairs)
        };
        let of_state = |s: JobState| -> Json {
            Json::Arr(snap.iter().filter(|j| j.state == s).map(entry).collect())
        };
        let manifest = obj(vec![
            ("version", Json::Num(1.0)),
            ("drained", of_state(JobState::Drained)),
            ("queued", of_state(JobState::Queued)),
        ]);
        let path = self.state_dir().join("drain.json");
        let tmp = self.state_dir().join("drain.json.tmp");
        std::fs::write(&tmp, manifest.to_string_compact())
            .map_err(|e| format!("write {tmp:?}: {e}"))?;
        std::fs::rename(&tmp, &path).map_err(|e| format!("rename {tmp:?}: {e}"))
    }
}

/// `/jobs` body: the queue snapshot as a JSON array.
fn jobs_json(queue: &JobQueue) -> String {
    let jobs: Vec<Json> = queue
        .snapshot()
        .iter()
        .map(|j| {
            let mut pairs = vec![
                ("id", Json::Num(j.id as f64)),
                ("name", Json::Str(j.name.clone())),
                ("state", Json::Str(j.state.as_str().into())),
                ("priority", Json::Num(j.priority as f64)),
                ("steps_done", Json::Num(j.steps_done as f64)),
            ];
            if let Some(l) = j.final_loss {
                pairs.push(("final_loss", Json::Num(l)));
            }
            if let Some(r) = j.stop_reason {
                pairs.push(("stop_reason", Json::Str(format!("{r:?}"))));
            }
            if let Some(e) = &j.error {
                pairs.push(("error", Json::Str(e.clone())));
            }
            obj(pairs)
        })
        .collect();
    obj(vec![("jobs", Json::Arr(jobs))]).to_string_compact()
}

/// One runner thread: claim → build the session *in this thread*
/// (sessions hold non-Send trait objects, so they never cross threads)
/// → run to the stop condition or the drain interrupt.
fn runner_loop(
    queue: &Arc<JobQueue>,
    registry: &Arc<MetricsRegistry>,
    pool: &Arc<WorkerPool>,
    drain: &Arc<AtomicBool>,
    state: &Path,
) {
    while let Some(job) = queue.claim() {
        match run_job(&job, registry, pool, drain, state) {
            Ok(JobEnd::Completed { steps, loss, reason }) => {
                queue.mark_completed(job.id, steps, loss, reason);
            }
            Ok(JobEnd::Drained { steps, checkpoint }) => {
                queue.mark_drained(job.id, steps, checkpoint);
            }
            Err(e) => {
                eprintln!("[serve] job {} ({}) failed: {e}", job.id, job.name);
                queue.mark_failed(job.id, e);
            }
        }
    }
}

enum JobEnd {
    Completed { steps: u64, loss: f64, reason: Option<crate::coordinator::StopReason> },
    Drained { steps: u64, checkpoint: PathBuf },
}

fn run_job(
    job: &crate::service::queue::Job,
    registry: &Arc<MetricsRegistry>,
    pool: &Arc<WorkerPool>,
    drain: &Arc<AtomicBool>,
    state: &Path,
) -> Result<JobEnd, String> {
    let mut spec = SessionSpec::new(job.config.clone());
    if let Some(ck) = &job.resume_from {
        spec = spec.resume_from(ck.clone());
    }
    let mut session = Session::build(spec).map_err(|e| e.to_string())?;
    // All concurrent sessions fan onto the one shared pool instead of
    // spinning up max_concurrent private pools.
    session.install_shared_pool(Arc::clone(pool));
    session.observe(Box::new(MetricsObserver::new(job.name.clone(), Arc::clone(registry))));
    if let Ok(log) = std::fs::File::create(state.join("logs").join(format!("job-{}.log", job.id)))
    {
        session.observe(Box::new(VerboseObserver::to_sink(Box::new(log))));
    }
    let stop = session.stop_condition();
    let outcome = session.run_until_interruptible(stop, &mut || {
        drain.load(Ordering::Relaxed) || SIGNAL_DRAIN.load(Ordering::Relaxed)
    });
    match outcome {
        RunOutcome::Stopped(reason) => {
            let out = state.join("out").join(format!("{}.csv", job.name));
            write_csv(&out, std::slice::from_ref(session.trace()))
                .map_err(|e| format!("write {out:?}: {e}"))?;
            Ok(JobEnd::Completed {
                steps: session.steps_done(),
                loss: session.trace().final_loss(),
                reason: Some(reason),
            })
        }
        RunOutcome::Interrupted => {
            let ck = state.join("ckpt").join(format!("job-{}.ckpt", job.id));
            session.save(&ck).map_err(|e| e.to_string())?;
            Ok(JobEnd::Drained { steps: session.steps_done(), checkpoint: ck })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_state(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "pdsgdm_daemon_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn serve_cfg(state: &Path) -> ServeConfig {
        ServeConfig {
            listen: "127.0.0.1:0".into(),
            max_concurrent: 2,
            pool_threads: Some(2),
            state_dir: state.display().to_string(),
            spool_dir: None,
            poll_ms: 10,
            exit_when_idle: true,
        }
    }

    const QUICK_JOB: &str = "\
algorithm = \"pd-sgdm\"
workers = 4
steps = 60
eval_every = 20

[workload]
kind = \"quadratic\"
dim = 16
heterogeneity = 1.0
noise = 0.05

[hyper]
eta = 0.05
";

    #[test]
    fn daemon_runs_submitted_jobs_to_completion_and_serves_http() {
        let state = temp_state("basic");
        let daemon = Daemon::new(serve_cfg(&state)).unwrap();
        daemon.submit_toml(&format!("{QUICK_JOB}[job]\nname = \"alpha\"\n")).unwrap();
        daemon.submit_toml(&format!("{QUICK_JOB}[job]\nname = \"beta\"\n")).unwrap();

        // Scrape while running: move run() to a thread, poll the addr.
        std::thread::scope(|scope| {
            let handle = scope.spawn(|| daemon.run().unwrap());
            let addr = loop {
                if let Some(a) = daemon.http_addr() {
                    break a;
                }
                std::thread::sleep(Duration::from_millis(5));
            };
            let (status, body) = http::get(addr, "/healthz").unwrap();
            assert_eq!((status, body.as_str()), (200, "ok\n"));
            handle.join().unwrap();
        });

        let snap = daemon.queue().snapshot();
        assert_eq!(snap.len(), 2);
        for j in &snap {
            assert_eq!(j.state, JobState::Completed, "{}: {:?}", j.name, j.error);
            assert_eq!(j.steps_done, 60);
            assert!(j.final_loss.unwrap().is_finite());
        }
        assert!(state.join("out/alpha.csv").is_file());
        assert!(state.join("out/beta.csv").is_file());
        assert!(state.join("logs/job-1.log").metadata().unwrap().len() > 0);
        let text = daemon.registry().render();
        assert!(text.contains("pdsgdm_job_steps_total{job=\"alpha\"} 60"), "{text}");
        std::fs::remove_dir_all(&state).unwrap();
    }

    #[test]
    fn failed_jobs_are_marked_not_fatal() {
        let state = temp_state("fail");
        let daemon = Daemon::new(serve_cfg(&state)).unwrap();
        // Transformer without artifacts fails at Session::build.
        daemon
            .submit_toml(
                "algorithm = \"pd-sgdm\"\nsteps = 5\n\
                 [workload]\nkind = \"transformer\"\nmodel = \"tiny\"\n\
                 artifacts_dir = \"/definitely/not/here\"\n",
            )
            .unwrap();
        daemon.submit_toml(QUICK_JOB).unwrap();
        daemon.run().unwrap();
        let snap = daemon.queue().snapshot();
        assert_eq!(snap[0].state, JobState::Failed);
        assert!(snap[0].error.as_deref().unwrap().contains("make artifacts"));
        assert_eq!(snap[1].state, JobState::Completed);
        std::fs::remove_dir_all(&state).unwrap();
    }

    #[test]
    fn drain_checkpoints_running_jobs_and_restart_resumes_bit_identically() {
        let state = temp_state("drain");
        // Reference: the same job run uninterrupted in a daemon.
        let ref_state = temp_state("drain_ref");
        let job = format!(
            "{}[job]\nname = \"long\"\n",
            QUICK_JOB.replace("steps = 60", "steps = 6000").replace("eval_every = 20", "eval_every = 1000")
        );
        let reference = Daemon::new(serve_cfg(&ref_state)).unwrap();
        reference.submit_toml(&job).unwrap();
        reference.run().unwrap();
        let want = std::fs::read_to_string(ref_state.join("out/long.csv")).unwrap();

        // Interrupted: drain once the job has made some progress.
        let daemon = Daemon::new(serve_cfg(&state)).unwrap();
        daemon.submit_toml(&job).unwrap();
        std::thread::scope(|scope| {
            let handle = scope.spawn(|| daemon.run().unwrap());
            while daemon.registry().steps_total("long") == 0 {
                std::thread::sleep(Duration::from_millis(2));
            }
            daemon.request_drain();
            handle.join().unwrap();
        });
        let snap = daemon.queue().snapshot();
        // The drain raced job completion; only assert the interesting
        // path when the interrupt landed mid-run.
        if snap[0].state == JobState::Drained {
            assert!(snap[0].checkpoint.as_ref().unwrap().is_file());
            assert!(snap[0].steps_done < 6000);
            assert!(state.join("drain.json").is_file());

            // Restart on the same state dir: recover() resumes the job.
            let daemon2 = Daemon::new(serve_cfg(&state)).unwrap();
            daemon2.run().unwrap();
            assert!(!state.join("drain.json").is_file(), "manifest consumed");
            let snap2 = daemon2.queue().snapshot();
            assert_eq!(snap2[0].state, JobState::Completed, "{:?}", snap2[0].error);
            assert_eq!(snap2[0].steps_done, 6000);
        }
        let got = std::fs::read_to_string(state.join("out/long.csv")).unwrap();
        assert_eq!(want, got, "resumed trace must match the uninterrupted run");
        std::fs::remove_dir_all(&state).unwrap();
        std::fs::remove_dir_all(&ref_state).unwrap();
    }

    #[test]
    fn spool_directory_feeds_the_queue() {
        let state = temp_state("spool");
        let spool = state.join("inbox");
        std::fs::create_dir_all(&spool).unwrap();
        std::fs::write(spool.join("a.toml"), QUICK_JOB).unwrap();
        std::fs::write(spool.join("b.toml"), "definitely not toml = = =").unwrap();
        let mut cfg = serve_cfg(&state);
        cfg.spool_dir = Some(spool.display().to_string());
        let daemon = Daemon::new(cfg).unwrap();
        // Seed one job so exit_when_idle doesn't win the race against
        // the first spool scan (the scan runs before the idle check).
        daemon.submit_toml(QUICK_JOB).unwrap();
        daemon.run().unwrap();
        assert!(spool.join("a.toml.submitted").is_file());
        assert!(spool.join("b.toml.rejected").is_file());
        let snap = daemon.queue().snapshot();
        assert_eq!(snap.len(), 2);
        assert!(snap.iter().all(|j| j.state == JobState::Completed));
        std::fs::remove_dir_all(&state).unwrap();
    }
}
