//! Minimal HTTP/1.1 server on `std::net::TcpListener` — enough to
//! serve `/metrics` and `/jobs` to a scraper, nothing more. GET only,
//! `Connection: close`, one short-lived handler thread per connection.
//! No external crates: this repo is offline by design.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// A response the route handler hands back.
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Self { status, content_type: "text/plain; charset=utf-8", body: body.into() }
    }

    pub fn json(body: impl Into<String>) -> Self {
        Self { status: 200, content_type: "application/json", body: body.into() }
    }

    /// Prometheus text exposition content type.
    pub fn metrics(body: impl Into<String>) -> Self {
        Self {
            status: 200,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: body.into(),
        }
    }
}

/// Route handler: path (query string already stripped) → response, or
/// `None` for 404.
pub type Handler = Arc<dyn Fn(&str) -> Option<Response> + Send + Sync>;

/// Background accept loop bound to one socket. Dropping the server (or
/// calling [`HttpServer::shutdown`]) stops the loop.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `bind` (e.g. `127.0.0.1:9090`; port 0 = ephemeral) and
    /// start accepting. The listener is non-blocking so the loop can
    /// poll the stop flag between connections.
    pub fn spawn(bind: &str, handler: Handler) -> std::io::Result<Self> {
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("pdsgdm-http".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let handler = Arc::clone(&handler);
                            // One short-lived thread per connection; the
                            // scrape endpoints answer in microseconds, so
                            // there's no pool to manage.
                            let _ = std::thread::Builder::new()
                                .name("pdsgdm-http-conn".into())
                                .spawn(move || handle_conn(stream, &handler));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(10)),
                    }
                }
            })
            .expect("spawn http accept thread");
        Ok(Self { addr, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join it. Idempotent.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// A slow-loris sender can't pin a handler thread longer than this per
/// socket op: reads *and* writes both carry a deadline.
const IO_TIMEOUT: Duration = Duration::from_secs(2);
/// Upper bound on the whole request head (request line + headers); a
/// scraper's GET fits in a few hundred bytes, so 8 KiB is generous.
const MAX_HEAD_BYTES: usize = 8192;
/// Upper bound on the request line alone (method + target + version) —
/// checked separately so an absurd URI gets the specific 414 instead
/// of the generic 431, and before the rest of the head is read.
const MAX_REQUEST_LINE_BYTES: usize = 2048;

fn handle_conn(mut stream: TcpStream, handler: &Handler) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));

    // Read until the end of the request head, bounding both the head
    // and the request line so a hostile sender can't grow memory.
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    let head_end = loop {
        match stream.read(&mut chunk) {
            Ok(0) => return,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                let line_end = buf.windows(2).position(|w| w == b"\r\n");
                if line_end.map_or(buf.len(), |p| p) > MAX_REQUEST_LINE_BYTES {
                    respond(&mut stream, &Response::text(414, "request line too long\n"));
                    return;
                }
                if let Some(pos) = find_head_end(&buf) {
                    break pos;
                }
                if buf.len() > MAX_HEAD_BYTES {
                    respond(&mut stream, &Response::text(431, "request head too large\n"));
                    return;
                }
            }
            Err(_) => return,
        }
    };
    let head = String::from_utf8_lossy(&buf[..head_end]);
    let request_line = head.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next()) {
        (Some(m), Some(t)) => (m, t),
        _ => {
            respond(&mut stream, &Response::text(400, "bad request\n"));
            return;
        }
    };
    if method != "GET" {
        respond(&mut stream, &Response::text(405, "method not allowed; GET only\n"));
        return;
    }
    let path = target.split('?').next().unwrap_or(target);
    match handler(path) {
        Some(r) => respond(&mut stream, &r),
        None => respond(&mut stream, &Response::text(404, "not found\n")),
    }
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn respond(stream: &mut TcpStream, r: &Response) {
    let reason = match r.status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        414 => "URI Too Long",
        431 => "Request Header Fields Too Large",
        _ => "Internal Server Error",
    };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        r.status,
        reason,
        r.content_type,
        r.body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(r.body.as_bytes());
    let _ = stream.flush();
}

/// Blocking GET against a local address; returns `(status, body)`.
/// Shared by the daemon's tests and the metrics exposition test — and
/// small enough to double as documentation of the wire format.
pub fn get(addr: SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let text = String::from_utf8_lossy(&raw).into_owned();
    let head_end = find_head_end(text.as_bytes())
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "no header end"))?;
    let status = text
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "no status"))?;
    Ok((status, text[head_end + 4..].to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_server() -> HttpServer {
        let handler: Handler = Arc::new(|path| match path {
            "/hello" => Some(Response::text(200, "hi\n")),
            "/json" => Some(Response::json("{\"ok\":true}")),
            _ => None,
        });
        HttpServer::spawn("127.0.0.1:0", handler).unwrap()
    }

    #[test]
    fn serves_known_routes_and_404s_unknown() {
        let server = test_server();
        let (status, body) = get(server.addr(), "/hello").unwrap();
        assert_eq!((status, body.as_str()), (200, "hi\n"));
        let (status, body) = get(server.addr(), "/json").unwrap();
        assert_eq!((status, body.as_str()), (200, "{\"ok\":true}"));
        let (status, _) = get(server.addr(), "/nope").unwrap();
        assert_eq!(status, 404);
        // Query strings are stripped before routing.
        let (status, _) = get(server.addr(), "/hello?x=1").unwrap();
        assert_eq!(status, 200);
    }

    #[test]
    fn rejects_non_get_methods() {
        let server = test_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        write!(stream, "POST /hello HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 405"), "{raw}");
    }

    #[test]
    fn caps_request_line_with_414() {
        let server = test_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        let long_path = "a".repeat(MAX_REQUEST_LINE_BYTES + 100);
        write!(stream, "GET /{long_path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 414"), "{raw}");
    }

    #[test]
    fn caps_request_head_with_431() {
        let server = test_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        // Short request line, endless headers: exceeds the head cap
        // without tripping the request-line cap.
        write!(stream, "GET /hello HTTP/1.1\r\n").unwrap();
        for i in 0..200 {
            // The server may respond 431 and close mid-stream; a broken
            // pipe here is the expected outcome, not a test failure.
            if write!(stream, "X-Pad-{i}: {}\r\n", "b".repeat(64)).is_err() {
                break;
            }
        }
        let _ = write!(stream, "\r\n");
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 431"), "{raw}");
    }

    /// A client that connects and never finishes its request must be
    /// cut loose by the read deadline, not pin the handler forever; a
    /// client that never reads its response is bounded by the write
    /// deadline the same way (both are IO_TIMEOUT).
    #[test]
    fn slow_client_is_dropped_by_deadline() {
        let server = test_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        write!(stream, "GET /hel").unwrap(); // half a request line, then silence
        let start = std::time::Instant::now();
        let mut raw = String::new();
        // The handler times out and drops the socket: read_to_string
        // returns (Ok on clean close or Err on reset), within ~IO_TIMEOUT.
        let _ = stream.read_to_string(&mut raw);
        assert!(raw.is_empty(), "no response expected, got {raw}");
        assert!(
            start.elapsed() < IO_TIMEOUT + Duration::from_secs(3),
            "handler held the socket past its deadline"
        );
    }

    #[test]
    fn shutdown_stops_accepting() {
        let mut server = test_server();
        let addr = server.addr();
        server.shutdown();
        // The listener is dropped with the accept loop; new connections
        // must fail (or at minimum never be served).
        std::thread::sleep(Duration::from_millis(30));
        assert!(get(addr, "/hello").is_err());
    }
}
