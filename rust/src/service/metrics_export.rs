//! Observer-fed metrics registry + Prometheus text exposition.
//!
//! The daemon attaches one [`MetricsObserver`] per running session; the
//! observer forwards the existing [`crate::coordinator::Observer`]
//! callbacks into a shared [`MetricsRegistry`]. Nothing else writes
//! metrics — the exporter sees exactly what any other observer sees, so
//! the numbers can't drift from the trace.
//!
//! [`MetricsRegistry::render`] emits Prometheus text exposition format
//! 0.0.4: one `# HELP`/`# TYPE` pair per metric family, then one sample
//! per job label. Families render in a fixed order and jobs in
//! `BTreeMap` order, so scrapes are deterministic (golden-testable).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::comm::transport::TransportCounters;
use crate::comm::FaultCounters;
use crate::coordinator::Observer;
use crate::metrics::TracePoint;

/// Per-job counters and gauges, all fed by observer callbacks.
#[derive(Clone, Debug, Default)]
struct JobMetrics {
    steps_total: u64,
    comm_rounds_total: u64,
    wire_bytes_total: u64,
    evals_total: u64,
    last_loss: Option<f64>,
    consensus_error: Option<f64>,
    sim_seconds: f64,
    faults: Option<FaultCounters>,
    /// Fleet-aggregated socket-transport counters (only populated for
    /// `[transport]` jobs; in-memory runs never fire the callback).
    transport: Option<TransportCounters>,
}

struct Inner {
    jobs: BTreeMap<String, JobMetrics>,
    /// `pdsgdm_jobs_state{state=...}` gauges, set by the daemon from
    /// queue snapshots (the one aggregate not derivable per-job).
    states: BTreeMap<&'static str, usize>,
}

/// Shared metrics store: one per daemon, behind an `Arc`.
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
    /// Daemon start, for uptime and per-second rate gauges.
    started: Instant,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner { jobs: BTreeMap::new(), states: BTreeMap::new() }),
            started: Instant::now(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Ensure `job` exists (so a queued-then-drained job still exports
    /// zeroed counters instead of vanishing).
    pub fn touch(&self, job: &str) {
        self.lock().jobs.entry(job.to_string()).or_default();
    }

    /// Update the `pdsgdm_jobs_state` gauges from a queue snapshot.
    pub fn set_state_counts(&self, counts: &[(&'static str, usize)]) {
        let mut inner = self.lock();
        for (state, n) in counts {
            inner.states.insert(state, *n);
        }
    }

    /// Total steps recorded for `job` — used by tests and the daemon's
    /// drain heuristics; mirrors `pdsgdm_job_steps_total`.
    pub fn steps_total(&self, job: &str) -> u64 {
        self.lock().jobs.get(job).map_or(0, |j| j.steps_total)
    }

    fn with_job(&self, job: &str, f: impl FnOnce(&mut JobMetrics)) {
        let mut inner = self.lock();
        f(inner.jobs.entry(job.to_string()).or_default());
    }

    /// Render the whole registry as Prometheus text exposition 0.0.4.
    pub fn render(&self) -> String {
        let inner = self.lock();
        let uptime = self.started.elapsed().as_secs_f64();
        let mut out = String::with_capacity(4096);

        // Escape a label value per the exposition format: backslash,
        // double-quote and newline.
        fn esc(v: &str) -> String {
            v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
        }
        // One family: HELP/TYPE once, then every sample.
        fn family(
            out: &mut String,
            name: &str,
            kind: &str,
            help: &str,
            samples: &[(String, f64)],
        ) {
            if samples.is_empty() {
                return;
            }
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
            for (labels, v) in samples {
                // Counters/gauges are finite by construction; NaN would
                // corrupt the exposition, so skip defensively.
                if v.is_finite() {
                    out.push_str(&format!("{name}{labels} {v}\n"));
                }
            }
        }
        let job_label = |j: &str| format!("{{job=\"{}\"}}", esc(j));

        family(
            &mut out,
            "pdsgdm_daemon_up",
            "gauge",
            "1 while the training service is alive.",
            &[(String::new(), 1.0)],
        );
        family(
            &mut out,
            "pdsgdm_daemon_uptime_seconds",
            "gauge",
            "Seconds since the daemon started.",
            &[(String::new(), uptime)],
        );
        let states: Vec<(String, f64)> = inner
            .states
            .iter()
            .map(|(s, n)| (format!("{{state=\"{s}\"}}"), *n as f64))
            .collect();
        family(
            &mut out,
            "pdsgdm_jobs_state",
            "gauge",
            "Jobs currently in each lifecycle state.",
            &states,
        );

        let collect = |f: &dyn Fn(&JobMetrics) -> Option<f64>| -> Vec<(String, f64)> {
            inner
                .jobs
                .iter()
                .filter_map(|(name, m)| f(m).map(|v| (job_label(name), v)))
                .collect()
        };

        family(
            &mut out,
            "pdsgdm_job_steps_total",
            "counter",
            "Global training iterations completed by this job.",
            &collect(&|m| Some(m.steps_total as f64)),
        );
        family(
            &mut out,
            "pdsgdm_job_comm_rounds_total",
            "counter",
            "Gossip/communication rounds completed by this job.",
            &collect(&|m| Some(m.comm_rounds_total as f64)),
        );
        family(
            &mut out,
            "pdsgdm_job_wire_bytes_total",
            "counter",
            "Wire bytes moved by this job's communication rounds.",
            &collect(&|m| Some(m.wire_bytes_total as f64)),
        );
        family(
            &mut out,
            "pdsgdm_job_evals_total",
            "counter",
            "Evaluation points recorded by this job.",
            &collect(&|m| Some(m.evals_total as f64)),
        );
        family(
            &mut out,
            "pdsgdm_job_last_loss",
            "gauge",
            "Global loss at this job's most recent evaluation.",
            &collect(&|m| m.last_loss),
        );
        family(
            &mut out,
            "pdsgdm_job_consensus_error",
            "gauge",
            "Consensus error at this job's most recent evaluation.",
            &collect(&|m| m.consensus_error),
        );
        family(
            &mut out,
            "pdsgdm_job_sim_seconds",
            "gauge",
            "Simulated alpha-beta wall-clock reached by this job.",
            &collect(&|m| Some(m.sim_seconds)),
        );
        family(
            &mut out,
            "pdsgdm_job_rounds_per_second",
            "gauge",
            "Communication rounds per real second since daemon start.",
            &collect(&|m| {
                (uptime > 0.0).then(|| m.comm_rounds_total as f64 / uptime)
            }),
        );
        family(
            &mut out,
            "pdsgdm_job_wire_bytes_per_second",
            "gauge",
            "Wire bytes per real second since daemon start.",
            &collect(&|m| (uptime > 0.0).then(|| m.wire_bytes_total as f64 / uptime)),
        );
        // Fault counters, split dense vs encoded via a `kind` label.
        let fault_samples = |f: &dyn Fn(&FaultCounters) -> (u64, u64)| -> Vec<(String, f64)> {
            inner
                .jobs
                .iter()
                .filter_map(|(name, m)| m.faults.as_ref().map(|c| (name, f(c))))
                .flat_map(|(name, (dense, encoded))| {
                    [
                        (
                            format!("{{job=\"{}\",kind=\"dense\"}}", esc(name)),
                            dense as f64,
                        ),
                        (
                            format!("{{job=\"{}\",kind=\"encoded\"}}", esc(name)),
                            encoded as f64,
                        ),
                    ]
                })
                .collect()
        };
        family(
            &mut out,
            "pdsgdm_job_dropped_messages_total",
            "counter",
            "Messages dropped by the fault plan (encoded = compressed-gossip subset).",
            &fault_samples(&|c| (c.dropped, c.dropped_encoded)),
        );
        family(
            &mut out,
            "pdsgdm_job_delayed_messages_total",
            "counter",
            "Messages delayed by the fault plan (encoded = compressed-gossip subset).",
            &fault_samples(&|c| (c.delayed_total, c.delayed_encoded)),
        );
        // Socket-transport wire counters, one family per counter so each
        // carries its own HELP line. `named()` walks the same list the
        // wire codec serializes, so a newly added counter shows up here
        // (with a generic HELP) without touching the exporter.
        fn transport_help(field: &str) -> &'static str {
            match field {
                "connect_retries" => "Connect attempts beyond the first, fleet-wide.",
                "send_retries" => "Frame send retries after timeouts/backpressure.",
                "reconnects" => "Link re-establishments after a hard send error.",
                "timeouts" => "Socket deadline expiries (read or write).",
                "heartbeats_sent" => "Heartbeat frames sent while waiting on peers.",
                "heartbeat_misses" => "Silent heartbeat intervals observed on live links.",
                "peers_dead" => "Peers declared dead (EOF, timeout, miss threshold).",
                "frames_sent" => "Frames put on the wire.",
                "frames_received" => "Frames decoded off the wire.",
                "bytes_sent" => "Bytes put on the wire (payloads + frame headers).",
                "bytes_received" => "Bytes read off the wire.",
                "crc_errors" => "Frames rejected by CRC32/structure checks.",
                _ => "Socket-transport counter.",
            }
        }
        for (idx, (field, _)) in TransportCounters::default().named().iter().enumerate() {
            let samples: Vec<(String, f64)> = inner
                .jobs
                .iter()
                .filter_map(|(name, m)| m.transport.as_ref().map(|t| (name, t)))
                .map(|(name, t)| (job_label(name), t.named()[idx].1 as f64))
                .collect();
            family(
                &mut out,
                &format!("pdsgdm_job_transport_{field}_total"),
                "counter",
                transport_help(field),
                &samples,
            );
        }
        out
    }
}

/// Bridges one session's [`Observer`] callbacks into the shared
/// registry. The session knows nothing about metrics; the daemon
/// attaches this like any other observer.
pub struct MetricsObserver {
    job: String,
    registry: Arc<MetricsRegistry>,
}

impl MetricsObserver {
    pub fn new(job: impl Into<String>, registry: Arc<MetricsRegistry>) -> Self {
        let job = job.into();
        registry.touch(&job);
        Self { job, registry }
    }
}

impl Observer for MetricsObserver {
    fn on_step(&mut self, _t: u64, _stats: &crate::algorithms::StepStats) {
        self.registry.with_job(&self.job, |m| m.steps_total += 1);
    }

    fn on_comm_round(&mut self, _t: u64, bytes: u64, _round_seconds: f64) {
        self.registry.with_job(&self.job, |m| {
            m.comm_rounds_total += 1;
            m.wire_bytes_total += bytes;
        });
    }

    fn on_eval(&mut self, _label: &str, p: &TracePoint) {
        self.registry.with_job(&self.job, |m| {
            m.evals_total += 1;
            m.last_loss = Some(p.loss);
            m.consensus_error = Some(p.consensus);
            m.sim_seconds = p.sim_seconds;
        });
    }

    fn on_fault_counters(&mut self, _step: u64, counters: &FaultCounters) {
        // The plan's counters are already cumulative; store the latest.
        self.registry.with_job(&self.job, |m| m.faults = Some(*counters));
    }

    fn on_transport_counters(&mut self, _step: u64, counters: &TransportCounters) {
        // Fleet-aggregated and cumulative, like the fault counters.
        self.registry.with_job(&self.job, |m| m.transport = Some(counters.clone()));
    }
}

/// Minimal Prometheus text-format checks shared by unit tests and the
/// exposition golden test: every non-comment line is
/// `name[{labels}] value`, every sample's family has HELP+TYPE above
/// it, and no family is declared twice.
pub fn validate_exposition(text: &str) -> Result<(), String> {
    let mut declared: BTreeMap<String, String> = BTreeMap::new();
    for (no, line) in text.lines().enumerate() {
        let err = |msg: &str| Err(format!("line {}: {msg}: {line}", no + 1));
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            let kw = parts.next().unwrap_or("");
            let name = parts.next().unwrap_or("");
            if !matches!(kw, "HELP" | "TYPE") {
                return err("unknown comment keyword");
            }
            if name.is_empty() {
                return err("missing metric family name");
            }
            if kw == "TYPE" {
                let t = parts.next().unwrap_or("");
                if !matches!(t, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                    return err("bad metric type");
                }
                if declared.insert(name.to_string(), t.to_string()).is_some() {
                    return err("duplicate metric family");
                }
            }
            continue;
        }
        // Sample line: name or name{...}, then exactly one value token.
        let name_end = line.find(['{', ' ']).ok_or_else(|| format!("line {}: no value: {line}", no + 1))?;
        let name = &line[..name_end];
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        {
            return err("bad metric name");
        }
        if !declared.contains_key(name) {
            return err("sample before HELP/TYPE declaration");
        }
        let rest = &line[name_end..];
        let value_part = if let Some(stripped) = rest.strip_prefix('{') {
            let close = stripped.find('}').ok_or_else(|| format!("line {}: unclosed labels: {line}", no + 1))?;
            &stripped[close + 1..]
        } else {
            rest
        };
        let value = value_part.trim();
        if value.parse::<f64>().is_err() {
            return err("value is not a number");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(reg: &Arc<MetricsRegistry>, job: &str, steps: u64, bytes: u64) {
        let mut obs = MetricsObserver::new(job, Arc::clone(reg));
        for t in 0..steps {
            obs.on_step(t, &crate::algorithms::StepStats::default());
            obs.on_comm_round(t, bytes, 0.5);
        }
        obs.on_eval(
            job,
            &TracePoint {
                step: steps,
                loss: 0.25,
                accuracy: 0.9,
                comm_mb: 1.0,
                consensus: 1e-3,
                grad_norm_sq: 0.0,
                sim_seconds: 2.0,
            },
        );
    }

    #[test]
    fn observer_feeds_counters_and_render_is_valid_exposition() {
        let reg = Arc::new(MetricsRegistry::new());
        feed(&reg, "job-a", 5, 100);
        feed(&reg, "job-b", 3, 40);
        reg.set_state_counts(&[("running", 2), ("queued", 0)]);
        assert_eq!(reg.steps_total("job-a"), 5);
        let text = reg.render();
        validate_exposition(&text).unwrap();
        assert!(text.contains("pdsgdm_job_steps_total{job=\"job-a\"} 5"), "{text}");
        assert!(text.contains("pdsgdm_job_wire_bytes_total{job=\"job-b\"} 120"), "{text}");
        assert!(text.contains("pdsgdm_job_last_loss{job=\"job-a\"} 0.25"), "{text}");
        assert!(text.contains("pdsgdm_jobs_state{state=\"running\"} 2"), "{text}");
        assert!(text.contains("pdsgdm_daemon_up 1"), "{text}");
    }

    #[test]
    fn fault_counters_export_dense_and_encoded_kinds() {
        let reg = Arc::new(MetricsRegistry::new());
        let mut obs = MetricsObserver::new("f", Arc::clone(&reg));
        obs.on_fault_counters(
            10,
            &FaultCounters { dropped: 7, dropped_encoded: 3, delayed_total: 5, delayed_encoded: 1 },
        );
        let text = reg.render();
        validate_exposition(&text).unwrap();
        assert!(text.contains("pdsgdm_job_dropped_messages_total{job=\"f\",kind=\"dense\"} 7"));
        assert!(text.contains("pdsgdm_job_dropped_messages_total{job=\"f\",kind=\"encoded\"} 3"));
        assert!(text.contains("pdsgdm_job_delayed_messages_total{job=\"f\",kind=\"dense\"} 5"));
    }

    #[test]
    fn transport_counters_export_one_family_per_field() {
        let reg = Arc::new(MetricsRegistry::new());
        let mut obs = MetricsObserver::new("t", Arc::clone(&reg));
        let mut c = TransportCounters::default();
        c.send_retries = 4;
        c.peers_dead = 1;
        c.bytes_sent = 12345;
        obs.on_transport_counters(20, &c);
        let text = reg.render();
        validate_exposition(&text).unwrap();
        assert!(text.contains("pdsgdm_job_transport_send_retries_total{job=\"t\"} 4"), "{text}");
        assert!(text.contains("pdsgdm_job_transport_peers_dead_total{job=\"t\"} 1"), "{text}");
        assert!(text.contains("pdsgdm_job_transport_bytes_sent_total{job=\"t\"} 12345"), "{text}");
        // Zero-valued fields still export (a scrape sees the whole set).
        assert!(text.contains("pdsgdm_job_transport_crc_errors_total{job=\"t\"} 0"), "{text}");
        // In-memory jobs never fire the callback: no transport families.
        let quiet = Arc::new(MetricsRegistry::new());
        MetricsObserver::new("q", Arc::clone(&quiet));
        assert!(!quiet.render().contains("pdsgdm_job_transport_"), "absent when unused");
    }

    #[test]
    fn label_values_are_escaped() {
        let reg = Arc::new(MetricsRegistry::new());
        reg.touch("we\"ird\\job");
        let text = reg.render();
        validate_exposition(&text).unwrap();
        assert!(text.contains("job=\"we\\\"ird\\\\job\""), "{text}");
    }

    #[test]
    fn validator_rejects_malformed_expositions() {
        assert!(validate_exposition("pdsgdm_x 1").is_err(), "sample before TYPE");
        assert!(validate_exposition(
            "# HELP a h\n# TYPE a counter\n# HELP a h\n# TYPE a counter\na 1"
        )
        .is_err());
        assert!(validate_exposition("# HELP a h\n# TYPE a counter\na one").is_err());
        assert!(validate_exposition("# TYPE a wat\na 1").is_err());
        assert!(validate_exposition("# HELP a h\n# TYPE a gauge\na{x=\"y\" 1").is_err());
    }
}
