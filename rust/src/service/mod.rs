//! Training service daemon: a long-lived `pdsgdm serve` process that
//! accepts `SessionSpec`-shaped job descriptions, multiplexes N
//! concurrent [`crate::coordinator::Session`]s onto ONE shared
//! [`crate::engine::WorkerPool`], exports Prometheus-text metrics over
//! a hand-rolled HTTP/1.1 listener, and drains gracefully on SIGTERM —
//! every running job is checkpointed to the versioned `PDSGDM02` format
//! and resumed bit-identically on restart.
//!
//! Layout:
//!
//! ```text
//! queue          FIFO/priority job queue + lifecycle states
//! metrics_export Observer-fed registry -> Prometheus exposition text
//! http           minimal offline HTTP/1.1 server (std::net only)
//! daemon         the serve loop: runners, signals, drain manifest
//! ```
//!
//! Everything is offline and dependency-free: HTTP sits directly on
//! `std::net::TcpListener`, JSON comes from [`crate::json`], TOML jobs
//! reuse [`crate::config::parse_toml`], and metrics flow ONLY through
//! the existing [`crate::coordinator::Observer`] hooks — the daemon
//! never reaches into session internals.

pub mod daemon;
pub mod http;
pub mod metrics_export;
pub mod queue;

pub use daemon::Daemon;
pub use http::{HttpServer, Response};
pub use metrics_export::{MetricsObserver, MetricsRegistry};
pub use queue::{Job, JobQueue, JobState};
