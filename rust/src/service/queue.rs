//! FIFO/priority job queue for the training service.
//!
//! A job is an [`crate::coordinator::SessionSpec`]-shaped description:
//! an [`ExperimentConfig`] plus optional `[job]` metadata (`name`,
//! `priority`) and an optional checkpoint to resume from. Runner
//! threads block on [`JobQueue::claim`]; the queue hands out the
//! highest-priority (ties: lowest id, i.e. submission order) queued
//! job. All state lives behind one mutex — the queue is the single
//! source of truth the `/jobs` endpoint, the metrics aggregates, and
//! the drain manifest all read.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Condvar, Mutex};

use crate::config::{parse_toml, ExperimentConfig};
use crate::coordinator::StopReason;

/// Lifecycle of a job inside the daemon.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Waiting for a runner slot.
    Queued,
    /// A runner thread owns it and is stepping its session.
    Running,
    /// Ran to its stop condition; results are on disk.
    Completed,
    /// The session errored (message on [`Job::error`]).
    Failed,
    /// Interrupted by drain; a `PDSGDM02` checkpoint holds its state.
    Drained,
}

impl JobState {
    /// Stable lowercase name used in `/jobs` JSON and metric labels.
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "completed",
            JobState::Failed => "failed",
            JobState::Drained => "drained",
        }
    }

    pub const ALL: [JobState; 5] = [
        JobState::Queued,
        JobState::Running,
        JobState::Completed,
        JobState::Failed,
        JobState::Drained,
    ];
}

/// One submitted job and everything the daemon knows about it.
#[derive(Clone, Debug)]
pub struct Job {
    /// Dense id in submission order (1-based; doubles as FIFO key).
    pub id: u64,
    /// Label for metrics/logs: `[job] name`, else `job-<id>`.
    pub name: String,
    /// Higher claims first; equal priorities run in submission order.
    pub priority: i64,
    pub config: ExperimentConfig,
    /// Resume this checkpoint before stepping (drain/restart path).
    pub resume_from: Option<PathBuf>,
    /// The spooled TOML this job was parsed from, for the manifest.
    pub source_path: Option<PathBuf>,
    pub state: JobState,
    /// Failure message when `state == Failed`.
    pub error: Option<String>,
    /// Steps completed at the last state transition.
    pub steps_done: u64,
    pub final_loss: Option<f64>,
    pub stop_reason: Option<StopReason>,
    /// Checkpoint written when this job was drained.
    pub checkpoint: Option<PathBuf>,
}

/// A parsed job file: the experiment config plus `[job]` metadata.
pub struct JobSpec {
    pub name: Option<String>,
    pub priority: i64,
    pub config: ExperimentConfig,
}

/// Parse a job TOML: a normal experiment config with an optional
/// `[job]` section (`name`, `priority`). The experiment parser already
/// whitelists the `job.*` keys, so one strict parse validates both.
pub fn parse_job_toml(src: &str) -> Result<JobSpec, String> {
    let config = ExperimentConfig::from_toml_str(src)?;
    let doc = parse_toml(src)?;
    let name = doc
        .get("job.name")
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| "job.name must be a string".to_string())
        })
        .transpose()?;
    let priority = doc
        .get("job.priority")
        .map(|v| v.as_i64().ok_or_else(|| "job.priority must be an integer".to_string()))
        .transpose()?
        .unwrap_or(0);
    Ok(JobSpec { name, priority, config })
}

/// Atomically drop a job file into a daemon's spool directory under a
/// sortable, collision-proof name: `EPOCH_MS-PID-SEQ.toml`. The daemon
/// scans lexicographically, so epoch-first preserves submission order;
/// pid + a process-wide sequence counter make two submissions in the
/// same millisecond — same process or not — land in distinct files
/// instead of silently overwriting (the rename target is additionally
/// guarded). Write-then-rename so the daemon never scans a
/// half-written job.
pub fn spool_job(spool: &std::path::Path, src: &str) -> std::io::Result<PathBuf> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let epoch_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis())
        .unwrap_or(0);
    let pid = std::process::id();
    loop {
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let file = format!("{epoch_ms:013}-{pid:05}-{seq:04}.toml");
        let dest = spool.join(&file);
        if dest.exists() {
            // Another process picked the same (epoch, pid-collision, seq)
            // triple — bump the sequence and retry rather than clobber.
            continue;
        }
        let tmp = spool.join(format!(".{file}.tmp"));
        std::fs::write(&tmp, src)?;
        std::fs::rename(&tmp, &dest)?;
        return Ok(dest);
    }
}

struct Inner {
    jobs: BTreeMap<u64, Job>,
    next_id: u64,
    /// No more claims after close: runners see `None` and exit.
    closed: bool,
}

/// Thread-safe priority queue + job table. Cheap to share behind an
/// `Arc`; every accessor takes the one lock briefly.
pub struct JobQueue {
    inner: Mutex<Inner>,
    /// Signals runners blocked in [`JobQueue::claim`].
    ready: Condvar,
}

impl Default for JobQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl JobQueue {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner { jobs: BTreeMap::new(), next_id: 1, closed: false }),
            ready: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // A runner panicking while holding the lock must not wedge the
        // daemon; the job table stays consistent (states are written in
        // single operations).
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Enqueue a job; returns its id. `name` defaults to `job-<id>`.
    pub fn submit(&self, spec: JobSpec, resume_from: Option<PathBuf>, source_path: Option<PathBuf>) -> u64 {
        let mut inner = self.lock();
        let id = inner.next_id;
        inner.next_id += 1;
        let name = spec.name.unwrap_or_else(|| format!("job-{id}"));
        inner.jobs.insert(
            id,
            Job {
                id,
                name,
                priority: spec.priority,
                config: spec.config,
                resume_from,
                source_path,
                state: JobState::Queued,
                error: None,
                steps_done: 0,
                final_loss: None,
                stop_reason: None,
                checkpoint: None,
            },
        );
        self.ready.notify_one();
        id
    }

    /// Block until a queued job is available (highest priority, then
    /// submission order), mark it running, and return a clone. Returns
    /// `None` once the queue is closed — the runner's exit signal.
    pub fn claim(&self) -> Option<Job> {
        let mut inner = self.lock();
        loop {
            if let Some(id) = inner
                .jobs
                .values()
                .filter(|j| j.state == JobState::Queued)
                .max_by_key(|j| (j.priority, std::cmp::Reverse(j.id)))
                .map(|j| j.id)
            {
                let job = inner.jobs.get_mut(&id).expect("id just selected");
                job.state = JobState::Running;
                return Some(job.clone());
            }
            if inner.closed {
                return None;
            }
            inner = self
                .ready
                .wait(inner)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
        }
    }

    /// Record where the spooled canonical copy of a job's TOML lives
    /// (the id is needed to name the copy, so this runs post-submit).
    pub fn set_source_path(&self, id: u64, path: PathBuf) {
        if let Some(j) = self.lock().jobs.get_mut(&id) {
            j.source_path = Some(path);
        }
    }

    /// Stop handing out jobs and wake every blocked runner.
    pub fn close(&self) {
        self.lock().closed = true;
        self.ready.notify_all();
    }

    pub fn mark_completed(&self, id: u64, steps: u64, loss: f64, reason: Option<StopReason>) {
        let mut inner = self.lock();
        if let Some(j) = inner.jobs.get_mut(&id) {
            j.state = JobState::Completed;
            j.steps_done = steps;
            j.final_loss = Some(loss);
            j.stop_reason = reason;
        }
    }

    pub fn mark_failed(&self, id: u64, error: String) {
        let mut inner = self.lock();
        if let Some(j) = inner.jobs.get_mut(&id) {
            j.state = JobState::Failed;
            j.error = Some(error);
        }
    }

    pub fn mark_drained(&self, id: u64, steps: u64, checkpoint: PathBuf) {
        let mut inner = self.lock();
        if let Some(j) = inner.jobs.get_mut(&id) {
            j.state = JobState::Drained;
            j.steps_done = steps;
            j.checkpoint = Some(checkpoint);
        }
    }

    /// All jobs in id (submission) order — the `/jobs` endpoint and the
    /// drain manifest render from this snapshot.
    pub fn snapshot(&self) -> Vec<Job> {
        self.lock().jobs.values().cloned().collect()
    }

    /// `(queued, running)` counts for the idle check and aggregates.
    pub fn active_counts(&self) -> (usize, usize) {
        let inner = self.lock();
        let queued = inner.jobs.values().filter(|j| j.state == JobState::Queued).count();
        let running = inner.jobs.values().filter(|j| j.state == JobState::Running).count();
        (queued, running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, priority: i64) -> JobSpec {
        JobSpec {
            name: Some(name.into()),
            priority,
            config: ExperimentConfig::default(),
        }
    }

    #[test]
    fn claims_by_priority_then_submission_order() {
        let q = JobQueue::new();
        q.submit(spec("low-a", 0), None, None);
        q.submit(spec("high", 5), None, None);
        q.submit(spec("low-b", 0), None, None);
        q.close(); // claims still drain the queue after close
        let order: Vec<String> = std::iter::from_fn(|| q.claim().map(|j| j.name)).collect();
        assert_eq!(order, ["high", "low-a", "low-b"]);
        assert!(q.claim().is_none(), "closed and empty");
    }

    #[test]
    fn claim_blocks_until_submit_and_close_releases() {
        use std::sync::Arc;
        let q = Arc::new(JobQueue::new());
        let q2 = Arc::clone(&q);
        let claimer = std::thread::spawn(move || q2.claim().map(|j| j.name));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.submit(spec("late", 0), None, None);
        assert_eq!(claimer.join().unwrap().as_deref(), Some("late"));

        let q3 = Arc::clone(&q);
        let waiter = std::thread::spawn(move || q3.claim());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(waiter.join().unwrap().is_none());
    }

    #[test]
    fn lifecycle_marks_update_the_snapshot() {
        let q = JobQueue::new();
        let a = q.submit(spec("a", 0), None, None);
        let b = q.submit(spec("b", 0), None, None);
        let claimed = q.claim().unwrap();
        assert_eq!(claimed.id, a);
        q.mark_completed(a, 60, 0.125, Some(StopReason::StepLimit));
        q.mark_drained(b, 0, PathBuf::from("/tmp/b.ckpt"));
        let snap = q.snapshot();
        assert_eq!(snap[0].state, JobState::Completed);
        assert_eq!(snap[0].final_loss, Some(0.125));
        assert_eq!(snap[1].state, JobState::Drained);
        assert_eq!(snap[1].checkpoint.as_deref(), Some(std::path::Path::new("/tmp/b.ckpt")));
        assert_eq!(q.active_counts(), (0, 0));
    }

    #[test]
    fn job_toml_round_trips_name_and_priority() {
        let s = parse_job_toml(
            "algorithm = \"pd-sgdm\"\nsteps = 10\n[job]\nname = \"mlp-a\"\npriority = 3",
        )
        .unwrap();
        assert_eq!(s.name.as_deref(), Some("mlp-a"));
        assert_eq!(s.priority, 3);
        assert_eq!(s.config.steps, 10);
        // defaults
        let s = parse_job_toml("algorithm = \"pd-sgdm\"").unwrap();
        assert_eq!(s.name, None);
        assert_eq!(s.priority, 0);
        // bad types surface as errors, not defaults
        assert!(parse_job_toml("[job]\npriority = \"high\"").is_err());
    }

    /// Two submissions inside the same epoch second (same millisecond,
    /// even) must land in two distinct spool files — the old
    /// epoch+pid+loop-index scheme collided across invocations and
    /// silently overwrote the earlier job.
    #[test]
    fn same_second_double_submit_never_collides() {
        let dir = std::env::temp_dir().join(format!("pdsgdm-spool-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let a = spool_job(&dir, "steps = 1\n").unwrap();
        let b = spool_job(&dir, "steps = 2\n").unwrap();
        assert_ne!(a, b, "same-millisecond submissions must not collide");
        assert_eq!(std::fs::read_to_string(&a).unwrap(), "steps = 1\n");
        assert_eq!(std::fs::read_to_string(&b).unwrap(), "steps = 2\n");
        // Spool scan order == submission order (epoch-first, seq-second).
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        names.sort();
        assert_eq!(names.len(), 2, "no stray tmp files left behind");
        assert!(a.ends_with(&names[0]) && b.ends_with(&names[1]));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
