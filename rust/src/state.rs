//! Binary state (de)serialization for full-fidelity checkpoints.
//!
//! The `PDSGDM02` checkpoint format (see [`crate::coordinator`]) needs to
//! round-trip *every* mutable bit of a run — worker iterates, momentum
//! and error-feedback buffers, RNG streams, batch-sampler cursors, byte
//! counters — so that a resumed session reproduces the uninterrupted
//! trace bit-identically. No serde exists in this offline environment,
//! so this module provides a tiny length-prefixed little-endian format:
//!
//! * every primitive is written LE (`f32`/`f64` via `to_bits`, so
//!   floats round-trip exactly, NaN payloads included);
//! * strings and slices are length-prefixed;
//! * components mark their payload with a [`StateWriter::tag`] that the
//!   reader verifies with [`StateReader::expect_tag`] — loading a
//!   checkpoint into the wrong algorithm fails loudly instead of
//!   reinterpreting buffers.
//!
//! [`StateReader`] is fully bounds-checked and returns `Err` (never
//! panics) on truncated or foreign input; property-tested below and in
//! rust/tests/session_resume.rs.

/// Append-only binary writer for checkpoint payloads.
#[derive(Debug, Default)]
pub struct StateWriter {
    buf: Vec<u8>,
}

impl StateWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Raw bytes, for embedding one writer's output inside another.
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.put_u64(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Component marker; pair with [`StateReader::expect_tag`].
    pub fn tag(&mut self, t: &str) {
        self.put_str(t);
    }

    pub fn put_u64s(&mut self, xs: &[u64]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn put_f32s(&mut self, xs: &[f32]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    /// K rows of equal-length f32 vectors (worker-major state matrices).
    pub fn put_f32_mat(&mut self, rows: &[Vec<f32>]) {
        self.put_u64(rows.len() as u64);
        for r in rows {
            self.put_f32s(r);
        }
    }

    /// A flat K×d worker-state bank as ONE contiguous section (the v3
    /// arena layout). The leading [`FLAT_MAT_SENTINEL`] distinguishes it
    /// from the v2 [`StateWriter::put_f32_mat`] layout, whose first u64
    /// is a row count — a valid v2 section can never start with the
    /// sentinel because [`StateReader::take_len`] rejects a row count
    /// that large.
    pub fn put_f32_flat_mat(&mut self, k: usize, d: usize, data: &[f32]) {
        assert_eq!(data.len(), k * d, "flat mat shape mismatch");
        self.put_u64(FLAT_MAT_SENTINEL);
        self.put_u64(k as u64);
        self.put_u64(d as u64);
        self.put_f32s(data);
    }
}

/// Marks a contiguous (v3) worker-state section; see
/// [`StateWriter::put_f32_flat_mat`].
pub const FLAT_MAT_SENTINEL: u64 = u64::MAX;

/// Bounds-checked reader over a checkpoint payload.
#[derive(Debug)]
pub struct StateReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> StateReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated state: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn take_u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn take_f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    /// A length guarded against adversarial/corrupt prefixes: the payload
    /// of `elem_bytes`-sized elements must actually fit in what remains.
    fn take_len(&mut self, elem_bytes: usize) -> Result<usize, String> {
        let n = self.take_u64()? as usize;
        if n.checked_mul(elem_bytes).map(|b| b > self.remaining()).unwrap_or(true) {
            return Err(format!("corrupt state: length {n} exceeds remaining bytes"));
        }
        Ok(n)
    }

    pub fn take_bytes(&mut self) -> Result<&'a [u8], String> {
        let n = self.take_len(1)?;
        self.take(n)
    }

    pub fn take_str(&mut self) -> Result<&'a str, String> {
        let b = self.take_bytes()?;
        std::str::from_utf8(b).map_err(|_| "corrupt state: non-utf8 string".to_string())
    }

    pub fn expect_tag(&mut self, want: &str) -> Result<(), String> {
        let got = self.take_str()?;
        if got != want {
            return Err(format!("state tag mismatch: wanted {want:?}, found {got:?}"));
        }
        Ok(())
    }

    pub fn take_u64s(&mut self) -> Result<Vec<u64>, String> {
        let n = self.take_len(8)?;
        (0..n).map(|_| self.take_u64()).collect()
    }

    pub fn take_f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.take_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f32::from_bits(u32::from_le_bytes(self.take(4)?.try_into().unwrap())));
        }
        Ok(out)
    }

    /// Restore an f32 slice in place, requiring the saved length to match.
    pub fn take_f32s_into(&mut self, out: &mut [f32], what: &str) -> Result<(), String> {
        let n = self.take_len(4)?;
        if n != out.len() {
            return Err(format!("{what}: saved dim {n} != live dim {}", out.len()));
        }
        for o in out.iter_mut() {
            *o = f32::from_bits(u32::from_le_bytes(self.take(4)?.try_into().unwrap()));
        }
        Ok(())
    }

    /// Restore a worker-major state matrix in place (strict shape check).
    pub fn take_f32_mat_into(&mut self, rows: &mut [Vec<f32>], what: &str) -> Result<(), String> {
        let k = self.take_len(1)?;
        if k != rows.len() {
            return Err(format!("{what}: saved K {k} != live K {}", rows.len()));
        }
        for (i, r) in rows.iter_mut().enumerate() {
            self.take_f32s_into(r, &format!("{what}[{i}]"))?;
        }
        Ok(())
    }

    /// Restore a flat K×d bank in place. Accepts BOTH layouts: the v3
    /// contiguous section (leading [`FLAT_MAT_SENTINEL`]) and the legacy
    /// v2 per-worker layout written by [`StateWriter::put_f32_mat`] /
    /// the pre-arena momentum banks, whose first u64 is the row count.
    pub fn take_f32_flat_mat_into(
        &mut self,
        k: usize,
        d: usize,
        data: &mut [f32],
        what: &str,
    ) -> Result<(), String> {
        if data.len() != k * d {
            return Err(format!("{what}: live buffer is not {k}x{d}"));
        }
        let first = self.take_u64()?;
        if first == FLAT_MAT_SENTINEL {
            let sk = self.take_u64()? as usize;
            let sd = self.take_u64()? as usize;
            if sk != k || sd != d {
                return Err(format!("{what}: saved shape {sk}x{sd} != live {k}x{d}"));
            }
            self.take_f32s_into(data, what)
        } else {
            // v2 shim: `first` is the row count of a per-worker layout.
            let sk = first as usize;
            if sk != k {
                return Err(format!("{what}: saved K {sk} != live K {k}"));
            }
            for (i, row) in data.chunks_mut(d.max(1)).enumerate() {
                self.take_f32s_into(row, &format!("{what}[{i}]"))?;
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_and_slice_roundtrip() {
        let mut w = StateWriter::new();
        w.tag("test");
        w.put_u64(u64::MAX);
        w.put_f64(-0.0);
        w.put_f64(f64::NAN);
        w.put_str("pd-sgdm(p=4)");
        w.put_u64s(&[1, 2, 3]);
        w.put_f32s(&[1.5, -2.25, f32::INFINITY]);
        let bytes = w.into_bytes();

        let mut r = StateReader::new(&bytes);
        r.expect_tag("test").unwrap();
        assert_eq!(r.take_u64().unwrap(), u64::MAX);
        assert_eq!(r.take_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.take_f64().unwrap().is_nan());
        assert_eq!(r.take_str().unwrap(), "pd-sgdm(p=4)");
        assert_eq!(r.take_u64s().unwrap(), vec![1, 2, 3]);
        let f = r.take_f32s().unwrap();
        assert_eq!(f[0], 1.5);
        assert_eq!(f[2], f32::INFINITY);
        assert!(r.is_done());
    }

    #[test]
    fn mat_roundtrip_in_place() {
        let rows = vec![vec![1.0f32, 2.0], vec![-3.0, 4.0], vec![0.0, f32::NAN]];
        let mut w = StateWriter::new();
        w.put_f32_mat(&rows);
        let bytes = w.into_bytes();
        let mut got = vec![vec![9.0f32; 2]; 3];
        StateReader::new(&bytes).take_f32_mat_into(&mut got, "xs").unwrap();
        for (a, b) in rows.iter().zip(&got) {
            let bits = |v: &Vec<f32>| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(a), bits(b));
        }
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let mut w = StateWriter::new();
        w.put_f32_mat(&[vec![1.0f32; 4]; 2]);
        let bytes = w.into_bytes();
        let mut wrong_k = vec![vec![0.0f32; 4]; 3];
        assert!(StateReader::new(&bytes).take_f32_mat_into(&mut wrong_k, "xs").is_err());
        let mut wrong_d = vec![vec![0.0f32; 5]; 2];
        assert!(StateReader::new(&bytes).take_f32_mat_into(&mut wrong_d, "xs").is_err());
    }

    #[test]
    fn tag_mismatch_and_truncation_are_errors_not_panics() {
        let mut w = StateWriter::new();
        w.tag("cpd-sgdm");
        w.put_f32s(&[1.0; 16]);
        let bytes = w.into_bytes();
        assert!(StateReader::new(&bytes).expect_tag("pd-sgdm").is_err());
        for cut in [0, 3, 9, bytes.len() - 1] {
            let mut r = StateReader::new(&bytes[..cut]);
            // whatever we try to read, we must get Err, never a panic
            let _ = r.expect_tag("cpd-sgdm").and_then(|_| r.take_f32s().map(|_| ()));
        }
    }

    #[test]
    fn adversarial_length_prefix_rejected() {
        let mut w = StateWriter::new();
        w.put_u64(u64::MAX); // claims 2^64-1 elements
        let bytes = w.into_bytes();
        assert!(StateReader::new(&bytes).take_f32s().is_err());
        assert!(StateReader::new(&bytes).take_u64s().is_err());
        assert!(StateReader::new(&bytes).take_bytes().is_err());
    }

    #[test]
    fn flat_mat_round_trip_and_v2_shim() {
        let rows = vec![vec![1.0f32, -0.0], vec![f32::NAN, 4.5], vec![7.0, 8.0]];
        let flat: Vec<f32> = rows.iter().flatten().copied().collect();

        // v3 contiguous section round-trips bit-exactly.
        let mut w = StateWriter::new();
        w.put_f32_flat_mat(3, 2, &flat);
        let v3 = w.into_bytes();
        let mut got = vec![0.0f32; 6];
        StateReader::new(&v3).take_f32_flat_mat_into(3, 2, &mut got, "xs").unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&got), bits(&flat));

        // The SAME reader call accepts a legacy v2 per-worker section.
        let mut w = StateWriter::new();
        w.put_f32_mat(&rows);
        let v2 = w.into_bytes();
        let mut got = vec![0.0f32; 6];
        StateReader::new(&v2).take_f32_flat_mat_into(3, 2, &mut got, "xs").unwrap();
        assert_eq!(bits(&got), bits(&flat));
    }

    #[test]
    fn flat_mat_shape_mismatch_is_an_error_in_both_layouts() {
        let flat = vec![0.5f32; 6];
        let mut w = StateWriter::new();
        w.put_f32_flat_mat(3, 2, &flat);
        let v3 = w.into_bytes();
        let mut wrong = vec![0.0f32; 4];
        assert!(StateReader::new(&v3).take_f32_flat_mat_into(2, 2, &mut wrong, "xs").is_err());
        let mut wrong = vec![0.0f32; 6];
        assert!(StateReader::new(&v3).take_f32_flat_mat_into(2, 3, &mut wrong, "xs").is_err());

        let mut w = StateWriter::new();
        w.put_f32_mat(&[vec![0.5f32; 2]; 3]);
        let v2 = w.into_bytes();
        let mut wrong = vec![0.0f32; 4];
        assert!(StateReader::new(&v2).take_f32_flat_mat_into(2, 2, &mut wrong, "xs").is_err());
        let mut wrong = vec![0.0f32; 9];
        assert!(StateReader::new(&v2).take_f32_flat_mat_into(3, 3, &mut wrong, "xs").is_err());
    }

    #[test]
    fn nested_bytes_blocks() {
        let mut inner = StateWriter::new();
        inner.put_u64(7);
        let mut outer = StateWriter::new();
        outer.put_bytes(&inner.into_bytes());
        outer.put_str("after");
        let bytes = outer.into_bytes();
        let mut r = StateReader::new(&bytes);
        let blk = r.take_bytes().unwrap();
        assert_eq!(StateReader::new(blk).take_u64().unwrap(), 7);
        assert_eq!(r.take_str().unwrap(), "after");
    }
}
