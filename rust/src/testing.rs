//! Minimal in-crate property-testing harness.
//!
//! No `proptest` crate exists in this offline build environment, so the
//! repo carries its own: [`forall`] runs a closure against many seeded
//! random cases and, on failure, reports the case index + derived seed so
//! the exact case replays with `forall_case`. Generation is driven by the
//! deterministic [`crate::rng::Xoshiro256`], so failures are always
//! reproducible.

use crate::compress::{CompressedVec, Compressor, Identity};
use crate::rng::Xoshiro256;

/// A codec that lies about its wire cost: behaves exactly like
/// [`Identity`] but costs one byte it never emits. Shared by the tests
/// of [`crate::compress::check_wire_size`]'s `Err` arm and of the comm
/// round's release-mode panic on a miscosted codec — one definition, so
/// the two cannot drift apart.
#[derive(Clone, Copy, Debug)]
pub struct MisCosted;

impl Compressor for MisCosted {
    fn name(&self) -> String {
        "miscosted".into()
    }

    fn compress_into(&self, x: &[f32], rng: &mut Xoshiro256, out: &mut CompressedVec) {
        Identity.compress_into(x, rng, out);
        out.wire_bytes += 1; // the lie
    }

    fn encode_into(&self, c: &CompressedVec, out: &mut Vec<u8>) {
        Identity.encode_into(c, out);
    }

    fn decode_into(&self, bytes: &[u8], out: &mut [f32]) {
        Identity.decode_into(bytes, out);
    }

    fn delta(&self, _d: usize) -> f64 {
        1.0
    }

    fn encoded_bytes(&self, d: usize) -> usize {
        4 * d + 1
    }

    fn box_clone(&self) -> Box<dyn Compressor> {
        Box::new(*self)
    }
}

/// Run `body` against `cases` independently-seeded RNG streams derived
/// from `seed`. Panics (re-raising the inner panic message) identify the
/// failing case and its replay seed.
pub fn forall<F: FnMut(&mut Xoshiro256)>(seed: u64, cases: usize, mut body: F) {
    for case in 0..cases {
        let case_seed = case_seed(seed, case);
        let mut rng = Xoshiro256::seed_from_u64(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case}/{cases} (replay: forall_case({seed:#x}, {case})): {msg}"
            );
        }
    }
}

/// Replay a single failing case reported by [`forall`].
pub fn forall_case<F: FnOnce(&mut Xoshiro256)>(seed: u64, case: usize, body: F) {
    let mut rng = Xoshiro256::seed_from_u64(case_seed(seed, case));
    body(&mut rng);
}

fn case_seed(seed: u64, case: usize) -> u64 {
    // SplitMix-style avalanche so consecutive cases are decorrelated.
    let mut z = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z ^ (z >> 31)
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "index {i}: got {g}, want {w} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0;
        forall(1, 25, |_rng| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    fn forall_reports_failing_case() {
        let err = std::panic::catch_unwind(|| {
            forall(2, 50, |rng| {
                assert!(rng.next_f64() < 0.9, "drew a big one");
            })
        })
        .expect_err("should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("property failed at case"), "{msg}");
        assert!(msg.contains("drew a big one"), "{msg}");
    }

    #[test]
    fn replay_reproduces_case_stream() {
        let mut first: Option<u64> = None;
        forall_case(0xABCD, 3, |rng| first = Some(rng.next_u64()));
        let mut again: Option<u64> = None;
        forall_case(0xABCD, 3, |rng| again = Some(rng.next_u64()));
        assert_eq!(first, again);
    }

    #[test]
    fn allclose_passes_and_fails() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, 1e-6);
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6)
        });
        assert!(r.is_err());
    }
}
