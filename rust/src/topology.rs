//! Decentralized-training topologies and their mixing matrices.
//!
//! The paper models the worker fleet as an undirected graph `G = (V, W)`
//! with a symmetric doubly-stochastic `W` (Assumption 1); all convergence
//! constants enter through the spectral gap `rho = 1 - |lambda_2(W)|`
//! (Lemma 1). This module builds the standard families — the paper's
//! ring, plus chain/complete/star/2-D torus/hypercube/random-regular for
//! the topology ablation — and two weighting schemes (uniform-degree as
//! used in the paper's 1/3-ring, and Metropolis–Hastings for irregular
//! graphs).

use crate::linalg::{self, Mat};
use crate::rng::Xoshiro256;

/// Undirected simple graph on `[0, k)` as adjacency lists.
#[derive(Clone, Debug)]
pub struct Graph {
    pub k: usize,
    adj: Vec<Vec<usize>>,
}

impl Graph {
    pub fn empty(k: usize) -> Self {
        Self { k, adj: vec![Vec::new(); k] }
    }

    pub fn add_edge(&mut self, i: usize, j: usize) {
        assert!(i != j && i < self.k && j < self.k, "bad edge ({i},{j})");
        if !self.adj[i].contains(&j) {
            self.adj[i].push(j);
            self.adj[j].push(i);
        }
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    /// Connectivity via BFS — every topology we hand to an algorithm must
    /// be connected or consensus is impossible (rho = 0).
    pub fn is_connected(&self) -> bool {
        if self.k == 0 {
            return true;
        }
        let mut seen = vec![false; self.k];
        let mut queue = vec![0usize];
        seen[0] = true;
        while let Some(i) = queue.pop() {
            for &j in &self.adj[i] {
                if !seen[j] {
                    seen[j] = true;
                    queue.push(j);
                }
            }
        }
        seen.into_iter().all(|s| s)
    }
}

/// Topology families. `Ring` with K=8 is the paper's experimental setup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Cycle: worker k talks to k±1 (mod K). The paper's setup.
    Ring,
    /// Path: like Ring without the wrap-around edge (worst-case rho).
    Chain,
    /// All-to-all. rho = 1: decentralized == centralized averaging.
    Complete,
    /// Hub-and-spoke around worker 0.
    Star,
    /// 2-D torus on an r x c grid (requires K = r*c with r,c >= 2).
    Torus2d,
    /// Hypercube (requires K a power of two).
    Hypercube,
    /// Random d-regular graph (configuration model with retries).
    RandomRegular { degree: usize },
}

impl Topology {
    pub fn parse(s: &str) -> Option<Topology> {
        match s {
            "ring" => Some(Topology::Ring),
            "chain" => Some(Topology::Chain),
            "complete" | "full" => Some(Topology::Complete),
            "star" => Some(Topology::Star),
            "torus" | "torus2d" => Some(Topology::Torus2d),
            "hypercube" => Some(Topology::Hypercube),
            _ => s.strip_prefix("regular-").and_then(|d| {
                d.parse().ok().map(|degree| Topology::RandomRegular { degree })
            }),
        }
    }

    pub fn build(self, k: usize, seed: u64) -> Graph {
        assert!(k >= 1, "need at least one worker");
        let mut g = Graph::empty(k);
        if k == 1 {
            return g;
        }
        match self {
            Topology::Ring => {
                for i in 0..k {
                    g.add_edge(i, (i + 1) % k);
                }
            }
            Topology::Chain => {
                for i in 0..k - 1 {
                    g.add_edge(i, i + 1);
                }
            }
            Topology::Complete => {
                for i in 0..k {
                    for j in (i + 1)..k {
                        g.add_edge(i, j);
                    }
                }
            }
            Topology::Star => {
                for i in 1..k {
                    g.add_edge(0, i);
                }
            }
            Topology::Torus2d => {
                let (r, c) = torus_dims(k).expect("torus requires K = r*c, r,c >= 2");
                for i in 0..r {
                    for j in 0..c {
                        let id = i * c + j;
                        g.add_edge(id, i * c + (j + 1) % c);
                        g.add_edge(id, ((i + 1) % r) * c + j);
                    }
                }
            }
            Topology::Hypercube => {
                assert!(k.is_power_of_two(), "hypercube requires K = 2^n");
                let bits = k.trailing_zeros();
                for i in 0..k {
                    for b in 0..bits {
                        let j = i ^ (1 << b);
                        if j > i {
                            g.add_edge(i, j);
                        }
                    }
                }
            }
            Topology::RandomRegular { degree } => {
                g = random_regular(k, degree, seed);
            }
        }
        debug_assert!(g.is_connected(), "{self:?} built a disconnected graph");
        g
    }
}

/// Factor K as r*c with both >= 2 and as square as possible.
fn torus_dims(k: usize) -> Option<(usize, usize)> {
    let mut best = None;
    let mut r = (k as f64).sqrt() as usize;
    while r >= 2 {
        if k % r == 0 && k / r >= 2 {
            best = Some((r, k / r));
            break;
        }
        r -= 1;
    }
    best
}

/// Configuration-model random d-regular graph; retries until simple and
/// connected (fast for the K <= 64 sizes we use).
fn random_regular(k: usize, degree: usize, seed: u64) -> Graph {
    assert!(degree >= 2 && degree < k && (k * degree) % 2 == 0,
            "invalid (K={k}, degree={degree}) for a regular graph");
    let mut rng = Xoshiro256::seed_from_u64(seed);
    'attempt: for _ in 0..10_000 {
        let mut stubs: Vec<usize> = (0..k).flat_map(|i| std::iter::repeat(i).take(degree)).collect();
        rng.shuffle(&mut stubs);
        let mut g = Graph::empty(k);
        for pair in stubs.chunks(2) {
            let (a, b) = (pair[0], pair[1]);
            if a == b || g.neighbors(a).contains(&b) {
                continue 'attempt; // multi-edge or loop: resample
            }
            g.add_edge(a, b);
        }
        if g.is_connected() {
            return g;
        }
    }
    panic!("failed to sample a connected {degree}-regular graph on {k} nodes");
}

/// Mixing-weight schemes for turning a graph into W.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Weighting {
    /// w_ij = 1/(deg_max + 1) off-diagonal, remainder on the diagonal.
    /// For the ring this is the paper's (1/3, 1/3, 1/3).
    UniformDegree,
    /// Metropolis–Hastings: w_ij = 1/(1 + max(deg_i, deg_j)); always
    /// doubly stochastic on irregular graphs (star, random).
    Metropolis,
    /// Lazy Metropolis: (I + W_mh)/2 — guarantees lambda_n > 0 so
    /// |lambda_2| is the relevant eigenvalue even on bipartite graphs.
    LazyMetropolis,
}

/// Build the doubly-stochastic mixing matrix for `g` under `scheme`.
pub fn mixing_matrix(g: &Graph, scheme: Weighting) -> Mat {
    let k = g.k;
    let mut w = Mat::zeros(k, k);
    if k == 1 {
        w[(0, 0)] = 1.0;
        return w;
    }
    match scheme {
        Weighting::UniformDegree => {
            let dmax = (0..k).map(|i| g.degree(i)).max().unwrap();
            let wij = 1.0 / (dmax as f64 + 1.0);
            for i in 0..k {
                for &j in g.neighbors(i) {
                    w[(i, j)] = wij;
                }
                w[(i, i)] = 1.0 - wij * g.degree(i) as f64;
            }
        }
        Weighting::Metropolis | Weighting::LazyMetropolis => {
            for i in 0..k {
                for &j in g.neighbors(i) {
                    w[(i, j)] = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
                }
            }
            for i in 0..k {
                let off: f64 = (0..k).filter(|&j| j != i).map(|j| w[(i, j)]).sum();
                w[(i, i)] = 1.0 - off;
            }
            if scheme == Weighting::LazyMetropolis {
                for i in 0..k {
                    for j in 0..k {
                        w[(i, j)] *= 0.5;
                    }
                    w[(i, i)] += 0.5;
                }
            }
        }
    }
    debug_assert!(w.is_doubly_stochastic(1e-9));
    w
}

/// Convenience: (graph, W, rho) for a named topology.
pub fn build(topology: Topology, k: usize, scheme: Weighting, seed: u64) -> (Graph, Mat, f64) {
    let g = topology.build(k, seed);
    let w = mixing_matrix(&g, scheme);
    let rho = linalg::spectral_gap(&w, seed ^ 0xA5A5);
    (g, w, rho)
}

/// W as row-major f32, the form the XLA mix artifact and the in-process
/// gossip kernels consume.
pub fn w_to_f32(w: &Mat) -> Vec<f32> {
    w.data.iter().map(|&x| x as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOPOS: &[(Topology, usize)] = &[
        (Topology::Ring, 8),
        (Topology::Chain, 8),
        (Topology::Complete, 8),
        (Topology::Star, 8),
        (Topology::Torus2d, 8),
        (Topology::Hypercube, 8),
        (Topology::RandomRegular { degree: 3 }, 8),
    ];

    #[test]
    fn all_topologies_connected() {
        for &(t, k) in TOPOS {
            assert!(t.build(k, 1).is_connected(), "{t:?}");
        }
    }

    #[test]
    fn ring_degrees_are_two() {
        let g = Topology::Ring.build(8, 0);
        for i in 0..8 {
            assert_eq!(g.degree(i), 2);
        }
        assert_eq!(g.edge_count(), 8);
    }

    #[test]
    fn paper_ring_weights_are_one_third() {
        let g = Topology::Ring.build(8, 0);
        let w = mixing_matrix(&g, Weighting::UniformDegree);
        for i in 0..8 {
            assert!((w[(i, i)] - 1.0 / 3.0).abs() < 1e-12);
            assert!((w[(i, (i + 1) % 8)] - 1.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn all_weightings_doubly_stochastic_on_all_topologies() {
        // Property test (Assumption 1): every (topology, weighting) pair
        // yields symmetric doubly-stochastic W with entries in [0,1].
        for &(t, k) in TOPOS {
            let g = t.build(k, 3);
            for scheme in [Weighting::UniformDegree, Weighting::Metropolis, Weighting::LazyMetropolis] {
                let w = mixing_matrix(&g, scheme);
                assert!(w.is_doubly_stochastic(1e-9), "{t:?} {scheme:?}");
            }
        }
    }

    #[test]
    fn spectral_gap_ordering_matches_theory() {
        // complete > hypercube/torus > ring > chain for K=16.
        let gap = |t: Topology| build(t, 16, Weighting::UniformDegree, 5).2;
        let complete = gap(Topology::Complete);
        let hyper = gap(Topology::Hypercube);
        let ring = gap(Topology::Ring);
        let chain = gap(Topology::Chain);
        assert!(complete > hyper && hyper > ring && ring > chain,
                "complete={complete} hyper={hyper} ring={ring} chain={chain}");
        assert!((complete - 1.0).abs() < 1e-6);
        assert!(chain > 0.0);
    }

    #[test]
    fn ring8_gap_closed_form() {
        // rho = 1 - (1 + 2cos(2π/8))/3 for the 1/3-ring.
        let (_, _, rho) = build(Topology::Ring, 8, Weighting::UniformDegree, 0);
        let expect = 1.0 - (1.0 + 2.0 * (2.0 * std::f64::consts::PI / 8.0).cos()) / 3.0;
        assert!((rho - expect).abs() < 1e-6, "rho={rho} expect={expect}");
    }

    #[test]
    fn star_metropolis_handles_irregular_degrees() {
        let g = Topology::Star.build(9, 0);
        let w = mixing_matrix(&g, Weighting::Metropolis);
        assert!(w.is_doubly_stochastic(1e-9));
        // leaf-leaf weight must be zero (no edge)
        assert_eq!(w[(1, 2)], 0.0);
    }

    #[test]
    fn random_regular_is_regular_and_seeded() {
        let g1 = Topology::RandomRegular { degree: 4 }.build(16, 42);
        let g2 = Topology::RandomRegular { degree: 4 }.build(16, 42);
        for i in 0..16 {
            assert_eq!(g1.degree(i), 4);
            assert_eq!(g1.neighbors(i), g2.neighbors(i), "seeded determinism");
        }
    }

    #[test]
    fn torus_dims_reasonable() {
        assert_eq!(torus_dims(8), Some((2, 4)));
        assert_eq!(torus_dims(16), Some((4, 4)));
        assert_eq!(torus_dims(7), None);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Topology::parse("ring"), Some(Topology::Ring));
        assert_eq!(Topology::parse("regular-3"), Some(Topology::RandomRegular { degree: 3 }));
        assert_eq!(Topology::parse("nope"), None);
    }

    #[test]
    fn k1_degenerates_to_identity() {
        let (_, w, rho) = build(Topology::Ring, 1, Weighting::UniformDegree, 0);
        assert_eq!(w[(0, 0)], 1.0);
        assert!((rho - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mixing_preserves_mean_numerically() {
        // W x̄-preservation, the invariant behind Eq. (18).
        let (_, w, _) = build(Topology::Torus2d, 12, Weighting::Metropolis, 7);
        let x: Vec<f64> = (0..12).map(|i| (i * i) as f64).collect();
        let y = w.matvec(&x);
        let mx: f64 = x.iter().sum::<f64>() / 12.0;
        let my: f64 = y.iter().sum::<f64>() / 12.0;
        assert!((mx - my).abs() < 1e-9);
    }
}
